"""deepseek-v2-lite-16b [arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2-Lite].

27L d_model=2048, MLA: 16 heads, kv_lora_rank=512, qk_nope=128, qk_rope=64,
v_head=128 (decode caches ONLY the 512+64 latent per token — the paper's
KV-memory contribution). MoE: 64 routed experts (expert d_ff=1408) top-6 +
2 shared experts, first layer dense (d_ff=10944). vocab=102400.

Spec-discrepancy note (DESIGN.md): the assignment line says "160 routed";
that is full V2 — V2-Lite has 64 routed experts (hf config), which we
follow, matching the assignment's primary "MoE 64e top-6".
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=1408,                     # routed expert width (assignment)
        vocab=102_400,
        use_mla=True,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        n_experts=64,
        moe_top_k=6,
        moe_d_ff=1408,
        n_shared_experts=2,
        moe_score="softmax",
        moe_norm_topk=False,
        first_k_dense=1,
        dense_d_ff=10944,
    ),
    smoke=ModelConfig(
        arch="deepseek-v2-lite-16b",
        family="moe",
        n_layers=3,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_head=32,
        d_ff=64,
        vocab=512,
        use_mla=True,
        kv_lora_rank=64,
        qk_nope_dim=32,
        qk_rope_dim=16,
        v_head_dim=32,
        n_experts=8,
        moe_top_k=2,
        moe_d_ff=64,
        n_shared_experts=2,
        moe_score="softmax",
        moe_norm_topk=False,
        first_k_dense=1,
        dense_d_ff=256,
        attn_chunk_q=64,
        attn_chunk_kv=64,
    ),
)
