"""zamba2-1.2b [arXiv:2411.15242; hf Zyphra/Zamba2-1.2B] — hybrid.

38 Mamba-2 layers (d_model=2048, d_inner=4096, headdim=64 -> 64 ssm heads,
state=64) with ONE shared attention+MLP block invoked every 6th layer
(weights shared across its invocations, per-invocation LoRA deltas,
rank 128). Shared block: 32H MHA (kv=32 per the assignment), d_ff=8192.
vocab=32000.

Simplification noted in DESIGN.md §Arch-applicability: the published model
concatenates the original embedding to the shared-block input (2*d_model);
we attend over d_model and fold the difference into the LoRA deltas.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_head=64,
        d_ff=8192,
        vocab=32000,
        ssm_heads=64,
        ssm_headdim=64,
        ssm_state=64,
        ssm_groups=1,
        ssm_conv_kernel=4,
        attn_every=6,
        shared_lora_rank=128,
        tie_embeddings=True,
    ),
    smoke=ModelConfig(
        arch="zamba2-1.2b",
        family="hybrid",
        n_layers=7,
        d_model=128,
        n_heads=8,
        n_kv_heads=8,
        d_head=16,
        d_ff=256,
        vocab=512,
        ssm_heads=8,
        ssm_headdim=16,
        ssm_state=16,
        ssm_groups=1,
        ssm_conv_kernel=4,
        ssm_chunk=32,
        attn_every=3,
        shared_lora_rank=8,
        tie_embeddings=True,
        attn_chunk_q=64,
        attn_chunk_kv=64,
    ),
)
