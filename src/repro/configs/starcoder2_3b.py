"""starcoder2-3b [arXiv:2402.19173; hf bigcode/starcoder2-3b].

30L d_model=3072 24H (GQA kv=2, d_head=128) d_ff=12288 vocab=49152.
LayerNorm, plain gelu MLP, biases everywhere, RoPE theta~1e6, tied
embeddings, sliding-window attention (4096) on ALL layers — which makes
its decode state window-bounded, so the long_500k cell runs (DESIGN.md).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_head=128,
        d_ff=12288,
        vocab=49152,
        rope_theta=999_999.44,
        attn_bias=True,
        attn_out_bias=True,
        mlp_type="mlp",
        act="gelu",
        mlp_bias=True,
        norm="layernorm",
        norm_eps=1e-5,
        tie_embeddings=True,
        window=4096,
        layer_pattern="local",
    ),
    smoke=ModelConfig(
        arch="starcoder2-3b",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_head=16,
        d_ff=256,
        vocab=512,
        rope_theta=999_999.44,
        attn_bias=True,
        attn_out_bias=True,
        mlp_type="mlp",
        act="gelu",
        mlp_bias=True,
        norm="layernorm",
        norm_eps=1e-5,
        tie_embeddings=True,
        window=64,
        layer_pattern="local",
        attn_chunk_q=64,
        attn_chunk_kv=64,
    ),
)
