"""yi-6b — llama-architecture GQA decoder [arXiv:2403.04652; hf 01-ai/Yi-6B].

32L d_model=4096 32H (GQA kv=4, d_head=128) d_ff=11008 vocab=64000,
RMSNorm + SwiGLU, RoPE theta=5e6, untied embeddings, no biases.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="yi-6b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_head=128,
        d_ff=11008,
        vocab=64000,
        rope_theta=5_000_000.0,
        norm_eps=1e-5,
    ),
    smoke=ModelConfig(
        arch="yi-6b",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_head=16,
        d_ff=256,
        vocab=512,
        rope_theta=5_000_000.0,
        norm_eps=1e-5,
        attn_chunk_q=64,
        attn_chunk_kv=64,
    ),
)
