from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeSpec,
    batch_specs,
    get_config,
    get_smoke_config,
    input_specs,
    list_archs,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "batch_specs",
    "get_config",
    "get_smoke_config",
    "input_specs",
    "list_archs",
]
