"""hubert-xlarge [arXiv:2106.07447] — encoder-only audio transformer.

48L d_model=1280 16H (MHA) d_ff=5120 vocab=504 (masked-prediction units).
The conv waveform frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, T, 512) which a linear layer
projects to d_model. Encoder-only: bidirectional attention, no decode
shapes. LayerNorm + gelu MLP + biases (wav2vec2 family).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_head=80,
        d_ff=5120,
        vocab=504,
        rope=False,
        attn_bias=True,
        attn_out_bias=True,
        mlp_type="mlp",
        act="gelu",
        mlp_bias=True,
        norm="layernorm",
        norm_eps=1e-5,
        encoder_only=True,
        frontend="audio",
        frontend_dim=512,
    ),
    smoke=ModelConfig(
        arch="hubert-xlarge",
        family="audio",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=8,
        d_head=16,
        d_ff=256,
        vocab=64,
        rope=False,
        attn_bias=True,
        attn_out_bias=True,
        mlp_type="mlp",
        act="gelu",
        mlp_bias=True,
        norm="layernorm",
        norm_eps=1e-5,
        encoder_only=True,
        frontend="audio",
        frontend_dim=32,
        attn_chunk_q=64,
        attn_chunk_kv=64,
    ),
)
