"""granite-moe-3b-a800m [hf ibm-granite/granite-3.0-3b-a800m-base].

32L d_model=1536 24H (GQA kv=8, d_head=64) vocab=49155.
MoE: 40 experts top-8, expert d_ff=512, no shared experts, top-k weights
renormalized. Granite signature scalar multipliers: embedding 12.0,
residual 0.22, attention_multiplier 1/128, logits_scaling 6.0. Tied
embeddings.

The assignment line lists both "MoE 40e top-8" and "32 experts top-8";
we implement the primary 40-expert spec (DESIGN.md). 40 does not divide
the 16-way model axis -> the sharding rules fall back from EP to TP
inside each expert (d_ff axis), automatically.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_head=64,
        d_ff=512,
        vocab=49155,
        attn_scale=1.0 / 128.0,        # attention_multiplier
        n_experts=40,
        moe_top_k=8,
        moe_d_ff=512,
        moe_norm_topk=True,
        tie_embeddings=True,
        embedding_multiplier=12.0,
        residual_multiplier=0.22,
        logits_scaling=6.0,
        norm_eps=1e-6,
    ),
    smoke=ModelConfig(
        arch="granite-moe-3b-a800m",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=4,
        d_head=16,
        d_ff=64,
        vocab=512,
        attn_scale=1.0 / 16.0,
        n_experts=10,
        moe_top_k=2,
        moe_d_ff=64,
        moe_norm_topk=True,
        tie_embeddings=True,
        embedding_multiplier=12.0,
        residual_multiplier=0.22,
        logits_scaling=6.0,
        attn_chunk_q=64,
        attn_chunk_kv=64,
    ),
)
