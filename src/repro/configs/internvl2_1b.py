"""internvl2-1b [arXiv:2404.16821; hf OpenGVLab/InternVL2-1B] — VLM.

Text backbone = Qwen2-0.5B: 24L d_model=896 14H (GQA kv=2, d_head=64)
d_ff=4864 vocab=151655, QKV bias, RoPE theta=1e6, tied embeddings.
The InternViT vision tower is a STUB per the assignment: ``input_specs``
provides precomputed patch embeddings (B, 256, 1024); the model owns the
MLP projector (1024 -> d_model) and prepends the projected patches to the
token sequence.

14 heads do not divide the 16-way model axis: the sharding rules fall back
to replicated heads for this arch (activations shard on batch only) —
exercised deliberately as the "awkward divisibility" case (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_head=64,
        d_ff=4864,
        vocab=151_655,
        rope_theta=1_000_000.0,
        attn_bias=True,
        tie_embeddings=True,
        frontend="vision",
        frontend_dim=1024,
        n_patches=256,
    ),
    smoke=ModelConfig(
        arch="internvl2-1b",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=7,                     # keep the awkward head count
        n_kv_heads=1,
        d_head=16,
        d_ff=256,
        vocab=512,
        rope_theta=1_000_000.0,
        attn_bias=True,
        tie_embeddings=True,
        frontend="vision",
        frontend_dim=64,
        n_patches=16,
        attn_chunk_q=64,
        attn_chunk_kv=64,
    ),
)
