"""mamba2-130m [arXiv:2405.21060; hf state-spaces/mamba2-130m] — pure SSM.

24L d_model=768, attention-free. d_inner = 2*768 = 1536, headdim=64 ->
24 SSD heads, state=128, 1 group, conv kernel 4. vocab=50280 (gpt-neox
tokenizer padded), tied embeddings. SSD chunk 256 (intra-chunk quadratic
on the MXU + inter-chunk lax.scan recurrence — models/ssm.py).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        vocab=50280,
        rope=False,
        ssm_heads=24,
        ssm_headdim=64,
        ssm_state=128,
        ssm_groups=1,
        ssm_conv_kernel=4,
        ssm_chunk=256,
        tie_embeddings=True,
        norm_eps=1e-5,
    ),
    smoke=ModelConfig(
        arch="mamba2-130m",
        family="ssm",
        n_layers=2,
        d_model=128,
        vocab=512,
        rope=False,
        ssm_heads=8,
        ssm_headdim=16,
        ssm_state=16,
        ssm_groups=1,
        ssm_conv_kernel=4,
        ssm_chunk=32,
        tie_embeddings=True,
        norm_eps=1e-5,
    ),
)
