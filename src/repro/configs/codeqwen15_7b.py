"""codeqwen1.5-7b [hf Qwen/CodeQwen1.5-7B] — qwen1.5 architecture.

32L d_model=4096 32H (kv=32 i.e. MHA per the assignment) d_ff=13440
vocab=92416, SwiGLU, RoPE theta=1e6, QKV biases (qwen signature), untied.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="codeqwen1.5-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_head=128,
        d_ff=13440,
        vocab=92416,
        rope_theta=1_000_000.0,
        attn_bias=True,
        norm_eps=1e-6,
    ),
    smoke=ModelConfig(
        arch="codeqwen1.5-7b",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=8,
        d_head=16,
        d_ff=256,
        vocab=512,
        rope_theta=1_000_000.0,
        attn_bias=True,
        attn_chunk_q=64,
        attn_chunk_kv=64,
    ),
)
