"""gemma2-27b [arXiv:2408.00118; hf google/gemma-2-27b].

46L d_model=4608 32H (GQA kv=16, d_head=128) d_ff=36864 vocab=256000.
Alternating local(4096)/global attention (even layers local), logit
softcapping (attn 50, final 30), GeGLU, sandwich (pre+post) RMSNorm with
the gemma (1+w) convention, tied embeddings scaled by sqrt(d_model),
query scale 1/sqrt(query_pre_attn_scalar=144).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch="gemma2-27b",
        family="dense",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        d_head=128,
        d_ff=36864,
        vocab=256_000,
        act="gelu",
        attn_softcap=50.0,
        final_softcap=30.0,
        attn_scale=144.0 ** -0.5,       # query_pre_attn_scalar = 4608/32
        window=4096,
        layer_pattern="local_global",
        norm_scale_plus_one=True,
        post_norms=True,
        tie_embeddings=True,
        embed_scale=4608.0 ** 0.5,
    ),
    smoke=ModelConfig(
        arch="gemma2-27b",
        family="dense",
        n_layers=4,
        d_model=128,
        n_heads=8,
        n_kv_heads=4,
        d_head=16,
        d_ff=256,
        vocab=512,
        act="gelu",
        attn_softcap=50.0,
        final_softcap=30.0,
        attn_scale=16.0 ** -0.5,
        window=64,
        layer_pattern="local_global",
        norm_scale_plus_one=True,
        post_norms=True,
        tie_embeddings=True,
        embed_scale=128.0 ** 0.5,
        attn_chunk_q=64,
        attn_chunk_kv=64,
    ),
)
