"""Config system: one ``ModelConfig`` per assigned architecture (exact
published values), the input-shape sets, the registry, and the
``input_specs()`` ShapeDtypeStruct factories used by the dry-run.

Shapes (assigned set, LM-family: seq_len x global_batch):
    train_4k     4_096 x 256   -> train_step
    prefill_32k  32_768 x 32   -> prefill (encoder fwd for encoder-only)
    decode_32k   32_768 x 128  -> serve_step (1 token, 32k KV cache)
    long_500k    524_288 x 1   -> serve_step; sub-quadratic attention only

Applicability (DESIGN.md §6): decode shapes skip encoder-only archs;
long_500k runs only for families whose per-token state is bounded
(SSM / hybrid) or whose attention is windowed (gemma2 local/global,
starcoder2 all-window). Pure full-attention decoders skip it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    # --- attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    rope: bool = True
    rope_theta: float = 10_000.0
    attn_bias: bool = False
    attn_out_bias: bool = False
    attn_softcap: float | None = None
    attn_scale: float | None = None          # None = 1/sqrt(d_head)
    window: int | None = None                # sliding window size
    layer_pattern: str = "global"            # global | local_global | local
    encoder_only: bool = False
    # --- mlp
    d_ff: int = 0
    mlp_type: str = "glu"                    # glu | mlp
    act: str = "silu"
    mlp_bias: bool = False
    # --- norm / embedding
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    norm_eps: float = 1e-6
    norm_scale_plus_one: bool = False        # gemma (1 + w) convention
    post_norms: bool = False                 # gemma2 sandwich norms
    tie_embeddings: bool = False
    embed_scale: float | None = None         # gemma: sqrt(d_model)
    final_softcap: float | None = None
    logits_scaling: float = 1.0              # granite: divide logits
    embedding_multiplier: float = 1.0
    residual_multiplier: float = 1.0
    # --- MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    moe_score: str = "softmax"               # softmax | sigmoid
    moe_norm_topk: bool = False
    moe_routed_scale: float = 1.0
    moe_capacity_factor: float = 1.25
    first_k_dense: int = 0
    dense_d_ff: int = 0                      # d_ff of the first-k dense layers
    # --- MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba2 / zamba2)
    ssm_heads: int = 0
    ssm_headdim: int = 0
    ssm_state: int = 0
    ssm_groups: int = 1
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 256
    ssm_intra_dtype: str = "f32"             # §Perf: bf16 intra-chunk SSD
    attn_every: int = 0                      # zamba2: shared block cadence
    shared_lora_rank: int = 0
    # --- modality frontend (stub per assignment)
    frontend: str = "none"                   # none | audio | vision
    frontend_dim: int = 0
    n_patches: int = 0
    # --- dtypes / execution
    param_dtype: Any = jnp.float32
    act_dtype: Any = jnp.bfloat16
    cache_dtype: Any = jnp.bfloat16
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    loss_chunk: int = 2048                   # CE seq-chunking (0 = full
                                             # logits; big-vocab memory fix)
    remat: str = "none"                      # none | full | dots
    scan_layers: bool = True
    triangle_schedule: bool = False          # §Perf: triangular causal chunks
    attn_head_constraint: bool = True        # §Perf: pin q/k/v heads->model
                                             # so chunk loops don't emit
                                             # per-step seq collectives
                                             # (False = §Perf baseline)
    # --- shape applicability overrides
    max_train_seq: int = 1 << 20

    # ----- derived / helpers
    def layer_window(self, layer: int) -> int | None:
        if self.layer_pattern == "local":
            return self.window
        if self.layer_pattern == "local_global":
            return self.window if layer % 2 == 0 else None
        return None

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k? (bounded per-token state)"""
        if self.family in ("ssm", "hybrid"):
            return True
        # every layer windowed, or alternating local/global (gemma2):
        # decode state is window-bounded on local layers and linear-per-token
        # on the (few) global ones.
        return self.layer_pattern in ("local", "local_global") and \
            self.window is not None

    def supports(self, shape: str) -> bool:
        s = SHAPES[shape]
        if s.kind == "decode" and self.encoder_only:
            return False
        if shape == "long_500k" and not self.subquadratic:
            return False
        return True

    def skip_reason(self, shape: str) -> str | None:
        if self.supports(shape):
            return None
        if SHAPES[shape].kind == "decode" and self.encoder_only:
            return "encoder-only arch has no decode step"
        return "pure full-attention arch: 500k decode cache is out of scope"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}
_SMOKE: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch] = cfg
    _SMOKE[cfg.arch] = smoke
    return cfg


def get_config(arch: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[arch]


def get_smoke_config(arch: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE[arch]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    import importlib
    for mod in (
        "yi_6b", "gemma2_27b", "codeqwen15_7b", "starcoder2_3b",
        "hubert_xlarge", "zamba2_1p2b", "deepseek_v2_lite",
        "granite_moe_3b", "internvl2_1b", "mamba2_130m",
    ):
        importlib.import_module(f"repro.configs.{mod}")


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for the dry-run (no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """Abstract model inputs for one (arch, shape) cell.

    train:   {"tokens", "labels"} (+ modality extras)
    prefill: {"tokens"} (+ extras)
    decode:  {"tokens" (B,1), "lengths" (B,)}; the KV cache specs come from
             serve.decode.cache_specs (they are serve_step state, not data).
    """
    s = SHAPES[shape]
    B, L = s.global_batch, s.seq_len
    i32 = jnp.int32

    def tok(shp):
        return jax.ShapeDtypeStruct(shp, i32)

    if s.kind == "train":
        batch: dict = {"tokens": tok((B, L)), "labels": tok((B, L))}
    elif s.kind == "prefill":
        batch = {"tokens": tok((B, L))}
    else:  # decode
        batch = {"tokens": tok((B, 1)),
                 "lengths": jax.ShapeDtypeStruct((B,), i32)}

    if cfg.frontend == "audio":
        # stub: precomputed frame embeddings replace the token stream
        if s.kind in ("train", "prefill"):
            batch.pop("tokens")
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, L, cfg.frontend_dim), jnp.float32)
    elif cfg.frontend == "vision" and s.kind in ("train", "prefill"):
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.frontend_dim), jnp.float32)
    return batch


def batch_specs(cfg: ModelConfig, shape: str, mesh) -> dict:
    """NamedShardings matching input_specs (batch axis -> (pod, data))."""
    from repro.models.sharding import logical_sharding
    out = {}
    for name, sds in input_specs(cfg, shape).items():
        logical = ["batch"] + [None] * (len(sds.shape) - 1)
        out[name] = logical_sharding(logical, mesh, dims=sds.shape)
    return out
