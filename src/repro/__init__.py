"""repro — a multi-pod JAX training/serving framework built around fast
K-NN-graph construction (Kluser et al. 2021: NN-Descent with turbosampling
selection, greedy memory reordering, and MXU-blocked distance evaluation).

Public API:
  * ``repro.build_knn_graph`` / ``repro.core`` — the paper's contribution.
  * ``repro.models`` / ``repro.configs`` — the assigned LM architectures.
  * ``repro.train`` / ``repro.serve`` — training and serving substrates.
  * ``repro.launch`` — production mesh, dry-run, roofline tooling.
"""
from repro.core import (
    DescentConfig,
    DescentStats,
    MutableKNNStore,
    NeighborLists,
    OnlineConfig,
    SearchConfig,
    apply_permutation,
    brute_force_knn,
    build_knn_graph,
    distance_recall,
    graph_search,
    greedy_reorder,
    knn_delete,
    knn_insert,
    locality_stats,
    nn_descent_iteration,
    recall_at_k,
    window_cluster_purity,
)

__version__ = "0.1.0"

__all__ = [
    "DescentConfig",
    "DescentStats",
    "MutableKNNStore",
    "NeighborLists",
    "OnlineConfig",
    "SearchConfig",
    "apply_permutation",
    "brute_force_knn",
    "build_knn_graph",
    "distance_recall",
    "graph_search",
    "greedy_reorder",
    "knn_delete",
    "knn_insert",
    "locality_stats",
    "nn_descent_iteration",
    "recall_at_k",
    "window_cluster_purity",
]
