"""Block composition + per-family layer stacks.

Uniform stacks run under ``lax.scan`` over layer-stacked parameters (HLO
size and compile time stay O(1) in depth; remat policy applied to the scan
body). Heterogeneous patterns keep the scan structure:

  * gemma2 local/global alternation — scan over PAIRS of (local, global)
    sub-blocks (23 pairs for 46 layers);
  * deepseek first-k-dense — separate dense layer params, then a scan over
    the MoE layers;
  * zamba2 — scan over segments of ``attn_every`` mamba layers, each
    segment followed by the SHARED attention+MLP block (weights shared,
    per-segment LoRA deltas indexed by the scan counter).

Every schema helper mirrors its apply function 1:1 (params.py contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamDef,
    glu,
    glu_schema,
    layernorm,
    layernorm_schema,
    mlp,
    mlp_schema,
    rmsnorm,
    rmsnorm_schema,
)
from repro.models.sharding import shard_act


# ---------------------------------------------------------------------------
# schema utilities
# ---------------------------------------------------------------------------

def stack_schema(schema, n: int):
    """Prepend a scan ('stack') axis to every ParamDef leaf."""
    return jax.tree.map(
        lambda d: ParamDef((n, *d.shape), ("stack", *d.logical), d.init,
                           d.scale, d.dtype),
        schema,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def norm_schema(cfg):
    if cfg.norm == "layernorm":
        return layernorm_schema(cfg.d_model, cfg.param_dtype)
    return rmsnorm_schema(cfg.d_model, cfg.param_dtype)


def apply_norm(p, x, cfg):
    if cfg.norm == "layernorm":
        return layernorm(p, x, eps=cfg.norm_eps)
    return rmsnorm(p, x, eps=cfg.norm_eps,
                   scale_plus_one=cfg.norm_scale_plus_one)


# ---------------------------------------------------------------------------
# blocks (attention / mlp / moe / mamba)
# ---------------------------------------------------------------------------

def ffn_schema(cfg, *, d_ff: int | None = None):
    f = d_ff or cfg.d_ff
    if cfg.mlp_type == "mlp":
        return mlp_schema(cfg.d_model, f, bias=cfg.mlp_bias,
                          dtype=cfg.param_dtype)
    return glu_schema(cfg.d_model, f, dtype=cfg.param_dtype)


def apply_ffn(p, x, cfg):
    if cfg.mlp_type == "mlp":
        return mlp(p, x, act=cfg.act)
    return glu(p, x, act=cfg.act)


def attn_block_schema(cfg, *, ffn: str = "dense"):
    s = {
        "norm1": norm_schema(cfg),
        "attn": attn.mla_schema(cfg) if cfg.use_mla else attn.gqa_schema(cfg),
        "norm2": norm_schema(cfg),
    }
    if ffn == "moe":
        s["ffn"] = moe_mod.moe_schema(cfg)
    elif ffn == "dense_first":        # deepseek first-k dense width
        s["ffn"] = ffn_schema(cfg, d_ff=cfg.dense_d_ff)
    else:
        s["ffn"] = ffn_schema(cfg)
    if cfg.post_norms:
        s["norm_post_attn"] = norm_schema(cfg)
        s["norm_post_ffn"] = norm_schema(cfg)
    return s


def attn_block(p, x, cfg, *, window=None, encoder=False, ffn="dense",
               positions=None):
    # sequence-parallel boundary: block inputs live seq-sharded over the
    # model axis (norm/residual are pointwise in seq); attention/mlp
    # internals re-gather seq and shard heads/d_ff instead. XLA emits the
    # all-gather / reduce-scatter pair this constraint implies.
    x = shard_act(x, ("batch", "seq_act", None))
    h = apply_norm(p["norm1"], x, cfg)
    if cfg.use_mla:
        a = attn.mla_attention(p["attn"], h, cfg, positions=positions,
                               triangle=cfg.triangle_schedule)
    else:
        a = attn.gqa_attention(p["attn"], h, cfg, window=window,
                               positions=positions, encoder=encoder,
                               triangle=cfg.triangle_schedule)
    if cfg.post_norms:
        a = apply_norm(p["norm_post_attn"], a, cfg)
    x = x + cfg.residual_multiplier * a

    h = apply_norm(p["norm2"], x, cfg)
    if ffn == "moe":
        m = moe_mod.moe_ffn(p["ffn"], h, cfg)
    else:
        m = apply_ffn(p["ffn"], h, cfg)
    if cfg.post_norms:
        m = apply_norm(p["norm_post_ffn"], m, cfg)
    return x + cfg.residual_multiplier * m


def mamba_block_schema(cfg):
    return {"norm": norm_schema(cfg), "mixer": ssm_mod.mamba_schema(cfg)}


def mamba_block(p, x, cfg):
    x = shard_act(x, ("batch", "seq_act", None))
    h = apply_norm(p["norm"], x, cfg)
    return x + cfg.residual_multiplier * ssm_mod.mamba_block(
        p["mixer"], h, cfg)


# --- zamba2 shared block: GQA attn + GLU with per-invocation LoRA ----------

def shared_block_schema(cfg):
    d, r = cfg.d_model, cfg.shared_lora_rank
    n_inv = cfg.n_layers // cfg.attn_every
    dt = cfg.param_dtype
    return {
        "block": attn_block_schema(cfg),
        # per-invocation LoRA deltas on the attention input projection and
        # the mlp gate (stacked over invocations; indexed by scan counter)
        "lora_a": ParamDef((n_inv, d, r), ("stack", "d_model", "lora"),
                           dtype=dt, scale=0.02),
        "lora_b": ParamDef((n_inv, r, d), ("stack", "lora", "d_model"),
                           "zeros", dtype=dt),
    }


def shared_block(p, x, cfg, inv: jax.Array):
    la = p["lora_a"][inv]
    lb = p["lora_b"][inv]
    x = x + (x @ la.astype(x.dtype)) @ lb.astype(x.dtype)
    return attn_block(p["block"], x, cfg)


# ---------------------------------------------------------------------------
# remat
# ---------------------------------------------------------------------------

def _remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=None)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


# ---------------------------------------------------------------------------
# per-family stacks
# ---------------------------------------------------------------------------

def stack_schema_for(cfg) -> dict:
    if cfg.family == "ssm":
        return {"layers": stack_schema(mamba_block_schema(cfg), cfg.n_layers)}
    if cfg.family == "hybrid":
        n_seg = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers - n_seg * cfg.attn_every
        s: dict = {
            "segments": stack_schema(
                stack_schema(mamba_block_schema(cfg), cfg.attn_every), n_seg),
            "shared": shared_block_schema(cfg),
        }
        if rem:
            s["tail"] = stack_schema(mamba_block_schema(cfg), rem)
        return s
    if cfg.family == "moe" or cfg.n_experts:
        k = cfg.first_k_dense
        s = {}
        if k:
            s["dense_layers"] = stack_schema(
                attn_block_schema(cfg, ffn="dense_first"), k)
        s["layers"] = stack_schema(
            attn_block_schema(cfg, ffn="moe"), cfg.n_layers - k)
        return s
    if cfg.layer_pattern == "local_global":
        assert cfg.n_layers % 2 == 0
        pair = {"local": attn_block_schema(cfg),
                "global": attn_block_schema(cfg)}
        return {"pairs": stack_schema(pair, cfg.n_layers // 2)}
    return {"layers": stack_schema(attn_block_schema(cfg), cfg.n_layers)}


def run_stack(params: dict, x: jax.Array, cfg, *, positions=None) -> jax.Array:
    """Full-sequence forward through the layer stack (train/prefill).

    Scan bodies re-apply the sequence-parallel constraint at EXIT so the
    carries the autodiff machinery saves per layer live seq-sharded over
    the model axis (a 46-layer gemma2 microbatch saves ~23x150MB carries;
    sharded 16-way that is ~220MB/chip instead of 3.5GB)."""
    enc = cfg.encoder_only

    def out_c(h):
        return shard_act(h, ("batch", "seq_act", None))

    if cfg.family == "ssm":
        def body(h, lp):
            return out_c(mamba_block(lp, h, cfg)), None
        x, _ = jax.lax.scan(_remat(body, cfg), x, params["layers"])
        return x

    if cfg.family == "hybrid":
        def body(carry, seg):
            h, inv = carry
            lp, _ = seg

            def inner(hh, lpp):
                return mamba_block(lpp, hh, cfg), None
            h, _ = jax.lax.scan(inner, h, lp)
            h = shared_block(params["shared"], h, cfg, inv)
            return (out_c(h), inv + 1), None
        n_seg = cfg.n_layers // cfg.attn_every
        (x, _), _ = jax.lax.scan(
            _remat(body, cfg), (x, jnp.int32(0)),
            (params["segments"], jnp.arange(n_seg)),
        )
        if "tail" in params:
            def body_t(h, lp):
                return mamba_block(lp, h, cfg), None
            x, _ = jax.lax.scan(body_t, x, params["tail"])
        return x

    if cfg.family == "moe" or cfg.n_experts:
        if "dense_layers" in params:
            def body_d(h, lp):
                return out_c(attn_block(lp, h, cfg, ffn="dense_first",
                                        positions=positions)), None
            x, _ = jax.lax.scan(_remat(body_d, cfg), x,
                                params["dense_layers"])

        def body(h, lp):
            return out_c(attn_block(lp, h, cfg, ffn="moe",
                                    positions=positions)), None
        x, _ = jax.lax.scan(_remat(body, cfg), x, params["layers"])
        return x

    if cfg.layer_pattern == "local_global":
        def body(h, lp):
            h = attn_block(lp["local"], h, cfg, window=cfg.window,
                           positions=positions)
            h = attn_block(lp["global"], h, cfg, window=None,
                           positions=positions)
            return out_c(h), None
        x, _ = jax.lax.scan(_remat(body, cfg), x, params["pairs"])
        return x

    window = cfg.window if cfg.layer_pattern == "local" else None

    def body(h, lp):
        return out_c(attn_block(lp, h, cfg, window=window, encoder=enc,
                                positions=positions)), None
    x, _ = jax.lax.scan(_remat(body, cfg), x, params["layers"])
    return x
