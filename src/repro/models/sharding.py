"""Logical-axis sharding rules (MaxText-style).

Every parameter/activation axis in the model stack is annotated with a
LOGICAL name; this module maps logical names onto physical mesh axes for
the production meshes defined in launch/mesh.py:

    single-pod:  (data=16, model=16)
    multi-pod:   (pod=2, data=16, model=16)

Rules (DESIGN.md §6):
    batch                 -> ('pod', 'data')   (DP over pods and data axis)
    vocab/heads/d_ff/...  -> 'model'           (TP)
    d_model on params     -> 'data'            (FSDP: ZeRO-3 style)
    kv_seq (decode cache) -> 'data'            (long-context sequence shard)
    experts               -> 'model'           (EP when divisible)

A rule maps a logical axis to a priority list of mesh axes; the first axis
present in the mesh AND dividing the dimension size is chosen (so e.g. a
14-head attention simply falls back to unsharded heads instead of failing).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# logical axis -> candidate mesh axes, in priority order. A tuple entry
# means "all of these together" (e.g. batch over pod AND data).
DEFAULT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"),),
    "batch_nopod": (("data",),),
    "seq": (),                      # activations: sequence unsharded (train)
    "seq_act": (("model",),),       # SEQUENCE PARALLEL: block-boundary
                                    # activations shard seq -> model (the
                                    # Megatron-SP trick, via constraints)
    "kv_seq": (("data",), ("model",)),   # decode KV cache sequence axis;
                                    # falls to model when data is taken by
                                    # batch and kv_heads can't use model
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "d_ff": (("model",),),
    "d_model": (("data",),),        # params only (FSDP axis)
    "d_model_act": (),              # activations: d_model replicated
    "experts": (("model",),),
    "expert_cap": (("data", "model"), ("data",)),  # MoE capacity axis:
                                    # both axes when EP is unavailable
    "ssm_state": (),
    "ssm_heads": (("model",),),
    "conv_k": (),
    "frontend": (),
    "lora": (),
    "stack": (),                    # scan-stacked layer axis: never sharded
    None: (),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple = tuple(DEFAULT_RULES.items())

    def as_dict(self) -> dict:
        return dict(self.rules)


def _pick_axes(
    logical: str | None,
    dim: int | None,
    mesh: Mesh,
    rules: dict[str, tuple],
    used: set | None = None,
) -> tuple[str, ...] | None:
    """Choose mesh axes for one logical axis (None = replicate). A
    candidate is skipped when any of its axes is already ``used`` by an
    earlier logical axis of the same value — so priority lists fall
    through (e.g. kv_seq: data taken by batch -> model)."""
    for cand in rules.get(logical, ()):
        axes = cand if isinstance(cand, tuple) else (cand,)
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes:
            continue
        if used is not None and any(a in used for a in axes):
            continue
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if dim is None or dim % total == 0:
            return axes
    return None


def logical_to_spec(
    logical_axes: Sequence[str | None],
    mesh: Mesh,
    *,
    dims: Sequence[int] | None = None,
    rules: ShardingRules | None = None,
) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for ``mesh``.

    ``dims`` (optional) enables divisibility fallback: a logical axis whose
    size does not divide by its mesh-axis product is replicated instead.
    A mesh axis is used at most once (first logical axis wins).
    """
    rd = (rules or ShardingRules()).as_dict()
    used: set[str] = set()
    out = []
    for i, name in enumerate(logical_axes):
        dim = None if dims is None else dims[i]
        axes = _pick_axes(name, dim, mesh, rd, used)
        if axes is None:
            out.append(None)
        else:
            used.update(axes)
            out.append(axes[0] if len(axes) == 1 else axes)
    return P(*out)


def logical_sharding(
    logical_axes: Sequence[str | None],
    mesh: Mesh,
    *,
    dims: Sequence[int] | None = None,
    rules: ShardingRules | None = None,
) -> NamedSharding:
    return NamedSharding(
        mesh, logical_to_spec(logical_axes, mesh, dims=dims, rules=rules)
    )


def tree_logical_to_sharding(schema_axes, schema_shapes, mesh, rules=None):
    """Map a pytree of logical-axes tuples (+ matching shapes tree) to a
    pytree of NamedShardings."""
    return jax.tree.map(
        lambda ax, shp: logical_sharding(ax, mesh, dims=shp, rules=rules),
        schema_axes,
        schema_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


# ---------------------------------------------------------------------------
# Activation sharding constraints (sequence parallelism, sharded logits,
# MoE dispatch placement). The model code annotates activations with
# LOGICAL axes via ``shard_act``; a driver (dryrun/train/serve launcher)
# installs the mesh with ``activation_mesh(mesh)``. Outside that context
# shard_act is a no-op, so smoke tests and CPU runs see plain jnp.
# ---------------------------------------------------------------------------

import contextlib
import threading

_ACT = threading.local()


@contextlib.contextmanager
def activation_mesh(mesh: Mesh, rules: ShardingRules | None = None):
    prev = getattr(_ACT, "ctx", None)
    _ACT.ctx = (mesh, rules)
    try:
        yield
    finally:
        _ACT.ctx = prev


def shard_act(x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
    ctx = getattr(_ACT, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    sh = logical_sharding(logical, mesh, dims=x.shape, rules=rules)
    return jax.lax.with_sharding_constraint(x, sh)
