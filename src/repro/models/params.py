"""Parameter schema system.

A model is declared once as a nested dict of ``ParamDef`` leaves (shape +
logical sharding axes + initializer). From that single schema we derive:

  * ``init_tree``      — materialized parameters (smoke tests, examples)
  * ``abstract_tree``  — ShapeDtypeStruct stand-ins (dry-run: lower/compile
                         a 27B model on CPU without allocating a byte)
  * ``sharding_tree``  — NamedSharding per leaf from the logical rules
  * ``count_params``   — exact parameter count (roofline MODEL_FLOPS term)

This keeps the model code, the dry-run, and the sharding rules from ever
drifting apart — the schema IS the single source of truth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.models.sharding import ShardingRules, logical_sharding


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """One parameter: shape, logical axes (same arity), init spec."""
    shape: tuple
    logical: tuple
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float | None = None  # stddev; None = 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _fan_in(shape: tuple) -> int:
    # convention: last axis is the output axis for 2D+; fan_in = product of
    # the rest (matches the matmul contractions used in layers.py)
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    return max(int(np.prod(shape[:-1])), 1)


def init_leaf(key: jax.Array, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "neg":
        return jnp.full(d.shape, -1, d.dtype)
    if d.init == "embed":
        s = d.scale if d.scale is not None else 1.0
        return (s * jax.random.normal(key, d.shape)).astype(d.dtype)
    s = d.scale if d.scale is not None else 1.0 / math.sqrt(_fan_in(d.shape))
    return (s * jax.random.normal(key, d.shape)).astype(d.dtype)


def init_tree(key: jax.Array, schema) -> dict:
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [init_leaf(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(schema) -> dict:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), schema, is_leaf=_is_def
    )


def sharding_tree(
    schema, mesh: Mesh, rules: ShardingRules | None = None
) -> dict:
    return jax.tree.map(
        lambda d: logical_sharding(d.logical, mesh, dims=d.shape, rules=rules),
        schema,
        is_leaf=_is_def,
    )


def spec_tree(schema, mesh: Mesh, rules: ShardingRules | None = None) -> dict:
    """PartitionSpec tree (for pjit in_shardings)."""
    return jax.tree.map(
        lambda s: s.spec, sharding_tree(schema, mesh, rules),
        is_leaf=lambda x: isinstance(x, NamedSharding),
    )


def count_params(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=_is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


def bytes_params(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=_is_def)
    return sum(
        int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves
    )


def cast_tree(params, dtype) -> dict:
    """Cast floating leaves (activations dtype for fwd) — keeps int leaves."""
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(cast, params)
