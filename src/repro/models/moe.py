"""Mixture-of-Experts layer (deepseek-v2-lite, granite-moe).

Token-choice top-k routing realized in a fully dense, pjit-shardable form:

  1. router logits (T, E); per-token top-k mask and gate weights.
  2. per-expert candidate scores (E, T): the token's gate if it selected the
     expert, else -inf.
  3. ``lax.top_k`` over tokens gives each expert its C-token batch
     (score-priority capacity policy — tokens beyond capacity are dropped,
     highest-gate first; C = ceil(T*k/E) * capacity_factor).
  4. gather -> (E, C, D), batched expert GLU -> scatter-add back weighted.

Sharding: expert weight tensors carry the ``experts`` logical axis (EP over
the ``model`` mesh axis when E divides it — deepseek 64 experts / 16-way
model axis = 4 experts per chip); the (E, C, D) dispatch activations shard
(experts->model, cap->data), so the gather from the token-sharded (T, D)
activations IS the MoE all-to-all (XLA emits the collective). When E does
not divide the axis (granite: 40 experts, 16-way), EP is skipped by the
divisibility fallback and experts shard over d_ff/TP inside each expert
instead (DESIGN.md §6).

deepseek-v2 extras: shared experts (always-on GLU on the side), optional
routed scaling, sigmoid-vs-softmax scoring, first-k dense layers (handled
by the transformer stack, not here).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef
from repro.models.sharding import shard_act


def moe_schema(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = cfg.param_dtype
    s = {
        "router": ParamDef((d, e), ("d_model", "experts"), dtype=dt,
                           scale=0.02),
        "gate": ParamDef((e, d, f), ("experts", "d_model", "d_ff"), dtype=dt),
        "up": ParamDef((e, d, f), ("experts", "d_model", "d_ff"), dtype=dt),
        "down": ParamDef((e, f, d), ("experts", "d_ff", "d_model"), dtype=dt),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        s["shared"] = {
            "gate": ParamDef((d, fs), ("d_model", "d_ff"), dtype=dt),
            "up": ParamDef((d, fs), ("d_model", "d_ff"), dtype=dt),
            "down": ParamDef((fs, d), ("d_ff", "d_model"), dtype=dt),
        }
    return s


def moe_capacity(cfg, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.moe_top_k / cfg.n_experts
                  * cfg.moe_capacity_factor)
    c = max(int(-(-c // 128) * 128), 128)      # round up to 128 (MXU lanes)
    return min(c, n_tokens)                    # never exceed the token count


def moe_ffn(p: dict, x: jax.Array, cfg) -> jax.Array:
    """x: (B, L, D) -> (B, L, D)."""
    B, L, D = x.shape
    T = B * L
    E, K = cfg.n_experts, cfg.moe_top_k
    C = moe_capacity(cfg, T)
    xf = x.reshape(T, D)

    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (T, E)
    if cfg.moe_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)

    top_val, top_idx = jax.lax.top_k(scores, K)            # (T, K)
    if cfg.moe_norm_topk:
        top_val = top_val / jnp.maximum(
            jnp.sum(top_val, axis=-1, keepdims=True), 1e-20)
    top_val = top_val * cfg.moe_routed_scale

    # selected-gate matrix (T, E): gate weight where chosen, else 0
    sel = jnp.zeros((T, E), jnp.float32)
    sel = sel.at[jnp.arange(T)[:, None], top_idx].max(top_val)

    # per-expert top-C tokens by gate score (score-priority capacity)
    score_e = jnp.where(sel > 0, sel, -jnp.inf).T           # (E, T)
    top_c_val, top_c_idx = jax.lax.top_k(score_e, C)        # (E, C)
    slot_ok = jnp.isfinite(top_c_val)                       # expert had <C picks

    xe = xf[top_c_idx]                                      # (E, C, D) gather
    # EP placement: experts -> model (when divisible), capacity -> data.
    # The gather from token-sharded xf into this layout IS the MoE
    # dispatch all-to-all; the scatter-add back is the return leg.
    xe = shard_act(xe, ("experts", "expert_cap", None))
    g = jnp.einsum("ecd,edf->ecf", xe, p["gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, p["up"].astype(x.dtype))
    a = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", a, p["down"].astype(x.dtype))
    ye = shard_act(ye, ("experts", "expert_cap", None))
    ye = ye * jnp.where(slot_ok, top_c_val, 0.0)[..., None].astype(x.dtype)

    out = jnp.zeros((T, D), x.dtype)
    out = out.at[jnp.where(slot_ok, top_c_idx, T)].add(
        ye, mode="drop"
    )

    if "shared" in p:
        g = xf @ p["shared"]["gate"].astype(x.dtype)
        u = xf @ p["shared"]["up"].astype(x.dtype)
        out = out + (
            jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        ) @ p["shared"]["down"].astype(x.dtype)
    return out.reshape(B, L, D)


def aux_load_balance_loss(logits: jax.Array, top_idx: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balance auxiliary (f_i * P_i); optional in train."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac = jnp.zeros((E,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    frac = frac / jnp.maximum(frac.sum(), 1.0)
    return E * jnp.sum(frac * jnp.mean(probs, axis=0))
