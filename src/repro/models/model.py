"""Top-level LM: embedding (+ modality frontend stubs), layer stack,
final norm, output head, loss. All pure functions over schema-matched
param trees (see params.py) — the same code path materialized for smoke
tests and abstract for the multi-pod dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.layers import ParamDef, dense, dense_schema, embed_schema, softcap
from repro.models.params import count_params
from repro.models.sharding import shard_act
from repro.models.transformer import apply_norm, norm_schema


def model_schema(cfg) -> dict:
    dt = cfg.param_dtype
    s: dict = {
        "embed": embed_schema(cfg.vocab, cfg.d_model, dt),
        "stack": transformer.stack_schema_for(cfg),
        "final_norm": norm_schema(cfg),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = {
            "w": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "d_model"),
                          dtype=dt)
        }
    if cfg.frontend == "audio":
        s["frontend"] = dense_schema(
            cfg.frontend_dim, cfg.d_model, ("frontend", "d_model"),
            bias=True, dtype=dt)
    elif cfg.frontend == "vision":
        # 2-layer MLP projector (internvl mlp1)
        s["frontend"] = {
            "fc1": dense_schema(cfg.frontend_dim, cfg.d_model,
                                ("frontend", "d_model"), bias=True, dtype=dt),
            "fc2": dense_schema(cfg.d_model, cfg.d_model,
                                ("d_model", None), bias=True, dtype=dt),
        }
    return s


def embed_inputs(params: dict, batch: dict, cfg) -> jax.Array:
    """Token / frame / patch embedding -> (B, L', d) activations."""
    dt = cfg.act_dtype
    if cfg.frontend == "audio":
        x = dense(params["frontend"], batch["frames"].astype(dt))
        return x
    table = params["embed"]["table"]
    x = table.astype(dt)[batch["tokens"]]
    if cfg.embed_scale is not None:
        x = x * jnp.asarray(cfg.embed_scale, dt)
    if cfg.embedding_multiplier != 1.0:
        x = x * jnp.asarray(cfg.embedding_multiplier, dt)
    if cfg.frontend == "vision" and "patches" in batch:
        p = dense(params["frontend"]["fc1"], batch["patches"].astype(dt))
        p = jax.nn.gelu(p.astype(jnp.float32), approximate=True).astype(dt)
        p = dense(params["frontend"]["fc2"], p)
        x = jnp.concatenate([p, x], axis=1)      # patches prefix the text
    return x


def output_logits(params: dict, x: jax.Array, cfg) -> jax.Array:
    x = apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        w = params["embed"]["table"]
    else:
        w = params["lm_head"]["w"]
    logits = jnp.einsum("...d,vd->...v", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    if logits.ndim == 3:
        # (B, L, V) sharded batch x vocab — the 1M-token x 256k-vocab train
        # logits would be 1TB replicated; sharded they are ~4GB/chip.
        logits = shard_act(logits, ("batch", None, "vocab"))
    if cfg.logits_scaling != 1.0:
        logits = logits / cfg.logits_scaling
    logits = softcap(logits, cfg.final_softcap)
    return logits


def forward(params: dict, batch: dict, cfg) -> jax.Array:
    """Full-sequence forward -> fp32 logits (B, L', vocab)."""
    x = embed_inputs(params, batch, cfg)
    x = transformer.run_stack(params["stack"], x, cfg)
    return output_logits(params, x, cfg)


def _xent_terms(params, x, labels, cfg):
    """CE pieces for (B, Lc, d) states: (nll_sum, n_tokens, n_correct)."""
    logits = output_logits(params, x, cfg)
    mask = labels >= 0
    tgt = jnp.clip(labels, 0, cfg.vocab - 1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = jnp.sum((logz - gold) * mask)
    correct = jnp.sum((jnp.argmax(logits, -1) == tgt) & mask)
    return nll, jnp.sum(mask), correct


def loss_fn(params: dict, batch: dict, cfg) -> tuple[jax.Array, dict]:
    """Next-token (or masked-unit, for the encoder) cross entropy.

    labels < 0 are masked (vlm patch positions, padding). When the
    sequence exceeds ``cfg.loss_chunk``, CE is computed by a rematerialized
    scan over sequence chunks so the (B, L, vocab) logits tensor never
    materializes — at gemma2 scale that tensor is 1M x 256k x 4B = 1 TB;
    chunked, the live slice is loss_chunk/L of it and the backward
    recomputes each chunk's logits from the (tiny) final hidden states.
    """
    x = embed_inputs(params, batch, cfg)
    x = transformer.run_stack(params["stack"], x, cfg)
    if cfg.frontend == "vision" and "patches" in batch:
        x = x[:, cfg.n_patches:, :]              # text positions only
    labels = batch["labels"]
    B, L, _ = x.shape

    ck = cfg.loss_chunk
    if ck and L > ck and L % ck == 0:
        xc = x.reshape(B, L // ck, ck, -1).swapaxes(0, 1)
        lc = labels.reshape(B, L // ck, ck).swapaxes(0, 1)

        @jax.checkpoint
        def chunk(carry, xl):
            xcb, lcb = xl
            nll, n, corr = _xent_terms(params, xcb, lcb, cfg)
            a, b, c = carry
            return (a + nll, b + n, c + corr), None

        (nll, n_tok, correct), _ = jax.lax.scan(
            chunk, (jnp.float32(0), jnp.int32(0), jnp.int32(0)), (xc, lc))
    else:
        nll, n_tok, correct = _xent_terms(params, x, labels, cfg)

    denom = jnp.maximum(n_tok, 1)
    loss = nll / denom
    metrics = {
        "loss": loss,
        "tokens": n_tok,
        "accuracy": correct / denom,
    }
    return loss, metrics


def param_count(cfg) -> int:
    return count_params(model_schema(cfg))


def active_param_count(cfg) -> int:
    """Active-per-token params (MoE: shared + top_k routed only) — the
    N_active of the roofline MODEL_FLOPS = 6*N_active*D."""
    if not cfg.n_experts:
        return param_count(cfg)
    total = param_count(cfg)
    expert_p = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = (cfg.n_experts - cfg.moe_top_k) * expert_p * (
        cfg.n_layers - cfg.first_k_dense)
    return total - inactive
