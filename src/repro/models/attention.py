"""Attention for every assigned family: GQA (llama/qwen/starcoder2/yi),
local+global alternation with logit softcap (gemma2), MLA latent-KV
(deepseek-v2), encoder bidirectional (hubert), plus decode paths with
batched KV caches (per-slot positions for continuous batching).

Train/prefill path = chunked flash attention in pure jnp (lax.scan over q
chunks, inner scan over kv chunks, online softmax) — the numerically
identical HLO counterpart of kernels/flash_attention.py, which is the TPU
target. Memory is O(cq*ckv) per step regardless of sequence length.

Sliding-window layers use BANDED kv slicing: a q chunk only reads the
(window + cq) keys it can see, so both memory AND flops scale with the
window, not the sequence (gemma2 local layers; this is also what makes
long-context cells affordable).

Causal full-attention layers optionally use the triangular chunk schedule
(skip jk > jq) — ``triangle=True`` — halving flash flops vs the rectangular
masked sweep. Rectangular is the paper-faithful-baseline default; triangle
is a §Perf optimization (EXPERIMENTS.md).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, apply_rope, rmsnorm, rmsnorm_schema
from repro.models.sharding import shard_act

_NEG = -2.0e30


# ---------------------------------------------------------------------------
# Chunked flash core (pure jnp; TPU target = kernels/flash_attention.py)
# ---------------------------------------------------------------------------

def _flash_block(q, k, v, m, l, acc, qpos, kpos, *, causal, window,
                 softcap_v, scale, encoder):
    """One (q_chunk x kv_chunk) online-softmax update.

    q: (B, cq, H, Dq)  k: (B, ck, Hkv, Dq)  v: (B, ck, Hkv, Dv)
    m/l: (B, H, cq, 1); acc: (B, H, cq, Dv). MLA has Dv != Dq.
    qpos (cq,), kpos (ck,) absolute positions.
    """
    B, cq, H, Dh = q.shape
    Dv = v.shape[-1]
    ck, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, cq, Hkv, rep, Dh)
    logits = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qg.astype(jnp.float32), k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).reshape(B, H, cq, ck) * scale
    if softcap_v is not None:
        logits = softcap_v * jnp.tanh(logits / softcap_v)
    mask = jnp.ones((cq, ck), dtype=bool)
    if not encoder and causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    # kv validity (padding rows have kpos < 0)
    mask &= (kpos >= 0)[None, :]
    logits = jnp.where(mask[None, None], logits, _NEG)

    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m, m_cur)
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask[None, None], p, 0.0)
    l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jnp.einsum(
        "bgrqk,bkgd->bqgrd",
        p.reshape(B, Hkv, rep, cq, ck),
        v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).reshape(B, cq, H, Dv).transpose(0, 2, 1, 3)
    acc_new = acc * alpha + pv
    return m_new, l_new, acc_new


def chunked_attention(
    q: jax.Array,              # (B, Lq, H, Dh)
    k: jax.Array,              # (B, Lk, Hkv, Dh)
    v: jax.Array,              # (B, Lk, Hkv, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    q_offset: int = 0,
    cq: int = 512,
    ckv: int = 1024,
    encoder: bool = False,
    triangle: bool = False,
) -> jax.Array:
    B, Lq, H, Dh = q.shape
    Dv = v.shape[-1]
    Lk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    cq = min(cq, Lq)
    ckv = min(ckv, Lk)
    # pad sequences to chunk multiples (kpos<0 marks padding)
    pq, pk = (-Lq) % cq, (-Lk) % ckv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (Lq + pq) // cq, (Lk + pk) // ckv
    kpos_all = jnp.where(jnp.arange(Lk + pk) < Lk, jnp.arange(Lk + pk), -1)

    kc = k.reshape(B, nk, ckv, *k.shape[2:])
    vc = v.reshape(B, nk, ckv, *v.shape[2:])
    kposc = kpos_all.reshape(nk, ckv)

    banded = window is not None and not encoder
    if banded:
        # q chunk jq sees keys in [end - window - cq + 1, end]; slice a
        # static (window+cq) band, rounded up to ckv multiples
        band = ((window + cq + ckv - 1) // ckv + 1) * ckv

    def per_q_chunk(jq):
        qj = jax.lax.dynamic_slice_in_dim(q, jq * cq, cq, axis=1)
        qpos = q_offset + jq * cq + jnp.arange(cq)
        m0 = jnp.full((B, H, cq, 1), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, cq, 1), jnp.float32)
        a0 = jnp.zeros((B, H, cq, Dv), jnp.float32)

        if banded:
            start = jnp.clip(
                (q_offset + jq * cq + cq - 1 - window) // ckv * ckv,
                0, max(nk * ckv - band, 0),
            )
            kb = jax.lax.dynamic_slice_in_dim(k, start, min(band, nk * ckv), 1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, min(band, nk * ckv), 1)
            kp = jax.lax.dynamic_slice_in_dim(
                kpos_all, start, min(band, nk * ckv), 0
            )
            nb = kb.shape[1] // ckv

            def inner(carry, jk):
                m, l, acc = carry
                ks = jax.lax.dynamic_slice_in_dim(kb, jk * ckv, ckv, 1)
                vs = jax.lax.dynamic_slice_in_dim(vb, jk * ckv, ckv, 1)
                kp_ = jax.lax.dynamic_slice_in_dim(kp, jk * ckv, ckv, 0)
                m, l, acc = _flash_block(
                    qj, ks, vs, m, l, acc, qpos, kp_, causal=causal,
                    window=window, softcap_v=softcap, scale=scale,
                    encoder=encoder,
                )
                return (m, l, acc), None

            # flash-bwd memory model: recompute block probs in the
            # backward instead of saving (B,H,cq,ckv) tensors per step
            (m, l, acc), _ = jax.lax.scan(
                jax.checkpoint(inner), (m0, l0, a0), jnp.arange(nb)
            )
        else:
            nk_eff = nk
            if triangle and causal and not encoder and q_offset == 0 \
                    and Lq == Lk and cq == ckv:
                # triangular schedule: q chunk jq only visits jk <= jq
                def inner(carry, jk):
                    m, l, acc = carry
                    def do(args):
                        m, l, acc = args
                        return _flash_block(
                            qj, kc[:, jk], vc[:, jk], m, l, acc, qpos,
                            kposc[jk], causal=causal, window=window,
                            softcap_v=softcap, scale=scale, encoder=encoder,
                        )
                    m, l, acc = jax.lax.cond(
                        jk <= jq, do, lambda a: a, (m, l, acc)
                    )
                    return (m, l, acc), None
            else:
                def inner(carry, jk):
                    m, l, acc = carry
                    m, l, acc = _flash_block(
                        qj, kc[:, jk], vc[:, jk], m, l, acc, qpos,
                        kposc[jk], causal=causal, window=window,
                        softcap_v=softcap, scale=scale, encoder=encoder,
                    )
                    return (m, l, acc), None

            (m, l, acc), _ = jax.lax.scan(
                jax.checkpoint(inner), (m0, l0, a0), jnp.arange(nk_eff)
            )

        out = jnp.where(l > 0, acc / jnp.where(l > 0, l, 1.0), 0.0)
        return out.transpose(0, 2, 1, 3)        # (B, cq, H, Dh)

    chunks = jax.lax.map(per_q_chunk, jnp.arange(nq))   # (nq, B, cq, H, Dv)
    out = chunks.transpose(1, 0, 2, 3, 4).reshape(B, nq * cq, H, Dv)
    return out[:, :Lq].astype(q.dtype)


def decode_attention(
    q: jax.Array,              # (B, 1, H, Dh)
    k_cache: jax.Array,        # (B, S, Hkv, Dh)
    v_cache: jax.Array,        # (B, S, Hkv, Dh)
    kpos: jax.Array,           # (B, S) absolute position per slot, -1 empty
    pos: jax.Array,            # (B,) position of the new token
    *,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Single-token attention against a position-tagged KV cache.

    The cache may be a ring buffer (local-window layers: S = window); the
    per-slot absolute positions make masking independent of the physical
    slot order, so ring and linear caches share this one code path. The
    cache's S axis may be mesh-sharded (kv_seq -> data for long-context
    decode); the softmax over S then reduces across shards under pjit.
    """
    B, _, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, 1, Hkv, rep, Dh)
    # NOTE: no .astype on the cache operands — bf16 x bf16 -> f32 via
    # preferred_element_type is MXU-native; pre-converting materializes a
    # full f32 copy of the cache (2.5x decode HBM footprint)
    logits = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qg.astype(k_cache.dtype), k_cache,
        preferred_element_type=jnp.float32,
    ).reshape(B, H, 1, S) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = (kpos >= 0) & (kpos <= pos[:, None])
    if window is not None:
        mask &= kpos > (pos[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bgrqk,bkgd->bqgrd",
        p.reshape(B, Hkv, rep, 1, S).astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    ).reshape(B, 1, H * Dh)
    return out.astype(q.dtype).reshape(B, 1, H, Dh)


# ---------------------------------------------------------------------------
# GQA attention block (yi, codeqwen, starcoder2, gemma2, zamba2-shared, ...)
# ---------------------------------------------------------------------------

def gqa_schema(cfg) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.param_dtype
    s = {
        "wq": ParamDef((d, H, Dh), ("d_model", "heads", None), dtype=dt),
        "wk": ParamDef((d, Hkv, Dh), ("d_model", "kv_heads", None), dtype=dt),
        "wv": ParamDef((d, Hkv, Dh), ("d_model", "kv_heads", None), dtype=dt),
        "wo": ParamDef((H, Dh, d), ("heads", None, "d_model"), dtype=dt),
    }
    if cfg.attn_bias:
        s["bq"] = ParamDef((H, Dh), ("heads", None), "zeros", dtype=dt)
        s["bk"] = ParamDef((Hkv, Dh), ("kv_heads", None), "zeros", dtype=dt)
        s["bv"] = ParamDef((Hkv, Dh), ("kv_heads", None), "zeros", dtype=dt)
    if cfg.attn_out_bias:
        s["bo"] = ParamDef((d,), ("d_model",), "zeros", dtype=dt)
    return s


def _qkv(p, x, cfg):
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def _out(p, o, x_dtype):
    y = jnp.einsum("blhk,hkd->bld", o.astype(x_dtype), p["wo"].astype(x_dtype))
    if "bo" in p:
        y = y + p["bo"].astype(x_dtype)
    return y


def gqa_attention(
    p: dict,
    x: jax.Array,              # (B, L, d)
    cfg,
    *,
    window: int | None = None,
    positions: jax.Array | None = None,
    encoder: bool = False,
    triangle: bool = False,
    return_kv: bool = False,
):
    """Train/prefill attention (full sequence). return_kv -> also give the
    rope-applied (k, v) so serve/decode.py can seed its cache."""
    B, L, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    pos = positions if positions is not None else jnp.arange(L)
    if cfg.rope:
        q = apply_rope(q, pos, theta=cfg.rope_theta)
        k = apply_rope(k, pos, theta=cfg.rope_theta)
    if cfg.attn_head_constraint:
        # §Perf: pin heads->model BEFORE the chunk loops. Without this,
        # q/k/v inherit the seq->model block-boundary sharding and every
        # chunk-loop dynamic-slice over seq emits a collective (measured:
        # tens of thousands of small all-gathers per step).
        q = shard_act(q, ("batch", None, "heads", None))
        k = shard_act(k, ("batch", None, "kv_heads", None))
        v = shard_act(v, ("batch", None, "kv_heads", None))
    o = chunked_attention(
        q, k, v, causal=not encoder, window=window,
        softcap=cfg.attn_softcap, scale=cfg.attn_scale,
        cq=cfg.attn_chunk_q, ckv=cfg.attn_chunk_kv, encoder=encoder,
        triangle=triangle,
    )
    out = _out(p, o, x.dtype)
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(
    p: dict,
    x: jax.Array,              # (B, 1, d)
    cache: dict,               # {"k","v": (B,S,Hkv,Dh), "kpos": (B,S)}
    lengths: jax.Array,        # (B,) length BEFORE this token (= its pos)
    cfg,
    *,
    window: int | None = None,
) -> tuple[jax.Array, dict]:
    B = x.shape[0]
    S = cache["k"].shape[1]
    q, k, v = _qkv(p, x, cfg)
    if cfg.rope:
        q = apply_rope(q, lengths[:, None], theta=cfg.rope_theta)
        k = apply_rope(k, lengths[:, None], theta=cfg.rope_theta)
    bidx = jnp.arange(B)
    slot = lengths % S                  # ring write (S = window for local)
    kc = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    kp = cache["kpos"].at[bidx, slot].set(lengths)
    o = decode_attention(
        q, kc, vc, kp, lengths, window=window,
        softcap=cfg.attn_softcap, scale=cfg.attn_scale,
    )
    return _out(p, o, x.dtype), {"k": kc, "v": vc, "kpos": kp}


def gqa_cache_schema(cfg, batch: int, max_len: int,
                     window: int | None = None) -> dict:
    dt = cfg.cache_dtype
    S = min(window, max_len) if window is not None else max_len
    shape = (batch, S, cfg.n_kv_heads, cfg.d_head)
    ax = ("batch", "kv_seq", "kv_heads", None)
    return {"k": ParamDef(shape, ax, "zeros", dtype=dt),
            "v": ParamDef(shape, ax, "zeros", dtype=dt),
            "kpos": ParamDef((batch, S), ("batch", "kv_seq"), "neg",
                             dtype=jnp.int32)}


# ---------------------------------------------------------------------------
# MLA — deepseek-v2 multi-head latent attention
# ---------------------------------------------------------------------------

def mla_schema(cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    dt = cfg.param_dtype
    return {
        # q: full-rank projection (v2-lite has q_lora_rank = None)
        "wq": ParamDef((d, H, dn + dr), ("d_model", "heads", None), dtype=dt),
        # kv: joint down-projection to latent + shared rope key
        "wkv_a": ParamDef((d, r + dr), ("d_model", None), dtype=dt),
        "kv_norm": rmsnorm_schema(r, dt)["scale"],
        # up-projection latent -> per-head nope-key and value
        "wkv_b": ParamDef((r, H, dn + dv), (None, "heads", None), dtype=dt),
        "wo": ParamDef((H, dv, d), ("heads", None, "d_model"), dtype=dt),
    }


def _mla_qkv(p, x, cfg, pos):
    """Expanded (train/prefill) form: per-head K/V materialized."""
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, theta=cfg.rope_theta)

    kv = x @ p["wkv_a"].astype(x.dtype)                  # (B, L, r+dr)
    c_kv = rmsnorm({"scale": p["kv_norm"]}, kv[..., :r])
    k_rope = apply_rope(
        kv[..., r:][:, :, None, :], pos, theta=cfg.rope_theta
    )                                                     # (B, L, 1, dr)
    kvu = jnp.einsum("blr,rhk->blhk", c_kv, p["wkv_b"].astype(x.dtype))
    k_nope, v = kvu[..., :dn], kvu[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:3], dr))], axis=-1
    )
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    return qf, k, v, c_kv, kv[..., r:]


def mla_attention(p: dict, x: jax.Array, cfg, *,
                  positions: jax.Array | None = None,
                  triangle: bool = False, return_latent: bool = False):
    B, L, _ = x.shape
    pos = positions if positions is not None else jnp.arange(L)
    q, k, v, c_kv, _ = _mla_qkv(p, x, cfg, pos)
    if cfg.attn_head_constraint:
        q = shard_act(q, ("batch", None, "heads", None))
        k = shard_act(k, ("batch", None, "heads", None))
        v = shard_act(v, ("batch", None, "heads", None))
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    o = chunked_attention(
        q, k, v, causal=True, scale=scale,
        cq=cfg.attn_chunk_q, ckv=cfg.attn_chunk_kv, triangle=triangle,
    )
    out = jnp.einsum("blhk,hkd->bld", o, p["wo"].astype(x.dtype))
    if return_latent:
        # rope-applied shared key (B, L, dr) — cached alongside the latent
        dn = cfg.qk_nope_dim
        k_rope = k[..., 0, dn:]     # identical across heads (broadcast)
        return out, (c_kv, k_rope)
    return out


def mla_decode(
    p: dict,
    x: jax.Array,              # (B, 1, d)
    cache: dict,               # {"ckv": (B,S,r), "krope": (B,S,dr)}
    lengths: jax.Array,
    cfg,
) -> tuple[jax.Array, dict]:
    """Weight-absorbed decode: the cache stores ONLY the latent (r) and the
    shared rope key (dr) per token — the paper-exact KV-memory win of MLA.

    score(h) = q_nope(h) @ W_UK(h)^T @ c_kv^T  +  q_rope(h) @ k_rope^T
    out(h)   = softmax @ c_kv @ W_UV(h)
    """
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    pos = lengths[:, None]
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, theta=cfg.rope_theta)

    kv = x @ p["wkv_a"].astype(x.dtype)
    c_kv = rmsnorm({"scale": p["kv_norm"]}, kv[..., :r])   # (B, 1, r)
    k_rope = apply_rope(
        kv[..., r:][:, :, None, :], pos, theta=cfg.rope_theta
    )[:, :, 0, :]                                          # (B, 1, dr)

    bidx = jnp.arange(B)
    ckv_c = cache["ckv"].at[bidx, lengths].set(
        c_kv[:, 0].astype(cache["ckv"].dtype))
    kr_c = cache["krope"].at[bidx, lengths].set(
        k_rope[:, 0].astype(cache["krope"].dtype))
    kp_c = cache["kpos"].at[bidx, lengths].set(lengths)

    w_uk = p["wkv_b"].astype(x.dtype)[..., :dn]            # (r, H, dn)
    # absorb: q' = q_nope @ W_UK^T  -> latent space
    q_lat = jnp.einsum("blhk,rhk->blhr", q_nope, w_uk)     # (B, 1, H, r)
    s_lat = jnp.einsum(
        "blhr,bsr->bhls", q_lat.astype(ckv_c.dtype), ckv_c,
        preferred_element_type=jnp.float32,
    )
    s_rope = jnp.einsum(
        "blhk,bsk->bhls", q_rope.astype(kr_c.dtype), kr_c,
        preferred_element_type=jnp.float32,
    )
    scale = 1.0 / math.sqrt(dn + dr)
    logits = (s_lat + s_rope) * scale                      # (B, H, 1, S)
    mask = (kp_c >= 0) & (kp_c <= lengths[:, None])
    logits = jnp.where(mask[:, None, None, :], logits, _NEG)
    pr = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum(
        "bhls,bsr->blhr", pr.astype(ckv_c.dtype), ckv_c,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)                                      # (B, 1, H, r)
    w_uv = p["wkv_b"].astype(x.dtype)[..., dn:]            # (r, H, dv)
    o = jnp.einsum("blhr,rhv->blhv", o_lat, w_uv)
    y = jnp.einsum("blhv,hvd->bld", o, p["wo"].astype(x.dtype))
    return y, {"ckv": ckv_c, "krope": kr_c, "kpos": kp_c}


def mla_cache_schema(cfg, batch: int, max_len: int) -> dict:
    dt = cfg.cache_dtype
    return {
        "ckv": ParamDef((batch, max_len, cfg.kv_lora_rank),
                        ("batch", "kv_seq", None), "zeros", dtype=dt),
        "krope": ParamDef((batch, max_len, cfg.qk_rope_dim),
                          ("batch", "kv_seq", None), "zeros", dtype=dt),
        "kpos": ParamDef((batch, max_len), ("batch", "kv_seq"), "neg",
                         dtype=jnp.int32),
    }
