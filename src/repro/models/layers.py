"""Layer primitives shared by every architecture family.

Each primitive comes as a (schema builder, apply function) pair; schema
builders return nested dicts of ParamDef (see params.py), apply functions
consume the materialized (or abstract) params with the same structure.

Activations are computed in ``cfg.act_dtype`` (bf16 at scale) with fp32
for norms/softmax/logits; parameters are stored in ``cfg.param_dtype``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_schema(d: int, dtype=jnp.float32) -> dict:
    return {"scale": ParamDef((d,), ("d_model",), "ones", dtype=dtype)}


def rmsnorm(p: dict, x: jax.Array, *, eps: float = 1e-6,
            scale_plus_one: bool = False) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = p["scale"].astype(jnp.float32)
    if scale_plus_one:           # gemma convention: weight stored as (w-1)
        w = w + 1.0
    return (y * w).astype(x.dtype)


def layernorm_schema(d: int, dtype=jnp.float32) -> dict:
    return {
        "scale": ParamDef((d,), ("d_model",), "ones", dtype=dtype),
        "bias": ParamDef((d,), ("d_model",), "zeros", dtype=dtype),
    }


def layernorm(p: dict, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float = 10_000.0) -> jax.Array:
    """Inverse frequencies (d_head/2,) f32."""
    return 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )


def apply_rope(x: jax.Array, positions: jax.Array, *,
               theta: float = 10_000.0) -> jax.Array:
    """x: (..., L, H, Dh); positions: broadcastable to (..., L) int32.

    Half-split convention (llama/qwen/gemma): rotate [x1, x2] halves.
    """
    dh = x.shape[-1]
    inv = rope_frequencies(dh, theta)                       # (Dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., L, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., L, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / embedding
# ---------------------------------------------------------------------------

def dense_schema(d_in: int, d_out: int, logical: tuple,
                 *, bias: bool = False, dtype=jnp.float32,
                 init: str = "normal", scale: float | None = None) -> dict:
    s = {"w": ParamDef((d_in, d_out), logical, init, scale, dtype)}
    if bias:
        s["b"] = ParamDef((d_out,), (logical[-1],), "zeros", dtype=dtype)
    return s


def dense(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embed_schema(vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": ParamDef((vocab, d), ("vocab", "d_model"), "embed",
                              0.02, dtype)}


def embed(p: dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def unembed(p: dict, x: jax.Array) -> jax.Array:
    """Project to vocab logits in fp32 (numerics: loss in fp32 always)."""
    return jnp.einsum(
        "...d,vd->...v", x, p["table"],
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# MLP (GLU family) — llama/qwen/gemma style gate+up / down
# ---------------------------------------------------------------------------

def glu_schema(d: int, d_ff: int, dtype=jnp.float32) -> dict:
    return {
        "gate": ParamDef((d, d_ff), ("d_model", "d_ff"), dtype=dtype),
        "up": ParamDef((d, d_ff), ("d_model", "d_ff"), dtype=dtype),
        "down": ParamDef((d_ff, d), ("d_ff", "d_model"), dtype=dtype),
    }


def glu(p: dict, x: jax.Array, *, act: str = "silu") -> jax.Array:
    g = x @ p["gate"].astype(x.dtype)
    u = x @ p["up"].astype(x.dtype)
    if act == "silu":
        a = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    elif act == "gelu":
        a = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(x.dtype)
    else:
        raise ValueError(act)
    return (a * u) @ p["down"].astype(x.dtype)


def mlp_schema(d: int, d_ff: int, *, bias: bool = False,
               dtype=jnp.float32) -> dict:
    """Plain 2-layer MLP (starcoder2, hubert)."""
    return {
        "up": dense_schema(d, d_ff, ("d_model", "d_ff"), bias=bias, dtype=dtype),
        "down": dense_schema(d_ff, d, ("d_ff", "d_model"), bias=bias, dtype=dtype),
    }


def mlp(p: dict, x: jax.Array, *, act: str = "gelu") -> jax.Array:
    h = dense(p["up"], x)
    if act == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    else:
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    xf = x.astype(jnp.float32)
    return (cap * jnp.tanh(xf / cap)).astype(x.dtype)
