"""Model stack: layer primitives, attention (GQA/MLA/local-global), MoE,
Mamba-2 SSD, per-family transformer stacks, and the top-level LM — all
pure functions over ParamDef schemas (params.py), shardable via the
logical-axis rules (sharding.py).
"""
from repro.models.model import (
    active_param_count,
    forward,
    loss_fn,
    model_schema,
    param_count,
)
from repro.models.params import (
    ParamDef,
    abstract_tree,
    init_tree,
    sharding_tree,
    spec_tree,
)

__all__ = [
    "ParamDef",
    "abstract_tree",
    "active_param_count",
    "forward",
    "init_tree",
    "loss_fn",
    "model_schema",
    "param_count",
    "sharding_tree",
    "spec_tree",
]
