"""Mamba-2 / SSD blocks (mamba2-130m, zamba2 hybrid).

State-space duality (SSD, arXiv:2405.21060) chunked algorithm: the sequence
is split into chunks of Q tokens; within a chunk the token-mixing is the
quadratic masked-decay form (an MXU matmul, exactly the "blocked" compute
shape TPUs want), and across chunks a (B, H, P, N) state is carried by a
``lax.scan`` — intra-chunk quadratic + inter-chunk linear recurrence is the
whole duality. One scan does both (the per-chunk state pass feeds the next
chunk's inter term), so activation memory is O(chunk) not O(L).

Per head h with decay a_t = dt_t * A_h (A_h < 0):
    h_t = exp(a_t) h_{t-1} + dt_t * B_t x_t^T,   y_t = C_t h_t + D_h x_t

Projections are split (wz/wx/wB/wC/wdt) rather than fused so each gets a
clean TP sharding axis (heads for wx, replicated for the small B/C/dt);
the depthwise conv is causal with a (kernel-1)-token cache at decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, rmsnorm


def mamba_schema(cfg) -> dict:
    d = cfg.d_model
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    kern = cfg.ssm_conv_kernel
    conv_dim = H * P + 2 * G * N
    dt = cfg.param_dtype
    return {
        "wz": ParamDef((d, H, P), ("d_model", "ssm_heads", None), dtype=dt),
        "wx": ParamDef((d, H, P), ("d_model", "ssm_heads", None), dtype=dt),
        "wB": ParamDef((d, G, N), ("d_model", None, None), dtype=dt),
        "wC": ParamDef((d, G, N), ("d_model", None, None), dtype=dt),
        "wdt": ParamDef((d, H), ("d_model", "ssm_heads"), dtype=dt),
        "conv_w": ParamDef((kern, conv_dim), ("conv_k", None), dtype=dt,
                           scale=0.3),
        "conv_b": ParamDef((conv_dim,), (None,), "zeros", dtype=dt),
        "A_log": ParamDef((H,), ("ssm_heads",), "ones", dtype=jnp.float32),
        "D": ParamDef((H,), ("ssm_heads",), "ones", dtype=jnp.float32),
        "dt_bias": ParamDef((H,), ("ssm_heads",), "zeros", dtype=jnp.float32),
        "norm": ParamDef((H * P,), ("d_ff",), "ones", dtype=dt),
        "out": ParamDef((H, P, d), ("ssm_heads", None, "d_model"), dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv. x: (B, L, C); w: (K, C); left-pad K-1."""
    K = w.shape[0]
    if init_state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def ssd_scan(
    x: jax.Array,      # (B, L, H, P)
    dt: jax.Array,     # (B, L, H) f32, positive
    A: jax.Array,      # (H,) f32, negative
    Bm: jax.Array,     # (B, L, G, N)
    Cm: jax.Array,     # (B, L, G, N)
    *,
    chunk: int,
    h0: jax.Array | None = None,   # (B, H, P, N) initial state
    return_state: bool = False,
    intra_dtype=jnp.float32,       # §Perf: bf16 halves intra-chunk traffic
):
    B_, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (L + pad) // chunk
    Q = chunk

    def chunked(t):   # (B, L', ...) -> (nc, B, Q, ...)
        return t.reshape(B_, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    xs = (chunked(x), chunked(dt.astype(jnp.float32)),
          chunked(Bm), chunked(Cm))
    h_init = (jnp.zeros((B_, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    tri = jnp.tril(jnp.ones((Q, Q), dtype=bool))

    def step(h_prev, inp):
        x_c, dt_c, B_c, C_c = inp        # (B,Q,H,P) (B,Q,H) (B,Q,G,N)
        a_c = dt_c * A                    # (B, Q, H) negative
        cum = jnp.cumsum(a_c, axis=1)     # inclusive
        cum_t = cum.transpose(0, 2, 1)    # (B, H, Q)
        a_sum = cum_t[:, :, -1]           # (B, H)

        # head-expanded B/C (groups broadcast over heads within group)
        B_h = jnp.repeat(B_c, hpg, axis=2)           # (B, Q, H, N)
        C_h = jnp.repeat(C_c, hpg, axis=2)

        # ---- intra-chunk (quadratic, masked decay) — the MXU part.
        # intra_dtype=bf16 keeps the (B,H,Q,Q) streams in bf16 end to end
        # (halves the dominant backward traffic); the final y accumulation
        # stays f32.
        CB = jnp.einsum("bqhn,bkhn->bhqk", C_h.astype(intra_dtype),
                        B_h.astype(intra_dtype),
                        preferred_element_type=intra_dtype)
        # mask the ARGUMENT, not the exp: upper-triangle diffs are
        # positive and exp overflows; inf * 0 would NaN the backward
        darg = cum_t[:, :, :, None] - cum_t[:, :, None, :]
        Ldec = jnp.exp(jnp.where(tri[None, None], darg, -1e30))
        scores = CB * Ldec.astype(intra_dtype)
        scores = scores * dt_c.transpose(0, 2, 1)[:, :, None, :].astype(
            intra_dtype)                                           # dt_j
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", scores,
                             x_c.astype(intra_dtype),
                             preferred_element_type=jnp.float32)

        # ---- inter-chunk (contribution of carried state)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", C_h.astype(jnp.float32),
                             h_prev) * jnp.exp(cum)[..., None]

        # ---- state update for next chunk
        decay_end = jnp.exp(a_sum[:, None, :] - cum)  # (B, Q, H)
        wB = B_h.astype(jnp.float32) * (dt_c * decay_end)[..., None]
        state_c = jnp.einsum("bqhn,bqhp->bhpn", wB, x_c.astype(jnp.float32))
        h_new = jnp.exp(a_sum)[:, :, None, None] * h_prev + state_c
        return h_new, (y_intra + y_inter).astype(x.dtype)

    # flash-style memory model: the (B,H,Q,Q) intra-chunk tensors are
    # recomputed in the backward instead of being saved per chunk
    h_last, ys = jax.lax.scan(jax.checkpoint(step), h_init, xs)
    y = ys.swapaxes(0, 1).reshape(B_, nc * Q, H, P)[:, :L]
    if return_state:
        return y, h_last
    return y


def mamba_block(p: dict, x: jax.Array, cfg, *, return_cache: bool = False):
    """Full Mamba-2 block fwd (train/prefill). x: (B, L, d) -> (B, L, d)."""
    B, L, d = x.shape
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    z = jnp.einsum("bld,dhp->blhp", x, p["wz"].astype(x.dtype))
    xin = jnp.einsum("bld,dhp->blhp", x, p["wx"].astype(x.dtype))
    Bm = jnp.einsum("bld,dgn->blgn", x, p["wB"].astype(x.dtype))
    Cm = jnp.einsum("bld,dgn->blgn", x, p["wC"].astype(x.dtype))
    dt_raw = jnp.einsum("bld,dh->blh", x, p["wdt"].astype(x.dtype))

    conv_in = jnp.concatenate(
        [xin.reshape(B, L, H * P), Bm.reshape(B, L, G * N),
         Cm.reshape(B, L, G * N)], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    ).astype(x.dtype)
    xc = conv_out[..., : H * P].reshape(B, L, H, P)
    Bc = conv_out[..., H * P : H * P + G * N].reshape(B, L, G, N)
    Cc = conv_out[..., H * P + G * N :].reshape(B, L, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    intra = jnp.bfloat16 if cfg.ssm_intra_dtype == "bf16" else jnp.float32
    y, h_last = ssd_scan(xc, dt, A, Bc, Cc, chunk=cfg.ssm_chunk,
                         return_state=True, intra_dtype=intra)
    y = y + xc * p["D"].astype(x.dtype)[None, None, :, None]

    # gated RMSNorm then out-projection
    g = y.reshape(B, L, H * P) * jax.nn.silu(
        z.reshape(B, L, H * P).astype(jnp.float32)).astype(x.dtype)
    g = rmsnorm({"scale": p["norm"]}, g)
    out = jnp.einsum("blhp,hpd->bld", g.reshape(B, L, H, P),
                     p["out"].astype(x.dtype))
    if return_cache:
        K = cfg.ssm_conv_kernel
        conv_tail = conv_in[:, L - (K - 1):] if L >= K - 1 else jnp.pad(
            conv_in, ((0, 0), (K - 1 - L, 0), (0, 0)))
        return out, {"conv": conv_tail, "state": h_last}
    return out


def mamba_cache_schema(cfg, batch: int) -> dict:
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    conv_dim = H * P + 2 * G * N
    return {
        "conv": ParamDef((batch, cfg.ssm_conv_kernel - 1, conv_dim),
                         ("batch", None, None), "zeros", dtype=cfg.cache_dtype),
        "state": ParamDef((batch, H, P, N),
                          ("batch", "ssm_heads", None, "ssm_state"),
                          "zeros", dtype=jnp.float32),
    }


def mamba_decode(p: dict, x: jax.Array, cache: dict, cfg
                 ) -> tuple[jax.Array, dict]:
    """Single-token recurrent step. x: (B, 1, d)."""
    B = x.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    z = jnp.einsum("bld,dhp->blhp", x, p["wz"].astype(x.dtype))
    xin = jnp.einsum("bld,dhp->blhp", x, p["wx"].astype(x.dtype))
    Bm = jnp.einsum("bld,dgn->blgn", x, p["wB"].astype(x.dtype))
    Cm = jnp.einsum("bld,dgn->blgn", x, p["wC"].astype(x.dtype))
    dt_raw = jnp.einsum("bld,dh->blh", x, p["wdt"].astype(x.dtype))

    conv_in = jnp.concatenate(
        [xin.reshape(B, 1, H * P), Bm.reshape(B, 1, G * N),
         Cm.reshape(B, 1, G * N)], axis=-1)
    # roll the conv cache (kernel-1 past tokens)
    hist = jnp.concatenate([cache["conv"].astype(x.dtype), conv_in], axis=1)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(x.dtype)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    new_conv = hist[:, 1:]

    xc = conv_out[..., : H * P].reshape(B, H, P)
    Bc = conv_out[..., H * P : H * P + G * N].reshape(B, G, N)
    Cc = conv_out[..., H * P + G * N :].reshape(B, G, N)
    hpg = H // G
    B_h = jnp.repeat(Bc, hpg, axis=1)                  # (B, H, N)
    C_h = jnp.repeat(Cc, hpg, axis=1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                            # (B, H)
    h = cache["state"]                                 # (B, H, P, N) f32
    upd = (dt[..., None, None] * xc.astype(jnp.float32)[..., None]
           * B_h.astype(jnp.float32)[:, :, None, :])
    h_new = decay[..., None, None] * h + upd
    y = jnp.einsum("bhpn,bhn->bhp", h_new, C_h.astype(jnp.float32))
    y = y.astype(x.dtype) + xc * p["D"].astype(x.dtype)[None, :, None]

    g = y.reshape(B, 1, H * P) * jax.nn.silu(
        z.reshape(B, 1, H * P).astype(jnp.float32)).astype(x.dtype)
    g = rmsnorm({"scale": p["norm"]}, g)
    out = jnp.einsum("blhp,hpd->bld", g.reshape(B, 1, H, P),
                     p["out"].astype(x.dtype))
    return out, {"conv": new_conv.astype(cache["conv"].dtype),
                 "state": h_new}
