"""Training step + loop.

``make_train_step`` builds the pure (params, opt_state, batch) ->
(params, opt_state, metrics) function that launch/dryrun.py lowers on the
production mesh and launch/train.py jits for real runs:

  * microbatch gradient accumulation via ``lax.scan`` (activation memory
    / global-batch decoupling) — accumulate in fp32;
  * remat policy comes from the model config (scan-body checkpoint);
  * global-norm clip + AdamW (optimizer.py);
  * NaN-guard: non-finite loss/grad-norm produce a ``skipped`` flag and an
    identity update instead of poisoning the params (fault.py's rollback
    handles repeated failures).

The Python-side ``TrainLoop`` adds checkpointing, fault recovery and
throughput accounting around the pure step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import loss_fn
from repro.train import optimizer as opt_mod
from repro.train.optimizer import AdamState, OptimizerConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    nan_guard: bool = True
    opt: OptimizerConfig = OptimizerConfig()


def make_train_step(cfg, tc: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (p, s, metrics)."""

    def compute_grads(params, batch):
        if tc.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
            return grads, metrics

        m = tc.microbatches

        def split(x):
            b = x.shape[0]
            assert b % m == 0, (b, m)
            return x.reshape(m, b // m, *x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, mb, cfg), has_aux=True)(params)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / m, acc, grads)
            return (acc, loss_acc + loss / m), metrics

        (grads, loss), ms = jax.lax.scan(body, (zero, 0.0), mbs)
        metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        metrics["loss"] = loss
        return grads, metrics

    def train_step(params, opt_state: AdamState, batch):
        grads, metrics = compute_grads(params, batch)
        new_params, new_state, om = opt_mod.apply(
            tc.opt, params, opt_state, grads)
        metrics.update(om)

        if tc.nan_guard:
            ok = jnp.isfinite(metrics["loss"]) & jnp.isfinite(om["grad_norm"])
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, params)
            new_state = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_state, opt_state)
            metrics["skipped"] = (~ok).astype(jnp.int32)
        return new_params, new_state, metrics

    return train_step


@dataclasses.dataclass
class TrainLoop:
    """Python-side driver: checkpoint cadence, fault policy, throughput."""
    cfg: Any
    tc: TrainConfig
    step_fn: Callable
    checkpointer: Any = None       # train.checkpoint.Checkpointer
    fault: Any = None              # train.fault.FaultPolicy
    log_every: int = 10

    def run(self, params, opt_state, batches, *, start_step: int = 0,
            callback: Callable | None = None):
        history = []
        step = start_step
        t0 = time.time()
        for batch in batches:
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch)
            if self.fault is not None:
                params, opt_state, rolled = self.fault.after_step(
                    step, params, opt_state, metrics)
                if rolled:
                    step = self.fault.last_good_step
                    continue
            step += 1
            if self.checkpointer is not None:
                self.checkpointer.maybe_save(step, params, opt_state)
            if step % self.log_every == 0 or not history:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["steps_per_s"] = (
                    (step - start_step) / max(time.time() - t0, 1e-9))
                history.append(m)
                if callback:
                    callback(m)
        if self.checkpointer is not None:
            self.checkpointer.save(step, params, opt_state, wait=True)
        return params, opt_state, history
