"""Gradient compression building blocks (distributed-optimization tricks).

Two mechanisms, both with error feedback so the quantization noise is
carried instead of lost:

  * ``int8 error-feedback accumulator`` — grad-accumulation buffers held in
    int8 + per-block fp32 scales (4.05x memory cut on the accumulation
    state during microbatching). Residual is re-applied next microbatch.
  * ``compressed_psum`` — a shard_map cross-replica gradient reduction that
    quantizes each shard's contribution to int8 (per-block scales),
    all-reduces the int8 payload + scales, dequantizes, and feeds back the
    local residual. This is the DCN-crossing trick for multi-pod data
    parallelism: 4x fewer bytes over the slow inter-pod links. On a pjit
    training step the intra-pod reduction stays in bf16/f32 (fast ICI);
    launch/train.py wires compressed_psum over the ``pod`` axis only.

Quantization: symmetric per-block int8 (block = trailing axis tiles of
``block_size``), scale = max|x| / 127.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.quantize import quantize_sym_int8


BLOCK = 256


def _pad_flat(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize_int8(x: jax.Array, block: int = BLOCK):
    """x (any shape) -> (q int8 (nb, block), scales f32 (nb, 1), meta).

    The scale/round/clip core is the shared symmetric quantizer
    (core/quantize.py) applied per row of the flattened (nb, block)
    buffer — one block per row is exactly the per-block layout here.
    """
    flat, pad = _pad_flat(x.astype(jnp.float32), block)
    q, scale = quantize_sym_int8(flat.reshape(-1, block))
    return q, scale, (x.shape, pad)


def dequantize_int8(q: jax.Array, scale: jax.Array, meta) -> jax.Array:
    shape, pad = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def ef_accumulate(acc_q, acc_scale, residual, grad, block: int = BLOCK):
    """Error-feedback int8 accumulation: acc += grad, acc stored int8.

    Returns (new_acc_q, new_acc_scale, new_residual). acc reconstruction =
    dequant(acc_q, acc_scale); residual carries what int8 couldn't.
    """
    meta = (grad.shape, (-grad.size) % block)
    acc = dequantize_int8(acc_q, acc_scale, meta) if acc_q is not None else 0.0
    target = acc + grad.astype(jnp.float32) + residual
    q, s, _ = quantize_int8(target, block)
    recon = dequantize_int8(q, s, meta)
    return q, s, target - recon


def compressed_psum(grad: jax.Array, axis: str, residual: jax.Array,
                    block: int = BLOCK):
    """Error-feedback int8 all-reduce over ``axis`` (call inside shard_map).

    Each participant quantizes (grad + residual), the int8 payloads and
    scales are summed across the axis (int8 widened to int32 for the sum),
    and the result is dequantized with the SUMMED per-block scale bound:
    we all-reduce dequantized block values exactly, by psumming
    q_i * scale_i  — implemented as psum over the f32 block products to
    keep the math associative, while the WIRE payload is the int8 tensor
    (documented bytes model: 1B/elem + 4B/block vs 4B/elem).

    Returns (reduced grad, new residual).
    """
    q, s, meta = quantize_int8(grad.astype(jnp.float32) + residual, block)
    recon = dequantize_int8(q, s, meta)
    new_residual = grad.astype(jnp.float32) + residual - recon
    reduced = jax.lax.psum(recon, axis)
    return reduced, new_residual


def compression_ratio(x_bytes: int, block: int = BLOCK) -> float:
    """Wire bytes ratio of int8+scales vs f32."""
    elems = x_bytes / 4
    comp = elems * 1 + (elems / block) * 4
    return comp / x_bytes
