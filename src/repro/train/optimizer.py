"""AdamW (pure JAX, pytree-native) with global-norm clipping, LR schedules,
and ZeRO-style state sharding for free: optimizer moments are created with
``jax.eval_shape`` over the params tree, so under pjit they inherit the
params' (FSDP x TP) shardings — every moment is 2-D-sharded exactly like
its weight, which IS the ZeRO-3 layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"       # cosine | linear | constant


class AdamState(NamedTuple):
    step: jax.Array                # () int32
    m: Any                         # pytree like params
    v: Any


def init(params) -> AdamState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree.map(jnp.zeros_like, params))


def abstract_init(params_abs) -> AdamState:
    """ShapeDtypeStruct version (dry-run)."""
    z = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                     params_abs)
    return AdamState(jax.ShapeDtypeStruct((), jnp.int32), z, z)


def learning_rate(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 1.0 - (1.0 - cfg.min_lr_ratio) * t
    else:
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


def apply(
    cfg: OptimizerConfig,
    params,
    state: AdamState,
    grads,
) -> tuple[Any, AdamState, dict]:
    """One AdamW update. Returns (params, state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = learning_rate(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v, g):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_g = jax.tree.leaves(grads)
    out = [upd(p, m, v, g) for p, m, v, g in
           zip(flat_p, flat_m, flat_v, flat_g)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamState(step, new_m, new_v), metrics
