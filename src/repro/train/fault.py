"""Fault tolerance policy layer: NaN rollback, restart budget, straggler
watchdog, elastic re-meshing.

The pure train step already refuses to apply a non-finite update
(loop.py nan_guard); this layer handles the *persistent* failure modes a
1000-node fleet sees:

  * ``FaultPolicy`` — counts consecutive skipped steps; after
    ``max_consecutive_skips`` it rolls params/opt back to the last good
    checkpoint and advances the data stream past the poisonous batch.
    After ``max_restarts`` total rollbacks it raises (page the operator).
  * ``StragglerWatchdog`` — EWMA of step wall-time; steps slower than
    ``threshold x`` the EWMA are logged/counted (on real fleets this feeds
    the scheduler's hot-spare swap; here it exposes the hook + metrics,
    and the test suite exercises it with injected delays).
  * ``elastic_mesh`` — given the devices that are ACTUALLY alive, builds
    the largest (data, model) mesh preserving the model axis, so losing a
    slice re-forms a smaller data axis; checkpoint.load reshards into it
    (shard-count-agnostic layout).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np


@dataclasses.dataclass
class FaultPolicy:
    checkpointer: Any                 # train.checkpoint.Checkpointer
    max_consecutive_skips: int = 3
    max_restarts: int = 10
    last_good_step: int = 0
    _consecutive: int = 0
    _restarts: int = 0

    def after_step(self, step: int, params, opt_state, metrics):
        """Returns (params, opt_state, rolled_back: bool)."""
        skipped = bool(metrics.get("skipped", 0))
        if not skipped:
            self._consecutive = 0
            self.last_good_step = step + 1
            return params, opt_state, False
        self._consecutive += 1
        if self._consecutive < self.max_consecutive_skips:
            return params, opt_state, False
        # persistent failure: roll back
        self._restarts += 1
        self._consecutive = 0
        if self._restarts > self.max_restarts:
            raise RuntimeError(
                f"training unstable: {self._restarts} rollbacks "
                f"(step {step}); refusing to continue")
        ck_step = self.checkpointer.latest_step()
        if ck_step is None:
            raise RuntimeError("NaN streak before any checkpoint exists")
        self.checkpointer.wait()
        _, tree = self.checkpointer.load(
            ck_step, like={"params": params, "opt_state": opt_state})
        self.last_good_step = ck_step
        return tree["params"], tree["opt_state"], True


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.0            # x EWMA
    alpha: float = 0.1
    ewma: float | None = None
    stragglers: int = 0
    events: list = dataclasses.field(default_factory=list)
    _t_last: float | None = None

    def step_start(self):
        self._t_last = time.time()

    def step_end(self, step: int) -> bool:
        dt = time.time() - self._t_last
        slow = False
        if self.ewma is not None and dt > self.threshold * self.ewma:
            self.stragglers += 1
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
            slow = True
            # a straggler should not poison the baseline
            self.ewma = self.ewma * (1 - self.alpha / 4) + dt * self.alpha / 4
        else:
            self.ewma = dt if self.ewma is None else (
                self.ewma * (1 - self.alpha) + dt * self.alpha)
        return slow


def elastic_mesh(devices=None, *, model_axis: int = 16,
                 axis_names=("data", "model")):
    """Largest (data, model) mesh from the live device set, preserving the
    model axis (param layout survives); data axis shrinks to fit."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    model = min(model_axis, n)
    while n % model:
        model -= 1
    data = n // model
    arr = np.array(devices[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(arr, axis_names)
