from repro.train.loop import TrainConfig, TrainLoop, make_train_step
from repro.train.optimizer import AdamState, OptimizerConfig

__all__ = [
    "AdamState",
    "OptimizerConfig",
    "TrainConfig",
    "TrainLoop",
    "make_train_step",
]
