"""Sharded checkpointing: per-host npz shards + manifest, atomic rename,
async background writes, automatic resume.

Layout (step 1200, 2 hosts):
    ckpt_dir/
      step_00001200/
        manifest.json            # step, config hash, leaf index, done flag
        host_00000.npz           # this host's addressable shard data
        host_00001.npz
      latest -> step_00001200    # symlink, updated after manifest commit

Crash safety: writes go to ``step_X.tmp`` and are renamed into place only
after every file is flushed; a partial directory is never visible under
its final name, and ``latest_step`` ignores unrenamed temp dirs. Async
mode hands the (host-local, already-device-fetched) arrays to a writer
thread so the train loop never blocks on disk.

Shard-count agnosticism: leaves are saved as the host's addressable
shards + their index coordinates; ``load`` reassembles the GLOBAL array
then reshards to whatever mesh the restarting job has — elastic restarts
with a different device count (train/fault.py) load the same checkpoint.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((name, leaf))
    return out


def config_hash(cfg) -> str:
    return hashlib.sha1(repr(cfg).encode()).hexdigest()[:12]


@dataclasses.dataclass
class Checkpointer:
    directory: str
    every: int = 100
    keep: int = 3
    async_write: bool = True
    cfg_hash: str = ""

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------------ save
    def maybe_save(self, step: int, params, opt_state) -> bool:
        if self.every and step % self.every == 0:
            self.save(step, params, opt_state)
            return True
        return False

    def save(self, step: int, params, opt_state, *, wait: bool = False):
        self.wait()                     # one outstanding write at a time
        if self._error:
            raise self._error
        tree = {"params": params, "opt_state": opt_state}
        # fetch addressable data on the caller thread (device buffers are
        # not thread-safe to donate); numpy copies go to the writer.
        host_data = {}
        for name, leaf in _leaf_paths(tree):
            if isinstance(leaf, jax.Array) and len(leaf.sharding.device_set) > 1:
                shards = [
                    (s.index, np.asarray(s.data))
                    for s in leaf.addressable_shards
                ]
                host_data[name] = ("sharded", leaf.shape, str(leaf.dtype),
                                   shards)
            else:
                host_data[name] = ("full", None, None, np.asarray(leaf))

        def write():
            tmp = os.path.join(self.directory, f"step_{step:08d}.tmp")
            final = os.path.join(self.directory, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            payload = {}
            index = {}
            for name, (kind, shape, dtype, data) in host_data.items():
                if kind == "full":
                    payload[name] = data
                    index[name] = {"kind": "full"}
                else:
                    for i, (idx, arr) in enumerate(data):
                        payload[f"{name}@@{i}"] = arr
                    index[name] = {
                        "kind": "sharded",
                        "shape": list(shape),
                        "dtype": dtype,
                        "slices": [
                            [[sl.start, sl.stop] for sl in idx]
                            for idx, _ in data
                        ],
                    }
            host = jax.process_index()
            np.savez(os.path.join(tmp, f"host_{host:05d}.npz"), **payload)
            manifest = {
                "step": step,
                "cfg_hash": self.cfg_hash,
                "n_hosts": jax.process_count(),
                "index": index,
                "time": time.time(),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, final)        # atomic commit
            link = os.path.join(self.directory, "latest")
            tmp_link = link + ".tmp"
            try:
                if os.path.lexists(tmp_link):
                    os.unlink(tmp_link)
                os.symlink(os.path.basename(final), tmp_link)
                os.replace(tmp_link, link)
            except OSError:
                pass
            self._gc()

        if self.async_write and not wait:
            def run():
                try:
                    write()
                except Exception as e:        # surfaced on next save/wait
                    self._error = e
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self._list_steps())
        for s in steps[: -self.keep] if self.keep else []:
            import shutil
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True)

    # ------------------------------------------------------------------ load
    def _list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                man = os.path.join(self.directory, d, "manifest.json")
                if os.path.exists(man):
                    out.append(int(d.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self._list_steps()
        return max(steps) if steps else None

    def load(self, step: int | None = None, *, like=None, shardings=None):
        """Load {'params','opt_state'}; ``like`` (a pytree of arrays or
        ShapeDtypeStructs) provides the structure; ``shardings`` (same
        structure) places leaves on the current mesh (elastic reshard)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        buf: dict[str, np.ndarray] = {}
        for fn in sorted(os.listdir(d)):
            if fn.endswith(".npz"):
                with np.load(os.path.join(d, fn)) as z:
                    for k in z.files:
                        buf[k] = z[k]
        full: dict[str, np.ndarray] = {}
        for name, info in manifest["index"].items():
            if info["kind"] == "full":
                full[name] = buf[name]
            else:
                arr = np.zeros(info["shape"], dtype=info["dtype"])
                i = 0
                while f"{name}@@{i}" in buf:
                    sl = tuple(
                        slice(a, b) for a, b in info["slices"][i])
                    arr[sl] = buf[f"{name}@@{i}"]
                    i += 1
                full[name] = arr

        if like is None:
            return step, full
        tree = {"params": like[0], "opt_state": like[1]} \
            if isinstance(like, tuple) else like
        names = [n for n, _ in _leaf_paths(tree)]
        leaves = [full[n] for n in names]
        if shardings is not None:
            sh_tree = {"params": shardings[0], "opt_state": shardings[1]} \
                if isinstance(shardings, tuple) else shardings
            sh = [s for _, s in _leaf_paths(sh_tree)]
            leaves = [jax.device_put(l, s) for l, s in zip(leaves, sh)]
        else:
            leaves = [jnp.asarray(l) for l in leaves]
        _, treedef = jax.tree_util.tree_flatten(tree)
        out = jax.tree_util.tree_unflatten(treedef, leaves)
        return step, out
