"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground-truth implementations the kernels are validated
against (tests sweep shapes/dtypes and ``assert_allclose`` kernel vs ref).
They are also the CPU fallback used when running on a non-TPU backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Pairwise squared-l2 distance (paper §3.3, "blocked")
# ---------------------------------------------------------------------------

def pairwise_sq_l2_diff(a: jax.Array, b: jax.Array) -> jax.Array:
    """Direct diff-square-sum form — the paper's AVX FMA ladder.

    a: (M, D), b: (N, D) -> (M, N) float32. Numerically the most faithful
    form (no cancellation); O(M*N*D) loads without blocking.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    diff = a[:, None, :] - b[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def pairwise_sq_l2(a: jax.Array, b: jax.Array) -> jax.Array:
    """Norm-expansion form: ||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b^T.

    This is the MXU-friendly form the Pallas kernel implements. fp32
    accumulation, clamped at zero (cancellation guard).
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a2 = jnp.sum(a * a, axis=-1)
    b2 = jnp.sum(b * b, axis=-1)
    ab = a @ b.T
    out = a2[:, None] + b2[None, :] - 2.0 * ab
    return jnp.maximum(out, 0.0)


def centroid_assign(
    q: jax.Array,      # (m, dp) rows to assign
    q2: jax.Array,     # (m,) cached squared norms
    cent: jax.Array,   # (c, dp) centroids
    c2: jax.Array,     # (c,) centroid squared norms
    t: int,            # top-t nearest centroids returned per row
) -> tuple[jax.Array, jax.Array]:
    """Top-``t`` nearest centroids per row: one norm-expansion distance
    tile + partial top-k. Returns (dist (m, t) ascending, idx (m, t)).
    Oracle for the router's centroid-assignment dispatch (kernels/ops.py
    routes the pallas/interpret backends through the blocked l2 kernel +
    the same top-k reduction)."""
    d = jnp.maximum(
        q2[:, None] + c2[None, :]
        - 2.0 * q.astype(jnp.float32) @ cent.astype(jnp.float32).T,
        0.0,
    )
    neg, idx = jax.lax.top_k(-d, t)
    return jnp.maximum(-neg, 0.0), idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fused local join (paper §3.3 + §2 fused) — oracles for kernels/knn_join.py
# ---------------------------------------------------------------------------

_BIG = float(jnp.finfo(jnp.float32).max)


def _join_ok(ids: jax.Array, cn: int) -> jax.Array:
    """Join validity, shared by every join-distance oracle: at least one
    "new" endpoint, distinct slots, both occupied, distinct node ids."""
    c = ids.shape[1]
    slot = jnp.arange(c)
    ok = (slot[:, None] < cn) | (slot[None, :] < cn)
    ok &= slot[:, None] != slot[None, :]
    ok = ok[None]
    ok &= (ids[:, :, None] >= 0) & (ids[:, None, :] >= 0)
    ok &= ids[:, :, None] != ids[:, None, :]
    return ok


def knn_join_dists(
    xg: jax.Array,     # (n, C, dp) gathered candidate features
    x2g: jax.Array,    # (n, C) cached squared norms (0 on invalid slots)
    ids: jax.Array,    # (n, C) candidate node ids, -1 = invalid slot
    cn: int,           # width of the "new" candidate prefix
) -> tuple[jax.Array, jax.Array]:
    """Local-join pair-distance tensor: per row, squared-l2 between every
    candidate pair with at least one "new" endpoint, distinct slots and
    distinct ids; invalid pairs are +inf. Returns (dists (n, C, C),
    evals (n,) int32 — valid unordered pairs). Oracle for
    knn_join_dists_blocked."""
    ab = jnp.einsum(
        "ncd,ned->nce", xg.astype(jnp.float32), xg.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    dd = x2g[:, :, None] + x2g[:, None, :] - 2.0 * ab
    ok = _join_ok(ids, cn)
    out = jnp.where(ok, jnp.maximum(dd, 0.0), jnp.inf)
    evals = jnp.sum(ok.astype(jnp.int32), axis=(1, 2)) // 2
    return out, evals


def knn_join_select(
    gd: jax.Array,     # (n, W) gathered incoming pair distances (+inf pad)
    gi: jax.Array,     # (n, W) their candidate ids (-1 pad)
    kth: jax.Array,    # (n,) receiver k-th distance (prefilter threshold)
    c: int,            # output width (merge buffer size)
) -> tuple[jax.Array, jax.Array]:
    """Receiver-side prefilter + best-c selection: entries with
    ``gd < kth`` survive; the c smallest (stable on ties) come back as
    (dist (n, c) ascending, idx (n, c)) with (+inf, -1) fill. Oracle for
    knn_join_select_blocked."""
    w = gd.shape[1]
    pool = jnp.where((gi >= 0) & (gd < kth[:, None]), gd, _BIG)
    if c > w:
        pool = jnp.pad(pool, ((0, 0), (0, c - w)), constant_values=_BIG)
        gi = jnp.pad(gi, ((0, 0), (0, c - w)), constant_values=-1)
    neg, pos = jax.lax.top_k(-pool, c)
    d = -neg
    i = jnp.take_along_axis(gi, pos, axis=1)
    return (
        jnp.where(d < _BIG, d, jnp.inf),
        jnp.where(d < _BIG, i, -1),
    )


# ---------------------------------------------------------------------------
# Fused serving search (query-time §3.3) — oracle for kernels/knn_search.py
# ---------------------------------------------------------------------------

def knn_search_dists(
    q: jax.Array,      # (nq, dp) query block features
    q2: jax.Array,     # (nq,) hoisted query squared norms
    cg: jax.Array,     # (nq, W, dp) gathered candidate features
    c2g: jax.Array,    # (nq, W) cached candidate squared norms
    ids: jax.Array,    # (nq, W) candidate ids, -1 = invalid (incl. dead)
) -> jax.Array:
    """Query-time candidate distance tile: per query, squared-l2 to each of
    its W gathered candidates; invalid candidates (id -1 — unoccupied
    neighbor slots and tombstoned rows alike) come out +inf. Oracle for
    knn_search_dists_blocked."""
    ab = jnp.einsum(
        "qd,qwd->qw", q.astype(jnp.float32), cg.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    dd = q2[:, None] + c2g - 2.0 * ab
    return jnp.where(ids >= 0, jnp.maximum(dd, 0.0), jnp.inf)


# ---------------------------------------------------------------------------
# Quantized scoring tiles (two-stage distance path) — oracles for
# kernels/l2_quant.py. The int8 cross terms accumulate in fp32 here (the
# fast CPU path: the products are integers, exact in fp32 while the
# running sum stays under 2^24 — dp <= 1040, every shipped dim), which is
# bit-identical to the kernels' int32 MXU accumulation in that regime.
# ---------------------------------------------------------------------------

def knn_search_dists_q8(
    qq: jax.Array,     # (nq, dp) int8 query rows
    qscale: jax.Array,  # (nq,) query dequant scales
    q2: jax.Array,     # (nq,) quantized-query squared norms
    cq: jax.Array,     # (nq, W, dp) int8 gathered candidate rows
    cscale: jax.Array,  # (nq, W) candidate dequant scales
    c2g: jax.Array,    # (nq, W) cached quantized-candidate squared norms
    ids: jax.Array,    # (nq, W) candidate ids, -1 = invalid (incl. dead)
) -> jax.Array:
    """int8 query-time candidate distance tile with the dequant scales
    and norm expansion in the epilogue. Oracle for
    knn_search_dists_q8_blocked."""
    ab = jnp.einsum(
        "qd,qwd->qw", qq.astype(jnp.float32), cq.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    dd = q2[:, None] + c2g - 2.0 * (qscale[:, None] * cscale) * ab
    return jnp.where(ids >= 0, jnp.maximum(dd, 0.0), jnp.inf)


def knn_search_dists_bf16(
    q: jax.Array,      # (nq, dp) bf16 query rows
    q2: jax.Array,     # (nq,) bf16-rounded-query squared norms (f32)
    cg: jax.Array,     # (nq, W, dp) bf16 gathered candidate rows
    c2g: jax.Array,    # (nq, W) cached bf16-candidate squared norms
    ids: jax.Array,    # (nq, W) candidate ids, -1 = invalid (incl. dead)
) -> jax.Array:
    """bf16 query-time candidate distance tile, fp32 accumulation: the
    fp32 oracle applied to bf16-rounded rows (the oracle upcasts its
    operands anyway — only the kernel's MXU operand dtype differs).
    Oracle for knn_search_dists_bf16_blocked."""
    return knn_search_dists(q, q2, cg, c2g, ids)


def knn_join_dists_q8(
    xq: jax.Array,     # (n, C, dp) int8 gathered candidate rows
    xscale: jax.Array,  # (n, C) candidate dequant scales
    x2g: jax.Array,    # (n, C) cached quantized squared norms (0 invalid)
    ids: jax.Array,    # (n, C) candidate node ids, -1 = invalid slot
    cn: int,           # width of the "new" candidate prefix
) -> tuple[jax.Array, jax.Array]:
    """int8 local-join pair-distance tensor. Oracle for
    knn_join_dists_q8_blocked; same mask/evals contract as
    knn_join_dists."""
    xf = xq.astype(jnp.float32)
    ab = jnp.einsum("ncd,ned->nce", xf, xf,
                    preferred_element_type=jnp.float32)
    dd = x2g[:, :, None] + x2g[:, None, :] - 2.0 * (
        xscale[:, :, None] * xscale[:, None, :]
    ) * ab
    ok = _join_ok(ids, cn)
    out = jnp.where(ok, jnp.maximum(dd, 0.0), jnp.inf)
    return out, jnp.sum(ok.astype(jnp.int32), axis=(1, 2)) // 2


def knn_join_dists_bf16(
    xg: jax.Array,     # (n, C, dp) bf16 gathered candidate rows
    x2g: jax.Array,    # (n, C) cached bf16 squared norms (0 invalid)
    ids: jax.Array,    # (n, C) candidate node ids, -1 = invalid slot
    cn: int,
) -> tuple[jax.Array, jax.Array]:
    """bf16 local-join pair-distance tensor: the fp32 oracle applied to
    bf16-rounded rows (see knn_search_dists_bf16). Oracle for
    knn_join_dists_bf16_blocked."""
    return knn_join_dists(xg, x2g, ids, cn)


# ---------------------------------------------------------------------------
# Bounded top-k neighbor-list merge (paper §2 "calculate and update")
# ---------------------------------------------------------------------------

def knn_merge(
    cur_dist: jax.Array,   # (n, k) ascending
    cur_idx: jax.Array,    # (n, k)
    cand_dist: jax.Array,  # (n, c)
    cand_idx: jax.Array,   # (n, c)  (-1 = invalid slot)
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Merge candidates into sorted k-NN lists, deduplicating by id.

    Returns (new_dist, new_idx, updated) where ``updated`` is the per-row
    count of accepted candidates (the NN-Descent convergence counter).
    """
    n, k = cur_dist.shape
    # Invalidate candidates that already sit in the row's neighbor list or
    # that duplicate an earlier candidate in the same row.
    dup_graph = (cand_idx[:, :, None] == cur_idx[:, None, :]).any(-1)
    c = cand_idx.shape[1]
    dup_self = jnp.zeros_like(dup_graph)
    eq = cand_idx[:, :, None] == cand_idx[:, None, :]
    earlier = jnp.tril(jnp.ones((c, c), dtype=bool), k=-1)[None]
    dup_self = (eq & earlier).any(-1)
    invalid = dup_graph | dup_self | (cand_idx < 0)
    cand_dist = jnp.where(invalid, jnp.inf, cand_dist)

    all_dist = jnp.concatenate([cur_dist, cand_dist], axis=1)
    all_idx = jnp.concatenate([cur_idx, cand_idx], axis=1)
    order = jnp.argsort(all_dist, axis=1, stable=True)
    new_dist = jnp.take_along_axis(all_dist, order[:, :k], axis=1)
    new_idx = jnp.take_along_axis(all_idx, order[:, :k], axis=1)
    # a candidate was accepted iff it landed in the first k slots
    accepted = order[:, :k] >= k
    updated = jnp.sum(accepted & jnp.isfinite(new_dist), axis=1)
    return new_dist, new_idx, updated


def knn_compact(
    cur_dist: jax.Array,   # (n, k) ascending, +inf = empty
    cur_idx: jax.Array,    # (n, k), -1 = empty
    drop: jax.Array,       # (n, k) bool — entries to remove
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Drop masked entries from sorted lists; survivors stay sorted and
    packed to the front, freed slots become (inf, -1). Returns
    (dist, idx, removed). Oracle for knn_compact_blocked."""
    n, k = cur_dist.shape
    valid = cur_idx >= 0
    removed = jnp.sum(drop & valid, axis=1).astype(jnp.int32)
    masked = jnp.where(drop | ~valid, jnp.inf, cur_dist)
    order = jnp.argsort(masked, axis=1, stable=True)
    new_dist = jnp.take_along_axis(masked, order, axis=1)
    new_idx = jnp.where(
        jnp.isfinite(new_dist),
        jnp.take_along_axis(cur_idx, order, axis=1),
        -1,
    )
    return new_dist, new_idx, removed


# ---------------------------------------------------------------------------
# Frontier (gather/scatter) row dispatch — the online subsystem's chunked
# update primitives: apply merge/compact to an explicit compacted set of
# row ids instead of the whole store, so update cost scales with the
# frontier size (core/online.py). ``rows`` is a padded id buffer (-1 =
# padding slot, ids must be unique); non-listed rows pass through.
# ---------------------------------------------------------------------------

def knn_merge_rows(
    cur_dist: jax.Array,   # (n, k) ascending
    cur_idx: jax.Array,    # (n, k)
    rows: jax.Array,       # (f,) unique row ids, -1 = padding
    cand_dist: jax.Array,  # (f, c)
    cand_idx: jax.Array,   # (f, c)  (-1 = invalid slot)
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Merge per-frontier-row candidates into the listed rows only.

    Returns (dist, idx, updated) with full (n, k) arrays — rows not in
    ``rows`` are untouched — and ``updated`` (f,) the per-frontier-row
    accepted count (0 on padding slots). Oracle for knn_merge_rows_blocked.
    """
    n, _ = cur_dist.shape
    ok = rows >= 0
    safe = jnp.where(ok, rows, 0)
    sub_d = cur_dist[safe]
    sub_i = cur_idx[safe]
    cand_idx = jnp.where(ok[:, None], cand_idx, -1)
    md, mi, upd = knn_merge(sub_d, sub_i, cand_dist, cand_idx)
    tgt = jnp.where(ok, rows, n)          # padding scatters out of bounds
    out_d = cur_dist.at[tgt].set(md, mode="drop")
    out_i = cur_idx.at[tgt].set(mi, mode="drop")
    return out_d, out_i, jnp.where(ok, upd, 0)


def knn_compact_rows(
    cur_dist: jax.Array,   # (n, k) ascending, +inf = empty
    cur_idx: jax.Array,    # (n, k), -1 = empty
    rows: jax.Array,       # (f,) unique row ids, -1 = padding
    drop: jax.Array,       # (f, k) bool — entries to remove, frontier-local
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Drop masked entries from the listed rows only.

    Returns (dist, idx, removed) with full (n, k) arrays and ``removed``
    (f,) per-frontier-row. Oracle for knn_compact_rows_blocked."""
    n, _ = cur_dist.shape
    ok = rows >= 0
    safe = jnp.where(ok, rows, 0)
    sub_d = cur_dist[safe]
    sub_i = cur_idx[safe]
    drop = drop & ok[:, None]
    cd, ci, removed = knn_compact(sub_d, sub_i, drop)
    tgt = jnp.where(ok, rows, n)
    out_d = cur_dist.at[tgt].set(cd, mode="drop")
    out_i = cur_idx.at[tgt].set(ci, mode="drop")
    return out_d, out_i, jnp.where(ok, removed, 0)


# ---------------------------------------------------------------------------
# Flash attention (blocked attention for the LM stack)
# ---------------------------------------------------------------------------

def attention(
    q: jax.Array,              # (B, Lq, H, Dh)
    k: jax.Array,              # (B, Lk, Hkv, Dh)
    v: jax.Array,              # (B, Lk, Hkv, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Reference multi-head attention with GQA, sliding window, softcap.

    q_offset: absolute position of q[0] (for decode: q_offset = cache_len).
    """
    B, Lq, H, Dh = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Lq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Lq, k.shape[1]), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)
