"""Public jit'd wrappers for the Pallas kernels with backend dispatch.

On TPU the Pallas kernels run compiled (interpret=False); everywhere else
(this container is CPU) the same kernel bodies execute under interpret=True
when explicitly requested, and by default we dispatch to the pure-jnp
oracles in ref.py, which are numerically identical and compile to efficient
HLO. Tests exercise the interpret=True path against the oracles across
shape/dtype sweeps.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.knn_join import (
    knn_join_dists_blocked,
    knn_join_select_blocked,
)
from repro.kernels.knn_merge import (
    knn_compact_blocked,
    knn_compact_rows_blocked,
    knn_merge_blocked,
    knn_merge_rows_blocked,
)
from repro.kernels.knn_search import knn_search_dists_blocked
from repro.kernels.l2_blocked import pairwise_sq_l2_blocked
from repro.kernels.l2_quant import (
    knn_join_dists_bf16_blocked,
    knn_join_dists_q8_blocked,
    knn_search_dists_bf16_blocked,
    knn_search_dists_q8_blocked,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pairwise_sq_l2(
    a: jax.Array,
    b: jax.Array,
    *,
    backend: str = "auto",   # auto | pallas | interpret | ref
    tm: int = 128,
    tn: int = 128,
    tk: int = 512,
) -> jax.Array:
    """Pairwise squared-l2 distances, (M, D) x (N, D) -> (M, N) f32."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return pairwise_sq_l2_blocked(a, b, tm=tm, tn=tn, tk=tk)
    if backend == "interpret":
        return pairwise_sq_l2_blocked(a, b, tm=tm, tn=tn, tk=tk, interpret=True)
    return ref.pairwise_sq_l2(a, b)


def centroid_assign(
    q: jax.Array,
    q2: jax.Array,
    cent: jax.Array,
    c2: jax.Array,
    *,
    t: int = 1,
    backend: str = "auto",
):
    """Router centroid assignment: top-``t`` nearest centroids per row,
    (m, dp) x (c, dp) -> (dist (m, t) ascending, idx (m, t)). The distance
    tile reuses the blocked pairwise-l2 kernel (pallas/interpret) or its
    norm-expansion oracle (ref); the partial top-k reduction is shared."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend in ("pallas", "interpret"):
        d = pairwise_sq_l2_blocked(q, cent, interpret=backend == "interpret")
        neg, idx = jax.lax.top_k(-d, t)
        import jax.numpy as _jnp
        return _jnp.maximum(-neg, 0.0), idx.astype(_jnp.int32)
    return ref.centroid_assign(q, q2, cent, c2, t)


def knn_join_dists(
    xg: jax.Array,
    x2g: jax.Array,
    ids: jax.Array,
    *,
    cn: int,
    backend: str = "auto",
):
    """Fused local-join pair distances: (n, C, dp) gathered candidate
    features -> ((n, C, C) masked sq-l2 tensor, (n,) valid-pair counts)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_join_dists_blocked(xg, x2g, ids, cn=cn)
    if backend == "interpret":
        return knn_join_dists_blocked(xg, x2g, ids, cn=cn, interpret=True)
    return ref.knn_join_dists(xg, x2g, ids, cn)


def knn_join_select(
    gd: jax.Array,
    gi: jax.Array,
    kth: jax.Array,
    *,
    c: int,
    backend: str = "auto",
):
    """Receiver-side prefilter + best-c selection of gathered join pairs."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_join_select_blocked(gd, gi, kth, c=c)
    if backend == "interpret":
        return knn_join_select_blocked(gd, gi, kth, c=c, interpret=True)
    return ref.knn_join_select(gd, gi, kth, c)


def knn_search_dists(
    q: jax.Array,
    q2: jax.Array,
    cg: jax.Array,
    c2g: jax.Array,
    ids: jax.Array,
    *,
    backend: str = "auto",
):
    """Fused serving search: blocked query-time candidate distance tile
    ((nq, W, dp) gathered candidate features -> (nq, W) masked sq-l2)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_search_dists_blocked(q, q2, cg, c2g, ids)
    if backend == "interpret":
        return knn_search_dists_blocked(q, q2, cg, c2g, ids, interpret=True)
    return ref.knn_search_dists(q, q2, cg, c2g, ids)


def knn_search_dists_q8(
    qq: jax.Array,
    qscale: jax.Array,
    q2: jax.Array,
    cq: jax.Array,
    cscale: jax.Array,
    c2g: jax.Array,
    ids: jax.Array,
    *,
    backend: str = "auto",
):
    """Quantized serving scoring tile (int8 rows + per-row fp32 scales):
    (nq, W, dp) int8 gathered candidates -> (nq, W) masked sq-l2 with the
    scale application and norm expansion fused into the epilogue."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_search_dists_q8_blocked(qq, qscale, q2, cq, cscale, c2g,
                                           ids)
    if backend == "interpret":
        return knn_search_dists_q8_blocked(qq, qscale, q2, cq, cscale, c2g,
                                           ids, interpret=True)
    return ref.knn_search_dists_q8(qq, qscale, q2, cq, cscale, c2g, ids)


def knn_search_dists_bf16(
    q: jax.Array,
    q2: jax.Array,
    cg: jax.Array,
    c2g: jax.Array,
    ids: jax.Array,
    *,
    backend: str = "auto",
):
    """bf16 serving scoring tile: same contract as knn_search_dists with
    bf16 operands fed to the MXU (fp32 accumulation)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_search_dists_bf16_blocked(q, q2, cg, c2g, ids)
    if backend == "interpret":
        return knn_search_dists_bf16_blocked(q, q2, cg, c2g, ids,
                                             interpret=True)
    return ref.knn_search_dists_bf16(q, q2, cg, c2g, ids)


def knn_join_dists_q8(
    xq: jax.Array,
    xscale: jax.Array,
    x2g: jax.Array,
    ids: jax.Array,
    *,
    cn: int,
    backend: str = "auto",
):
    """Quantized local-join scoring tensor (int8): (n, C, dp) int8
    gathered candidates -> ((n, C, C) masked sq-l2, (n,) valid-pair
    counts). Same mask/evals contract as knn_join_dists."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_join_dists_q8_blocked(xq, xscale, x2g, ids, cn=cn)
    if backend == "interpret":
        return knn_join_dists_q8_blocked(xq, xscale, x2g, ids, cn=cn,
                                         interpret=True)
    return ref.knn_join_dists_q8(xq, xscale, x2g, ids, cn)


def knn_join_dists_bf16(
    xg: jax.Array,
    x2g: jax.Array,
    ids: jax.Array,
    *,
    cn: int,
    backend: str = "auto",
):
    """bf16 local-join scoring tensor: same contract as knn_join_dists
    with bf16 operands fed to the MXU (fp32 accumulation)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_join_dists_bf16_blocked(xg, x2g, ids, cn=cn)
    if backend == "interpret":
        return knn_join_dists_bf16_blocked(xg, x2g, ids, cn=cn,
                                           interpret=True)
    return ref.knn_join_dists_bf16(xg, x2g, ids, cn)


def knn_merge(
    cur_dist: jax.Array,
    cur_idx: jax.Array,
    cand_dist: jax.Array,
    cand_idx: jax.Array,
    *,
    backend: str = "auto",
):
    """Merge candidates into sorted bounded k-NN lists (dedup by id)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_merge_blocked(cur_dist, cur_idx, cand_dist, cand_idx)
    if backend == "interpret":
        return knn_merge_blocked(
            cur_dist, cur_idx, cand_dist, cand_idx, interpret=True
        )
    return ref.knn_merge(cur_dist, cur_idx, cand_dist, cand_idx)


def knn_compact(
    cur_dist: jax.Array,
    cur_idx: jax.Array,
    drop: jax.Array,
    *,
    backend: str = "auto",
):
    """Drop masked entries from sorted bounded k-NN lists (tombstone purge)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_compact_blocked(cur_dist, cur_idx, drop)
    if backend == "interpret":
        return knn_compact_blocked(cur_dist, cur_idx, drop, interpret=True)
    return ref.knn_compact(cur_dist, cur_idx, drop)


def knn_merge_rows(
    cur_dist: jax.Array,
    cur_idx: jax.Array,
    rows: jax.Array,
    cand_dist: jax.Array,
    cand_idx: jax.Array,
    *,
    backend: str = "auto",
):
    """Frontier merge: candidates target ``rows`` only (gather -> blocked
    merge kernel over the padded chunk -> scatter). -1 rows are padding."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_merge_rows_blocked(cur_dist, cur_idx, rows, cand_dist,
                                      cand_idx)
    if backend == "interpret":
        return knn_merge_rows_blocked(
            cur_dist, cur_idx, rows, cand_dist, cand_idx, interpret=True
        )
    return ref.knn_merge_rows(cur_dist, cur_idx, rows, cand_dist, cand_idx)


def knn_compact_rows(
    cur_dist: jax.Array,
    cur_idx: jax.Array,
    rows: jax.Array,
    drop: jax.Array,
    *,
    backend: str = "auto",
):
    """Frontier compact: drop masked entries from ``rows`` only (gather ->
    blocked compact kernel over the padded chunk -> scatter)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return knn_compact_rows_blocked(cur_dist, cur_idx, rows, drop)
    if backend == "interpret":
        return knn_compact_rows_blocked(cur_dist, cur_idx, rows, drop,
                                        interpret=True)
    return ref.knn_compact_rows(cur_dist, cur_idx, rows, drop)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    backend: str = "auto",
) -> jax.Array:
    """Blocked attention. The model stack calls models.attention (chunked
    scan) for large shapes; this wrapper is the kernel-level entry point."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "ref"
    if backend == "pallas":
        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset,
        )
    if backend == "interpret":
        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, interpret=True,
            tq=min(128, q.shape[1]), tk=min(128, k.shape[1]),
        )
    return ref.attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset,
    )
