"""Fused local-join kernel family (paper §3.3 blocked evaluation fused with
§2 update routing) — the build hot path without the global pair sort.

NN-Descent's local join evaluates all new x new / new x old candidate pairs
per node and routes every evaluated pair to BOTH endpoints. The seed
implementation flattened the pairs into an O(n*C^2) (receiver, candidate,
dist) list and pushed it through a global ``jnp.lexsort`` before the merge
— the sort and its HBM round-trips dominated iteration time and dwarfed
the distance einsum (see benchmarks/bench_build.py).

The fused form keeps everything receiver-local, in two blocked kernels:

  * ``knn_join_dists_blocked`` — for a block of rows, the full candidate
    pair-distance tensor (C x C per row) is computed in VMEM via the
    norm-expansion MXU form, with the join validity mask (at least one
    endpoint "new", distinct slots, distinct ids, valid ids) folded into
    the epilogue: invalid pairs come out +inf, and the per-row count of
    valid unordered pairs (the paper's dist_evals counter) is emitted
    alongside.
  * ``knn_join_select_blocked`` — for a block of RECEIVER rows, the
    gathered incoming pair distances are prefiltered against the
    receiver's current k-th distance and reduced to the best ``c``
    (dist, idx) pairs by an in-kernel partial top-C (the same
    min-extraction selection network as kernels/knn_merge.py — VPU-native,
    no gathers). Output is O(rows * c) instead of O(rows * pairs).

Between the two kernels sits a single *incidence inversion* (one stable
argsort of the n*C candidate ids — ~30x fewer elements than the pair
list): each receiver learns which (row, slot) positions list it, gathers
its incoming distance rows from the pair tensor, and the select kernel
reduces them. Receivers are then contiguous rows, so the final merge is a
chunked block merge (core/heap.py ``merge_block``) with no sort at all.
The driver lives in core/nn_descent.py; ref.py holds the pure-jnp oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TB = 128    # rows per block, pair-distance kernel
DEFAULT_TR = 256    # rows per block, select kernel
_BIG = float(jnp.finfo(jnp.float32).max)


def _join_dists_kernel(xg_ref, x2_ref, ids_ref, od_ref, ev_ref, *, cn: int):
    """Pair-distance tensor for one row block: (TB, C, dp) gathered
    candidate features -> (TB, C, C) masked squared-l2 distances."""
    xg = xg_ref[...].astype(jnp.float32)     # (TB, C, dp)
    x2 = x2_ref[...]                          # (TB, C)
    ids = ids_ref[...]                        # (TB, C), -1 = invalid

    # cross terms on the MXU (batched over the row block), fp32 accumulation
    ab = jax.lax.dot_general(
        xg, xg, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                         # (TB, C, C)
    dd = x2[:, :, None] + x2[:, None, :] - 2.0 * ab

    c = ids.shape[1]
    slot_s = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)[None]
    slot_t = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)[None]
    # join validity: at least one endpoint from the "new" pool (old x old
    # pairs are never evaluated — NN-Descent incremental search), distinct
    # slots, both slots occupied, distinct node ids
    ok = (slot_s < cn) | (slot_t < cn)
    ok &= slot_s != slot_t
    ok &= (ids[:, :, None] >= 0) & (ids[:, None, :] >= 0)
    ok &= ids[:, :, None] != ids[:, None, :]

    od_ref[...] = jnp.where(ok, jnp.maximum(dd, 0.0), jnp.inf)
    # each unordered pair appears at (s, t) and (t, s)
    ev_ref[...] = (
        jnp.sum(ok.astype(jnp.int32), axis=(1, 2)) // 2
    )[:, None]


@functools.partial(jax.jit, static_argnames=("cn", "tb", "interpret"))
def knn_join_dists_blocked(
    xg: jax.Array,     # (n, C, dp) gathered candidate features
    x2g: jax.Array,    # (n, C) cached squared norms (0 on invalid slots)
    ids: jax.Array,    # (n, C) candidate node ids, -1 = invalid slot
    *,
    cn: int,           # width of the "new" candidate prefix
    tb: int = DEFAULT_TB,
    interpret: bool = False,
):
    """Blocked local-join pair distances.

    Returns (dists (n, C, C) f32 with +inf on invalid pairs, evals (n,)
    int32 — the per-row count of valid unordered pairs).
    """
    n, c, dp = xg.shape
    npad = ((n + tb - 1) // tb) * tb
    pad = npad - n
    xg = jnp.pad(xg, ((0, pad), (0, 0), (0, 0)))
    x2g = jnp.pad(x2g, ((0, pad), (0, 0)))
    ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)

    kern = functools.partial(_join_dists_kernel, cn=cn)
    od, ev = pl.pallas_call(
        kern,
        grid=(npad // tb,),
        in_specs=[
            pl.BlockSpec((tb, c, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, c, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, c, c), jnp.float32),
            jax.ShapeDtypeStruct((npad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xg, x2g, ids)
    return od[:n], ev[:n, 0]


def _join_select_kernel(gd_ref, gi_ref, kth_ref, od_ref, oi_ref, *, c: int):
    """Receiver-side prefilter + partial top-c selection for one block of
    receiver rows. Same iota+select min-extraction network as
    kernels/knn_merge.py — every step stays VPU-native."""
    gd = gd_ref[...]                          # (TR, W)
    gi = gi_ref[...]                          # (TR, W)
    kth = kth_ref[...]                        # (TR, 1)

    # receiver-side prefilter: only pairs beating the receiver's current
    # k-th distance can change its list (paper §2 "update" short-circuit)
    pool = jnp.where((gi >= 0) & (gd < kth), gd, _BIG)
    lane = jax.lax.broadcasted_iota(jnp.int32, pool.shape, 1)
    out_d = []
    out_i = []
    for _t in range(c):
        amin = jnp.argmin(pool, axis=1)                     # (TR,)
        onehot = lane == amin[:, None]
        dmin = jnp.min(pool, axis=1)
        imin = jnp.sum(jnp.where(onehot, gi, 0), axis=1)
        out_d.append(jnp.where(dmin < _BIG, dmin, jnp.inf))
        out_i.append(jnp.where(dmin < _BIG, imin, -1))
        pool = jnp.where(onehot, _BIG, pool)
    od_ref[...] = jnp.stack(out_d, axis=1)
    oi_ref[...] = jnp.stack(out_i, axis=1)


@functools.partial(jax.jit, static_argnames=("c", "tr", "interpret"))
def knn_join_select_blocked(
    gd: jax.Array,     # (n, W) gathered incoming pair distances (+inf pad)
    gi: jax.Array,     # (n, W) their candidate ids (-1 pad)
    kth: jax.Array,    # (n,) receiver k-th distance (prefilter threshold)
    *,
    c: int,            # output width (merge buffer size)
    tr: int = DEFAULT_TR,
    interpret: bool = False,
):
    """Per-receiver best-c selection with the k-th-distance prefilter.

    Returns (dist (n, c) ascending with +inf fill, idx (n, c) with -1
    fill). Ties keep the lowest input position (stable, like the oracle).
    """
    n, w = gd.shape
    npad = ((n + tr - 1) // tr) * tr
    pad = npad - n
    gd = jnp.pad(gd, ((0, pad), (0, 0)), constant_values=jnp.inf)
    gi = jnp.pad(gi, ((0, pad), (0, 0)), constant_values=-1)
    kth = jnp.pad(kth, (0, pad))

    kern = functools.partial(_join_select_kernel, c=c)
    od, oi = pl.pallas_call(
        kern,
        grid=(npad // tr,),
        in_specs=[
            pl.BlockSpec((tr, w), lambda i: (i, 0)),
            pl.BlockSpec((tr, w), lambda i: (i, 0)),
            pl.BlockSpec((tr, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tr, c), lambda i: (i, 0)),
            pl.BlockSpec((tr, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, c), jnp.float32),
            jax.ShapeDtypeStruct((npad, c), jnp.int32),
        ],
        interpret=interpret,
    )(gd, gi, kth[:, None])
    return od[:n], oi[:n]
