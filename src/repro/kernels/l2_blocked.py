"""MXU-blocked pairwise squared-l2 distance kernel (paper §3.3, TPU form).

The paper's 5x5 AVX2 register blocking maximizes reuse of loaded vectors:
25 distances share 10 loads. On TPU the same insight maps to the 128x128
systolic MXU via the norm expansion

    ||a - b||^2 = ||a||^2 + ||b||^2 - 2 a.b^T

so the cross term is a tile matmul streamed through VMEM: a (TM, TK) tile of
A and a (TN, TK) tile of B produce TM*TN partial distances from TM+TN rows
loaded — reuse factor TM*TN/(TM+TN) ~ 64 at the default 128x128 tiles
(the paper's 25/10, scaled to the MXU).

The feature axis is the innermost (reduction) grid axis; squared norms are
accumulated alongside the dot product in VMEM scratch and fused into the
epilogue on the final reduction step, with a clamp at zero guarding the
cancellation the expansion form can suffer for near-identical points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TM = 128
DEFAULT_TN = 128
DEFAULT_TK = 512


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _l2_kernel(a_ref, b_ref, out_ref, acc_ref, a2_ref, b2_ref):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        a2_ref[...] = jnp.zeros_like(a2_ref)
        b2_ref[...] = jnp.zeros_like(b2_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    # cross term on the MXU, fp32 accumulation
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    a2_ref[...] += jnp.sum(a * a, axis=1, keepdims=True)
    b2_ref[...] += jnp.sum(b * b, axis=1, keepdims=True).T

    @pl.when(kk == pl.num_programs(2) - 1)
    def _epilogue():
        d2 = a2_ref[...] + b2_ref[...] - 2.0 * acc_ref[...]
        out_ref[...] = jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk", "interpret"))
def pairwise_sq_l2_blocked(
    a: jax.Array,
    b: jax.Array,
    *,
    tm: int = DEFAULT_TM,
    tn: int = DEFAULT_TN,
    tk: int = DEFAULT_TK,
    interpret: bool = False,
) -> jax.Array:
    """Blocked pairwise squared l2: a (M, D), b (N, D) -> (M, N) f32.

    M, N, D are padded to tile multiples internally. Zero feature padding is
    exact (changes neither norms nor dot products); padded rows are sliced
    away from the output.
    """
    m, d = a.shape
    n, _ = b.shape
    tk = min(tk, _ceil_to(d, 128))
    mp, np_, dp = _ceil_to(m, tm), _ceil_to(n, tn), _ceil_to(d, tk)
    a = jnp.pad(a, ((0, mp - m), (0, dp - d)))
    b = jnp.pad(b, ((0, np_ - n), (0, dp - d)))

    out = pl.pallas_call(
        _l2_kernel,
        grid=(mp // tm, np_ // tn, dp // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tn, tk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tm, tn), jnp.float32),
            pltpu.VMEM((tm, 1), jnp.float32),
            pltpu.VMEM((1, tn), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def vmem_bytes(tm: int, tn: int, tk: int, in_dtype=jnp.float32) -> int:
    """Static VMEM working-set estimate for a tile choice (for tuning)."""
    itemsize = jnp.dtype(in_dtype).itemsize
    tiles_in = (tm * tk + tn * tk) * itemsize
    scratch = (tm * tn + tm + tn) * 4
    out = tm * tn * 4
    # double-buffered inputs (pipeline) + scratch + output block
    return 2 * tiles_in + scratch + out
