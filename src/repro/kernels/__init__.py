# The paper's compute hot-spots as Pallas TPU kernels:
#   l2_blocked      — §3.3 blocked distance evaluations (MXU tiling)
#   l2_quant        — §3.3 at int8/bf16 density: quantized candidate-
#                     scoring tiles of the two-stage distance path
#                     (fp32 kernels below stay the exact re-rank stage)
#   knn_join        — §3.3+§2 fused local join (pair tensor + per-receiver
#                     prefilter/top-C selection, no global pair sort)
#   knn_search      — query-time §3.3: blocked multi-expansion candidate
#                     distance tile for the fused batched graph search
#   knn_merge       — §2 bounded neighbor-list update
#   flash_attention — LM-stack attention hotspot (blocked online softmax)
# ops.py = jit'd dispatch wrappers, ref.py = pure-jnp oracles.
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.knn_join import (
    knn_join_dists_blocked,
    knn_join_select_blocked,
)
from repro.kernels.knn_merge import (
    knn_compact_rows_blocked,
    knn_merge_blocked,
    knn_merge_rows_blocked,
)
from repro.kernels.knn_search import knn_search_dists_blocked
from repro.kernels.l2_blocked import pairwise_sq_l2_blocked
from repro.kernels.l2_quant import (
    knn_join_dists_bf16_blocked,
    knn_join_dists_q8_blocked,
    knn_search_dists_bf16_blocked,
    knn_search_dists_q8_blocked,
)

__all__ = [
    "ops",
    "ref",
    "flash_attention",
    "knn_compact_rows_blocked",
    "knn_join_dists_bf16_blocked",
    "knn_join_dists_blocked",
    "knn_join_dists_q8_blocked",
    "knn_join_select_blocked",
    "knn_merge_blocked",
    "knn_merge_rows_blocked",
    "knn_search_dists_bf16_blocked",
    "knn_search_dists_blocked",
    "knn_search_dists_q8_blocked",
    "pairwise_sq_l2_blocked",
]
