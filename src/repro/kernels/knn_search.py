"""Fused serving-search kernel (paper §3.3 blocked evaluation applied at
QUERY time) — the distance tile of the batched multi-expansion beam search.

The seed ``graph_search`` expanded ONE pool node per query per round and
evaluated its k neighbor distances with unblocked scalar row gathers plus a
per-round recomputation of the query norm. The fused search
(core/graph_search.py) instead expands the top-E unexpanded pool nodes of a
whole *block* of queries at once; the E·k gathered candidate rows per query
form a (q_block, E·k, dp) feature tile, and this kernel turns that tile
into the (q_block, E·k) candidate distance tile in one MXU pass:

    d(q, c) = ||q||^2 + ||c||^2 - 2 q·c

with both norms precomputed ONCE per batch (hoisted out of the round loop)
and the validity mask (invalid / dead candidates arrive as id -1) folded
into the epilogue: masked candidates come out +inf so the downstream
``knn_join_select`` top-C selection and bounded pool merge drop them for
free. The restriction to l2 is what makes this blocked form possible — the
source paper's core lesson, applied to the serving path.

That restriction is NOT a metric restriction: cosine and MIPS serving
(core/metric.py) reduce to squared l2 by transforming the INPUTS (rows
normalized; the MIPS augmented coordinate appended), so this kernel — and
every other kernel in the package — runs those metrics unchanged, with
identical tiles, masks and epilogues. Filtered queries ride the same id
mask: a row filtered out by a predicate reaches this kernel as id -1,
exactly like a tombstoned or padded candidate, and exits as +inf — zero
per-metric or per-filter kernel variants to maintain.

The gather itself (adjacency rows -> candidate ids -> feature rows) stays
outside the kernel in XLA, like every other kernel in this package
(cf. knn_join_dists_blocked's pre-gathered ``xg``): Pallas sees only
dense, layout-native tiles. ref.py holds the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TQ = 128    # query rows per block


def _search_dists_kernel(q_ref, q2_ref, cg_ref, c2_ref, ids_ref, od_ref):
    """Candidate distance tile for one query block: (TQ, dp) queries x
    (TQ, W, dp) gathered candidate features -> (TQ, W) masked sq-l2."""
    q = q_ref[...].astype(jnp.float32)        # (TQ, dp)
    q2 = q2_ref[...]                          # (TQ, 1)
    cg = cg_ref[...].astype(jnp.float32)      # (TQ, W, dp)
    c2 = c2_ref[...]                          # (TQ, W)
    ids = ids_ref[...]                        # (TQ, W), -1 = invalid/dead

    # cross terms on the MXU (batched over the query block), fp32 accum
    ab = jax.lax.dot_general(
        cg, q, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                         # (TQ, W)
    dd = q2 + c2 - 2.0 * ab
    od_ref[...] = jnp.where(ids >= 0, jnp.maximum(dd, 0.0), jnp.inf)


@functools.partial(jax.jit, static_argnames=("tq", "interpret"))
def knn_search_dists_blocked(
    q: jax.Array,      # (nq, dp) query block features
    q2: jax.Array,     # (nq,) hoisted query squared norms
    cg: jax.Array,     # (nq, W, dp) gathered candidate features
    c2g: jax.Array,    # (nq, W) cached candidate squared norms
    ids: jax.Array,    # (nq, W) candidate ids, -1 = invalid (incl. dead)
    *,
    tq: int = DEFAULT_TQ,
    interpret: bool = False,
):
    """Blocked query-time candidate distances.

    Returns dists (nq, W) f32 with +inf on invalid candidates. Validity
    (including tombstone/alive masking) is encoded by the caller as
    ``ids == -1`` and applied in the kernel epilogue.
    """
    nq, w, dp = cg.shape
    npad = ((nq + tq - 1) // tq) * tq
    pad = npad - nq
    q = jnp.pad(q, ((0, pad), (0, 0)))
    q2 = jnp.pad(q2, (0, pad))
    cg = jnp.pad(cg, ((0, pad), (0, 0), (0, 0)))
    c2g = jnp.pad(c2g, ((0, pad), (0, 0)))
    ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)

    od = pl.pallas_call(
        _search_dists_kernel,
        grid=(npad // tq,),
        in_specs=[
            pl.BlockSpec((tq, dp), lambda i: (i, 0)),
            pl.BlockSpec((tq, 1), lambda i: (i, 0)),
            pl.BlockSpec((tq, w, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((tq, w), lambda i: (i, 0)),
            pl.BlockSpec((tq, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tq, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, w), jnp.float32),
        interpret=interpret,
    )(q, q2[:, None], cg, c2g, ids)
    return od[:nq]
