"""Quantized blocked-distance kernels (paper §3.3 blocking at int8/bf16
density) — the candidate-SCORING stage of the two-stage distance path.

Shape-for-shape these are the mixed-precision twins of the fp32 tiles in
kernels/knn_search.py (serving: (TQ, W) candidate tile per query block)
and kernels/knn_join.py (build: (TB, C, C) pair tensor per row block).
What changes is the operand feed and the epilogue:

  * **int8** — rows arrive as symmetric per-row int8 with fp32 dequant
    scales (core/quantize.py). The cross terms run int8 x int8 on the MXU
    with int32 accumulation (`preferred_element_type=jnp.int32` — the
    native int8 systolic path, 4x the fp32 arithmetic density and 1/4 the
    HBM bytes per row), and the scale application is FUSED into the
    epilogue together with the norm expansion:

        d(a, b) = ||a||^2 + ||b||^2 - 2 * s_a * s_b * (a_i8 . b_i8)

    with ||.||^2 the cached norms of the QUANTIZED rows, so d(a, a) == 0
    exactly and near-identical rows cannot cancel below the clamp.

  * **bf16** — rows arrive as bf16 and feed the MXU directly (no scales,
    2x density / half the bytes); accumulation stays fp32.

Every output is fp32 with +inf on masked entries, exactly like the fp32
kernels, so the downstream select/merge machinery is unchanged — only
the scoring dtype moved. The fp32 kernels remain the RE-RANK stage: the
two-stage drivers (core/graph_search.py, core/nn_descent.py) re-score
surviving candidates with them before returning, so quantization shows
up as bounded candidate-recall noise, never as a wrong distance.

ref.py holds pure-jnp oracles. They accumulate the int8 cross terms in
fp32 (the fast CPU path: integer products are exact in fp32 while the
running sum stays under 2^24, i.e. for dp <= 1040 — every shipped dim),
bit-identical to the kernels' int32 accumulation in that regime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TQ = 128    # query rows per block (search tiles)
DEFAULT_TB = 128    # rows per block (join pair tensors)


# ---------------------------------------------------------------------------
# serving tiles: (TQ, W) candidate distances per query block
# ---------------------------------------------------------------------------


def _search_dists_q8_kernel(qq_ref, qs_ref, q2_ref, cq_ref, cs_ref, c2_ref,
                            ids_ref, od_ref):
    """int8 candidate tile: (TQ, dp) int8 queries x (TQ, W, dp) int8
    gathered candidates -> (TQ, W) masked sq-l2 via int32 MXU accumulation
    with the dequant scales applied in the epilogue."""
    qq = qq_ref[...]                          # (TQ, dp) int8
    qs = qs_ref[...]                          # (TQ, 1)
    q2 = q2_ref[...]                          # (TQ, 1)
    cq = cq_ref[...]                          # (TQ, W, dp) int8
    cs = cs_ref[...]                          # (TQ, W)
    c2 = c2_ref[...]                          # (TQ, W)
    ids = ids_ref[...]                        # (TQ, W), -1 = invalid/dead

    ab = jax.lax.dot_general(
        cq, qq, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )                                         # (TQ, W) i32
    dd = q2 + c2 - 2.0 * (qs * cs) * ab.astype(jnp.float32)
    od_ref[...] = jnp.where(ids >= 0, jnp.maximum(dd, 0.0), jnp.inf)


def _search_dists_bf16_kernel(q_ref, q2_ref, cg_ref, c2_ref, ids_ref, od_ref):
    """bf16 candidate tile: operands stay bf16 into the MXU, fp32 accum."""
    q = q_ref[...]                            # (TQ, dp) bf16
    q2 = q2_ref[...]                          # (TQ, 1)
    cg = cg_ref[...]                          # (TQ, W, dp) bf16
    c2 = c2_ref[...]                          # (TQ, W)
    ids = ids_ref[...]                        # (TQ, W)

    ab = jax.lax.dot_general(
        cg, q, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                         # (TQ, W)
    dd = q2 + c2 - 2.0 * ab
    od_ref[...] = jnp.where(ids >= 0, jnp.maximum(dd, 0.0), jnp.inf)


@functools.partial(jax.jit, static_argnames=("tq", "interpret"))
def knn_search_dists_q8_blocked(
    qq: jax.Array,     # (nq, dp) int8 query rows
    qscale: jax.Array,  # (nq,) query dequant scales
    q2: jax.Array,     # (nq,) quantized-query squared norms
    cq: jax.Array,     # (nq, W, dp) int8 gathered candidate rows
    cscale: jax.Array,  # (nq, W) candidate dequant scales
    c2g: jax.Array,    # (nq, W) cached quantized-candidate squared norms
    ids: jax.Array,    # (nq, W) candidate ids, -1 = invalid (incl. dead)
    *,
    tq: int = DEFAULT_TQ,
    interpret: bool = False,
):
    """Blocked int8 query-time candidate distances (see module docstring).
    Returns dists (nq, W) f32 with +inf on invalid candidates."""
    nq, w, dp = cq.shape
    npad = ((nq + tq - 1) // tq) * tq
    pad = npad - nq
    qq = jnp.pad(qq, ((0, pad), (0, 0)))
    qscale = jnp.pad(qscale, (0, pad))
    q2 = jnp.pad(q2, (0, pad))
    cq = jnp.pad(cq, ((0, pad), (0, 0), (0, 0)))
    cscale = jnp.pad(cscale, ((0, pad), (0, 0)))
    c2g = jnp.pad(c2g, ((0, pad), (0, 0)))
    ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)

    od = pl.pallas_call(
        _search_dists_q8_kernel,
        grid=(npad // tq,),
        in_specs=[
            pl.BlockSpec((tq, dp), lambda i: (i, 0)),
            pl.BlockSpec((tq, 1), lambda i: (i, 0)),
            pl.BlockSpec((tq, 1), lambda i: (i, 0)),
            pl.BlockSpec((tq, w, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((tq, w), lambda i: (i, 0)),
            pl.BlockSpec((tq, w), lambda i: (i, 0)),
            pl.BlockSpec((tq, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tq, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, w), jnp.float32),
        interpret=interpret,
    )(qq, qscale[:, None], q2[:, None], cq, cscale, c2g, ids)
    return od[:nq]


@functools.partial(jax.jit, static_argnames=("tq", "interpret"))
def knn_search_dists_bf16_blocked(
    q: jax.Array,      # (nq, dp) bf16 query rows
    q2: jax.Array,     # (nq,) bf16-rounded-query squared norms (f32)
    cg: jax.Array,     # (nq, W, dp) bf16 gathered candidate rows
    c2g: jax.Array,    # (nq, W) cached bf16-candidate squared norms
    ids: jax.Array,    # (nq, W) candidate ids, -1 = invalid (incl. dead)
    *,
    tq: int = DEFAULT_TQ,
    interpret: bool = False,
):
    """Blocked bf16 query-time candidate distances. Same contract as
    knn_search_dists_q8_blocked minus the scales."""
    nq, w, dp = cg.shape
    npad = ((nq + tq - 1) // tq) * tq
    pad = npad - nq
    q = jnp.pad(q, ((0, pad), (0, 0)))
    q2 = jnp.pad(q2, (0, pad))
    cg = jnp.pad(cg, ((0, pad), (0, 0), (0, 0)))
    c2g = jnp.pad(c2g, ((0, pad), (0, 0)))
    ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)

    od = pl.pallas_call(
        _search_dists_bf16_kernel,
        grid=(npad // tq,),
        in_specs=[
            pl.BlockSpec((tq, dp), lambda i: (i, 0)),
            pl.BlockSpec((tq, 1), lambda i: (i, 0)),
            pl.BlockSpec((tq, w, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((tq, w), lambda i: (i, 0)),
            pl.BlockSpec((tq, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tq, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, w), jnp.float32),
        interpret=interpret,
    )(q, q2[:, None], cg, c2g, ids)
    return od[:nq]


# ---------------------------------------------------------------------------
# build tiles: (TB, C, C) local-join pair tensors per row block
# ---------------------------------------------------------------------------


def _join_mask(ids: jax.Array, cn: int):
    """Join validity for one row block (same rule as kernels/knn_join.py):
    at least one endpoint "new", distinct slots, both occupied, distinct
    node ids. ids: (TB, C) -> ok (TB, C, C)."""
    c = ids.shape[1]
    slot_s = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)[None]
    slot_t = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)[None]
    ok = (slot_s < cn) | (slot_t < cn)
    ok &= slot_s != slot_t
    ok &= (ids[:, :, None] >= 0) & (ids[:, None, :] >= 0)
    ok &= ids[:, :, None] != ids[:, None, :]
    return ok


def _join_dists_q8_kernel(xq_ref, xs_ref, x2_ref, ids_ref, od_ref, ev_ref,
                          *, cn: int):
    """int8 pair tensor for one row block: (TB, C, dp) int8 gathered
    candidates -> (TB, C, C) masked sq-l2, int32 MXU accumulation."""
    xq = xq_ref[...]                          # (TB, C, dp) int8
    xs = xs_ref[...]                          # (TB, C)
    x2 = x2_ref[...]                          # (TB, C)
    ids = ids_ref[...]                        # (TB, C)

    ab = jax.lax.dot_general(
        xq, xq, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )                                         # (TB, C, C) i32
    dd = x2[:, :, None] + x2[:, None, :] - 2.0 * (
        xs[:, :, None] * xs[:, None, :]
    ) * ab.astype(jnp.float32)
    ok = _join_mask(ids, cn)
    od_ref[...] = jnp.where(ok, jnp.maximum(dd, 0.0), jnp.inf)
    ev_ref[...] = (jnp.sum(ok.astype(jnp.int32), axis=(1, 2)) // 2)[:, None]


def _join_dists_bf16_kernel(xg_ref, x2_ref, ids_ref, od_ref, ev_ref,
                            *, cn: int):
    """bf16 pair tensor for one row block, fp32 accumulation."""
    xg = xg_ref[...]                          # (TB, C, dp) bf16
    x2 = x2_ref[...]                          # (TB, C)
    ids = ids_ref[...]                        # (TB, C)

    ab = jax.lax.dot_general(
        xg, xg, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                         # (TB, C, C)
    dd = x2[:, :, None] + x2[:, None, :] - 2.0 * ab
    ok = _join_mask(ids, cn)
    od_ref[...] = jnp.where(ok, jnp.maximum(dd, 0.0), jnp.inf)
    ev_ref[...] = (jnp.sum(ok.astype(jnp.int32), axis=(1, 2)) // 2)[:, None]


def _pad_join(arrs, ids, tb):
    n = ids.shape[0]
    npad = ((n + tb - 1) // tb) * tb
    pad = npad - n
    out = [jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)) for a in arrs]
    return out, jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1), npad


@functools.partial(jax.jit, static_argnames=("cn", "tb", "interpret"))
def knn_join_dists_q8_blocked(
    xq: jax.Array,     # (n, C, dp) int8 gathered candidate rows
    xscale: jax.Array,  # (n, C) candidate dequant scales
    x2g: jax.Array,    # (n, C) cached quantized squared norms (0 invalid)
    ids: jax.Array,    # (n, C) candidate node ids, -1 = invalid slot
    *,
    cn: int,           # width of the "new" candidate prefix
    tb: int = DEFAULT_TB,
    interpret: bool = False,
):
    """Blocked int8 local-join pair distances. Returns (dists (n, C, C)
    f32 with +inf on invalid pairs, evals (n,) int32)."""
    n, c, dp = xq.shape
    (xq, xscale, x2g), ids, npad = _pad_join([xq, xscale, x2g], ids, tb)
    kern = functools.partial(_join_dists_q8_kernel, cn=cn)
    od, ev = pl.pallas_call(
        kern,
        grid=(npad // tb,),
        in_specs=[
            pl.BlockSpec((tb, c, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, c, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, c, c), jnp.float32),
            jax.ShapeDtypeStruct((npad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xq, xscale, x2g, ids)
    return od[:n], ev[:n, 0]


@functools.partial(jax.jit, static_argnames=("cn", "tb", "interpret"))
def knn_join_dists_bf16_blocked(
    xg: jax.Array,     # (n, C, dp) bf16 gathered candidate rows
    x2g: jax.Array,    # (n, C) cached bf16 squared norms (0 invalid)
    ids: jax.Array,    # (n, C) candidate node ids, -1 = invalid slot
    *,
    cn: int,
    tb: int = DEFAULT_TB,
    interpret: bool = False,
):
    """Blocked bf16 local-join pair distances. Same contract as the int8
    form minus the scales."""
    n, c, dp = xg.shape
    (xg, x2g), ids, npad = _pad_join([xg, x2g], ids, tb)
    kern = functools.partial(_join_dists_bf16_kernel, cn=cn)
    od, ev = pl.pallas_call(
        kern,
        grid=(npad // tb,),
        in_specs=[
            pl.BlockSpec((tb, c, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
            pl.BlockSpec((tb, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, c, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, c, c), jnp.float32),
            jax.ShapeDtypeStruct((npad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xg, x2g, ids)
    return od[:n], ev[:n, 0]
