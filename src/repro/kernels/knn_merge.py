"""Bounded neighbor-list merge kernel (paper §2 "calculate and update").

NN-Descent keeps, per node, a sorted bounded list of its k current nearest
neighbors. Each iteration produces a batch of candidate (id, distance)
pairs per node which must be merged into that list with deduplication.

The paper does this with scalar sorted-array insertion; the TPU form is a
row-blocked kernel: TM rows are processed per grid step, and the merge is a
k-step vectorized selection (each step extracts the row-wise minimum of the
remaining pool of current-neighbors + candidates). k is small (20 in all
paper experiments) so the unrolled k x (k + c) compare network stays in
VREGs — the analog of the paper keeping its 25 accumulators in registers.

Outputs the merged sorted lists and the per-row accepted-candidate count
(the convergence counter c in the NN-Descent stopping rule).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TM = 256
_BIG = float(jnp.finfo(jnp.float32).max)


def _merge_kernel(cd_ref, ci_ref, qd_ref, qi_ref, od_ref, oi_ref, upd_ref, *, k: int):
    cur_d = cd_ref[...]          # (TM, K) ascending
    cur_i = ci_ref[...]          # (TM, K)
    cand_d = qd_ref[...]         # (TM, C)
    cand_i = qi_ref[...]         # (TM, C)

    # --- dedup: candidate already in list, duplicate candidate, or invalid
    dup = cand_i < 0
    for j in range(k):
        dup |= cand_i == cur_i[:, j][:, None]
    c = cand_d.shape[1]
    eq = cand_i[:, :, None] == cand_i[:, None, :]
    earlier = jnp.tril(jnp.ones((c, c), dtype=bool), k=-1)[None]
    dup |= (eq & earlier).any(-1)
    cand_d = jnp.where(dup, _BIG, cand_d)

    # --- k-step vectorized min-extraction merge (iota+select one-hot form:
    # no gathers/fancy indexing, so every step stays VPU-native)
    pool_d = jnp.concatenate([jnp.where(jnp.isinf(cur_d), _BIG, cur_d), cand_d], axis=1)
    pool_i = jnp.concatenate([cur_i, cand_i], axis=1)
    is_cand = jnp.concatenate(
        [jnp.zeros(cur_d.shape, bool), jnp.ones(cand_d.shape, bool)], axis=1
    )
    lane = jax.lax.broadcasted_iota(jnp.int32, pool_d.shape, 1)
    out_d = []
    out_i = []
    n_upd = jnp.zeros((cur_d.shape[0],), jnp.int32)
    for _t in range(k):
        amin = jnp.argmin(pool_d, axis=1)                      # (TM,)
        onehot = lane == amin[:, None]
        dmin = jnp.min(pool_d, axis=1)
        imin = jnp.sum(jnp.where(onehot, pool_i, 0), axis=1)
        took_cand = jnp.any(onehot & is_cand, axis=1) & (dmin < _BIG)
        n_upd += took_cand.astype(jnp.int32)
        out_d.append(jnp.where(dmin < _BIG, dmin, jnp.inf))
        out_i.append(jnp.where(dmin < _BIG, imin, -1))
        pool_d = jnp.where(onehot, _BIG, pool_d)
    od_ref[...] = jnp.stack(out_d, axis=1)
    oi_ref[...] = jnp.stack(out_i, axis=1)
    upd_ref[...] = n_upd[:, None]


def _compact_kernel(cd_ref, ci_ref, dr_ref, od_ref, oi_ref, rm_ref, *, k: int):
    """Drop masked entries from sorted rows, keeping the survivors sorted
    and packed to the front — the tombstone-purge primitive of the online
    subsystem (core/online.py). Same k-step min-extraction network as
    ``_merge_kernel``: no gathers, VPU-native."""
    cur_d = cd_ref[...]                 # (TM, K) ascending
    cur_i = ci_ref[...]                 # (TM, K)
    drop = dr_ref[...] != 0             # (TM, K) int32 mask -> bool

    valid = cur_i >= 0
    rm_ref[...] = jnp.sum(
        (drop & valid).astype(jnp.int32), axis=1, keepdims=True
    )
    # survivors are tracked by mask, not by distance magnitude, so valid
    # entries at placeholder distances (heap.init_random's 3e38) survive
    # exactly as in the ref.knn_compact oracle
    keep = ~drop & valid & jnp.isfinite(cur_d)
    pool_d = jnp.where(keep, cur_d, _BIG)
    lane = jax.lax.broadcasted_iota(jnp.int32, pool_d.shape, 1)
    out_d = []
    out_i = []
    for _t in range(k):
        amin = jnp.argmin(pool_d, axis=1)
        onehot = lane == amin[:, None]
        dmin = jnp.min(pool_d, axis=1)
        imin = jnp.sum(jnp.where(onehot, cur_i, 0), axis=1)
        real = jnp.any(onehot & keep, axis=1)
        out_d.append(jnp.where(real, dmin, jnp.inf))
        out_i.append(jnp.where(real, imin, -1))
        pool_d = jnp.where(onehot, _BIG, pool_d)
        keep &= ~onehot
    od_ref[...] = jnp.stack(out_d, axis=1)
    oi_ref[...] = jnp.stack(out_i, axis=1)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def knn_compact_blocked(
    cur_dist: jax.Array,   # (n, k) ascending, +inf = empty slot
    cur_idx: jax.Array,    # (n, k) int32, -1 = empty
    drop: jax.Array,       # (n, k) bool — entries to remove
    *,
    tm: int = DEFAULT_TM,
    interpret: bool = False,
):
    """Remove ``drop``-masked entries from sorted bounded lists.

    Returns (dist, idx, removed): survivors packed to the front in
    ascending order, freed slots set to (inf, -1), ``removed`` the per-row
    count of dropped valid entries.
    """
    n, k = cur_dist.shape
    npad = ((n + tm - 1) // tm) * tm
    pad = npad - n
    cur_dist = jnp.pad(cur_dist, ((0, pad), (0, 0)), constant_values=jnp.inf)
    cur_idx = jnp.pad(cur_idx, ((0, pad), (0, 0)), constant_values=-1)
    drop_i = jnp.pad(
        drop.astype(jnp.int32), ((0, pad), (0, 0)), constant_values=0
    )

    kern = functools.partial(_compact_kernel, k=k)
    od, oi, rm = pl.pallas_call(
        kern,
        grid=(npad // tm,),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, k), jnp.float32),
            jax.ShapeDtypeStruct((npad, k), jnp.int32),
            jax.ShapeDtypeStruct((npad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(cur_dist, cur_idx, drop_i)
    return od[:n], oi[:n], rm[:n, 0]


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def knn_merge_blocked(
    cur_dist: jax.Array,   # (n, k) ascending, +inf = empty slot
    cur_idx: jax.Array,    # (n, k) int32, -1 = empty
    cand_dist: jax.Array,  # (n, c) f32
    cand_idx: jax.Array,   # (n, c) int32, -1 = invalid
    *,
    tm: int = DEFAULT_TM,
    interpret: bool = False,
):
    n, k = cur_dist.shape
    c = cand_dist.shape[1]
    npad = ((n + tm - 1) // tm) * tm
    pad = npad - n
    cur_dist = jnp.pad(cur_dist, ((0, pad), (0, 0)), constant_values=jnp.inf)
    cur_idx = jnp.pad(cur_idx, ((0, pad), (0, 0)), constant_values=-1)
    cand_dist = jnp.pad(cand_dist, ((0, pad), (0, 0)), constant_values=jnp.inf)
    cand_idx = jnp.pad(cand_idx, ((0, pad), (0, 0)), constant_values=-1)

    kern = functools.partial(_merge_kernel, k=k)
    od, oi, upd = pl.pallas_call(
        kern,
        grid=(npad // tm,),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((tm, c), lambda i: (i, 0)),
            pl.BlockSpec((tm, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, k), jnp.float32),
            jax.ShapeDtypeStruct((npad, k), jnp.int32),
            jax.ShapeDtypeStruct((npad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(cur_dist, cur_idx, cand_dist, cand_idx)
    return od[:n], oi[:n], upd[:n, 0]


# ---------------------------------------------------------------------------
# Frontier (gather/scatter) chunked dispatch — the online subsystem's
# sparse-update entry points: gather a compacted padded buffer of row ids,
# run the same row-blocked kernels over the (f, ...) chunk (the pallas grid
# is the per-chunk tiling), scatter the results back. Cost scales with the
# frontier size f, not the store size n. Oracles: ref.knn_merge_rows /
# ref.knn_compact_rows.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def knn_merge_rows_blocked(
    cur_dist: jax.Array,   # (n, k) ascending, +inf = empty slot
    cur_idx: jax.Array,    # (n, k) int32, -1 = empty
    rows: jax.Array,       # (f,) unique row ids, -1 = padding
    cand_dist: jax.Array,  # (f, c) f32
    cand_idx: jax.Array,   # (f, c) int32, -1 = invalid
    *,
    tm: int = DEFAULT_TM,
    interpret: bool = False,
):
    """Merge candidates into the listed rows only (full arrays returned)."""
    n, _ = cur_dist.shape
    ok = rows >= 0
    safe = jnp.where(ok, rows, 0)
    sub_d = cur_dist[safe]
    sub_i = cur_idx[safe]
    cand_idx = jnp.where(ok[:, None], cand_idx, -1)
    md, mi, upd = knn_merge_blocked(
        sub_d, sub_i, cand_dist, cand_idx, tm=tm, interpret=interpret
    )
    tgt = jnp.where(ok, rows, n)
    out_d = cur_dist.at[tgt].set(md, mode="drop")
    out_i = cur_idx.at[tgt].set(mi, mode="drop")
    return out_d, out_i, jnp.where(ok, upd, 0)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def knn_compact_rows_blocked(
    cur_dist: jax.Array,   # (n, k) ascending, +inf = empty slot
    cur_idx: jax.Array,    # (n, k) int32, -1 = empty
    rows: jax.Array,       # (f,) unique row ids, -1 = padding
    drop: jax.Array,       # (f, k) bool — frontier-local entries to remove
    *,
    tm: int = DEFAULT_TM,
    interpret: bool = False,
):
    """Drop masked entries from the listed rows only (full arrays returned)."""
    n, _ = cur_dist.shape
    ok = rows >= 0
    safe = jnp.where(ok, rows, 0)
    sub_d = cur_dist[safe]
    sub_i = cur_idx[safe]
    drop = drop & ok[:, None]
    cd, ci, removed = knn_compact_blocked(
        sub_d, sub_i, drop, tm=tm, interpret=interpret
    )
    tgt = jnp.where(ok, rows, n)
    out_d = cur_dist.at[tgt].set(cd, mode="drop")
    out_i = cur_idx.at[tgt].set(ci, mode="drop")
    return out_d, out_i, jnp.where(ok, removed, 0)
