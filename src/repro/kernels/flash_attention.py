"""Blocked (flash-style) attention kernel — the LM stack's compute hotspot.

Standard online-softmax tiling: the grid is (batch*heads, q blocks, kv
blocks) with the kv axis innermost; running max/denominator and the output
accumulator live in VMEM scratch and are rescaled per kv block. Supports
causal masking, sliding windows (gemma2 local layers), logit softcapping
(gemma2), GQA (kv-head folding happens in the index maps, so kv tiles are
fetched once per q-head group member — the VMEM pipeline dedups the loads),
and a q position offset for decode.

This kernel is the TPU target; the model stack's default path on CPU is the
numerically identical chunked-scan implementation in models/attention.py
(same cost structure, pure HLO), and tests assert both against ref.attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TQ = 256
DEFAULT_TK = 512
_NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int | None,
    softcap: float | None, q_offset: int, tq: int, tk: int,
):
    jq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (TQ, Dh)
    k = k_ref[0].astype(jnp.float32)            # (TK, Dh)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                    # (TQ, TK)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    qpos = q_offset + jq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    kpos = jk * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, _NEG)

    m_prev = m_ref[...]                          # (TQ, 1)
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(jk == pl.num_programs(2) - 1)
    def _final():
        l = l_ref[...]
        o_ref[0] = jnp.where(l > 0, acc_ref[...] / jnp.where(l > 0, l, 1.0), 0.0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "q_offset", "tq", "tk", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,              # (B, Lq, H, Dh)
    k: jax.Array,              # (B, Lk, Hkv, Dh)
    v: jax.Array,              # (B, Lk, Hkv, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    tq: int = DEFAULT_TQ,
    tk: int = DEFAULT_TK,
    interpret: bool = False,
) -> jax.Array:
    B, Lq, H, Dh = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    tq = min(tq, Lq)
    tk = min(tk, Lk)
    if Lq % tq or Lk % tk:
        raise ValueError(f"Lq={Lq} % tq={tq} or Lk={Lk} % tk={tk} != 0")

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Lq, Dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Lk, Dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Lk, Dh)

    def kv_index(bh, jq_, jk_):
        # fold the q head onto its kv head: bh = b*H + h -> b*Hkv + h//rep
        b = bh // H
        h = bh % H
        return (b * Hkv + h // rep, jk_, 0)

    kern = functools.partial(
        _flash_kernel, scale=1.0 / (Dh ** 0.5), causal=causal,
        window=window, softcap=softcap, q_offset=q_offset, tq=tq, tk=tk,
    )
    of = pl.pallas_call(
        kern,
        grid=(B * H, Lq // tq, Lk // tk),
        in_specs=[
            pl.BlockSpec((1, tq, Dh), lambda bh, jq_, jk_: (bh, jq_, 0)),
            pl.BlockSpec((1, tk, Dh), kv_index),
            pl.BlockSpec((1, tk, Dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, tq, Dh), lambda bh, jq_, jk_: (bh, jq_, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Lq, Dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return of.reshape(B, H, Lq, Dh).transpose(0, 2, 1, 3).astype(q.dtype)
