"""Continuous batching: a fixed pool of decode slots; requests join as
slots free up, every ``serve_step`` advances ALL active slots one token.

The decode step itself is shape-static (B = n_slots always); inactive
slots carry a dummy token and their outputs are ignored — the standard
TPU-friendly realization of continuous batching (no recompilation as
requests come and go).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class SlotState:
    active: bool = False
    rid: int = -1
    remaining: int = 0


class ContinuousBatcher:
    """Drives serve_step over a slot pool.

    prefill_fn(tokens (1, L)) -> (last_logits (1, V), cache_for_one, L)
    step_fn(cache, tokens (B,1), lengths (B,)) -> (logits (B, V), cache)
    write_slot(cache, slot_idx, one_cache, length) -> cache
    """

    def __init__(self, n_slots: int, step_fn: Callable,
                 prefill_fn: Callable, write_slot: Callable,
                 sampler: Callable | None = None, *,
                 knn_store: Any | None = None,
                 knn_capture: Callable | None = None,
                 knn_chunk: int = 64,
                 knn_frontier_chunk: int | None = None,
                 knn_q_block: int | None = None,
                 knn_router: Any | None = None,
                 knn_snapshot_dir: str | None = None,
                 knn_snapshot_every: int = 0,
                 knn_snapshot_keep: int = 3):
        self.n_slots = n_slots
        self.step_fn = step_fn
        self.prefill_fn = prefill_fn
        self.write_slot = write_slot
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        # datastore persistence (core/persist.py): with a snapshot
        # directory, a server cold-starts from the newest committed
        # snapshot instead of rebuilding the graph — and streamed inserts
        # are checkpointed every ``knn_snapshot_every`` captured rows by
        # an async writer that never blocks the decode/insert path
        self._knn_writer = None
        self._knn_snapshot_every = int(knn_snapshot_every)
        self._knn_rows_inserted = 0
        self._knn_rows_at_snap = 0
        if knn_snapshot_dir is not None:
            from repro.core import persist
            if knn_store is None \
                    and persist.latest_snapshot(knn_snapshot_dir) is not None:
                from repro.serve.knn_lm import MutableKNNDatastore
                knn_store = MutableKNNDatastore.restore(knn_snapshot_dir)
            self._knn_writer = persist.SnapshotWriter(
                knn_snapshot_dir, keep=knn_snapshot_keep)
        # frontier-chunk / query-block plumbing: streamed inserts touch a
        # frontier proportional to knn_chunk and retrieval batches are the
        # slot count, so the store's padded-chunk quantum
        # (OnlineConfig.chunk) and the fused search's query-block quantum
        # (OnlineConfig.q_block) can both be tuned alongside the serving
        # batch shape without rebuilding the datastore
        if knn_store is not None and hasattr(knn_store, "store"):
            store_cfg = knn_store.store.cfg
            if knn_frontier_chunk is not None:
                store_cfg = dataclasses.replace(store_cfg,
                                                chunk=knn_frontier_chunk)
            if knn_q_block is not None:
                store_cfg = dataclasses.replace(store_cfg,
                                                q_block=knn_q_block)
            if store_cfg is not knn_store.store.cfg:
                knn_store = dataclasses.replace(
                    knn_store,
                    store=dataclasses.replace(knn_store.store,
                                              cfg=store_cfg),
                )
            if knn_router is not None:
                # attach the coarse routing layer (idempotent): every
                # retrieval and insert-seeding search gets hierarchical
                # entry points; the store maintains the router across the
                # capture-hook inserts. True = default RouterConfig.
                from repro.core.online import ensure_router
                rcfg = None if knn_router is True else knn_router
                knn_store = dataclasses.replace(
                    knn_store, store=ensure_router(knn_store.store, rcfg)
                )
        self.slots = [SlotState() for _ in range(n_slots)]
        self.queue: list[Request] = []
        self.live: dict[int, Request] = {}
        self.tokens = np.zeros((n_slots, 1), np.int32)
        self.lengths = np.zeros((n_slots,), np.int32)
        self.steps = 0
        # optional online kNN-LM datastore growth: each decode step's
        # (captured key, sampled token) pairs from active slots are
        # buffered and inserted in fixed-size chunks so the jitted insert
        # path compiles once (serve/knn_lm.MutableKNNDatastore)
        self.knn_store = knn_store
        self.knn_capture = knn_capture
        self.knn_chunk = knn_chunk
        self._knn_keys: list[np.ndarray] = []
        self._knn_vals: list[int] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self, cache):
        for i, s in enumerate(self.slots):
            if s.active or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, one_cache, plen = self.prefill_fn(
                req.prompt[None, :])
            cache = self.write_slot(cache, i, one_cache, plen)
            first = int(self.sampler(logits[0]))
            req.out.append(first)
            self.tokens[i, 0] = first
            self.lengths[i] = plen
            self.slots[i] = SlotState(True, req.rid, req.max_new - 1)
            self.live[req.rid] = req
        return cache

    def step(self, cache):
        """One decode step for every active slot; returns new cache."""
        cache = self._admit(cache)
        if not any(s.active for s in self.slots):
            return cache, False
        logits, cache = self.step_fn(
            cache, jnp.asarray(self.tokens), jnp.asarray(self.lengths))
        nxt = np.asarray(self.sampler(logits))
        if self.knn_store is not None and self.knn_capture is not None:
            keys = np.asarray(self.knn_capture(logits))
            for i, s in enumerate(self.slots):
                if s.active:
                    self._knn_keys.append(keys[i])
                    self._knn_vals.append(int(nxt[i]))
            self._flush_knn()
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            self.lengths[i] += 1
            tok = int(nxt[i])
            self.tokens[i, 0] = tok
            req = self.live[s.rid]
            req.out.append(tok)
            s.remaining -= 1
            if s.remaining <= 0:
                req.done = True
                del self.live[s.rid]
                self.slots[i] = SlotState()
        self.steps += 1
        if self.knn_store is not None and not self.live and not self.queue:
            # stream drained: flush the sub-chunk tail so step()-driven
            # callers (not just run()) lose nothing
            self._flush_knn(final=True)
        return cache, True

    def flush_knn(self):
        """Flush any buffered (key, token) pairs into the datastore."""
        if self.knn_store is not None:
            self._flush_knn(final=True)

    def _flush_knn(self, final: bool = False):
        """Insert buffered (key, token) pairs in ``knn_chunk``-sized
        batches (fixed shapes -> the jitted insert path is reused); a
        ``final`` flush takes the remainder as a one-off shape."""
        while len(self._knn_vals) >= self.knn_chunk:
            self._knn_insert(self.knn_chunk)
        if final and self._knn_vals:
            self._knn_insert(len(self._knn_vals))

    def _knn_insert(self, m: int):
        kb = jnp.asarray(np.stack(self._knn_keys[:m]))
        vb = jnp.asarray(np.asarray(self._knn_vals[:m], np.int32))
        del self._knn_keys[:m]
        del self._knn_vals[:m]
        self.knn_store, _ = self.knn_store.append(
            kb, vb, key=jax.random.fold_in(jax.random.key(17), self.steps))
        self._knn_rows_inserted += m
        if (self._knn_writer is not None and self._knn_snapshot_every > 0
                and (self._knn_rows_inserted - self._knn_rows_at_snap
                     >= self._knn_snapshot_every)):
            self.snapshot_knn(wait=False)

    def snapshot_knn(self, *, wait: bool = True):
        """Snapshot the kNN datastore now (step = its allocation
        high-water mark). ``wait=False`` hands serialization to the
        async writer and returns immediately — the capture is consistent
        either way (the store's arrays are immutable)."""
        if self._knn_writer is None or self.knn_store is None:
            return
        self._knn_writer.save(
            self.knn_store.store, self.knn_store.store.n,
            values=self.knn_store.values, wait=wait,
        )
        self._knn_rows_at_snap = self._knn_rows_inserted

    def run(self, cache, *, max_steps: int = 10_000):
        while (self.queue or self.live) and self.steps < max_steps:
            cache, _ = self.step(cache)
        if self.knn_store is not None:
            self._flush_knn(final=True)
            if self._knn_writer is not None:
                # drain checkpoint: the next cold start resumes from the
                # full stream, not the last periodic snapshot. A pending
                # error from an earlier PERIODIC background write must
                # not abort this final snapshot (it supersedes whatever
                # that write would have saved): surface it as a warning
                # once the drain commits, and only re-raise it when the
                # drain itself also fails.
                periodic_err = self._knn_writer.poll()
                try:
                    self.snapshot_knn(wait=True)
                except Exception:
                    if periodic_err is not None:
                        warnings.warn(
                            "periodic background snapshot had already "
                            f"failed before the drain: {periodic_err}",
                            RuntimeWarning, stacklevel=2)
                    raise
                if periodic_err is not None:
                    warnings.warn(
                        "a periodic background snapshot failed "
                        f"({periodic_err}); the drain snapshot committed "
                        "and supersedes it", RuntimeWarning, stacklevel=2)
        return cache
