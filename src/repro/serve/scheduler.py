"""Serving schedulers: continuous LM batching and overload-robust
retrieval dispatch.

``ContinuousBatcher`` drives a fixed pool of decode slots; requests join
as slots free up, every ``serve_step`` advances ALL active slots one
token. The decode step itself is shape-static (B = n_slots always);
inactive slots carry a dummy token and their outputs are ignored — the
standard TPU-friendly realization of continuous batching (no
recompilation as requests come and go).

Both schedulers share the overload machinery below:

  * :class:`LaneQueue` — a bounded two-lane (interactive / batch) FIFO
    with strict interactive priority, per-request deadlines, and
    explicit shedding policies. Nothing is ever dropped silently: every
    request that will not be served carries a typed :class:`Rejection`.
  * :class:`RetrievalScheduler` — the kNN-serving admission layer: it
    pulls lane-pure batches off the queue, propagates each batch's
    tightest remaining deadline into ``SearchConfig.max_rounds_deadline``
    (the fused search's per-block round-budget cut) and runs the batch
    at its bucketed ``q_block`` ladder step, so a 7-query interactive
    burst compiles and runs in the 8-block rather than padding to the
    full batch block. Overload behavior is scripted through the
    ``sched.burst`` / ``sched.stall`` fault sites (core/faults.py), so
    shedding and expiry are testable without wall-clock flakiness.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core.graph_search import SearchConfig, q_block_bucket

LANES = ("interactive", "batch")    # pop order = priority order


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Typed verdict attached to every request the scheduler will not
    serve — the no-silent-drops contract. Codes:

      expired-at-admission  deadline already spent when submitted
      expired-in-queue      deadline passed while waiting for a slot
      queue-full            bounded queue at capacity (reject-new)
      shed-oldest           evicted as oldest batch request to admit a
                            newer one (drop-oldest-batch)
      truncated             scheduler stopped (max_steps / max_pumps)
                            before this request ran
    """
    code: str
    detail: str = ""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # overload-control fields (defaults preserve the pre-lane behavior:
    # unbounded queue, no deadline, nothing sheds)
    lane: str = "interactive"
    deadline_ms: float | None = None
    submitted_at: float | None = None
    rejection: Rejection | None = None
    truncated: bool = False


def _deadline_at(req) -> float | None:
    """Absolute expiry time on the scheduler clock, or None (no deadline
    or unknown submit time — such requests never expire)."""
    if req.deadline_ms is None or req.submitted_at is None:
        return None
    return req.submitted_at + req.deadline_ms / 1e3


class LaneQueue:
    """Bounded two-lane FIFO with typed shedding.

    Interactive requests always pop before batch requests (strict
    priority: batch traffic can starve under sustained interactive load,
    which is the intended SLO trade — batch work carries deadlines and
    expires with a typed rejection rather than waiting forever).

    ``max_queue`` bounds the TOTAL depth across both lanes (None =
    unbounded, the legacy behavior). At capacity, ``shed_policy``
    decides who pays:

      reject-new        the incoming request is refused (queue-full)
      drop-oldest-batch the oldest queued batch request is evicted
                        (shed-oldest) to admit the newcomer; with no
                        batch request to evict it degrades to reject-new

    Every push/pop takes the current scheduler-clock reading so deadline
    expiry is checked at both boundaries; pass ``now=None`` to skip the
    checks (clock-free callers). Counters (``admitted`` / ``shed`` /
    ``expired``) plus :meth:`depth` are the queue-side scheduler stats.
    """

    def __init__(self, max_queue: int | None = None,
                 shed_policy: str = "reject-new"):
        if shed_policy not in ("reject-new", "drop-oldest-batch"):
            raise ValueError(f"unknown shed_policy {shed_policy!r}")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.lanes = {lane: collections.deque() for lane in LANES}
        self.admitted = 0
        self.shed = 0
        self.expired = 0

    def __len__(self) -> int:
        return sum(len(q) for q in self.lanes.values())

    def __iter__(self):
        for lane in LANES:
            yield from self.lanes[lane]

    def depth(self) -> dict:
        return {lane: len(q) for lane, q in self.lanes.items()}

    def push(self, req, now: float | None = None) -> Rejection | None:
        """Admit ``req`` (returns None) or refuse it (returns the
        Rejection, also stored on ``req.rejection``)."""
        lane = req.lane or "interactive"
        if lane not in self.lanes:
            raise ValueError(f"unknown lane {lane!r}")
        if now is not None and req.submitted_at is None:
            req.submitted_at = now
        exp = _deadline_at(req)
        if now is not None and exp is not None and now >= exp:
            self.expired += 1
            req.rejection = Rejection(
                "expired-at-admission",
                f"deadline_ms={req.deadline_ms} already spent at submit")
            return req.rejection
        if self.max_queue is not None and len(self) >= self.max_queue:
            victim = None
            if self.shed_policy == "drop-oldest-batch" \
                    and self.lanes["batch"]:
                victim = self.lanes["batch"].popleft()
            if victim is not None:
                self.shed += 1
                victim.rejection = Rejection(
                    "shed-oldest",
                    "evicted as oldest batch request at capacity "
                    f"{self.max_queue}")
            else:
                self.shed += 1
                req.rejection = Rejection(
                    "queue-full", f"queue at capacity {self.max_queue}")
                return req.rejection
        self.lanes[lane].append(req)
        self.admitted += 1
        return None

    def pop(self, now: float | None = None, lane: str | None = None):
        """Next serviceable request (interactive first), or None.
        Requests whose deadline passed while queued are expired in place
        (typed rejection) and skipped. ``lane`` restricts to one lane —
        the dispatcher uses it to keep batches lane-pure."""
        for ln in LANES if lane is None else (lane,):
            q = self.lanes[ln]
            while q:
                req = q.popleft()
                exp = _deadline_at(req)
                if now is not None and exp is not None and now >= exp:
                    self.expired += 1
                    req.rejection = Rejection(
                        "expired-in-queue",
                        f"deadline_ms={req.deadline_ms} passed while "
                        "queued")
                    continue
                return req
        return None


@dataclasses.dataclass
class QueryRequest:
    """One retrieval request in the RetrievalScheduler.

    Terminal states are mutually exclusive and always explicit: either
    results land in ``dist``/``idx`` (served) or ``rejection`` is set
    (shed / expired / truncated). ``injected`` marks ``sched.burst``
    amplification copies so tests can separate scripted overload from
    real traffic.
    """
    qid: int
    query: np.ndarray               # (d,) float
    lane: str = "interactive"
    deadline_ms: float | None = None
    submitted_at: float | None = None
    finished_at: float | None = None
    dist: np.ndarray | None = None  # (k_out,) on completion
    idx: np.ndarray | None = None   # (k_out,) on completion
    rejection: Rejection | None = None
    injected: bool = False

    @property
    def done(self) -> bool:
        return self.idx is not None or self.rejection is not None

    @property
    def latency_ms(self) -> float | None:
        if self.finished_at is None or self.submitted_at is None:
            return None
        return (self.finished_at - self.submitted_at) * 1e3


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission/backpressure knobs for :class:`RetrievalScheduler`."""
    max_queue: int = 256            # total bound across both lanes
    shed_policy: str = "reject-new"     # or "drop-oldest-batch"
    max_batch: int = 64             # requests per dispatch (per pump)
    default_deadline_ms: float | None = None
    #                               # applied when submit() passes None
    min_deadline_s: float = 1e-3    # floor for the propagated budget cut
    result_cache: int = 0           # LRU result-cache capacity, in
    #                               # entries (0 = off). Keyed on the
    #                               # int8-quantized query bytes: near-
    #                               # duplicate queries (same int8 image)
    #                               # are answered at admission without a
    #                               # search dispatch. MUST be
    #                               # invalidated on every corpus
    #                               # mutation (RetrievalScheduler
    #                               # .invalidate_cache).


class RetrievalScheduler:
    """Admission control + deadline propagation for kNN retrieval.

    ``search_fn(queries (m, d) jnp, cfg: SearchConfig) -> (dist, idx)``
    is the underlying fused search — typically a closure over
    ``graph_search`` / ``MutableKNNStore.search`` /
    ``graph_search_sharded``. The scheduler owns WHEN it runs and with
    WHAT config:

      * :meth:`submit` runs admission through the bounded two-lane
        :class:`LaneQueue` — every refused request carries a typed
        :class:`Rejection` (never a silent drop).
      * :meth:`pump` pops one LANE-PURE batch (interactive lane drains
        first) of at most ``cfg.max_batch`` requests and dispatches it
        once. Lane purity is what makes the bucketed ``q_block`` ladder
        pay off: a 7-query interactive burst is dispatched alone and
        runs in the 8-block instead of padding to the full batch block.
      * Deadline propagation: the batch's TIGHTEST remaining deadline,
        divided by the number of search blocks the batch will occupy,
        becomes ``SearchConfig.max_rounds_deadline`` — the fused
        search's per-block time slice that cuts late blocks down to
        their minimum round budget (graph_search's deadline cut).
      * Result cache (``SchedulerConfig.result_cache`` > 0): an LRU of
        recent (query -> dist/idx) results keyed on the query's
        int8-quantized bytes (the quantize_sym_int8 per-row scheme, so
        near-duplicate queries that share an int8 image hit). Hits are
        answered AT ADMISSION — no queue slot, no dispatch, counted in
        ``cache_hits``. Deadline-cut dispatches never populate the
        cache (a degraded answer must not be replayed to a full-budget
        caller). The scheduler cannot see the corpus behind
        ``search_fn``: the OWNER must call :meth:`invalidate_cache`
        after every store mutation (insert/delete/restore), or stale
        results will be served.

    The scheduler is metric- and filter-agnostic: ``base_cfg.metric``
    rides through untouched to the search closure, and per-tenant
    ``filter_ids`` belong INSIDE ``search_fn`` (one scheduler per
    visibility domain — cache keys carry no filter identity, so mixing
    tenants behind one cached scheduler would leak results across the
    filter boundary).

    Fault sites (deterministic overload, core/faults.py): ``sched.burst``
    amplifies one submit into N injected copies; ``sched.stall``
    advances the scheduler's clock at the next pump, modelling a GC
    pause / slow kernel so queued-deadline expiry is scriptable. The
    clock itself is injectable (``clock=``) for fully virtual-time
    tests.
    """

    def __init__(self, search_fn: Callable, *,
                 base_cfg: SearchConfig | None = None,
                 cfg: SchedulerConfig | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.search_fn = search_fn
        self.base_cfg = base_cfg or SearchConfig()
        self.cfg = cfg or SchedulerConfig()
        self.queue = LaneQueue(self.cfg.max_queue, self.cfg.shed_policy)
        self._clock = clock
        self._stall = 0.0           # sched.stall virtual-clock offset
        self._next_qid = 0
        self.dispatches = 0
        self.served = 0
        self.latency_ms = {lane: [] for lane in LANES}
        # admission-path result LRU (SchedulerConfig.result_cache):
        # int8-quantized query bytes -> (dist, idx) numpy copies
        self._cache: collections.OrderedDict = collections.OrderedDict()
        self.cache_hits = 0

    def now(self) -> float:
        return self._clock() + self._stall

    @staticmethod
    def _cache_key(q: np.ndarray) -> bytes:
        """int8 image of the query (quantize_sym_int8's per-row scheme:
        scale = max|q|/127) + the scale bytes — collisions require the
        same quantized direction AND magnitude, i.e. queries the search
        itself could not meaningfully tell apart."""
        q = np.asarray(q, np.float32).reshape(-1)
        s = max(float(np.max(np.abs(q))) / 127.0, 1e-30) \
            if q.size else 1e-30
        qi = np.clip(np.round(q / s), -127, 127).astype(np.int8)
        return qi.tobytes() + np.float32(s).tobytes()

    def invalidate_cache(self) -> None:
        """Drop every cached result. Call after ANY mutation of the
        corpus behind ``search_fn`` (insert / delete / restore /
        re-quantization) — the scheduler cannot observe those, so cache
        coherence is the owner's contract."""
        self._cache.clear()

    def submit(self, query, *, lane: str = "interactive",
               deadline_ms: float | None = None,
               qid: int | None = None) -> QueryRequest:
        """Admit one query. Returns its QueryRequest — check
        ``.rejection`` for an admission-time refusal. A result-cache
        hit (SchedulerConfig.result_cache) is answered here directly:
        the returned request is already ``done`` with the cached
        dist/idx and never occupies a queue slot. An active
        ``sched.burst`` spec amplifies this arrival into ``arg``
        (default 8) extra injected copies submitted behind it."""
        if deadline_ms is None:
            deadline_ms = self.cfg.default_deadline_ms
        q = np.asarray(query)
        if qid is None:
            qid = self._next_qid
        self._next_qid = max(self._next_qid, qid) + 1
        req = QueryRequest(qid=qid, query=q, lane=lane,
                           deadline_ms=deadline_ms)
        if self.cfg.result_cache > 0:
            ck = self._cache_key(q)
            hit = self._cache.get(ck)
            if hit is not None:
                self._cache.move_to_end(ck)
                now = self.now()
                req.submitted_at = now
                req.dist, req.idx = hit[0].copy(), hit[1].copy()
                req.finished_at = now
                self.cache_hits += 1
                self.latency_ms[lane].append(0.0)
                return req
        self.queue.push(req, self.now())
        spec = faults.fire("sched.burst")
        if spec is not None:
            n = int(spec.arg) if spec.arg is not None else 8
            for _ in range(max(0, n)):
                copy = QueryRequest(
                    qid=self._next_qid, query=q, lane=lane,
                    deadline_ms=deadline_ms, injected=True)
                self._next_qid += 1
                self.queue.push(copy, self.now())
        return req

    def pump(self) -> list:
        """Dispatch one lane-pure batch. Returns the served requests
        ([] when the queue had nothing serviceable). Full-budget
        dispatches populate the result cache; deadline-cut ones do
        not (their answers may be round-budget degraded)."""
        spec = faults.fire("sched.stall")
        if spec is not None:
            self._stall += float(spec.arg) if spec.arg is not None \
                else 0.05
        now = self.now()
        first = self.queue.pop(now)
        if first is None:
            return []
        batch = [first]
        while len(batch) < self.cfg.max_batch:
            nxt = self.queue.pop(now, lane=first.lane)
            if nxt is None:
                break
            batch.append(nxt)
        scfg = self.base_cfg
        nq = len(batch)
        n_blocks = max(1, math.ceil(nq / q_block_bucket(nq, scfg)))
        rem = [_deadline_at(r) - now for r in batch
               if _deadline_at(r) is not None]
        if rem:
            slice_s = max(min(rem), self.cfg.min_deadline_s) / n_blocks
            scfg = dataclasses.replace(scfg, max_rounds_deadline=slice_s)
        dist, idx = self.search_fn(
            jnp.asarray(np.stack([r.query for r in batch])), scfg)
        dist = np.asarray(dist)
        idx = np.asarray(idx)
        end = self.now()
        for j, r in enumerate(batch):
            r.dist, r.idx, r.finished_at = dist[j], idx[j], end
            if r.latency_ms is not None:
                self.latency_ms[r.lane].append(r.latency_ms)
            if self.cfg.result_cache > 0 and not rem:
                self._cache[self._cache_key(r.query)] = (
                    dist[j].copy(), idx[j].copy())
        while len(self._cache) > self.cfg.result_cache:
            self._cache.popitem(last=False)
        self.dispatches += 1
        self.served += nq
        return batch

    def run_until_drained(self, *, max_pumps: int = 10_000) -> list:
        """Pump until the queue is empty; returns every served request.
        Exhausting ``max_pumps`` marks the leftovers truncated (typed
        rejection) and warns — never a silent drop. The scheduler stays
        usable afterwards (submit-after-drain is a fresh start)."""
        served = []
        pumps = 0
        while len(self.queue) and pumps < max_pumps:
            served.extend(self.pump())
            pumps += 1
        leftover = [r for r in self.queue]
        if leftover:
            for r in leftover:
                r.rejection = Rejection(
                    "truncated",
                    f"run_until_drained(max_pumps={max_pumps}) exhausted")
            for q in self.queue.lanes.values():
                q.clear()
            warnings.warn(
                f"run_until_drained(max_pumps={max_pumps}) exhausted "
                f"with {len(leftover)} request(s) still queued; marked "
                "truncated", RuntimeWarning, stacklevel=2)
        return served

    def stats(self) -> dict:
        q = self.queue
        return {
            "depth": q.depth(),
            "admitted": q.admitted,
            "shed": q.shed,
            "expired": q.expired,
            "served": self.served,
            "dispatches": self.dispatches,
            "cache_hits": self.cache_hits,
            "cache_size": len(self._cache),
            "latency_ms": {lane: list(v)
                           for lane, v in self.latency_ms.items()},
        }


@dataclasses.dataclass
class SlotState:
    active: bool = False
    rid: int = -1
    remaining: int = 0


class ContinuousBatcher:
    """Drives serve_step over a slot pool.

    prefill_fn(tokens (1, L)) -> (last_logits (1, V), cache_for_one, L)
    step_fn(cache, tokens (B,1), lengths (B,)) -> (logits (B, V), cache)
    write_slot(cache, slot_idx, one_cache, length) -> cache
    """

    def __init__(self, n_slots: int, step_fn: Callable,
                 prefill_fn: Callable, write_slot: Callable,
                 sampler: Callable | None = None, *,
                 knn_store: Any | None = None,
                 knn_capture: Callable | None = None,
                 knn_chunk: int = 64,
                 knn_frontier_chunk: int | None = None,
                 knn_q_block: int | None = None,
                 knn_router: Any | None = None,
                 knn_snapshot_dir: str | None = None,
                 knn_snapshot_every: int = 0,
                 knn_snapshot_keep: int = 3,
                 max_queue: int | None = None,
                 shed_policy: str = "reject-new",
                 clock: Callable[[], float] = time.monotonic):
        self.n_slots = n_slots
        self.step_fn = step_fn
        self.prefill_fn = prefill_fn
        self.write_slot = write_slot
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        # datastore persistence (core/persist.py): with a snapshot
        # directory, a server cold-starts from the newest committed
        # snapshot instead of rebuilding the graph — and streamed inserts
        # are checkpointed every ``knn_snapshot_every`` captured rows by
        # an async writer that never blocks the decode/insert path
        self._knn_writer = None
        self._knn_snapshot_every = int(knn_snapshot_every)
        self._knn_rows_inserted = 0
        self._knn_rows_at_snap = 0
        if knn_snapshot_dir is not None:
            from repro.core import persist
            if knn_store is None \
                    and persist.latest_snapshot(knn_snapshot_dir) is not None:
                from repro.serve.knn_lm import MutableKNNDatastore
                knn_store = MutableKNNDatastore.restore(knn_snapshot_dir)
            self._knn_writer = persist.SnapshotWriter(
                knn_snapshot_dir, keep=knn_snapshot_keep)
        # frontier-chunk / query-block plumbing: streamed inserts touch a
        # frontier proportional to knn_chunk and retrieval batches are the
        # slot count, so the store's padded-chunk quantum
        # (OnlineConfig.chunk) and the fused search's query-block quantum
        # (OnlineConfig.q_block) can both be tuned alongside the serving
        # batch shape without rebuilding the datastore
        if knn_store is not None and hasattr(knn_store, "store"):
            store_cfg = knn_store.store.cfg
            if knn_frontier_chunk is not None:
                store_cfg = dataclasses.replace(store_cfg,
                                                chunk=knn_frontier_chunk)
            if knn_q_block is not None:
                store_cfg = dataclasses.replace(store_cfg,
                                                q_block=knn_q_block)
            if store_cfg is not knn_store.store.cfg:
                knn_store = dataclasses.replace(
                    knn_store,
                    store=dataclasses.replace(knn_store.store,
                                              cfg=store_cfg),
                )
            if knn_router is not None:
                # attach the coarse routing layer (idempotent): every
                # retrieval and insert-seeding search gets hierarchical
                # entry points; the store maintains the router across the
                # capture-hook inserts. True = default RouterConfig.
                from repro.core.online import ensure_router
                rcfg = None if knn_router is True else knn_router
                knn_store = dataclasses.replace(
                    knn_store, store=ensure_router(knn_store.store, rcfg)
                )
        self.slots = [SlotState() for _ in range(n_slots)]
        # bounded two-lane admission (defaults = legacy behavior:
        # unbounded, nothing sheds, no deadlines enforced)
        self.queue = LaneQueue(max_queue, shed_policy)
        self.clock = clock
        self.live: dict[int, Request] = {}
        self.tokens = np.zeros((n_slots, 1), np.int32)
        self.lengths = np.zeros((n_slots,), np.int32)
        self.steps = 0
        # optional online kNN-LM datastore growth: each decode step's
        # (captured key, sampled token) pairs from active slots are
        # buffered and inserted in fixed-size chunks so the jitted insert
        # path compiles once (serve/knn_lm.MutableKNNDatastore)
        self.knn_store = knn_store
        self.knn_capture = knn_capture
        self.knn_chunk = knn_chunk
        self._knn_keys: list[np.ndarray] = []
        self._knn_vals: list[int] = []

    def submit(self, req: Request) -> Rejection | None:
        """Queue a request. Returns None when admitted, or the typed
        Rejection (also stored on ``req.rejection``) when the bounded
        queue refuses it."""
        return self.queue.push(req, self.clock())

    def _admit(self, cache):
        for i, s in enumerate(self.slots):
            if s.active:
                continue
            req = self.queue.pop(self.clock())
            if req is None:
                break
            logits, one_cache, plen = self.prefill_fn(
                req.prompt[None, :])
            cache = self.write_slot(cache, i, one_cache, plen)
            first = int(self.sampler(logits[0]))
            req.out.append(first)
            self.tokens[i, 0] = first
            self.lengths[i] = plen
            self.slots[i] = SlotState(True, req.rid, req.max_new - 1)
            self.live[req.rid] = req
        return cache

    def step(self, cache):
        """One decode step for every active slot; returns new cache."""
        cache = self._admit(cache)
        if not any(s.active for s in self.slots):
            return cache, False
        logits, cache = self.step_fn(
            cache, jnp.asarray(self.tokens), jnp.asarray(self.lengths))
        nxt = np.asarray(self.sampler(logits))
        if self.knn_store is not None and self.knn_capture is not None:
            keys = np.asarray(self.knn_capture(logits))
            for i, s in enumerate(self.slots):
                if s.active:
                    self._knn_keys.append(keys[i])
                    self._knn_vals.append(int(nxt[i]))
            self._flush_knn()
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            self.lengths[i] += 1
            tok = int(nxt[i])
            self.tokens[i, 0] = tok
            req = self.live[s.rid]
            req.out.append(tok)
            s.remaining -= 1
            if s.remaining <= 0:
                req.done = True
                del self.live[s.rid]
                self.slots[i] = SlotState()
        self.steps += 1
        if self.knn_store is not None and not self.live and not self.queue:
            # stream drained: flush the sub-chunk tail so step()-driven
            # callers (not just run()) lose nothing
            self._flush_knn(final=True)
        return cache, True

    def flush_knn(self):
        """Flush any buffered (key, token) pairs into the datastore."""
        if self.knn_store is not None:
            self._flush_knn(final=True)

    def _flush_knn(self, final: bool = False):
        """Insert buffered (key, token) pairs in ``knn_chunk``-sized
        batches (fixed shapes -> the jitted insert path is reused); a
        ``final`` flush takes the remainder as a one-off shape."""
        while len(self._knn_vals) >= self.knn_chunk:
            self._knn_insert(self.knn_chunk)
        if final and self._knn_vals:
            self._knn_insert(len(self._knn_vals))

    def _knn_insert(self, m: int):
        kb = jnp.asarray(np.stack(self._knn_keys[:m]))
        vb = jnp.asarray(np.asarray(self._knn_vals[:m], np.int32))
        del self._knn_keys[:m]
        del self._knn_vals[:m]
        self.knn_store, _ = self.knn_store.append(
            kb, vb, key=jax.random.fold_in(jax.random.key(17), self.steps))
        self._knn_rows_inserted += m
        if (self._knn_writer is not None and self._knn_snapshot_every > 0
                and (self._knn_rows_inserted - self._knn_rows_at_snap
                     >= self._knn_snapshot_every)):
            self.snapshot_knn(wait=False)

    def snapshot_knn(self, *, wait: bool = True):
        """Snapshot the kNN datastore now (step = its allocation
        high-water mark). ``wait=False`` hands serialization to the
        async writer and returns immediately — the capture is consistent
        either way (the store's arrays are immutable)."""
        if self._knn_writer is None or self.knn_store is None:
            return
        self._knn_writer.save(
            self.knn_store.store, self.knn_store.store.n,
            values=self.knn_store.values, wait=wait,
        )
        self._knn_rows_at_snap = self._knn_rows_inserted

    def run(self, cache, *, max_steps: int = 10_000):
        while (len(self.queue) or self.live) and self.steps < max_steps:
            cache, _ = self.step(cache)
        leftover = len(self.queue) + len(self.live)
        if leftover:
            # max_steps exhausted with work outstanding: mark every
            # queued/live request truncated (partial output stays in
            # ``req.out``) instead of returning as if nothing happened
            for req in list(self.live.values()):
                req.truncated = True
            for req in self.queue:
                req.truncated = True
            warnings.warn(
                f"run(max_steps={max_steps}) exhausted with {leftover} "
                "request(s) unfinished; marked truncated",
                RuntimeWarning, stacklevel=2)
        if self.knn_store is not None:
            self._flush_knn(final=True)
            if self._knn_writer is not None:
                # drain checkpoint: the next cold start resumes from the
                # full stream, not the last periodic snapshot. A pending
                # error from an earlier PERIODIC background write must
                # not abort this final snapshot (it supersedes whatever
                # that write would have saved): surface it as a warning
                # once the drain commits, and only re-raise it when the
                # drain itself also fails.
                periodic_err = self._knn_writer.poll()
                try:
                    self.snapshot_knn(wait=True)
                except Exception:
                    if periodic_err is not None:
                        warnings.warn(
                            "periodic background snapshot had already "
                            f"failed before the drain: {periodic_err}",
                            RuntimeWarning, stacklevel=2)
                    raise
                if periodic_err is not None:
                    warnings.warn(
                        "a periodic background snapshot failed "
                        f"({periodic_err}); the drain snapshot committed "
                        "and supersedes it", RuntimeWarning, stacklevel=2)
        return cache
