"""Serving: prefill + single-token decode with per-family batched caches.

Cache trees mirror the parameter stack structure (scan-stacked over
layers), so decode steps scan over (layer_params, layer_cache) pairs and
HLO size stays depth-independent. Cache kinds:

  * GQA linear cache  (B, max_len, Hkv, Dh) + kpos tags
  * GQA ring cache    (B, window,  Hkv, Dh) — local-window layers store
    only ``window`` entries (gemma2 local, starcoder2): long_500k decode
    memory is window-bounded on those layers.
  * MLA latent cache  (B, max_len, kv_lora + rope) — deepseek-v2's
    KV-compression contribution, with weight-absorbed decode.
  * SSM cache         conv tail (B, K-1, conv_dim) + state (B, H, P, N):
    O(1) in sequence length — why the ssm/hybrid archs own long_500k.

``serve_step`` is the function the decode_32k / long_500k dry-run cells
lower: (params, cache, tokens (B,1), lengths (B,)) -> (logits, cache).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.model import embed_inputs, output_logits
from repro.models.params import abstract_tree, init_tree, sharding_tree
from repro.models.transformer import (
    apply_ffn,
    apply_norm,
    stack_schema,
)


# ---------------------------------------------------------------------------
# cache schemas (mirror transformer.stack_schema_for)
# ---------------------------------------------------------------------------

def cache_schema(cfg, batch: int, max_len: int) -> dict:
    if cfg.family == "ssm":
        return {"layers": stack_schema(
            ssm_mod.mamba_cache_schema(cfg, batch), cfg.n_layers)}
    if cfg.family == "hybrid":
        n_seg = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers - n_seg * cfg.attn_every
        s = {
            "segments": stack_schema(stack_schema(
                ssm_mod.mamba_cache_schema(cfg, batch), cfg.attn_every),
                n_seg),
            "shared": stack_schema(
                attn.gqa_cache_schema(cfg, batch, max_len), n_seg),
        }
        if rem:
            s["tail"] = stack_schema(
                ssm_mod.mamba_cache_schema(cfg, batch), rem)
        return s
    one = (attn.mla_cache_schema(cfg, batch, max_len) if cfg.use_mla
           else None)
    if cfg.family == "moe" or cfg.n_experts:
        k = cfg.first_k_dense
        mk = one or attn.gqa_cache_schema(cfg, batch, max_len)
        s = {"layers": stack_schema(mk, cfg.n_layers - k)}
        if k:
            s["dense_layers"] = stack_schema(mk, k)
        return s
    if cfg.layer_pattern == "local_global":
        pair = {
            "local": attn.gqa_cache_schema(cfg, batch, max_len,
                                           window=cfg.window),
            "global": attn.gqa_cache_schema(cfg, batch, max_len),
        }
        return {"pairs": stack_schema(pair, cfg.n_layers // 2)}
    window = cfg.window if cfg.layer_pattern == "local" else None
    return {"layers": stack_schema(
        attn.gqa_cache_schema(cfg, batch, max_len, window=window),
        cfg.n_layers)}


def init_cache(cfg, batch: int, max_len: int) -> dict:
    return init_tree(jax.random.key(0), cache_schema(cfg, batch, max_len))


def abstract_cache(cfg, batch: int, max_len: int) -> dict:
    return abstract_tree(cache_schema(cfg, batch, max_len))


def cache_shardings(cfg, batch: int, max_len: int, mesh, rules=None):
    return sharding_tree(cache_schema(cfg, batch, max_len), mesh, rules)


# ---------------------------------------------------------------------------
# block decode steps
# ---------------------------------------------------------------------------

def _attn_block_decode(p, x, c, lengths, cfg, *, window=None, ffn="dense"):
    h = apply_norm(p["norm1"], x, cfg)
    if cfg.use_mla:
        a, c2 = attn.mla_decode(p["attn"], h, c, lengths, cfg)
    else:
        a, c2 = attn.gqa_decode(p["attn"], h, c, lengths, cfg, window=window)
    if cfg.post_norms:
        a = apply_norm(p["norm_post_attn"], a, cfg)
    x = x + cfg.residual_multiplier * a
    h = apply_norm(p["norm2"], x, cfg)
    if ffn == "moe":
        m = moe_mod.moe_ffn(p["ffn"], h, cfg)
    else:
        m = apply_ffn(p["ffn"], h, cfg)
    if cfg.post_norms:
        m = apply_norm(p["norm_post_ffn"], m, cfg)
    return x + cfg.residual_multiplier * m, c2


def _mamba_block_decode(p, x, c, cfg):
    h = apply_norm(p["norm"], x, cfg)
    y, c2 = ssm_mod.mamba_decode(p["mixer"], h, c, cfg)
    return x + cfg.residual_multiplier * y, c2


def _shared_block_decode(p, x, c, lengths, cfg, inv):
    la = p["lora_a"][inv]
    lb = p["lora_b"][inv]
    x = x + (x @ la.astype(x.dtype)) @ lb.astype(x.dtype)
    return _attn_block_decode(p["block"], x, c, lengths, cfg)


# ---------------------------------------------------------------------------
# serve_step: one token for every slot
# ---------------------------------------------------------------------------

def serve_step(params, cache, tokens, lengths, cfg):
    """(B,1) tokens at positions ``lengths`` -> (logits (B, vocab), cache)."""
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    stack = params["stack"]

    if cfg.family == "ssm":
        def body(h, pc):
            lp, lc = pc
            h, lc2 = _mamba_block_decode(lp, h, lc, cfg)
            return h, lc2
        x, new_layers = jax.lax.scan(body, x, (stack["layers"],
                                               cache["layers"]))
        new_cache = {"layers": new_layers}

    elif cfg.family == "hybrid":
        def body(carry, pc):
            h, inv = carry
            seg_p, seg_c, sh_c = pc

            def inner(hh, pc2):
                lp, lc = pc2
                hh, lc2 = _mamba_block_decode(lp, hh, lc, cfg)
                return hh, lc2
            h, seg_c2 = jax.lax.scan(inner, h, (seg_p, seg_c))
            la = stack["shared"]["lora_a"][inv]
            lb = stack["shared"]["lora_b"][inv]
            h = h + (h @ la.astype(h.dtype)) @ lb.astype(h.dtype)
            h, sh_c2 = _attn_block_decode(
                stack["shared"]["block"], h, sh_c, lengths, cfg)
            return (h, inv + 1), (seg_c2, sh_c2)
        (x, _), (new_seg, new_sh) = jax.lax.scan(
            body, (x, jnp.int32(0)),
            (stack["segments"], cache["segments"], cache["shared"]))
        new_cache = {"segments": new_seg, "shared": new_sh}
        if "tail" in stack:
            def body_t(h, pc):
                lp, lc = pc
                h, lc2 = _mamba_block_decode(lp, h, lc, cfg)
                return h, lc2
            x, new_tail = jax.lax.scan(body_t, x,
                                       (stack["tail"], cache["tail"]))
            new_cache["tail"] = new_tail

    elif cfg.family == "moe" or cfg.n_experts:
        new_cache = {}
        if "dense_layers" in stack:
            def body_d(h, pc):
                lp, lc = pc
                h, lc2 = _attn_block_decode(lp, h, lc, lengths, cfg,
                                            ffn="dense")
                return h, lc2
            x, nd = jax.lax.scan(body_d, x, (stack["dense_layers"],
                                             cache["dense_layers"]))
            new_cache["dense_layers"] = nd

        def body(h, pc):
            lp, lc = pc
            h, lc2 = _attn_block_decode(lp, h, lc, lengths, cfg, ffn="moe")
            return h, lc2
        x, nl = jax.lax.scan(body, x, (stack["layers"], cache["layers"]))
        new_cache["layers"] = nl

    elif cfg.layer_pattern == "local_global":
        def body(h, pc):
            lp, lc = pc
            h, c_l = _attn_block_decode(lp["local"], h, lc["local"],
                                        lengths, cfg, window=cfg.window)
            h, c_g = _attn_block_decode(lp["global"], h, lc["global"],
                                        lengths, cfg)
            return h, {"local": c_l, "global": c_g}
        x, new_pairs = jax.lax.scan(body, x, (stack["pairs"],
                                              cache["pairs"]))
        new_cache = {"pairs": new_pairs}

    else:
        window = cfg.window if cfg.layer_pattern == "local" else None

        def body(h, pc):
            lp, lc = pc
            h, lc2 = _attn_block_decode(lp, h, lc, lengths, cfg,
                                        window=window)
            return h, lc2
        x, new_layers = jax.lax.scan(body, x, (stack["layers"],
                                               cache["layers"]))
        new_cache = {"layers": new_layers}

    logits = output_logits(params, x, cfg)[:, 0]       # (B, vocab)
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that seeds the cache
# ---------------------------------------------------------------------------

def _seed_gqa(cfg, k, v, max_len, window):
    """Build a {k, v, kpos} cache from prefill (B, L, Hkv, Dh) tensors."""
    B, L = k.shape[0], k.shape[1]
    S = min(window, max_len) if window is not None else max_len
    dt = cfg.cache_dtype
    if S >= L:
        kc = jnp.pad(k.astype(dt), ((0, 0), (0, S - L), (0, 0), (0, 0)))
        vc = jnp.pad(v.astype(dt), ((0, 0), (0, S - L), (0, 0), (0, 0)))
        kp = jnp.broadcast_to(
            jnp.where(jnp.arange(S) < L, jnp.arange(S), -1), (B, S))
    else:
        # ring: keep the last S positions, placed at their slot pos % S
        kt, vt = k[:, L - S:], v[:, L - S:]
        pos = jnp.arange(L - S, L)
        slot = pos % S
        kc = jnp.zeros((B, S, *k.shape[2:]), dt).at[:, slot].set(
            kt.astype(dt))
        vc = jnp.zeros((B, S, *v.shape[2:]), dt).at[:, slot].set(
            vt.astype(dt))
        kp = jnp.zeros((B, S), jnp.int32).at[:, slot].set(
            jnp.broadcast_to(pos, (B, S)))
    return {"k": kc, "v": vc, "kpos": kp.astype(jnp.int32)}


def _seed_mla(cfg, ckv, krope, max_len):
    B, L = ckv.shape[0], ckv.shape[1]
    dt = cfg.cache_dtype
    ck = jnp.pad(ckv.astype(dt), ((0, 0), (0, max_len - L), (0, 0)))
    kr = jnp.pad(krope.astype(dt), ((0, 0), (0, max_len - L), (0, 0)))
    kp = jnp.broadcast_to(
        jnp.where(jnp.arange(max_len) < L, jnp.arange(max_len), -1),
        (B, max_len))
    return {"ckv": ck, "krope": kr, "kpos": kp.astype(jnp.int32)}


def _attn_block_prefill(p, x, cfg, max_len, *, window=None, ffn="dense"):
    h = apply_norm(p["norm1"], x, cfg)
    if cfg.use_mla:
        a, (ckv, krope) = attn.mla_attention(p["attn"], h, cfg,
                                             return_latent=True)
        c = _seed_mla(cfg, ckv, krope, max_len)
    else:
        a, (k, v) = attn.gqa_attention(p["attn"], h, cfg, window=window,
                                       return_kv=True)
        c = _seed_gqa(cfg, k, v, max_len, window)
    if cfg.post_norms:
        a = apply_norm(p["norm_post_attn"], a, cfg)
    x = x + cfg.residual_multiplier * a
    h = apply_norm(p["norm2"], x, cfg)
    m = moe_mod.moe_ffn(p["ffn"], h, cfg) if ffn == "moe" \
        else apply_ffn(p["ffn"], h, cfg)
    if cfg.post_norms:
        m = apply_norm(p["norm_post_ffn"], m, cfg)
    return x + cfg.residual_multiplier * m, c


def _mamba_block_prefill(p, x, cfg):
    h = apply_norm(p["norm"], x, cfg)
    y, c = ssm_mod.mamba_block(p["mixer"], h, cfg, return_cache=True)
    return x + cfg.residual_multiplier * y, c


def prefill(params, batch, cfg, max_len: int, *, last_only: bool = False):
    """Full-sequence prefill. Returns (logits, cache, lengths); logits are
    (B, L, V), or (B, V) for the new-token sampling position when
    ``last_only`` (serving never materializes the (B, 32k, V) tensor)."""
    x = embed_inputs(params, batch, cfg)
    L = x.shape[1]
    B = x.shape[0]
    stack = params["stack"]

    if cfg.family == "ssm":
        def body(h, lp):
            h, c = _mamba_block_prefill(lp, h, cfg)
            return h, c
        x, layers = jax.lax.scan(body, x, stack["layers"])
        cache = {"layers": layers}

    elif cfg.family == "hybrid":
        def body(carry, seg):
            h, inv = carry
            lp, _ = seg

            def inner(hh, lpp):
                return _mamba_block_prefill(lpp, hh, cfg)
            h, seg_c = jax.lax.scan(inner, h, lp)
            la = stack["shared"]["lora_a"][inv]
            lb = stack["shared"]["lora_b"][inv]
            h = h + (h @ la.astype(h.dtype)) @ lb.astype(h.dtype)
            h, sh_c = _attn_block_prefill(stack["shared"]["block"], h, cfg,
                                          max_len)
            return (h, inv + 1), (seg_c, sh_c)
        n_seg = cfg.n_layers // cfg.attn_every
        (x, _), (seg_c, sh_c) = jax.lax.scan(
            body, (x, jnp.int32(0)),
            (stack["segments"], jnp.arange(n_seg)))
        cache = {"segments": seg_c, "shared": sh_c}
        if "tail" in stack:
            def body_t(h, lp):
                return _mamba_block_prefill(lp, h, cfg)
            x, tail_c = jax.lax.scan(body_t, x, stack["tail"])
            cache["tail"] = tail_c

    elif cfg.family == "moe" or cfg.n_experts:
        cache = {}
        if "dense_layers" in stack:
            def body_d(h, lp):
                return _attn_block_prefill(lp, h, cfg, max_len,
                                           ffn="dense_first")
            x, cd = jax.lax.scan(body_d, x, stack["dense_layers"])
            cache["dense_layers"] = cd

        def body(h, lp):
            return _attn_block_prefill(lp, h, cfg, max_len, ffn="moe")
        x, cl = jax.lax.scan(body, x, stack["layers"])
        cache["layers"] = cl

    elif cfg.layer_pattern == "local_global":
        def body(h, lp):
            h, c_l = _attn_block_prefill(lp["local"], h, cfg, max_len,
                                         window=cfg.window)
            h, c_g = _attn_block_prefill(lp["global"], h, cfg, max_len)
            return h, {"local": c_l, "global": c_g}
        x, pairs = jax.lax.scan(body, x, stack["pairs"])
        cache = {"pairs": pairs}

    else:
        window = cfg.window if cfg.layer_pattern == "local" else None

        def body(h, lp):
            return _attn_block_prefill(lp, h, cfg, max_len, window=window)
        x, layers = jax.lax.scan(body, x, stack["layers"])
        cache = {"layers": layers}

    if last_only:
        logits = output_logits(params, x[:, -1:], cfg)[:, 0]
    else:
        logits = output_logits(params, x, cfg)
    lengths = jnp.full((B,), L, jnp.int32)
    return logits, cache, lengths
