"""kNN-LM serving — the paper's K-NN graph as a first-class serving
component (DESIGN.md §3).

Datastore build: run the LM over a corpus, record (hidden state ->
next token) pairs, then build the K-NN GRAPH over the keys with the
paper's NN-Descent (core/). At decode time the query hidden state is
answered by greedy graph search (core/graph_search.py) over that graph —
NOT brute force — and the retrieved neighbors' continuation tokens form a
distance-weighted distribution that is interpolated with the LM logits:

    p(y) = (1 - lam) * p_LM(y) + lam * p_kNN(y)
    p_kNN(y) ∝ sum_{(k_i, v_i): v_i = y} exp(-d(q, k_i) / T)

The graph build cost is where the paper's optimizations (turbosampling,
blocked distances, reordering) pay off at datastore scale; the reorder
permutation ALSO improves search-time locality (neighbors of a graph node
sit in adjacent datastore rows after σ).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import DescentConfig, SearchConfig, build_knn_graph, graph_search
from repro.core import metric as metric_mod
from repro.core.online import (
    MutableKNNStore,
    OnlineConfig,
    knn_delete,
    knn_insert,
)
from repro.core.quantize import QuantizedStore, quantize_corpus
from repro.core.router import Router, RouterConfig, build_router


@dataclasses.dataclass
class KNNDatastore:
    keys: jax.Array         # (n, d) hidden states (reordered by sigma)
    values: jax.Array       # (n,) next-token ids  (reordered alike)
    graph_idx: jax.Array    # (n, k) K-NN graph in the reordered id space
    build_stats: dict
    # serving-search knobs (fused batched search; None = per-call default)
    search_cfg: SearchConfig | None = None
    # cached quantized mirror of ``keys`` for the two-stage scoring path
    # (built when ``build(precision=...)`` is quantized; the search
    # re-ranks fp32, so retrieval distances stay exact)
    qstore: QuantizedStore | None = None
    # coarse routing layer (core/router.py): hierarchical entry points
    # for every knn_logits search (built when ``build(router=...)``)
    router: Router | None = None
    # distance metric the datastore was built under ("l2" | "cosine" |
    # "mips"); ``keys`` are stored TRANSFORMED (normalized / augmented —
    # core/metric.py), so every knn_logits search must run under the
    # same metric. ``mips_m`` is the MIPS norm bound M baked into the
    # augmented coordinate (0.0 unless metric == "mips").
    metric: str = "l2"
    mips_m: float = 0.0

    @classmethod
    def build(cls, keys: jax.Array, values: jax.Array, *, k: int = 16,
              cfg: DescentConfig | None = None,
              precision: str = "f32",
              metric: str = "l2",
              router: RouterConfig | None = None,
              key: jax.Array | None = None):
        """``precision`` selects the serving-time candidate-scoring dtype
        (SearchConfig.precision): quantized modes precompute the corpus
        mirror here so every knn_logits call scores on int8/bf16 rows.
        The precision is carried by the mirror itself (knn_logits derives
        a quantized SearchConfig from it per call), NOT by pinning
        ``search_cfg`` — so per-call ``beam``/``rounds`` keep working.
        ``router`` builds the coarse routing layer over the keys so every
        retrieval seeds its beam from the query's nearest centroids.
        ``metric`` ("l2" | "cosine" | "mips") selects the retrieval
        distance: keys are transformed ONCE here (core/metric.py) and
        stored transformed, the graph/mirror/router are built over the
        transformed rows, and every knn_logits search reuses the pure-l2
        kernels unchanged (queries transformed per call)."""
        cfg = cfg or DescentConfig(k=k, rho=1.0, max_iters=10)
        if cfg.metric != metric:
            cfg = dataclasses.replace(cfg, metric=metric)
        dist, idx, st = build_knn_graph(keys, k=k, cfg=cfg, key=key)
        # store the TRANSFORMED keys (same transform the graph build
        # applied internally — deterministic, so M matches exactly);
        # mirror and router are built over the transformed rows too
        keys, mips_m = metric_mod.transform_corpus(
            keys.astype(jnp.float32), metric)
        return cls(
            keys=keys,
            values=values,
            graph_idx=idx,
            build_stats={"iters": st.iters, "dist_evals": st.dist_evals,
                         "reordered": st.reordered},
            qstore=(None if precision == "f32"
                    else quantize_corpus(keys, precision)),
            router=(None if router is None
                    else build_router(
                        keys, cfg=router,
                        key=jax.random.key(29) if key is None else key,
                    )),
            metric=metric,
            mips_m=mips_m,
        )

    def snapshot(self, directory: str, step: int = 0, *,
                 keep: int = 0) -> str:
        """Persist keys/values/graph (+ mirror, + router) under a
        committed step directory (core/persist.py format, kind
        ``knn_datastore``). Returns the step directory."""
        from repro.core import persist
        arrays, meta = persist.capture_datastore(self)
        return persist.write_snapshot(directory, step, arrays, meta,
                                      keep=keep)

    @classmethod
    def restore(cls, directory: str, step: int | None = None):
        """Zero-rebuild cold start: reload a snapshotted datastore (the
        newest committed step when ``step`` is None) — no NN-Descent, no
        re-quantization, no router refit; retrieval results are
        bit-identical to the datastore that was snapshotted."""
        from repro.core import persist
        step, arrays, manifest = persist.read_snapshot(directory, step)
        parts = persist.rebuild_datastore(arrays, manifest)
        return cls(
            build_stats={**manifest.get("build_stats", {}),
                         "restored_step": step},
            **parts,
        )


@dataclasses.dataclass
class MutableKNNDatastore:
    """Growable kNN-LM datastore: the online store (core/online.py) plus a
    value array that grows in lockstep — so the datastore can absorb
    (hidden state, next token) pairs *during decoding* (see the capture
    hook in serve/scheduler.py) and retire stale entries, without a full
    graph rebuild."""

    store: MutableKNNStore
    values: jax.Array       # (cap,) next-token ids, row-aligned with store
    build_stats: dict
    # serving-search knobs (fused batched search; None = store defaults)
    search_cfg: SearchConfig | None = None
    # pending background fp32 feature load (quantized-first restore only;
    # see core/persist.Fp32Loader) — resolve with ``finish_fp32``
    fp32_loader: Any = None

    @classmethod
    def build(cls, keys: jax.Array, values: jax.Array, *, k: int = 16,
              cfg: DescentConfig | None = None,
              online_cfg: OnlineConfig | None = None,
              frontier_chunk: int | None = None,
              q_block: int | None = None,
              precision: str | None = None,
              metric: str | None = None,
              router: RouterConfig | None = None,
              key: jax.Array | None = None):
        """``frontier_chunk`` overrides the online store's frontier chunk
        size (OnlineConfig.chunk): streamed decode-time inserts touch a
        frontier proportional to the insert batch, so serving stacks tune
        the padded-chunk quantum to their stream batch size (see the
        capture hook in serve/scheduler.py). ``q_block`` likewise
        overrides the fused search's query-block quantum
        (OnlineConfig.q_block): the search compiles once per block shape,
        so serving stacks match it to their decode batch. ``precision``
        overrides OnlineConfig.precision: quantized modes make the store
        keep an int8/bf16 mirror that the query and insert-seeding
        searches score on (fp32 re-rank — exact retrieval distances).
        ``router`` overrides OnlineConfig.router: the store builds and
        maintains the coarse routing layer (hierarchical entry points for
        every query and insert-seeding search). ``metric`` overrides
        OnlineConfig.metric ("l2" | "cosine" | "mips"): the store keeps
        its rows transformed (core/metric.py) and transforms queries and
        decode-time inserts itself, so append/search/delete all stay
        metric-consistent with zero caller-side work."""
        cfg = cfg or DescentConfig(k=k, rho=1.0, max_iters=10)
        online_cfg = online_cfg or OnlineConfig()
        if frontier_chunk is not None:
            online_cfg = dataclasses.replace(online_cfg,
                                             chunk=frontier_chunk)
        if q_block is not None:
            online_cfg = dataclasses.replace(online_cfg, q_block=q_block)
        if precision is not None:
            online_cfg = dataclasses.replace(online_cfg,
                                             precision=precision)
        if metric is not None:
            online_cfg = dataclasses.replace(online_cfg, metric=metric)
        if router is not None:
            online_cfg = dataclasses.replace(online_cfg, router=router)
        store, st = MutableKNNStore.build(
            keys, k=k, cfg=online_cfg, descent=cfg, key=key)
        vals = jnp.zeros((store.capacity,), values.dtype)
        vals = vals.at[:values.shape[0]].set(values)
        return cls(
            store=store,
            values=vals,
            build_stats={"iters": st.iters, "dist_evals": st.dist_evals,
                         "reordered": st.reordered},
        )

    def append(self, keys: jax.Array, values: jax.Array, *,
               key: jax.Array | None = None):
        """Insert (key, value) pairs; returns (datastore, insert stats)."""
        n0 = self.store.n
        store, stats = knn_insert(self.store, keys, key=key)
        vals = self.values
        if store.capacity != vals.shape[0]:     # store doubled: grow alike
            vals = jnp.zeros((store.capacity,), vals.dtype
                             ).at[:vals.shape[0]].set(vals)
        vals = vals.at[n0:n0 + keys.shape[0]].set(values)
        return dataclasses.replace(self, store=store, values=vals), stats

    def delete(self, ids: jax.Array):
        store, stats = knn_delete(self.store, ids)
        return dataclasses.replace(self, store=store), stats

    def snapshot(self, directory: str, step: int | None = None, *,
                 keep: int = 0) -> str:
        """Persist the full online store (features, graph, tombstones,
        norms, quantized mirror, router) plus the row-aligned values
        under a committed step directory (core/persist.py; default step =
        the allocation high-water mark). Returns the step directory."""
        from repro.core import persist
        return persist.snapshot_store(
            self.store, directory,
            self.store.n if step is None else step,
            values=self.values, keep=keep,
        )

    @classmethod
    def restore(cls, directory: str, step: int | None = None, *,
                quantized_first: bool = False):
        """Zero-rebuild cold start from a snapshot (the newest committed
        step when ``step`` is None): search results, subsequent inserts
        and deletes are bit-identical to the store that was snapshotted.
        ``quantized_first`` serves from the 4x-smaller quantized mirror
        immediately (quantized-accurate distances) while the fp32 rows
        load in the background — call ``finish_fp32()`` to swap them in
        and re-enable exact fp32 re-rank."""
        from repro.core import persist
        res = persist.restore_store(directory, step,
                                    quantized_first=quantized_first)
        values = res.values
        if values is None:
            values = jnp.zeros((res.store.capacity,), jnp.int32)
        return cls(
            store=res.store,
            values=values,
            build_stats={"restored_step": res.step,
                         "live": res.manifest.get("live"),
                         "tombstones": res.manifest.get("tombstones")},
            fp32_loader=res.fp32_loader,
        )

    def finish_fp32(self):
        """Resolve a quantized-first restore: block until the background
        fp32 load completes and return a datastore whose store re-ranks
        on the exact rows. No-op without a pending loader."""
        if self.fp32_loader is None:
            return self
        store = self.fp32_loader.apply(self.store)
        return dataclasses.replace(self, store=store, fp32_loader=None)


def knn_logits(
    ds: KNNDatastore | MutableKNNDatastore,
    queries: jax.Array,      # (q, d) hidden states
    vocab: int,
    *,
    k: int = 8,
    temperature: float = 10.0,
    beam: int = 32,
    rounds: int = 24,
    key: jax.Array | None = None,
    cfg: SearchConfig | None = None,
    filter_ids: jax.Array | None = None,
) -> jax.Array:
    """Graph-search retrieval -> (q, vocab) log-probabilities.

    ``key`` seeds the search entry points; serving loops should thread a
    varying key (e.g. fold_in of the decode step) so repeated batches
    explore different entries. When None, entries derive from the query
    batch content (see core/graph_search), never from a shared constant.
    ``cfg`` (or the datastore's ``search_cfg``) selects the fused batched
    search knobs; default is the fused path with legacy beam/rounds. A
    datastore built with a quantized ``precision`` carries the mode on
    its cached mirror: with no pinned cfg, the two-stage search runs at
    the CALL's beam/rounds (nothing is silently overridden).

    The datastore's build ``metric`` is enforced here the same way: a
    datastore built under cosine/mips holds TRANSFORMED keys, so the
    search always runs under the build metric (a caller cfg with a
    different metric is overridden, never silently mis-scored). The
    retrieval weights exp(-d/T) use the transformed-space squared-l2
    distance, which is a monotone map of the native metric — ranking is
    exact; retune ``temperature`` when switching metrics.

    ``filter_ids`` restricts retrieval to admitted datastore rows —
    (n,) bool shared across the batch or (q, n) bool per query (e.g.
    per-tenant visibility during decode). Filtered rows are never
    retrieved, so they contribute zero mass to p_kNN (zero leakage —
    same contract as core/graph_search)."""
    cfg = cfg or ds.search_cfg
    if cfg is None and getattr(ds, "qstore", None) is not None:
        cfg = SearchConfig(beam=beam, rounds=rounds,
                           precision=ds.qstore.mode)
    if isinstance(ds, MutableKNNDatastore):
        # the store enforces its own OnlineConfig.metric inside search
        dist, idx = ds.store.search(queries, k_out=k, beam=beam,
                                    rounds=rounds, key=key, cfg=cfg,
                                    filter_ids=filter_ids)
    else:
        met = getattr(ds, "metric", "l2")
        if cfg is None:
            cfg = SearchConfig(beam=beam, rounds=rounds, metric=met)
        elif cfg.metric != met:
            cfg = dataclasses.replace(cfg, metric=met)
        dist, idx = graph_search(ds.keys, ds.graph_idx, queries,
                                 k_out=k, beam=beam, rounds=rounds,
                                 key=key, cfg=cfg, qstore=ds.qstore,
                                 router=getattr(ds, "router", None),
                                 filter_ids=filter_ids)
    # empty slots carry (+inf, -1) and must get zero weight; a row with
    # NO valid hit at all (empty store, or a poisoned query sanitized at
    # admission) would make softmax 0/0 — such rows degrade to the flat
    # log(1e-20) floor instead of propagating NaN into the interpolation
    valid = idx >= 0
    w = jax.nn.softmax(jnp.where(valid, -dist / temperature, -jnp.inf),
                       axis=-1)                             # (q, k)
    w = jnp.where(valid & jnp.any(valid, axis=-1, keepdims=True), w, 0.0)
    vals = ds.values[jnp.clip(idx, 0, ds.values.shape[0] - 1)]
    probs = jnp.zeros((queries.shape[0], vocab))
    probs = probs.at[jnp.arange(queries.shape[0])[:, None], vals].add(w)
    return jnp.log(jnp.maximum(probs, 1e-20))


def interpolate(lm_logits: jax.Array, knn_logp: jax.Array,
                lam: float = 0.25) -> jax.Array:
    """log[(1-lam) p_LM + lam p_kNN]."""
    lm_logp = jax.nn.log_softmax(lm_logits.astype(jnp.float32), axis=-1)
    return jnp.logaddexp(
        lm_logp + jnp.log1p(-lam), knn_logp + jnp.log(lam))
