from repro.serve.decode import (
    abstract_cache,
    cache_schema,
    cache_shardings,
    init_cache,
    prefill,
    serve_step,
)
from repro.serve.knn_lm import (
    KNNDatastore,
    MutableKNNDatastore,
    interpolate,
    knn_logits,
)
from repro.serve.scheduler import (
    ContinuousBatcher,
    LaneQueue,
    QueryRequest,
    Rejection,
    Request,
    RetrievalScheduler,
    SchedulerConfig,
)

__all__ = [
    "ContinuousBatcher",
    "KNNDatastore",
    "LaneQueue",
    "MutableKNNDatastore",
    "QueryRequest",
    "Rejection",
    "Request",
    "RetrievalScheduler",
    "SchedulerConfig",
    "abstract_cache",
    "cache_schema",
    "cache_shardings",
    "init_cache",
    "interpolate",
    "knn_logits",
    "prefill",
    "serve_step",
]
