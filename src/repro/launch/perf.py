import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# §Perf hillclimb driver: lower named VARIANTS of the three chosen cells,
# extract roofline terms, and write results/perf/<cell>__<variant>.json.
# The EXPERIMENTS.md §Perf log records hypothesis -> change -> before ->
# after for each variant, in order.
#
#   PYTHONPATH=src python -m repro.launch.perf --cell knn --variant ring
#   PYTHONPATH=src python -m repro.launch.perf --cell mamba --all
#
# Cells:
#   knn    = knn-build x knn_1m_256   (paper-representative)
#   mamba  = mamba2-130m x train_4k   (worst roofline fraction)
#   moe    = deepseek-v2-lite x train_4k (most collective-bound)


import argparse
import dataclasses
import json
import time

import jax

from repro.configs import SHAPES, batch_specs, get_config, input_specs
from repro.launch.dryrun import _finish, _train_cfg
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops_step
from repro.models import abstract_tree, active_param_count, model_schema, sharding_tree
from repro.models.sharding import activation_mesh
from repro.train import TrainConfig, make_train_step
from repro.train import optimizer as opt_mod


def lower_train_variant(arch: str, shape: str, cfg_overrides: dict,
                        microbatches: int = 4):
    cfg = _train_cfg(get_config(arch))
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mesh = make_production_mesh()
    s = SHAPES[shape]
    schema = model_schema(cfg)
    params_abs = abstract_tree(schema)
    params_sp = sharding_tree(schema, mesh)
    opt_abs = opt_mod.abstract_init(params_abs)
    opt_sp = opt_mod.AdamState(
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        params_sp, params_sp)
    batch_abs = input_specs(cfg, shape)
    batch_sp = batch_specs(cfg, shape, mesh)
    step = make_train_step(cfg, TrainConfig(microbatches=microbatches))
    with activation_mesh(mesh):
        lowered = jax.jit(
            step, in_shardings=(params_sp, opt_sp, batch_sp),
            out_shardings=(params_sp, opt_sp, None),
            donate_argnums=(0, 1),
        ).lower(params_abs, opt_abs, batch_abs)
        rec = _finish(lowered, mesh, "train", model_flops_step(
            "train", cfg, s.seq_len, s.global_batch,
            active_param_count(cfg)))
    rec["microbatches"] = microbatches
    return rec


def lower_knn_variant(fetch: str, n=1 << 20, d=256, k=20):
    from repro.core.distributed import make_sharded_iteration_lowerable
    mesh = make_production_mesh()
    lowered, mf = make_sharded_iteration_lowerable(
        mesh, n=n, d=d, k=k, fetch=fetch)
    compiled = lowered.compile()
    text = compiled.as_text()

    # CPU artifact correction: the CPU backend decomposes each lax.
    # all_to_all into P per-destination slice fusions, each charged the
    # full buffer by the byte model — on TPU an all-to-all is ONE fused
    # collective. Quantify those sites and report a corrected memory
    # term alongside the raw one (documented in EXPERIMENTS §Perf).
    from repro.launch.attr import attribute
    rows = attribute(text, top=10**6)
    artifact = sum(b for b, kind, comp, op, m, meta in rows
                   if kind == "fusion" and meta.endswith("all_to_all"))
    import repro.launch.dryrun as dr
    rec = dr._finish(lowered, mesh, "knn", mf)
    if isinstance(rec.get("roofline"), dict):
        raw = rec["roofline"]["hbm_bytes_per_chip"]
        corrected = max(raw - artifact, 0.0)
        rec["roofline"]["a2a_artifact_bytes"] = artifact
        rec["roofline"]["t_memory_corrected_s"] = corrected / 819e9
    return rec


VARIANTS = {
    "knn": {
        "ring": lambda: lower_knn_variant("ring"),
        "a2a": lambda: lower_knn_variant("a2a"),
    },
    "mamba": {
        "baseline": lambda: lower_train_variant(
            "mamba2-130m", "train_4k", {}),
        "bf16_intra": lambda: lower_train_variant(
            "mamba2-130m", "train_4k", {"ssm_intra_dtype": "bf16"}),
        "chunk128": lambda: lower_train_variant(
            "mamba2-130m", "train_4k", {"ssm_chunk": 128}),
        "bf16_chunk128": lambda: lower_train_variant(
            "mamba2-130m", "train_4k",
            {"ssm_intra_dtype": "bf16", "ssm_chunk": 128}),
        "bf16str_chunk128": lambda: lower_train_variant(
            "mamba2-130m", "train_4k",
            {"ssm_intra_dtype": "bf16", "ssm_chunk": 128}),
        "mb8_bf16_c128": lambda: lower_train_variant(
            "mamba2-130m", "train_4k",
            {"ssm_intra_dtype": "bf16", "ssm_chunk": 128},
            microbatches=8),
    },
    "moe": {
        "baseline": lambda: lower_train_variant(
            "deepseek-v2-lite-16b", "train_4k",
            {"attn_head_constraint": False}),
        "headshard": lambda: lower_train_variant(
            "deepseek-v2-lite-16b", "train_4k",
            {"attn_head_constraint": True}),
        "headshard_mb2": lambda: lower_train_variant(
            "deepseek-v2-lite-16b", "train_4k",
            {"attn_head_constraint": True}, microbatches=2),
        "headshard_tri": lambda: lower_train_variant(
            "deepseek-v2-lite-16b", "train_4k",
            {"attn_head_constraint": True, "triangle_schedule": True}),
        # triangle only engages when cq == ckv (chunk grid must be square)
        "headshard_tri512": lambda: lower_train_variant(
            "deepseek-v2-lite-16b", "train_4k",
            {"attn_head_constraint": True, "triangle_schedule": True,
             "attn_chunk_kv": 512}),
    },
    "gemma": {
        "baseline": lambda: lower_train_variant(
            "gemma2-27b", "train_4k", {"attn_head_constraint": False}),
        "headshard": lambda: lower_train_variant(
            "gemma2-27b", "train_4k", {"attn_head_constraint": True}),
        "headshard_tri": lambda: lower_train_variant(
            "gemma2-27b", "train_4k",
            {"attn_head_constraint": True, "triangle_schedule": True,
             "attn_chunk_kv": 512}),
        "headshard_mb2": lambda: lower_train_variant(
            "gemma2-27b", "train_4k",
            {"attn_head_constraint": True}, microbatches=2),
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--variant")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    os.makedirs("results/perf", exist_ok=True)
    todo = (sorted(VARIANTS[args.cell]) if args.all
            else [args.variant])
    for v in todo:
        path = f"results/perf/{args.cell}__{v}.json"
        if os.path.exists(path):
            print(f"skip {v} (exists)")
            continue
        t0 = time.time()
        rec = VARIANTS[args.cell][v]()
        rec.update({"cell": args.cell, "variant": v,
                    "compile_s": round(time.time() - t0, 1)})
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=str)
        r = rec["roofline"]
        print(f"[{args.cell}:{v}] bneck={r['bottleneck']} "
              f"t_c={r['t_compute_s']:.3e} t_m={r['t_memory_s']:.3e} "
              f"t_coll={r['t_collective_s']:.3e} "
              f"rl_frac={r['roofline_fraction']:.4f}", flush=True)


if __name__ == "__main__":
    main()
