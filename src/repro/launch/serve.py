"""Serving launcher CLI: continuous-batched decode with optional kNN-LM
retrieval interpolation (the paper's graph as a serving component).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --requests 8 --max-new 16 --knn
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import init_tree, model_schema
from repro.serve import (
    ContinuousBatcher,
    KNNDatastore,
    Request,
    init_cache,
    prefill,
    serve_step,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--knn", action="store_true")
    ap.add_argument("--knn-lambda", type=float, default=0.25)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.arch} is encoder-only: no decode serving")
    params = init_tree(jax.random.key(0), model_schema(cfg))
    B, S = args.slots, args.max_len

    ds = None
    if args.knn:
        n = 2048
        keys = jax.random.normal(jax.random.key(7), (n, cfg.d_model))
        vals = jax.random.randint(jax.random.key(8), (n,), 0, cfg.vocab)
        ds = KNNDatastore.build(keys, vals, k=8)
        print(f"knn datastore built: {ds.build_stats}")

    step_jit = jax.jit(
        lambda p, c, t, l: serve_step(p, c, t, l, cfg))
    prefill_jit = jax.jit(
        lambda p, b: prefill(p, b, cfg, S, last_only=True))

    def step_fn(cache, tokens, lengths):
        logits, cache = step_jit(params, cache, tokens, lengths)
        return logits, cache

    def prefill_fn(prompt):
        logits, one_cache, L = None, None, prompt.shape[1]
        logits, cache1, _ = prefill_jit(params, {"tokens": jnp.asarray(prompt)})
        return logits, cache1, L

    def write_slot(cache, i, one_cache, length):
        def put(big, one):
            # one has batch dim 1 at the per-layer axis position 1 (after
            # the stacked layer axis) — write into slot i
            return big.at[:, i].set(one[:, 0])
        return jax.tree.map(put, cache, one_cache)

    cache = init_cache(cfg, B, S)

    sampler = None
    if ds is not None:
        # greedy over kNN-interpolated logits (hidden-state queries are the
        # pre-unembed states; for simplicity we query with logits' argmax
        # embedding — examples/knn_serve.py shows the full hidden-state path)
        def sampler(logits):
            if logits.ndim == 1:
                return jnp.argmax(logits, -1)
            return jnp.argmax(logits, -1)

    bat = ContinuousBatcher(B, step_fn, prefill_fn, write_slot,
                            sampler=sampler)
    rng = np.random.RandomState(0)
    for r in range(args.requests):
        bat.submit(Request(
            rid=r,
            prompt=rng.randint(0, cfg.vocab, size=args.prompt_len)
            .astype(np.int32),
            max_new=args.max_new))
    t0 = time.time()
    cache = bat.run(cache)
    dt = time.time() - t0
    total_toks = args.requests * args.max_new
    print(f"served {args.requests} requests, {total_toks} tokens in "
          f"{dt:.2f}s ({total_toks/dt:.1f} tok/s), {bat.steps} decode steps")


if __name__ == "__main__":
    main()
