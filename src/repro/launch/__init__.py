from repro.launch.mesh import (
    HBM_BW,
    ICI_BW_PER_LINK,
    PEAK_FLOPS_BF16,
    make_production_mesh,
    make_test_mesh,
)
from repro.launch.roofline import Roofline, model_flops_step, parse_collectives

__all__ = [
    "HBM_BW",
    "ICI_BW_PER_LINK",
    "PEAK_FLOPS_BF16",
    "Roofline",
    "make_production_mesh",
    "make_test_mesh",
    "model_flops_step",
    "parse_collectives",
]
