"""Loop-aware cost analysis over compiled (optimized, SPMD-partitioned)
HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each ``while`` BODY
ONCE — for scan-based models (scan over layers, microbatch accumulation,
chunked attention, SSD chunk recurrence) that undercounts flops/bytes/
collectives by the product of every enclosing trip count (verified
empirically: a scan of 10 matmuls reports the flops of one). XLA leaves
the information to fix this in the text: every while carries
``backend_config={"known_trip_count":{"n":...}}``.

This module parses the HLO text into its computations, costs each op, and
aggregates over the call graph with loop multipliers:

  flops       — dot/convolution contraction flops (from operand shapes +
                contraction dims); elementwise flops are ignored (VPU-side,
                never the MXU roofline term)
  bytes       — per top-level op: operand + output bytes, with slice-aware
                adjustments (dynamic-slice / gather read the slice, not
                the buffer); fusions are costed at their call-site
                operands/outputs (internals never touch HBM)
  collectives — per kind, with transfer-volume factors (all-reduce ~ 2x
                payload for RS+AG, all-gather counts its output, etc.),
                multiplied through enclosing loops; groups containing
                device ids >= pod-stride apart are tagged DCN (cross-pod)

Validated against cost_analysis on loop-free modules (test suite) and
used by launch/dryrun.py for the §Roofline terms.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLL_FACTORS = {
    # (bytes factor on payload, which payload: 'out' or 'in')
    "all-gather": (1.0, "out"),
    "all-reduce": (2.0, "in"),          # ring RS + AG
    "reduce-scatter": (1.0, "in"),
    "all-to-all": (1.0, "in"),
    "collective-permute": (1.0, "in"),
    "ragged-all-to-all": (1.0, "in"),
}


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' or '(f32[2], s32[])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_shape: str
    operands: list
    attrs: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    shapes: dict            # %name -> out_shape string


_KIND_RE = re.compile(
    r"^((?:\([^)]*\)|[\w\[\],{}]+))\s+([\w\-]+)(?:-start|-done)?\(")


def parse_module(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        if s == "}":
            if cur is not None:
                comps[cur.name] = cur
                cur = None
            continue
        hdr = _COMP_HDR_RE.match(s)
        if hdr and s.endswith("{"):
            cur = Computation(hdr.group(1), [], {})
            continue
        if cur is None:
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rest = d.groups()
        km = _KIND_RE.match(rest)
        if not km:
            continue
        out_shape, kind = km.groups()
        # suffix fix: '-start'/'-done' stripped by regex group
        if rest[km.end(2):km.end(2) + 6] == "-start":
            kind = kind + "-start"
        elif rest[km.end(2):km.end(2) + 5] == "-done":
            kind = kind + "-done"
        # operand list is inside the first parens after kind
        p0 = rest.find("(", km.end(2))
        depth = 0
        p1 = p0
        for i in range(p0, len(rest)):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    p1 = i
                    break
        operands = _OPERAND_RE.findall(rest[p0:p1 + 1])
        attrs = rest[p1 + 1:]
        cur.shapes[name] = out_shape
        cur.ops.append(Op(name, kind, out_shape, operands, attrs,
                          is_root=s.startswith("ROOT")))
    return comps


def _root_dus_update_bytes(comp: Computation) -> float | None:
    """If a fusion computation's root is a dynamic-update-slice (directly
    or behind a bitcast), its big target buffer is ALIASED in-place: true
    HBM traffic is the UPDATE slice, not the buffer. Returns update bytes
    or None."""
    by_name = {op.name: op for op in comp.ops}
    root = next((op for op in comp.ops if op.is_root), None)
    seen = 0
    while root is not None and root.kind in ("bitcast", "copy") and seen < 4:
        root = by_name.get(root.operands[0]) if root.operands else None
        seen += 1
    if root is not None and root.kind == "dynamic-update-slice" \
            and len(root.operands) > 1:
        return _shape_bytes(comp.shapes.get(root.operands[1], ""))
    return None


def _dot_flops(op: Op, comp: Computation) -> float:
    lhs = comp.shapes.get(op.operands[0], "") if op.operands else ""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    lhs_dims = _SHAPE_RE.search(lhs)
    if not lhs_dims:
        return 0.0
    dims = [int(d) for d in lhs_dims.group(2).split(",") if d]
    contract = 1
    if m:
        for i in m.group(1).split(","):
            if i and int(i) < len(dims):
                contract *= dims[int(i)]
    out_elems = _shape_elems(op.out_shape)
    return 2.0 * out_elems * max(contract, 1)


def _conv_flops(op: Op, comp: Computation) -> float:
    # flops = 2 * out_elems * (kernel spatial * in_channels per group)
    rhs = comp.shapes.get(op.operands[1], "") if len(op.operands) > 1 else ""
    rm = _SHAPE_RE.search(rhs)
    if not rm:
        return 0.0
    kdims = [int(d) for d in rm.group(2).split(",") if d]
    out_elems = _shape_elems(op.out_shape)
    if not kdims:
        return 0.0
    import numpy as _np
    return 2.0 * out_elems * float(_np.prod(kdims[:-1])) if len(kdims) > 1 \
        else 2.0 * out_elems * kdims[0]


def _op_bytes(op: Op, comp: Computation) -> float:
    k = op.kind
    if k in ("parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "iota", "after-all", "partition-id", "replica-id"):
        return 0.0
    out_b = _shape_bytes(op.out_shape)
    if k in ("dynamic-slice", "gather"):
        return 2.0 * out_b
    if k == "dynamic-update-slice":
        upd = comp.shapes.get(op.operands[1], "") if len(op.operands) > 1 \
            else ""
        return 2.0 * _shape_bytes(upd) + out_b * 0.0
    if k == "scatter":
        upd = comp.shapes.get(op.operands[-1], "")
        return 2.0 * _shape_bytes(upd) + out_b
    if k in ("broadcast", "copy", "transpose", "reshape", "convert",
             "slice", "reverse", "pad", "concatenate"):
        in_b = sum(_shape_bytes(comp.shapes.get(o, ""))
                   for o in op.operands)
        return float(min(in_b, out_b * 4) + out_b)
    # default: operands + output
    in_b = sum(_shape_bytes(comp.shapes.get(o, "")) for o in op.operands)
    return float(in_b + out_b)


def _trip_count(op: Op) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.attrs)
    if m:
        return float(m.group(1))
    return 1.0


def _called_comps(op: Op) -> list:
    out = []
    for key in ("calls", "to_apply", "condition", "body",
                "true_computation", "false_computation"):
        m = re.search(rf"{key}=%?([\w.\-]+)", op.attrs)
        if m:
            out.append((key, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
    if m:
        for c in _OPERAND_RE.findall(m.group(1)):
            out.append(("branch", c))
    return out


_DCN_STRIDE = 256   # device ids >= one pod apart -> cross-pod (DCN)


def _coll_is_dcn(op: Op) -> bool:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", op.attrs)
    ids: list[int] = []
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x]
    else:
        m = re.search(r"replica_groups=\[\d+,(\d+)\]<=\[([\d,]+)\]",
                      op.attrs)
        if m:
            # iota format [G,S]<=[dims] — conservative: stride test on dims
            return False
    if len(ids) >= 2:
        return max(ids) - min(ids) >= _DCN_STRIDE
    return False


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    dcn_bytes: float = 0.0
    coll_bytes_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.dcn_bytes += other.dcn_bytes * mult
        for k, v in other.coll_bytes_by_kind.items():
            self.coll_bytes_by_kind[k] += v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += v * mult


def analyze(text: str, entry: str | None = None) -> Cost:
    comps = parse_module(text)
    memo: dict[str, Cost] = {}

    # find entry computation (the module prints ENTRY header; we captured
    # its name without the ENTRY marker — pick the one named main* or the
    # one not referenced by others)
    if entry is None:
        referenced = set()
        for c in comps.values():
            for op in c.ops:
                for _, callee in _called_comps(op):
                    referenced.add(callee)
        candidates = [n for n in comps if n not in referenced]
        entry = next((n for n in candidates if n.startswith("main")),
                     candidates[0] if candidates else next(iter(comps)))

    def cost_of(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        c = Cost()
        for op in comp.ops:
            k = op.kind
            base_kind = k.replace("-start", "").replace("-done", "")
            if k.endswith("-done"):
                continue
            if base_kind in _COLL_FACTORS:
                factor, which = _COLL_FACTORS[base_kind]
                if which == "out":
                    payload = _shape_bytes(op.out_shape)
                    if k.endswith("-start"):
                        # '-start' outputs (operand, result) tuple: halve
                        payload = payload / 2.0
                else:
                    payload = sum(_shape_bytes(comp.shapes.get(o, ""))
                                  for o in op.operands)
                b = factor * payload
                c.coll_bytes += b
                c.coll_counts[base_kind] += 1
                c.coll_bytes_by_kind[base_kind] += b
                if _coll_is_dcn(op):
                    c.dcn_bytes += b
                c.bytes += _op_bytes(op, comp)
                continue
            if k == "dot":
                c.flops += _dot_flops(op, comp)
                c.bytes += _op_bytes(op, comp)
            elif k == "convolution":
                c.flops += _conv_flops(op, comp)
                c.bytes += _op_bytes(op, comp)
            elif k == "while":
                trip = _trip_count(op)
                for key, callee in _called_comps(op):
                    mult = trip if key == "body" else trip + 1
                    c.add(cost_of(callee), mult)
                c.bytes += _shape_bytes(op.out_shape)
            elif k == "conditional":
                branches = [cc for _, cc in _called_comps(op)]
                if branches:
                    w = 1.0 / len(branches)
                    for cc in branches:
                        c.add(cost_of(cc), w)
                c.bytes += _op_bytes(op, comp)
            elif k in ("fusion",):
                # flops/collectives recurse into the fused computation.
                # bytes: a fusion's true HBM traffic is its call-site
                # operands+output — EXCEPT when the fusion internally
                # dynamic-slices a big operand (scan-stacked weights!),
                # where it only reads the slice. The internal per-op sum
                # models that case (parameters count 0, the slice op counts
                # its output); elementwise fusions overcount internally
                # (intermediates live in registers). min() of the two
                # bounds picks the right model for each case.
                call_site = _op_bytes(op, comp)
                internal = 0.0
                dus_update = None
                for _, callee in _called_comps(op):
                    sub = cost_of(callee)
                    c.flops += sub.flops
                    c.coll_bytes += sub.coll_bytes
                    c.dcn_bytes += sub.dcn_bytes
                    for kk, vv in sub.coll_bytes_by_kind.items():
                        c.coll_bytes_by_kind[kk] += vv
                    for kk, vv in sub.coll_counts.items():
                        c.coll_counts[kk] += vv
                    internal += sub.bytes
                    cc = comps.get(callee)
                    if cc is not None and dus_update is None:
                        dus_update = _root_dus_update_bytes(cc)
                out_b = _shape_bytes(op.out_shape)
                if dus_update is not None:
                    # in-place accumulation: buffer aliased (appears as an
                    # operand AND the output); traffic = other operands +
                    # 2x the update slice
                    c.bytes += max(call_site - 2.0 * out_b, 0.0) \
                        + 2.0 * dus_update
                elif internal > 0:
                    c.bytes += max(min(call_site, internal), out_b)
                else:
                    c.bytes += call_site
            elif k in ("call", "custom-call", "reduce", "sort", "map",
                       "scatter", "select-and-scatter", "reduce-window"):
                for key, callee in _called_comps(op):
                    sub = cost_of(callee)
                    # comparators/reducers: tiny; include flops only
                    c.flops += sub.flops
                c.bytes += _op_bytes(op, comp)
            else:
                c.bytes += _op_bytes(op, comp)
        memo[name] = c
        return c

    return cost_of(entry)


def analyze_compiled(compiled) -> Cost:
    return analyze(compiled.as_text())
