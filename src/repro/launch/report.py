"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md §Dry-run and
§Roofline markdown tables.

    PYTHONPATH=src python -m repro.launch.report results/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(outdir: str) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_e(x):
    return f"{x:.2e}"


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | status | chips | resident GiB | "
           "no-liveness upper GiB | fits 16G (res/upper) | compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "ok":
            m = r["memory"]
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r['chips']} | {m['resident_bytes']/2**30:.2f} | "
                f"{m['upper_bytes']/2**30:.2f} | "
                f"{'yes' if m['fits_16g_resident'] else 'NO'}/"
                f"{'yes' if m['fits_16g'] else 'no'} | {r['compile_s']} |")
        else:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']}: {r.get('reason', r.get('returncode'))} "
                f"| - | - | - | - | - |")
    return "\n".join(out)


def roofline_table(rows: list[dict], mesh: str = "single") -> str:
    out = ["| arch | shape | t_compute s | t_memory s | t_coll s | "
           "bottleneck | MODEL_FLOPS | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_e(rl['t_compute_s'])} | "
            f"{fmt_e(rl['t_memory_s'])} | {fmt_e(rl['t_collective_s'])} | "
            f"{rl['bottleneck']} | {fmt_e(rl['model_flops'])} | "
            f"{rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.4f} |")
    return "\n".join(out)


def collectives_summary(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | collective bytes/chip | DCN bytes | "
           "top kinds |",
           "|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            continue
        c = r["collectives"]
        kinds = sorted(c["bytes"].items(), key=lambda kv: -kv[1])[:2]
        ks = ", ".join(f"{k} {fmt_e(v)}" for k, v in kinds)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_e(c['total_bytes'])} | {fmt_e(c.get('dcn_bytes', 0))} | "
            f"{ks} |")
    return "\n".join(out)


def main():
    outdir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = load(outdir)
    ok = [r for r in rows if r["status"] == "ok"]
    skip = [r for r in rows if r["status"] == "skip"]
    err = [r for r in rows if r["status"] not in ("ok", "skip")]
    print(f"## Dry-run summary: {len(ok)} compiled, {len(skip)} documented "
          f"skips, {len(err)} errors\n")
    print("### §Dry-run\n")
    print(dryrun_table(rows))
    print("\n### §Roofline (single-pod, 256 chips)\n")
    print(roofline_table(rows, "single"))
    print("\n### Multi-pod deltas (512 chips)\n")
    print(roofline_table(rows, "multi"))
    print("\n### Collective traffic\n")
    print(collectives_summary(ok))


if __name__ == "__main__":
    main()
