"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all in seconds (§Roofline):

    compute    = HLO_FLOPs / (chips * 197 TFLOP/s)
    memory     = HLO_bytes / (chips * 819 GB/s)
    collective = sum over collective ops of operand_bytes / (chips * 50GB/s)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
NOT in cost_analysis — we parse the optimized HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Mesh awareness: each collective's bytes are divided by
the number of participating groups (replica_groups) so the term reflects
per-link traffic of ONE group member, matching the per-chip denominators.
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_shape_bytes(sh: str) -> int:
    """'f32[128,256]' -> bytes; tuples handled by caller."""
    m = _SHAPE_RE.match(sh.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt, 4)
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * b


def _line_output_bytes(line: str) -> int:
    """Sum the byte size of an HLO op's OUTPUT shape (handles tuples)."""
    # '%name = f32[8,128]{1,0} all-gather(...)' or '(f32[..], f32[..]) all-to-all'
    m = re.search(r"=\s*(\([^)]*\)|[\w\[\],{}]+)\s+[\w-]+", line)
    if not m:
        return 0
    shp = m.group(1)
    if shp.startswith("("):
        return sum(_parse_shape_bytes(s) for s in shp[1:-1].split(",")
                   if "[" in s)
    return _parse_shape_bytes(shp.split("{")[0])


def _n_groups(line: str) -> int:
    """Number of replica groups (1 group of N devices -> traffic counted
    once; G independent groups run in parallel on disjoint links)."""
    m = re.search(r"replica_groups=\{(.*?)\}\s", line)
    if not m:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if m:
            return int(m.group(1))
        return 1
    body = m.group(1)
    return max(body.count("{"), 1)


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    total_bytes: float          # per-participant traffic proxy

    def as_dict(self):
        return {"counts": self.counts, "bytes": self.bytes_by_kind,
                "total_bytes": self.total_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    bytes_by: dict = {}
    total = 0.0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("//") or "=" not in ls:
            continue
        for kind in _COLLECTIVES:
            # match op name immediately after the output shape
            if re.search(rf"[\s\)]{kind}(-start|-done)?\(", ls) or \
               re.search(rf"=\s*\S+\s+{kind}(-start)?\(", ls):
                if f"{kind}-done" in ls:
                    break               # counted at -start
                out_b = _line_output_bytes(ls)
                groups = _n_groups(ls)
                per_part = out_b / max(groups, 1)
                counts[kind] = counts.get(kind, 0) + 1
                bytes_by[kind] = bytes_by.get(kind, 0.0) + per_part
                total += per_part
                break
    return CollectiveStats(counts, bytes_by, total)


@dataclasses.dataclass
class Roofline:
    """All byte/flop inputs are PER-CHIP (XLA's SPMD cost_analysis reports
    the per-device partitioned module — verified empirically; the
    spec formula global_FLOPs/(chips*peak) is identical since
    global = per_chip * chips). model_flops is GLOBAL (6*N*D)."""
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip HLO bytes accessed
    coll_bytes: float            # per-chip collective operand bytes
    chips: int
    model_flops: float = 0.0     # global useful flops

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        # per-participant collective bytes; ~4 usable ICI links per chip
        return self.coll_bytes / (4 * ICI_BW_PER_LINK)

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def step_time(self) -> float:
        """Roofline lower bound on step time (max of the three terms,
        assuming perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops (remat/redundancy waste)."""
        tot = self.flops * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-based MFU bound implied by the three terms: useful
        flops per second at the roofline step time over peak."""
        if not self.model_flops:
            return 0.0
        t = self.step_time
        return self.model_flops / (t * self.chips * PEAK_FLOPS_BF16)

    def as_dict(self):
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_from_compiled(compiled, hlo_text: str, chips: int,
                           model_flops: float = 0.0) -> Roofline:
    """Loop-aware terms via launch.hlo_cost (cost_analysis counts while
    bodies once — useless for scan-based models; see hlo_cost docstring).
    The raw cost_analysis numbers are kept by the caller for reference."""
    from repro.launch import hlo_cost
    c = hlo_cost.analyze(hlo_text)
    return Roofline(flops=c.flops, hbm_bytes=c.bytes,
                    coll_bytes=c.coll_bytes, chips=chips,
                    model_flops=model_flops)


def model_flops_train(cfg, n_tokens: int, active_params: int) -> float:
    """6*N*D (fwd 2ND + bwd 4ND)."""
    return 6.0 * active_params * n_tokens


def model_flops_step(kind: str, cfg, seq: int, batch: int,
                     active_params: int) -> float:
    if kind == "train":
        return 6.0 * active_params * seq * batch
    if kind == "prefill":
        return 2.0 * active_params * seq * batch
    return 2.0 * active_params * batch      # decode: one token per slot
