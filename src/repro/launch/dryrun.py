import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (docstring below; the two lines above MUST precede every other import —
# jax locks the device count at first initialization)
_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production mesh with ShapeDtypeStruct stand-ins (no allocation), and
extract the roofline terms from the compiled artifact.

The two lines above MUST run before any other import (jax locks the
device count at first init) — this file is the only place the 512
placeholder devices exist; tests/benches see the real single CPU device.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --arch yi-6b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --sweep --out results/dryrun   # all cells,
                                        # one subprocess per cell (isolation)
  python -m repro.launch.dryrun --arch knn-build --shape knn_1m_256

The paper's own workload (sharded NN-Descent iteration) is a first-class
pseudo-arch ``knn-build`` with its own shape set, so the K-NN engine shows
up in the same roofline table as the LM cells.
"""
__doc__ = _DOC

import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, batch_specs, get_config, input_specs, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    model_flops_step,
    roofline_from_compiled,
)
from repro.models import abstract_tree, active_param_count, model_schema, param_count, sharding_tree
from repro.models.sharding import activation_mesh
from repro.serve import decode as serve_decode
from repro.train import TrainConfig, make_train_step
from repro.train import optimizer as opt_mod

HBM_PER_CHIP = 16 * 1024**3        # v5e: 16 GiB


KNN_SHAPES = {
    # (n points, dim, k): paper-representative K-NN graph builds
    "knn_1m_256": (1 << 20, 256, 20),
    "knn_16m_64": (1 << 24, 64, 20),
}


def _serve_cfg(cfg):
    """Inference deployments run bf16 params (halves HBM)."""
    return dataclasses.replace(cfg, param_dtype=jnp.bfloat16)


def _train_cfg(cfg):
    return dataclasses.replace(cfg, remat="full")


def lower_cell(arch: str, shape: str, multi_pod: bool,
               *, microbatches: int = 4, extra_cfg: dict | None = None):
    """Lower + compile one cell; returns the result record dict."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()

    if arch == "knn-build":
        rec = _lower_knn_cell(shape, mesh)
    else:
        cfg = get_config(arch)
        if extra_cfg:
            cfg = dataclasses.replace(cfg, **extra_cfg)
        if not cfg.supports(shape):
            return {"arch": arch, "shape": shape,
                    "mesh": "multi" if multi_pod else "single",
                    "status": "skip", "reason": cfg.skip_reason(shape)}
        s = SHAPES[shape]
        if s.kind == "train":
            rec = _lower_train(cfg, shape, mesh, microbatches)
        elif s.kind == "prefill":
            rec = _lower_prefill(cfg, shape, mesh)
        else:
            rec = _lower_decode(cfg, shape, mesh)
        rec["params"] = param_count(cfg)
        rec["active_params"] = active_param_count(cfg)

    rec.update({
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips, "status": "ok",
        "compile_s": round(time.time() - t0, 1),
    })
    return rec


def _finish(lowered, mesh, kind, model_flops):
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    rl = roofline_from_compiled(compiled, hlo, mesh.size,
                                model_flops=model_flops)
    from repro.launch import hlo_cost
    cost = hlo_cost.analyze(hlo)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    # Memory model: resident = arguments + outputs - donated aliases
    # (params/opt donated in train, cache donated in decode). The CPU
    # backend's temp_size sums ALL temporary allocations without liveness
    # (while-loop double buffers, layout copies TPU would alias), so the
    # judged peak is max(allocator peak, resident) and temp_bytes is
    # recorded for reference only (see DESIGN.md §12.3).
    resident = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes)
    peak = max(ma.peak_memory_in_bytes, resident)
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "allocator_peak_bytes": ma.peak_memory_in_bytes,
        "resident_bytes": resident,
        "conservative_peak_bytes": resident + ma.temp_size_in_bytes,
        "peak_bytes": peak,
    }
    mem["fits_16g"] = mem["peak_bytes"] <= HBM_PER_CHIP
    return {
        "kind": kind, "memory": mem, "roofline": rl.as_dict(),
        "collectives": {
            "counts": dict(cost.coll_counts),
            "bytes": dict(cost.coll_bytes_by_kind),
            "total_bytes": cost.coll_bytes,
            "dcn_bytes": cost.dcn_bytes,
        },
        # raw XLA aggregate (counts while bodies ONCE — reference only)
        "xla_cost_analysis_raw": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
    }


def _lower_train(cfg, shape, mesh, microbatches):
    cfg = _train_cfg(cfg)
    s = SHAPES[shape]
    schema = model_schema(cfg)
    params_abs = abstract_tree(schema)
    params_sp = sharding_tree(schema, mesh)
    opt_abs = opt_mod.abstract_init(params_abs)
    opt_sp = opt_mod.AdamState(
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        params_sp, params_sp)
    batch_abs = input_specs(cfg, shape)
    batch_sp = batch_specs(cfg, shape, mesh)

    tc = TrainConfig(microbatches=microbatches)
    step = make_train_step(cfg, tc)

    with activation_mesh(mesh):
        # donate params+opt (in-place update: the production train loop
        # does the same; halves the resident param/moment footprint)
        lowered = jax.jit(
            step,
            in_shardings=(params_sp, opt_sp, batch_sp),
            out_shardings=(params_sp, opt_sp, None),
            donate_argnums=(0, 1),
        ).lower(params_abs, opt_abs, batch_abs)
        rec = _finish(lowered, mesh, "train", model_flops_step(
            "train", cfg, s.seq_len, s.global_batch,
            active_param_count(cfg)))
    rec["microbatches"] = microbatches
    return rec


def _lower_prefill(cfg, shape, mesh):
    cfg = _serve_cfg(cfg)
    s = SHAPES[shape]
    schema = model_schema(cfg)
    params_abs = abstract_tree(schema)
    params_sp = sharding_tree(schema, mesh)
    batch_abs = input_specs(cfg, shape)
    batch_sp = batch_specs(cfg, shape, mesh)

    def fn(params, batch):
        logits, cache, lengths = serve_decode.prefill(
            params, batch, cfg, s.seq_len, last_only=True)
        return logits, cache, lengths

    cache_sp = serve_decode.cache_shardings(cfg, s.global_batch,
                                             s.seq_len, mesh)
    with activation_mesh(mesh):
        lowered = jax.jit(
            fn, in_shardings=(params_sp, batch_sp),
            out_shardings=(None, cache_sp, None),
        ).lower(params_abs, batch_abs)
        return _finish(lowered, mesh, "prefill", model_flops_step(
            "prefill", cfg, s.seq_len, s.global_batch,
            active_param_count(cfg)))


def _lower_decode(cfg, shape, mesh):
    cfg = _serve_cfg(cfg)
    s = SHAPES[shape]
    B, S = s.global_batch, s.seq_len
    schema = model_schema(cfg)
    params_abs = abstract_tree(schema)
    params_sp = sharding_tree(schema, mesh)
    cache_abs = serve_decode.abstract_cache(cfg, B, S)
    cache_sp = serve_decode.cache_shardings(cfg, B, S, mesh)
    batch_abs = input_specs(cfg, shape)
    batch_sp = batch_specs(cfg, shape, mesh)

    def fn(params, cache, tokens, lengths):
        return serve_decode.serve_step(params, cache, tokens, lengths, cfg)

    with activation_mesh(mesh):
        # donate the cache (in-place update, as a real server would)
        lowered = jax.jit(
            fn,
            in_shardings=(params_sp, cache_sp, batch_sp["tokens"],
                          batch_sp["lengths"]),
            out_shardings=(None, cache_sp),
            donate_argnums=(1,),
        ).lower(params_abs, cache_abs, batch_abs["tokens"],
                batch_abs["lengths"])
        return _finish(lowered, mesh, "decode", model_flops_step(
            "decode", cfg, S, B, active_param_count(cfg)))


def _lower_knn_cell(shape, mesh):
    """The paper's workload: one sharded NN-Descent iteration + the exact
    ring-KNN validator, points sharded over the data axis."""
    from repro.core.distributed import make_sharded_iteration_lowerable
    n, d, k = KNN_SHAPES[shape]
    lowered, model_flops = make_sharded_iteration_lowerable(
        mesh, n=n, d=d, k=k)
    return _finish(lowered, mesh, "knn", model_flops)


def _print_rec(rec):
    print(json.dumps(rec, indent=2, default=str))
    if rec.get("status") == "ok":
        r = rec["roofline"]
        m = rec["memory"]
        print(
            f"[{rec['arch']} x {rec['shape']} x {rec['mesh']}] "
            f"bottleneck={r['bottleneck']} "
            f"t=(c {r['t_compute_s']:.2e}, m {r['t_memory_s']:.2e}, "
            f"coll {r['t_collective_s']:.2e})s "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"roofline_frac={r['roofline_fraction']:.3f} "
            f"peak_mem={m['peak_bytes']/2**30:.2f}GiB "
            f"fits16G={m['fits_16g']}",
            file=sys.stderr)


def all_cells():
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            cells.append((arch, shape))
    for shape in KNN_SHAPES:
        cells.append(("knn-build", shape))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    if args.sweep:
        os.makedirs(args.out or "results/dryrun", exist_ok=True)
        outdir = args.out or "results/dryrun"
        meshes = ["single", "multi"]
        for arch, shape in all_cells():
            for mesh_kind in meshes:
                name = f"{arch}__{shape}__{mesh_kind}.json"
                path = os.path.join(outdir, name)
                if os.path.exists(path):
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", path,
                       "--microbatches", str(args.microbatches)]
                if mesh_kind == "multi":
                    cmd.append("--multi-pod")
                print(f"=== {arch} x {shape} x {mesh_kind}", flush=True)
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode:
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "mesh": mesh_kind, "status": "error",
                                   "returncode": r.returncode}, f)
        return

    rec = lower_cell(args.arch, args.shape, args.multi_pod,
                     microbatches=args.microbatches)
    _print_rec(rec)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2, default=str)


if __name__ == "__main__":
    main()
