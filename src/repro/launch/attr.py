import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Byte-attribution over compiled HLO: which op sites (x loop multipliers)
# dominate the memory term. The §Perf hypothesis-forming tool.
#   PYTHONPATH=src python -m repro.launch.attr --cell knn --variant a2a

import argparse
from collections import defaultdict

from repro.launch import hlo_cost as H


def attribute(text: str, top: int = 25):
    comps = H.parse_module(text)
    # multipliers per computation
    mult = defaultdict(float)
    referenced = set()
    for c in comps.values():
        for op in c.ops:
            for _, callee in H._called_comps(op):
                referenced.add(callee)
    entry = next((n for n in comps if n not in referenced
                  and n.startswith("main")), None)
    if entry is None:
        entry = next(n for n in comps if n not in referenced)

    def walk(name, m):
        mult[name] += m
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            if op.kind == "while":
                trip = H._trip_count(op)
                for key, callee in H._called_comps(op):
                    walk(callee, m * (trip if key == "body" else trip + 1))
            elif op.kind == "conditional":
                br = [cc for _, cc in H._called_comps(op)]
                for cc in br:
                    walk(cc, m / max(len(br), 1))
            elif op.kind == "fusion":
                pass          # costed at call site
            else:
                for _, callee in H._called_comps(op):
                    walk(callee, m)
    walk(entry, 1.0)

    memo: dict = {}

    def comp_bytes(name):
        """bytes of one execution of computation `name` (for fusion
        internals), memoized."""
        if name in memo:
            return memo[name]
        memo[name] = 0.0
        comp = comps.get(name)
        if comp is None:
            return 0.0
        t = 0.0
        for op in comp.ops:
            t += site_bytes(op, comp)
        memo[name] = t
        return t

    def site_bytes(op, comp):
        if op.kind == "fusion":
            call_site = H._op_bytes(op, comp)
            internal = 0.0
            dus = None
            for _, callee in H._called_comps(op):
                internal += comp_bytes(callee)
                cc = comps.get(callee)
                if cc is not None and dus is None:
                    dus = H._root_dus_update_bytes(cc)
            out_b = H._shape_bytes(op.out_shape)
            if dus is not None:
                return max(call_site - 2 * out_b, 0) + 2 * dus
            if internal > 0:
                return max(min(call_site, internal), out_b)
            return call_site
        if op.kind in ("while", "conditional"):
            return 0.0       # attributed through children
        return H._op_bytes(op, comp)

    rows = []
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for op in comp.ops:
            b = site_bytes(op, comp) * m
            if b > 0:
                meta = ""
                i = op.attrs.find('op_name="')
                if i >= 0:
                    meta = op.attrs[i + 9: i + 120].split('"')[0]
                rows.append((b, op.kind, name, op.name, m, meta))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    # re-lower, keep the hlo text
    import repro.launch.dryrun as dr
    captured = {}
    orig = dr._finish

    def capture(lowered, mesh, kind, mf):
        compiled = lowered.compile()
        captured["text"] = compiled.as_text()
        return {"kind": kind, "memory": {}, "roofline": {},
                "collectives": {}}

    dr._finish = capture
    import repro.launch.perf as perf
    perf._finish = capture
    try:
        perf.VARIANTS[args.cell][args.variant]()
    finally:
        dr._finish = orig
        perf._finish = orig
    rows = attribute(captured["text"], args.top)
    tot = sum(r[0] for r in rows)
    print(f"top-{args.top} byte sites (sum {tot:.3e}):")
    for b, kind, comp, op, m, meta in rows:
        print(f"{b:10.3e}  {kind:22s} x{m:<8.0f} {meta[:80]}")


if __name__ == "__main__":
    main()
