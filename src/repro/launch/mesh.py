"""Production meshes.

    single-pod:  (data=16, model=16)        = 256 chips  (TPU v5e pod)
    multi-pod:   (pod=2, data=16, model=16) = 512 chips  (2 pods over DCN)

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (works with 4-8 forced host devices)."""
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


# TPU v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~4 links usable per chip)
