"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt --resume auto

On this CPU container only --smoke configs are runnable; the full-config
path is exercised by the dry-run (launch/dryrun.py). The launcher wires
together: config -> schema -> (mesh+shardings if >1 device) -> data
pipeline -> train loop with checkpointing + fault policy.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.models import init_tree, model_schema, param_count
from repro.train import OptimizerConfig, TrainConfig, TrainLoop, make_train_step
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import Checkpointer, config_hash
from repro.train.fault import FaultPolicy, StragglerWatchdog


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default=None, choices=[None, "auto"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.arch} params={param_count(cfg):,}")

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    vocab=cfg.vocab)
    pipe = TokenPipeline(dc)

    params = init_tree(jax.random.key(0), model_schema(cfg))
    opt_state = opt_mod.init(params)

    tc = TrainConfig(
        microbatches=args.microbatches,
        opt=OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                            total_steps=args.steps),
    )
    step_fn = jax.jit(make_train_step(cfg, tc))

    ck = None
    fault = None
    start_step = 0
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir, every=args.ckpt_every,
                          cfg_hash=config_hash(cfg))
        fault = FaultPolicy(ck)
        if args.resume == "auto" and ck.latest_step() is not None:
            start_step, tree = ck.load(
                like={"params": params, "opt_state": opt_state})
            params, opt_state = tree["params"], tree["opt_state"]
            print(f"resumed from step {start_step}")

    dog = StragglerWatchdog()

    def log(m):
        print(json.dumps({k: round(v, 5) if isinstance(v, float) else v
                          for k, v in m.items()}))

    loop = TrainLoop(cfg, tc, step_fn, checkpointer=ck, fault=fault,
                     log_every=args.log_every)

    def batches():
        n = 0
        for b in pipe:
            if n >= args.steps - start_step:
                return
            dog.step_start()
            yield b
            n += 1

    params, opt_state, hist = loop.run(
        params, opt_state, batches(), start_step=start_step, callback=log)
    print(f"done: {len(hist)} logs, final loss "
          f"{hist[-1]['loss'] if hist else float('nan'):.4f}")
    return params, opt_state, hist


if __name__ == "__main__":
    main()
