"""Deterministic, restartable, host-sharded data pipeline.

Sources are synthetic (this container has no corpora) but the pipeline
layer is real: deterministic sample order derived from (seed, step) so a
restarted job resumes mid-epoch bit-identically; host sharding by
process_index; sequence packing; background prefetch.

``SemanticOrderedSource`` is the paper's technique applied at the corpus
level (DESIGN.md §3): a K-NN graph over example embeddings + the greedy
reorder permutation produce a locality-optimized traversal order, so
consecutive batches draw from nearby regions of embedding space
(semantic batching; datastore/page locality in retrieval training).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    vocab: int = 512
    seed: int = 0
    pack: bool = True
    prefetch: int = 2


class SyntheticLMSource:
    """Deterministic synthetic token documents (zipfian unigrams with
    per-doc topic drift so consecutive tokens correlate — gives training
    a learnable signal for the examples)."""

    def __init__(self, vocab: int, seed: int = 0, mean_len: int = 384):
        self.vocab = vocab
        self.seed = seed
        self.mean_len = mean_len

    def doc(self, i: int) -> np.ndarray:
        rng = np.random.RandomState((self.seed * 1_000_003 + i) % (2**31))
        n = max(8, int(rng.exponential(self.mean_len)))
        topic = rng.randint(0, max(self.vocab // 64, 1))
        base = rng.zipf(1.5, size=n) % (self.vocab // 2)
        drift = (topic * 64 + rng.randint(0, 64, size=n)) % self.vocab
        use_topic = rng.rand(n) < 0.5
        return np.where(use_topic, drift, base).astype(np.int32)


def pack_documents(source, start_doc: int, seq_len: int, n_seqs: int,
                   *, eod: int = 0):
    """Pack docs into (n_seqs, seq_len+1) contiguous token rows; returns
    (rows, next_doc) so the caller can resume exactly."""
    need = n_seqs * (seq_len + 1)
    toks: list[np.ndarray] = []
    total = 0
    d = start_doc
    while total < need:
        t = source.doc(d)
        toks.append(np.append(t, eod))
        total += len(t) + 1
        d += 1
    flat = np.concatenate(toks)[:need]
    return flat.reshape(n_seqs, seq_len + 1), d


class TokenPipeline:
    """Host-sharded iterator of {'tokens','labels'} batches."""

    def __init__(self, dc: DataConfig, *, process_index: int | None = None,
                 process_count: int | None = None,
                 order: np.ndarray | None = None):
        self.dc = dc
        self.pi = jax.process_index() if process_index is None else process_index
        self.pc = jax.process_count() if process_count is None else process_count
        assert dc.global_batch % self.pc == 0
        self.local_batch = dc.global_batch // self.pc
        self.source = SyntheticLMSource(dc.vocab, dc.seed)
        self.order = order          # optional semantic permutation of docs
        self._doc = self.pi         # interleave hosts over the doc stream

    def state(self) -> dict:
        return {"doc": self._doc}

    def restore(self, state: dict):
        self._doc = state["doc"]

    def _next_rows(self) -> np.ndarray:
        rows, nxt = pack_documents(
            _Permuted(self.source, self.order), self._doc,
            self.dc.seq_len, self.local_batch)
        # stride hosts: each host consumes every pc-th doc region
        self._doc = self._doc + (nxt - self._doc) * self.pc
        return rows

    def __iter__(self) -> Iterator[dict]:
        if self.dc.prefetch:
            return _prefetch(self._gen(), self.dc.prefetch)
        return self._gen()

    def _gen(self):
        while True:
            rows = self._next_rows()
            yield {
                "tokens": jnp.asarray(rows[:, :-1]),
                "labels": jnp.asarray(rows[:, 1:]),
            }


class _Permuted:
    def __init__(self, source, order):
        self.source = source
        self.order = order

    def doc(self, i: int) -> np.ndarray:
        if self.order is None:
            return self.source.doc(i)
        return self.source.doc(int(self.order[i % len(self.order)]))


def _prefetch(gen, depth: int):
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in gen:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
