from repro.data.pipeline import DataConfig, SyntheticLMSource, TokenPipeline, pack_documents
from repro.data.ordering import mean_pool_embeddings, semantic_order

__all__ = [
    "DataConfig",
    "SyntheticLMSource",
    "TokenPipeline",
    "mean_pool_embeddings",
    "pack_documents",
    "semantic_order",
]
