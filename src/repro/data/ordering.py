"""Semantic data ordering — the paper's greedy reorder (§3.2) applied at
the corpus level.

Build a K-NN graph over per-example embeddings with the paper's
NN-Descent, run the greedy clustering heuristic to get the locality
permutation σ, and traverse the corpus in σ-order: consecutive training
batches then draw from nearby regions of embedding space. This is the
exact C3 mechanism (turn data-space locality into memory/stream-space
locality) — the beneficiary here is the retrieval datastore / embedding
cache instead of the L2 cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DescentConfig, build_knn_graph, greedy_reorder, locality_stats
from repro.core.heap import NeighborLists


def semantic_order(
    embeddings: jax.Array,     # (n_docs, d) example embeddings
    *,
    k: int = 10,
    key: jax.Array | None = None,
    cfg: DescentConfig | None = None,
) -> tuple[np.ndarray, dict]:
    """Returns (order (n,) int32: position -> doc id, stats)."""
    cfg = cfg or DescentConfig(k=k, rho=1.0, max_iters=8, reorder=False)
    dist, idx, st = build_knn_graph(embeddings, k=k, cfg=cfg, key=key)
    nl = NeighborLists(dist, idx, jnp.zeros_like(idx, dtype=bool))
    before = locality_stats(nl)
    sigma, sigma_inv = greedy_reorder(nl)
    # reorderd graph locality (for reporting): rewrite ids through sigma
    n = idx.shape[0]
    idx_r = jnp.where(idx >= 0, sigma[jnp.clip(idx, 0, n - 1)], -1)[sigma_inv]
    after = locality_stats(
        NeighborLists(dist[sigma_inv], idx_r, jnp.zeros_like(idx_r, dtype=bool)))
    stats = {
        "build_iters": st.iters,
        "dist_evals": st.dist_evals,
        "in_block_before": before["in_block_fraction"],
        "in_block_after": after["in_block_fraction"],
    }
    return np.asarray(sigma_inv), stats     # position p reads doc sigma_inv[p]


def mean_pool_embeddings(token_batches, d_proj: int = 64,
                         vocab: int | None = None, seed: int = 0):
    """Cheap example embeddings for ordering when no model is in hand:
    random-projection bag-of-tokens (deterministic). token_batches:
    (n, L) int32 array."""
    toks = np.asarray(token_batches)
    n, L = toks.shape
    v = int(vocab if vocab is not None else toks.max() + 1)
    rng = np.random.RandomState(seed)
    proj = rng.normal(0, 1 / np.sqrt(d_proj), size=(v, d_proj)).astype(
        np.float32)
    out = proj[toks.reshape(-1)].reshape(n, L, d_proj).mean(axis=1)
    return jnp.asarray(out)
