"""Query-time greedy best-first search over a built K-NN graph.

This is the serving-side consumer of the paper's artifact: given the
NN-Descent graph, answer nearest-neighbor queries by repeatedly expanding
the closest unexpanded pool entry and merging its graph neighbors into the
pool (NSW/NSG-style search restricted to the K-NN graph, fixed shapes:
bounded pool, static expansion rounds). Used by serve/knn_lm.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


_BIG = 3.0e38


@functools.partial(jax.jit, static_argnames=("hops", "capacity"))
def expand_frontier(
    graph_idx: jax.Array,   # (n, k) neighbor ids, -1 = empty
    seeds: jax.Array,       # (s,) seed row ids, -1 = padding
    *,
    hops: int = 1,
    capacity: int,
    alive: jax.Array | None = None,   # (n,) bool — rows to keep
):
    """h-hop outbound closure of ``seeds`` over the K-NN graph, compacted
    into a padded id buffer (the localized-update frontier of
    core/online.py: after a change at ``seeds``, refinement only needs to
    propagate along this closure — the friend-of-a-friend principle).

    Returns (ids (capacity,) int32 ascending with -1 padding at the tail,
    mask (n,) bool). When the closure exceeds ``capacity`` the smallest
    ``capacity`` ids are kept (the mask is exact either way). The mask
    passes are O(n*k) bitwise work — no distance evaluations; the point is
    that the *expensive* per-row kernels then run on the compacted ids.
    """
    n, _ = graph_idx.shape
    mask = jnp.zeros((n,), bool)
    mask = mask.at[jnp.where(seeds >= 0, seeds, n)].set(True, mode="drop")
    for _h in range(hops):
        hit = mask[:, None] & (graph_idx >= 0)
        tgt = jnp.where(hit, graph_idx, n).reshape(-1)
        mask = mask.at[tgt].set(True, mode="drop")
    if alive is not None:
        mask &= alive
    ids = jnp.nonzero(mask, size=capacity, fill_value=-1)[0].astype(jnp.int32)
    return ids, mask


@functools.partial(jax.jit, static_argnames=("k_out", "beam", "rounds"))
def graph_search(
    x: jax.Array,          # (n, d) corpus (feature-padded ok)
    graph_idx: jax.Array,  # (n, k) neighbor ids
    queries: jax.Array,    # (q, d)
    *,
    k_out: int = 10,
    beam: int = 32,
    rounds: int = 24,
    entry: jax.Array | None = None,   # (e,) entry point ids
    key: jax.Array | None = None,
    alive: jax.Array | None = None,   # (n,) bool — tombstone mask
):
    """Returns (dist (q, k_out), idx (q, k_out)) ascending.

    With ``alive`` given (the online store's tombstone mask), dead nodes
    are neither expanded nor returned: entry points are drawn from live
    rows only and dead neighbors are masked out of the pool.
    """
    n, k = graph_idx.shape
    x = x.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1)
    if entry is None:
        # one entry per beam slot: a K-NN graph over clustered data has no
        # inter-cluster edges, so search can only reach clusters that hold
        # an entry point — spread the whole beam across the corpus
        key = jax.random.key(0) if key is None else key
        if alive is None:
            entry = jax.random.randint(key, (beam,), 0, n)
        else:
            # uniform over live rows: top-`beam` random keys among alive
            w = jnp.where(alive, jax.random.uniform(key, (n,)), -1.0)
            _, entry = jax.lax.top_k(w, beam)

    def q_dist(q, ids):
        rows = x[ids]
        return jnp.maximum(
            x2[ids] - 2.0 * rows @ q + jnp.sum(q * q), 0.0
        )

    def one_query(q):
        pool_i = jnp.full((beam,), -1, dtype=jnp.int32)
        pool_d = jnp.full((beam,), _BIG, dtype=jnp.float32)
        pool_e = jnp.zeros((beam,), dtype=bool)   # expanded?
        e = entry.shape[0]
        pool_i = pool_i.at[:e].set(entry.astype(jnp.int32))
        pool_d = pool_d.at[:e].set(q_dist(q, entry))
        if alive is not None:
            dead = (pool_i >= 0) & ~alive[jnp.clip(pool_i, 0, n - 1)]
            pool_d = jnp.where(dead, _BIG, pool_d)

        def round_fn(_, state):
            pool_d, pool_i, pool_e = state
            # best unexpanded entry
            score = jnp.where(pool_e | (pool_i < 0), _BIG, pool_d)
            b = jnp.argmin(score)
            node = pool_i[b]
            can = score[b] < _BIG
            pool_e = pool_e.at[b].set(True)
            nbrs = graph_idx[jnp.clip(node, 0, n - 1)]       # (k,)
            nb_ok = (nbrs >= 0) & can
            if alive is not None:
                nb_ok &= alive[jnp.clip(nbrs, 0, n - 1)]
            nd = jnp.where(nb_ok, q_dist(q, jnp.clip(nbrs, 0, n - 1)), _BIG)
            # merge pool + neighbors, dedup by id, keep best `beam`
            all_i = jnp.concatenate([pool_i, jnp.where(nb_ok, nbrs, -1)])
            all_d = jnp.concatenate([pool_d, nd])
            all_e = jnp.concatenate([pool_e, jnp.zeros((k,), bool)])
            # dedup: mark later duplicates invalid (stable: pool first).
            # Sort-by-id adjacent-duplicate pass — O(m log m) instead of
            # the O(m^2) eq&earlier matrix; the stable sort keeps the
            # earliest (pool) occurrence first among equal ids, preserving
            # the expanded flag exactly like the matrix form did.
            sid = jnp.argsort(all_i, stable=True)
            si = all_i[sid]
            adj = jnp.concatenate(
                [jnp.zeros((1,), bool), si[1:] == si[:-1]]
            )
            dup = jnp.zeros_like(adj).at[sid].set(adj) & (all_i >= 0)
            all_d = jnp.where(dup | (all_i < 0), _BIG, all_d)
            order = jnp.argsort(all_d)[:beam]
            return all_d[order], all_i[order], all_e[order]

        pool_d, pool_i, pool_e = jax.lax.fori_loop(
            0, rounds, round_fn, (pool_d, pool_i, pool_e)
        )
        out_d, out_i = pool_d[:k_out], pool_i[:k_out]
        if alive is not None:
            # dead entry points survive in the pool at distance _BIG;
            # never surface them
            out_i = jnp.where(out_d >= _BIG, -1, out_i)
        return out_d, out_i

    return jax.vmap(one_query)(queries.astype(jnp.float32))
