"""Query-time search over a built K-NN graph — fused, batched, blocked.

This is the serving-side consumer of the paper's artifact: given the
NN-Descent graph, answer nearest-neighbor queries by beam search restricted
to the K-NN graph (NSW/NSG-style, fixed shapes). Used by serve/knn_lm.py,
the online store's query path, and the online insert's seeding.

Two implementations behind ``SearchConfig.backend``:

  * **fused** (auto | pallas | interpret) — the serving counterpart of the
    fused build join: queries are processed in blocks of
    ``SearchConfig.q_block``; each round expands the top-``expand``
    unexpanded pool nodes of EVERY query in the block at once (the
    friend-of-a-friend principle — Baron & Darling — is what lets several
    frontier nodes expand per round without losing convergence, and Wang
    et al.'s GPU construction shows the win of batching traversal into
    wide fixed-shape rounds). The E·k gathered neighbor rows become one
    (q_block, E·k) distance tile on the MXU (kernels/knn_search.py, norms
    hoisted once per batch), the ``knn_join_select`` partial top-C
    machinery reduces the tile under the pool's k-th-distance prefilter
    (no per-round full argsorts), and the pool is maintained by the same
    sort-free bounded merge as the build (``heap.merge_kernel`` /
    ``ops.knn_merge``, dedup by id) with the NeighborLists ``new`` flag
    reused as "not yet expanded". Sequential depth drops from ``rounds``
    to ~``rounds/expand``, with a convergence early-out when no query in
    the block has an unexpanded pool entry left.

  * **ref** — the original one-node-per-round greedy loop, retained as
    the parity oracle (same interface, per-query vmap, full argsorts).

``rounds`` is the *expansion budget* (total pool nodes expanded per
query) under both backends: the fused path runs ceil(rounds/expand)
rounds of ``expand`` expansions, so with expand | rounds (the default
and every shipped config) both backends expand exactly ``rounds`` nodes;
otherwise the fused budget rounds UP to the next multiple of ``expand``
(core/online.py's analytic eval bound accounts for this).

Entry points: when ``entry`` is None and a ``router`` is passed (the
serving default — MutableKNNStore / KNNDatastore thread theirs), each
query's beam is seeded from the member rows of its top-``router_t``
centroids (core/router.py — the hierarchical entry points that fixed the
large-n recall collapse); holes, and the no-router / ``router="off"`` /
``backend="ref"`` cases, fall back to a keyed draw uniform over live
(and filter-admitted) rows. When ``key`` is None it is derived from the
*content* of the query batch instead of a silent constant, so repeated
serving batches stop reusing identical entry points while identical
batches stay deterministic; serving callers should still thread an
explicit key (serve/knn_lm.knn_logits, core/online.knn_insert do).

**Metric** (``SearchConfig.metric``: l2 | cosine | mips): the kernels
only ever compute squared l2 — cosine and MIPS ride the input-side
reductions of core/metric.py. The CORPUS handed to ``graph_search`` must
already be transformed (stores built with ``OnlineConfig.metric`` /
``DescentConfig.metric`` do this once at build/insert); the QUERIES are
transformed here, once per batch (cosine: row-normalize; mips: append
the zero coordinate — realized as zero right-padding, which is also what
feature padding does, so any narrower query widens safely). Returned
distances are transformed-space squared l2 (monotone in the native
metric; ``metric.similarity_from_dist`` converts back exactly).

**Filtered search** (``filter_ids``): a caller-supplied predicate mask —
(n,) shared across the batch, or (q, n) per query (True = row admitted).
It rides the exact alive-mask path the tombstones use: filtered rows are
neither expanded nor returned (their ids fold to -1 before the distance
tile, and ``kernels/knn_search.py``'s epilogue maps id -1 to +inf), so a
filtered-out id can never surface — the zero-leakage contract the CI
metric lane gates. Highly selective filters cost recall the way mass
deletions do: the beam must traverse THROUGH admitted rows only (see
docs/METRICS.md; ``metric.filter_frac`` reports the admitted fraction).
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings

import jax
import jax.numpy as jnp

from repro.core import heap, quantize
from repro.core import metric as metric_mod
from repro.core.heap import NeighborLists
from repro.core.quantize import QuantizedStore
from repro.kernels import ops


_BIG = 3.0e38


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    beam: int = 32          # pool width per query
    rounds: int = 24        # expansion budget: pool nodes expanded/query
    expand: int = 4         # E: nodes expanded per round (fused path)
    q_block: int = 256      # queries per fused block (compile-once shape)
    backend: str = "auto"   # auto | pallas | interpret = fused kernels;
                            # ref = the greedy one-node-per-round oracle
    select_c: int = 0       # candidate width handed to the pool merge
                            # (0 = beam; the top-C select reduces the E*k
                            # tile to this before the bounded merge)
    precision: str = "f32"  # f32 | bf16 | int8 — candidate-SCORING dtype
                            # (kernels/l2_quant.py). Quantized modes run
                            # the whole beam traversal on the quantized
                            # store and re-rank the final pool with the
                            # exact fp32 kernel before returning, so the
                            # output distances stay exact; quantization
                            # costs bounded candidate-recall noise only.
                            # backend="ref" (the parity oracle) is always
                            # fp32 and ignores this knob.
    metric: str = "l2"      # l2 | cosine | mips — metric the distances
                            # realize via core/metric.py's input-side
                            # reductions (the kernels stay pure squared
                            # l2). The corpus must be pre-transformed
                            # (stores with a matching OnlineConfig.metric
                            # are); queries transform per batch inside
                            # graph_search. Returned distances are
                            # transformed-space l2 — monotone in the
                            # native metric, convertible back exactly
                            # via metric.similarity_from_dist.
    router: str = "auto"    # auto = seed the beam from the router's
                            # centroid member lists when a router is
                            # passed; off = always random entries.
                            # backend="ref" keeps random entries either
                            # way (the parity oracle predates the router).
    router_t: int = 4       # centroids probed per query when routing
    strict: bool = False    # admission policy for poisoned query batches
                            # (NaN/Inf rows): True rejects the whole
                            # batch with ValueError; False (default)
                            # sanitizes — bad rows are zeroed for the
                            # traversal, their outputs overwritten with
                            # (+inf, -1), and a RuntimeWarning reports
                            # the count. Dim mismatches always reject
                            # (there is no safe way to guess features).
    max_rounds_deadline: float = 0.0
                            # per-q_block time slice in seconds; 0 = off.
                            # Once the batch has spent its cumulative
                            # slice, remaining blocks run with the
                            # expansion budget cut to one fused round
                            # (rounds=expand) — degraded recall, never a
                            # stall. Ignored under tracing (shard_map).
    fixed_block: bool = False
                            # True pads EVERY batch to the full q_block
                            # (one compiled shape, full-block latency for
                            # any batch size — the legacy serving quantum,
                            # kept as the SLO baseline). False (default)
                            # runs each batch at its q_block_bucket ladder
                            # step: powers of two up to q_block, compiled
                            # once per bucket, so a 7-query burst pays the
                            # 8-block, not the q_block-block.

    @property
    def n_rounds(self) -> int:
        """Fused sequential depth: ceil(rounds / expand)."""
        return max(1, -(-self.rounds // self.expand))


def q_block_bucket(nq: int, cfg: "SearchConfig") -> int:
    """The bucketed ``q_block`` ladder: the query-block shape a batch of
    ``nq`` queries runs at. Buckets are powers of two capped at
    ``cfg.q_block`` (step-quantized exactly like the online store's
    capacity doubling), so the set of compiled block shapes stays
    O(log q_block) — each bucket compiles once and is reused by every
    batch that lands in it — while a small interactive burst stops
    paying the full-block distance tile (pad waste stays < 2x).
    ``cfg.fixed_block`` pins the ladder to the single legacy full-block
    quantum (the measured baseline in benchmarks/bench_slo.py).
    Metric- and filter-independent: the bucket depends only on ``nq``
    (query transforms are per-row, and per-query ``filter_ids`` masks
    are sliced along the query axis with the block)."""
    if cfg.fixed_block or nq <= 0:
        return max(1, cfg.q_block)
    return max(1, min(cfg.q_block, 1 << (nq - 1).bit_length()))


@functools.partial(jax.jit, static_argnames=("hops", "capacity"))
def expand_frontier(
    graph_idx: jax.Array,   # (n, k) neighbor ids, -1 = empty
    seeds: jax.Array,       # (s,) seed row ids, -1 = padding
    *,
    hops: int = 1,
    capacity: int,
    alive: jax.Array | None = None,   # (n,) bool — rows to keep
):
    """h-hop outbound closure of ``seeds`` over the K-NN graph, compacted
    into a padded id buffer (the localized-update frontier of
    core/online.py: after a change at ``seeds``, refinement only needs to
    propagate along this closure — the friend-of-a-friend principle).

    Returns (ids (capacity,) int32 ascending with -1 padding at the tail,
    mask (n,) bool). When the closure exceeds ``capacity`` the rows
    NEAREST the seeds (fewest hops, ids breaking ties) are kept — the
    old smallest-id truncation systematically dropped late rows on
    hub-heavy closures (ROADMAP watch item). The mask is exact either
    way. The hop passes are O(n*k) bitwise work — no distance
    evaluations; the point is that the *expensive* per-row kernels then
    run on the compacted ids. Pure graph topology, so metric-oblivious
    (it never sees features); ``alive`` folds out tombstoned rows —
    query-time filter masks do NOT apply here (the frontier is an
    update-path construct, not a query result).
    """
    n, _ = graph_idx.shape
    # scatter-min BFS: hop[i] = fewest hops from any seed (hops+1 = unseen)
    hop = jnp.full((n,), hops + 1, jnp.int32)
    hop = hop.at[jnp.where(seeds >= 0, seeds, n)].min(0, mode="drop")
    for h in range(1, hops + 1):
        hit = (hop[:, None] < h) & (graph_idx >= 0)
        tgt = jnp.where(hit, graph_idx, n).reshape(-1)
        hop = hop.at[tgt].min(h, mode="drop")
    mask = hop <= hops
    if alive is not None:
        mask &= alive
    big = jnp.iinfo(jnp.int32).max
    kcap = min(capacity, n)
    # lexicographic (hop, id) packed into one key — (hops+2)*n stays well
    # inside int32 for every supported store size
    score = jnp.where(mask, hop * n + jnp.arange(n, dtype=jnp.int32), big)
    sel = jnp.sort(score)[:kcap]
    # recover ids and re-sort ascending (the -1 tail sorts last via the
    # n sentinel) — _frontier_slots searchsorts over this buffer
    ids = jnp.sort(jnp.where(sel < big, sel % n, n))
    ids = jnp.where(ids < n, ids, -1).astype(jnp.int32)
    if kcap < capacity:
        ids = jnp.concatenate(
            [ids, jnp.full((capacity - kcap,), -1, jnp.int32)]
        )
    return ids, mask


# ---------------------------------------------------------------------------
# entry-point seeding
# ---------------------------------------------------------------------------


def _batch_key(queries: jax.Array) -> jax.Array:
    """Content-derived entry key: replaces the retired silent
    ``jax.random.key(0)`` fallback. Two folds: the plain feature sum plus
    a position-weighted sum (bounded cos weights, so the positional term
    survives f32 accumulation at any batch size) — permuted batches share
    the first hash but not the second, so shuffled copies of a batch no
    longer reuse identical entry points."""
    flat = queries.astype(jnp.float32).reshape(-1)
    w = jnp.cos(jnp.arange(flat.shape[0], dtype=jnp.float32) * 1.6180339)
    h1 = jax.lax.bitcast_convert_type(jnp.sum(flat), jnp.uint32)
    h2 = jax.lax.bitcast_convert_type(jnp.sum(flat * w), jnp.uint32)
    return jax.random.fold_in(jax.random.fold_in(jax.random.key(0), h1), h2)


def _draw_entries(
    key: jax.Array, n: int, beam: int, alive: jax.Array | None
) -> jax.Array:
    """One entry per beam slot, uniform over live rows. Both branches use
    the keyed top-k draw — sampling WITHOUT replacement (the retired
    ``randint`` draw produced duplicate ids that the pool merge then
    dedup'd away, silently wasting beam slots). Width is min(beam, n)."""
    w = jax.random.uniform(key, (n,))
    if alive is not None:
        w = jnp.where(alive, w, -1.0)
    _, entry = jax.lax.top_k(w, min(beam, n))
    return entry.astype(jnp.int32)


# ---------------------------------------------------------------------------
# public dispatcher
# ---------------------------------------------------------------------------


def _admit_queries(queries: jax.Array, d: int, strict: bool):
    """Admission check at the search boundary: a poisoned batch (NaN/Inf
    rows, wrong feature dim) must not propagate through the pool merge —
    one non-finite distance poisons every merge it touches.

    Returns (queries, bad_rows) where bad_rows is a (q,) bool mask of
    sanitized rows (None when the batch is clean). ``strict`` rejects
    non-finite rows with ValueError instead of sanitizing; a feature-dim
    mismatch always rejects (no safe way to guess features). Skipped
    entirely under tracing — graph_search runs inside shard_map bodies,
    where the DRIVER (graph_search_sharded) has already admitted the
    concrete batch."""
    if isinstance(queries, jax.core.Tracer) or queries.shape[0] == 0:
        return queries, None
    if queries.ndim != 2 or queries.shape[1] != d:
        raise ValueError(
            f"query batch has shape {tuple(queries.shape)}; corpus rows "
            f"have feature dim {d} — rejecting the batch at admission"
        )
    finite = jnp.all(jnp.isfinite(queries), axis=1)
    if bool(jnp.all(finite)):
        return queries, None
    n_bad = int(jnp.sum(~finite))
    if strict:
        raise ValueError(
            f"query batch contains {n_bad} non-finite row(s) (NaN/Inf) — "
            "rejected (SearchConfig.strict=True)"
        )
    warnings.warn(
        f"sanitized {n_bad} non-finite query row(s); their results are "
        "empty (+inf/-1)", RuntimeWarning, stacklevel=3)
    return jnp.where(finite[:, None], queries, 0.0), ~finite


def _mask_bad_rows(dist, idx, bad_rows):
    """Overwrite sanitized rows' outputs with the empty-slot sentinel."""
    if bad_rows is None:
        return dist, idx
    return (jnp.where(bad_rows[:, None], jnp.inf, dist),
            jnp.where(bad_rows[:, None], -1, idx))


def graph_search(
    x: jax.Array,          # (n, d) corpus (feature-padded ok)
    graph_idx: jax.Array,  # (n, k) neighbor ids
    queries: jax.Array,    # (q, d)
    *,
    k_out: int = 10,
    beam: int = 32,
    rounds: int = 24,
    entry: jax.Array | None = None,   # (e,) entry point ids
    key: jax.Array | None = None,
    alive: jax.Array | None = None,   # (n,) bool — tombstone mask
    x2: jax.Array | None = None,      # (n,) cached squared norms
    cfg: SearchConfig | None = None,
    qstore: QuantizedStore | None = None,   # cached quantized corpus
    router=None,                            # core/router.Router — routed seeds
    filter_ids: jax.Array | None = None,    # (n,) shared or (q, n) per-query
                                            # predicate mask (True = admitted)
):
    """Returns (dist (q, k_out), idx (q, k_out)) ascending; empty slots
    are (+inf/_BIG, -1).

    ``cfg`` wins over the legacy ``beam``/``rounds`` kwargs when given.
    ``entry`` may be (e,) shared across the batch or (q, e) per-query
    (-1 = hole). With ``router`` given (and ``cfg.router != "off"``) the
    beam is seeded per-query from the member rows of the query's top
    ``cfg.router_t`` centroids — the hierarchical entry points that fix
    the large-n recall collapse of uniform-random seeding; holes (dead or
    missing members) fall back to the random draw. ``backend="ref"``
    keeps random entries (the parity oracle).
    With ``alive`` given (the online store's tombstone mask), dead nodes
    are neither expanded nor returned: entry points are drawn from live
    rows only and dead neighbors are masked out of the candidate tile.
    ``x2`` lets callers with a cached norm vector (MutableKNNStore) skip
    the per-call recomputation; queries' norms are hoisted once per batch
    either way.

    ``cfg.metric`` selects l2 / cosine / mips via the input-side
    reductions (module docstring): the corpus/``x2`` must already be
    transformed, the queries are transformed here, distances come back
    as transformed-space squared l2 under EVERY backend (``"ref"``
    included — the oracle is metric-general through the same reduction).

    ``filter_ids`` restricts results to admitted rows — (n,) bool shared
    across the batch, or (q, n) bool per query. Filtered rows behave
    exactly like tombstoned ones for this call: never seeded, never
    expanded, never returned (zero leakage, gated in CI). Both layouts
    work under every backend and precision.

    With ``cfg.precision`` "int8"/"bf16" the traversal scores candidates
    on the quantized corpus mirror and re-ranks the final pool fp32 (see
    SearchConfig). ``qstore`` passes a cached mirror (MutableKNNStore /
    KNNDatastore keep one); without it the mirror is quantized here, once
    per call — fine for one-shot searches, wasteful for serving loops.
    """
    if cfg is None:
        cfg = SearchConfig(beam=beam, rounds=rounds)
    x = x.astype(jnp.float32)
    queries = queries.astype(jnp.float32)
    if cfg.metric == "cosine":
        queries = metric_mod.normalize_rows(queries)
    elif cfg.metric == "mips" and queries.ndim == 2 \
            and queries.shape[1] < x.shape[1]:
        # the mips query transform is literally zero right-padding (the
        # augmented coordinate is 0), same as feature padding — widen
        # narrower query batches up to the transformed corpus width
        queries = jnp.pad(queries, ((0, 0), (0, x.shape[1]
                                             - queries.shape[1])))
    else:
        metric_mod.check_metric(cfg.metric)
    queries, bad_rows = _admit_queries(queries, x.shape[1], cfg.strict)
    n = graph_idx.shape[0]
    filt = None
    if filter_ids is not None:
        filter_ids = jnp.asarray(filter_ids, bool)
        if filter_ids.shape[-1] != n:
            raise ValueError(
                f"filter_ids covers {filter_ids.shape[-1]} rows; the "
                f"graph has {n}")
        if filter_ids.ndim == 1:
            # a shared predicate IS a tombstone mask for this call —
            # fold it into `alive` and the whole existing path (entry
            # draw, candidate masking, epilogue) enforces it for free
            alive = filter_ids if alive is None else alive & filter_ids
        else:
            filt = filter_ids
    if n == 0:
        # empty corpus (a store before its first insert): every query
        # gets the empty result, same contract as a fully-dead store
        return (jnp.full((queries.shape[0], k_out), jnp.inf, jnp.float32),
                jnp.full((queries.shape[0], k_out), -1, jnp.int32))
    if x2 is None:
        x2 = jnp.sum(x * x, axis=1)
    if entry is None:
        key = _batch_key(queries) if key is None else key
        if (router is not None and cfg.router != "off"
                and cfg.backend != "ref" and queries.shape[0] > 0):
            from repro.core.router import route_entries
            # probe the FULL member set of the top-t centroids (IVF-style:
            # up to t*m candidates), not just beam of them — the seed tile
            # scores every candidate against the query and the bounded
            # merge keeps the best ``beam``, so wider probing costs one
            # wider seed tile, never a wider traversal
            t = min(cfg.router_t, router.centroids.shape[0])
            width = min(max(cfg.beam, t * router.members.idx.shape[1]), n)
            ent = route_entries(
                router, queries, width, t=cfg.router_t, backend=cfg.backend,
            )                                           # (q, e), -1 holes
            if alive is not None:
                ent = jnp.where(
                    (ent >= 0) & alive[jnp.clip(ent, 0, n - 1)], ent, -1
                )
            # holes (dead or missing members) fall back to a random draw
            rnd = _draw_entries(key, n, width, alive)
            entry = jnp.where(ent >= 0, ent, rnd[None, :])
        else:
            entry = _draw_entries(key, n, cfg.beam, alive)
    entry = entry.astype(jnp.int32)
    if filt is not None:
        # per-query predicates need per-query entries: broadcast shared
        # seeds, drop seeds the query's own filter rejects, and refill
        # the holes from a keyed draw over each query's admitted live
        # rows (same sampling-without-replacement trick as
        # _draw_entries, one weight vector shared across the batch)
        if entry.ndim == 1:
            entry = jnp.broadcast_to(
                entry[None, :], (queries.shape[0], entry.shape[0]))
        fok = jnp.take_along_axis(filt, jnp.clip(entry, 0, n - 1), axis=1)
        entry = jnp.where((entry >= 0) & fok, entry, -1)
        key = _batch_key(queries) if key is None else key
        w = jax.random.uniform(jax.random.fold_in(key, 7), (n,))
        if alive is not None:
            w = jnp.where(alive, w, -1.0)
        fd, fent = jax.lax.top_k(
            jnp.where(filt, w[None, :], -1.0), min(entry.shape[1], n))
        fent = jnp.where(fd >= 0.0, fent, -1).astype(jnp.int32)
        if fent.shape[1] < entry.shape[1]:
            fent = jnp.pad(
                fent, ((0, 0), (0, entry.shape[1] - fent.shape[1])),
                constant_values=-1)
        entry = jnp.where(entry >= 0, entry, fent)
    if cfg.precision == "f32" or cfg.backend == "ref":
        qstore = None
    elif qstore is None or qstore.mode != cfg.precision:
        # a cached mirror of the WRONG mode (e.g. an int8 store searched
        # with precision="bf16") would be scored as raw codes by the
        # other kernel — silently garbage. Quantize fresh instead.
        qstore = quantize.quantize_corpus(x, cfg.precision)

    if cfg.backend == "ref":
        rd, ri = _graph_search_ref(
            x, x2, graph_idx, queries, entry, alive, filt,
            k_out=k_out, beam=cfg.beam, rounds=cfg.rounds,
        )
        return _mask_bad_rows(rd, ri, bad_rows)

    # fused batched path: pad the batch to whole q_blocks, run the jitted
    # block search per block, slice the pad off. Small batches (decode
    # steps, insert seeding, interactive bursts) run at their
    # q_block_bucket ladder step — next power of two, compiled once per
    # bucket — unless cfg.fixed_block pins the full-block quantum.
    nq = queries.shape[0]
    if nq == 0:     # idle serving tick / empty insert batch
        return (jnp.zeros((0, k_out), jnp.float32),
                jnp.full((0, k_out), -1, jnp.int32))
    qb = q_block_bucket(nq, cfg)
    pad = (-nq) % qb
    qp = jnp.pad(queries, ((0, pad), (0, 0)))
    q2 = jnp.sum(qp * qp, axis=1)
    if entry.ndim == 2:     # per-query seeds ride along with their block
        entry = jnp.pad(entry, ((0, pad), (0, 0)), constant_values=-1)
    if filt is not None:    # pad queries admit everything (sliced off)
        filt = jnp.pad(filt, ((0, pad), (0, 0)), constant_values=True)
    # Deadline degradation: once the batch has spent its cumulative
    # per-block slice, remaining blocks run with the expansion budget cut
    # to ONE fused round — the answer degrades (fewer expansions, lower
    # recall), the latency does not. Needs wall time, so each block is
    # synced when armed; meaningless under tracing (no wall clock), so
    # the knob is ignored there.
    deadline = cfg.max_rounds_deadline
    use_deadline = deadline > 0.0 and not isinstance(queries,
                                                    jax.core.Tracer)
    # host-side knobs (the deadline value, the fixed_block baseline flag)
    # must not fragment _search_block's static-cfg compile cache: the
    # scheduler propagates a FRESH max_rounds_deadline per dispatch, and
    # keying compiles on it would recompile every batch for an identical
    # traced computation
    run_cfg = dataclasses.replace(cfg, max_rounds_deadline=0.0,
                                  fixed_block=False)
    cut_cfg = None
    t0 = time.monotonic() if use_deadline else 0.0
    outs_d, outs_i = [], []
    for bi, s in enumerate(range(0, nq + pad, qb)):
        bcfg = run_cfg
        if use_deadline and bi > 0 \
                and time.monotonic() - t0 > deadline * bi:
            if cut_cfg is None:     # one extra (cached) compile, ever
                cut_cfg = dataclasses.replace(run_cfg, rounds=cfg.expand)
            bcfg = cut_cfg
        ent_b = entry if entry.ndim == 1 else entry[s:s + qb]
        od, oi = _search_block(
            x, x2, graph_idx, qp[s:s + qb], q2[s:s + qb], ent_b, alive,
            None if filt is None else filt[s:s + qb],
            qstore, k_out=k_out, cfg=bcfg,
        )
        if use_deadline:
            od.block_until_ready()
        outs_d.append(od)
        outs_i.append(oi)
    out_d = outs_d[0] if len(outs_d) == 1 else jnp.concatenate(outs_d)
    out_i = outs_i[0] if len(outs_i) == 1 else jnp.concatenate(outs_i)
    return _mask_bad_rows(out_d[:nq], out_i[:nq], bad_rows)


# ---------------------------------------------------------------------------
# fused batched multi-expansion search
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k_out", "cfg"))
def _search_block(
    x: jax.Array,          # (n, dp) f32 corpus
    x2: jax.Array,         # (n,) corpus squared norms (hoisted)
    graph_idx: jax.Array,  # (n, k)
    q: jax.Array,          # (qb, dp) f32 query block
    q2: jax.Array,         # (qb,) query squared norms (hoisted)
    entry: jax.Array,      # (e,) shared or (qb, e) per-query entry ids
    alive: jax.Array | None,
    filt: jax.Array | None,          # (qb, n) per-query predicate mask
    qstore: QuantizedStore | None,   # quantized corpus mirror (quant only)
    *,
    k_out: int,
    cfg: SearchConfig,
):
    """One query block of the fused search (see module docstring).
    ``filt`` (per-query filtered search) masks candidates exactly like
    ``alive`` does, gathered per query row."""
    n, k = graph_idx.shape
    qb = q.shape[0]
    beam = cfg.beam
    e = cfg.expand
    c_sel = cfg.select_c or beam
    rows = jnp.arange(qb, dtype=jnp.int32)[:, None]

    # quantized scoring stage: the query block is quantized ONCE per block
    # (the serving twin of the hoisted norms) at the MIRROR's width — the
    # mirror drops the fp32 layout's zero feature padding (quantize.
    # mirror_width) — and the whole traversal (seeds, candidate tiles,
    # pool-kth prefilter) runs on quantized distances so comparisons stay
    # self-consistent; the exact fp32 re-rank of the final pool happens
    # after the round loop
    quant = cfg.precision != "f32" and qstore is not None
    if quant:
        qq = quantize.quantize_corpus(q, cfg.precision,
                                      width=qstore.data.shape[1])

    # ---- seed the pool: all entry distances in ONE blocked matmul, then
    # one bounded merge (dedups repeated entries, drops dead ones)
    ent = jnp.clip(entry, 0, n - 1)
    if entry.ndim == 2:
        # per-query (routed) seeds: the gathered (qb, e, dp) rows go
        # through the same masked search tile as candidate scoring, so
        # -1 holes come back +inf and vanish in the merge
        eids = entry
        if alive is not None:
            eids = jnp.where(alive[ent], eids, -1)
        if filt is not None:   # filt implies per-query entries (dispatcher)
            eids = jnp.where(jnp.take_along_axis(filt, ent, 1), eids, -1)
        if quant:
            c2q = jnp.where(eids >= 0, qstore.x2[ent], 0.0)
            if cfg.precision == "int8":
                ed = ops.knn_search_dists_q8(
                    qq.data, qq.scale, qq.x2, qstore.data[ent],
                    qstore.scale[ent], c2q, eids, backend=cfg.backend,
                )                                       # (qb, E0)
            else:
                ed = ops.knn_search_dists_bf16(
                    qq.data, qq.x2, qstore.data[ent], c2q, eids,
                    backend=cfg.backend,
                )                                       # (qb, E0)
        else:
            ed = ops.knn_search_dists(
                q, q2, x[ent], jnp.where(eids >= 0, x2[ent], 0.0), eids,
                backend=cfg.backend,
            )                                           # (qb, E0)
    elif quant:
        ab = qq.data.astype(jnp.float32) @ (
            qstore.data[ent].astype(jnp.float32).T
        )
        ab = (qq.scale[:, None] * qstore.scale[ent][None, :]) * ab
        ed = jnp.maximum(
            qq.x2[:, None] + qstore.x2[ent][None, :] - 2.0 * ab, 0.0
        )                                               # (qb, E0)
        eids = jnp.broadcast_to(entry[None, :], ed.shape)
        if alive is not None:
            eids = jnp.where(alive[ent][None, :], eids, -1)
    else:
        ed = jnp.maximum(
            q2[:, None] + x2[ent][None, :] - 2.0 * q @ x[ent].T, 0.0
        )                                               # (qb, E0)
        eids = jnp.broadcast_to(entry[None, :], ed.shape)
        if alive is not None:
            eids = jnp.where(alive[ent][None, :], eids, -1)
    pool = NeighborLists(
        jnp.full((qb, beam), jnp.inf, jnp.float32),
        jnp.full((qb, beam), -1, jnp.int32),
        jnp.zeros((qb, beam), bool),        # ``new`` == not yet expanded
    )
    pool, _ = heap.merge_kernel(
        pool, jnp.where(eids >= 0, ed, jnp.inf), eids, backend=cfg.backend
    )

    inf_q = jnp.full((qb,), jnp.inf, jnp.float32)
    slot_iota = jnp.broadcast_to(
        jnp.arange(beam, dtype=jnp.int32)[None, :], (qb, beam)
    )

    def round_fn(state):
        pool_d, pool_i, pool_new, r = state
        # top-E unexpanded pool slots per query (partial top-C select —
        # the same machinery as the build join, no full argsort)
        _, ss = ops.knn_join_select(
            pool_d, jnp.where(pool_new & (pool_i >= 0), slot_iota, -1),
            inf_q, c=e, backend=cfg.backend,
        )                                               # (qb, E) slots
        can = ss >= 0
        safe_s = jnp.where(can, ss, 0)
        nodes = jnp.where(can, jnp.take_along_axis(pool_i, safe_s, 1), -1)
        # mark expanded (disabled writes go out of bounds -> dropped)
        pool_new = pool_new.at[rows, jnp.where(can, ss, beam)].set(
            False, mode="drop"
        )
        # adjacency + feature gather for the whole block, then the fused
        # distance tile with validity/alive masking in the epilogue
        nbrs = graph_idx[jnp.clip(nodes, 0, n - 1)]     # (qb, E, k)
        ok = can[:, :, None] & (nbrs >= 0)
        if alive is not None:
            ok &= alive[jnp.clip(nbrs, 0, n - 1)]
        if filt is not None:
            # per-query predicate: filtered candidates fold to -1 here,
            # the epilogue maps id -1 to +inf — zero-leakage by the same
            # mechanism tombstones use
            ok &= jnp.take_along_axis(
                filt, jnp.clip(nbrs, 0, n - 1).reshape(qb, e * k), 1
            ).reshape(qb, e, k)
        cand = jnp.where(ok, nbrs, -1).reshape(qb, e * k)
        safe_c = jnp.where(cand >= 0, cand, 0)
        if quant:
            # quantized scoring tile: int8/bf16 gathered rows (2-4x fewer
            # HBM bytes), scales + norm expansion fused in the epilogue
            c2q = jnp.where(cand >= 0, qstore.x2[safe_c], 0.0)
            if cfg.precision == "int8":
                dd = ops.knn_search_dists_q8(
                    qq.data, qq.scale, qq.x2, qstore.data[safe_c],
                    qstore.scale[safe_c], c2q, cand, backend=cfg.backend,
                )                                       # (qb, E*k)
            else:
                dd = ops.knn_search_dists_bf16(
                    qq.data, qq.x2, qstore.data[safe_c], c2q, cand,
                    backend=cfg.backend,
                )                                       # (qb, E*k)
        else:
            dd = ops.knn_search_dists(
                q, q2, x[safe_c], jnp.where(cand >= 0, x2[safe_c], 0.0),
                cand, backend=cfg.backend,
            )                                           # (qb, E*k)
        # pool-k-th prefilter + partial top-C, then the sort-free bounded
        # merge (dedup by id; accepted slots come back unexpanded)
        cd, ci = ops.knn_join_select(
            dd, cand, pool_d[:, -1], c=c_sel, backend=cfg.backend
        )
        nl, _ = heap.merge_kernel(
            NeighborLists(pool_d, pool_i, pool_new), cd, ci,
            backend=cfg.backend,
        )
        return nl.dist, nl.idx, nl.new, r + 1

    def cond_fn(state):
        pool_d, pool_i, pool_new, r = state
        # early-out: every pool entry of every query already expanded
        return (r < cfg.n_rounds) & jnp.any(pool_new & (pool_i >= 0))

    pool_d, pool_i, _, _ = jax.lax.while_loop(
        cond_fn, round_fn,
        (pool.dist, pool.idx, pool.new, jnp.zeros((), jnp.int32)),
    )
    if quant:
        # stage two: exact fp32 re-rank of the surviving pool with the
        # EXISTING fp32 kernel — quantization decided pool membership
        # (bounded recall noise), never the returned distances/order
        safe_p = jnp.clip(pool_i, 0, n - 1)
        dex = ops.knn_search_dists(
            q, q2, x[safe_p], jnp.where(pool_i >= 0, x2[safe_p], 0.0),
            pool_i, backend=cfg.backend,
        )                                               # (qb, beam)
        return ops.knn_join_select(
            dex, pool_i, jnp.full((qb,), jnp.inf, jnp.float32), c=k_out,
            backend=cfg.backend,
        )
    return pool_d[:, :k_out], pool_i[:, :k_out]


# ---------------------------------------------------------------------------
# reference greedy loop (parity oracle)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k_out", "beam", "rounds"))
def _graph_search_ref(
    x: jax.Array,          # (n, dp) f32
    x2: jax.Array,         # (n,) corpus squared norms (hoisted)
    graph_idx: jax.Array,  # (n, k)
    queries: jax.Array,    # (q, dp) f32
    entry: jax.Array,      # (e,) shared or (q, e) per-query entry ids
    alive: jax.Array | None,
    filt: jax.Array | None,   # (q, n) per-query predicate mask
    *,
    k_out: int,
    beam: int,
    rounds: int,
):
    """The original one-node-per-round greedy search, kept as the fused
    path's parity oracle — metric-general through the same input-side
    reduction as the fused path (it sees transformed rows, computes pure
    l2), and filter-aware: ``filt`` rows mask entries and candidates
    exactly like ``alive`` does, vmapped per query. Norms are hoisted:
    x2 comes in precomputed and each query's norm is evaluated once per
    batch, not once per round."""
    n, k = graph_idx.shape
    if entry.ndim == 1:
        entry = jnp.broadcast_to(
            entry[None, :], (queries.shape[0], entry.shape[0])
        )

    def q_dist(q, q2s, ids):
        rows = x[ids]
        return jnp.maximum(x2[ids] - 2.0 * rows @ q + q2s, 0.0)

    def one_query(q, q2s, ent, frow):
        pool_i = jnp.full((beam,), -1, dtype=jnp.int32)
        pool_d = jnp.full((beam,), _BIG, dtype=jnp.float32)
        pool_e = jnp.zeros((beam,), dtype=bool)   # expanded?
        e = ent.shape[0]
        ve = ent >= 0
        pool_i = pool_i.at[:e].set(jnp.where(ve, ent, -1).astype(jnp.int32))
        pool_d = pool_d.at[:e].set(
            jnp.where(ve, q_dist(q, q2s, jnp.clip(ent, 0, n - 1)), _BIG)
        )
        if alive is not None:
            dead = (pool_i >= 0) & ~alive[jnp.clip(pool_i, 0, n - 1)]
            pool_d = jnp.where(dead, _BIG, pool_d)
        if frow is not None:
            shut = (pool_i >= 0) & ~frow[jnp.clip(pool_i, 0, n - 1)]
            pool_d = jnp.where(shut, _BIG, pool_d)

        def round_fn(_, state):
            pool_d, pool_i, pool_e = state
            # best unexpanded entry
            score = jnp.where(pool_e | (pool_i < 0), _BIG, pool_d)
            b = jnp.argmin(score)
            node = pool_i[b]
            can = score[b] < _BIG
            pool_e = pool_e.at[b].set(True)
            nbrs = graph_idx[jnp.clip(node, 0, n - 1)]       # (k,)
            nb_ok = (nbrs >= 0) & can
            if alive is not None:
                nb_ok &= alive[jnp.clip(nbrs, 0, n - 1)]
            if frow is not None:
                nb_ok &= frow[jnp.clip(nbrs, 0, n - 1)]
            nd = jnp.where(
                nb_ok, q_dist(q, q2s, jnp.clip(nbrs, 0, n - 1)), _BIG
            )
            # merge pool + neighbors, dedup by id, keep best `beam`
            all_i = jnp.concatenate([pool_i, jnp.where(nb_ok, nbrs, -1)])
            all_d = jnp.concatenate([pool_d, nd])
            all_e = jnp.concatenate([pool_e, jnp.zeros((k,), bool)])
            # dedup: mark later duplicates invalid (stable: pool first).
            # Sort-by-id adjacent-duplicate pass — O(m log m) instead of
            # the O(m^2) eq&earlier matrix; the stable sort keeps the
            # earliest (pool) occurrence first among equal ids, preserving
            # the expanded flag exactly like the matrix form did.
            sid = jnp.argsort(all_i, stable=True)
            si = all_i[sid]
            adj = jnp.concatenate(
                [jnp.zeros((1,), bool), si[1:] == si[:-1]]
            )
            dup = jnp.zeros_like(adj).at[sid].set(adj) & (all_i >= 0)
            all_d = jnp.where(dup | (all_i < 0), _BIG, all_d)
            order = jnp.argsort(all_d)[:beam]
            return all_d[order], all_i[order], all_e[order]

        pool_d, pool_i, pool_e = jax.lax.fori_loop(
            0, rounds, round_fn, (pool_d, pool_i, pool_e)
        )
        out_d, out_i = pool_d[:k_out], pool_i[:k_out]
        # dead / hole entry points survive in the pool at distance _BIG;
        # never surface them
        out_i = jnp.where(out_d >= _BIG, -1, out_i)
        return out_d, out_i

    q2 = jnp.sum(queries * queries, axis=1)
    if filt is None:
        return jax.vmap(
            lambda q, q2s, ent: one_query(q, q2s, ent, None)
        )(queries, q2, entry)
    return jax.vmap(one_query)(queries, q2, entry, filt)
