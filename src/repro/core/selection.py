"""Selection step — paper §3.1, heap-free "turbosampling", TPU form.

Per NN-Descent iteration, every node u needs a bounded sample of its
neighborhood N(u) = adj(u) ∪ adj⁻¹(u) (forward and reverse edges of the
current graph), split into "new" and "old" pools (incremental search).

The paper's progression, reproduced here:
  naive (3 passes: reverse, union, sample)   -> selection_naive()
  PyNNDescent fused one-pass w/ heaps        -> selection_heap()
  turbosampling: heap-free, per-edge accept  -> selection_turbo()
     with prob rho*k/|N(u)|, expectation-equal to random-weight heaps

The TPU realization of turbosampling is fully dense: reverse degrees come
from one segment_sum over the edge list; each directed (receiver,
candidate) incidence is accepted by an independent Bernoulli with that
probability; accepted incidences are compacted into fixed (n, C) buffers by
a single (receiver, random) sort — no heap, no dynamic shapes, and the sort
replaces the paper's cache-resident incremental inserts (assumption change
#5 in DESIGN.md).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.heap import NeighborLists


class Candidates(NamedTuple):
    new_idx: jax.Array   # (n, c_new) i32, -1 = empty
    old_idx: jax.Array   # (n, c_old) i32, -1 = empty
    sampled_fwd: jax.Array  # (n, k) bool: forward new slots sampled this round


def _incidences(nl: NeighborLists):
    """All directed (receiver, candidate, is_new, is_forward_slot) triples.

    Forward: u receives its own adjacency; reverse: v = adj(u) receives u.
    Shapes: (2*n*k,) flattened, slot index retained for flag clearing.
    """
    n, k = nl.idx.shape
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    valid = nl.idx >= 0
    fwd_recv = rows.reshape(-1)
    fwd_cand = jnp.where(valid, nl.idx, 0).reshape(-1)
    rev_recv = fwd_cand
    rev_cand = fwd_recv
    is_new = nl.new.reshape(-1)
    valid = valid.reshape(-1)
    recv = jnp.concatenate([fwd_recv, rev_recv])
    cand = jnp.concatenate([fwd_cand, rev_cand])  # candidate for receiver
    new = jnp.concatenate([is_new, is_new])
    val = jnp.concatenate([valid, valid])
    is_fwd = jnp.concatenate(
        [jnp.ones_like(valid), jnp.zeros_like(valid)]
    )
    slot = jnp.tile(jnp.arange(n * k, dtype=jnp.int32), 2)
    return recv, cand, new, val, is_fwd, slot


def _compact(
    recv: jax.Array,
    cand: jax.Array,
    accept: jax.Array,
    rnd: jax.Array,
    n: int,
    c: int,
) -> jax.Array:
    """Compact accepted (receiver, candidate) incidences into an (n, c)
    buffer: sort by (receiver, random), keep the first c per receiver.
    This is exact uniform reservoir sampling of the accepted set."""
    key_recv = jnp.where(accept, recv, n)  # rejected sort to the end
    order = jnp.lexsort((rnd, key_recv))
    recv_s = key_recv[order]
    cand_s = cand[order]
    # position within the receiver's group
    first = jnp.searchsorted(recv_s, jnp.arange(n + 1), side="left")
    pos = jnp.arange(recv_s.shape[0]) - first[jnp.clip(recv_s, 0, n)]
    # writes with recv_s == n (rejected) or pos >= c (overflow) fall out of
    # bounds and are dropped — exactly the semantics we want.
    out = jnp.full((n, c), -1, dtype=jnp.int32)
    out = out.at[recv_s, pos].set(cand_s, mode="drop")
    return out


def selection_turbo(
    key: jax.Array,
    nl: NeighborLists,
    rho_k: int,
) -> Candidates:
    """Heap-free turbosampling (paper C2). rho_k = max candidates per pool."""
    n, k = nl.idx.shape
    recv, cand, is_new, valid, is_fwd, slot = _incidences(nl)

    # |N(u)| = forward degree (k) + reverse degree, per pool (new/old)
    def pool_degree(mask):
        return jax.ops.segment_sum(
            mask.astype(jnp.int32), recv, num_segments=n
        )

    deg_new = pool_degree(valid & is_new)
    deg_old = pool_degree(valid & ~is_new)

    k_acc, k_new, k_old = jax.random.split(key, 3)
    p_new = jnp.minimum(1.0, rho_k / jnp.maximum(deg_new, 1))[recv]
    p_old = jnp.minimum(1.0, rho_k / jnp.maximum(deg_old, 1))[recv]
    u = jax.random.uniform(k_acc, recv.shape)
    acc_new = valid & is_new & (u < p_new)
    acc_old = valid & ~is_new & (u < p_old)

    rnd_new = jax.random.uniform(k_new, recv.shape)
    rnd_old = jax.random.uniform(k_old, recv.shape)
    new_buf = _compact(recv, cand, acc_new, rnd_new, n, rho_k)
    old_buf = _compact(recv, cand, acc_old, rnd_old, n, rho_k)

    # forward new slots that were accepted are "joined" -> clear their flag
    nk = n * k
    sampled_fwd = jnp.zeros((nk,), dtype=bool)
    sampled_fwd = sampled_fwd.at[jnp.where(acc_new & is_fwd, slot, 0)].max(
        acc_new & is_fwd
    )
    return Candidates(new_buf, old_buf, sampled_fwd.reshape(n, k))


def selection_heap(
    key: jax.Array,
    nl: NeighborLists,
    rho_k: int,
) -> Candidates:
    """PyNNDescent-style fused selection (paper C1): draw one uniform weight
    per incidence, keep the rho_k smallest per receiver. Same output
    distribution family as turbosampling but samples exactly rho_k when
    available. Realized with the same sort machinery (the 'heap' is the
    per-receiver top-rho_k of the random weights)."""
    n, k = nl.idx.shape
    recv, cand, is_new, valid, is_fwd, slot = _incidences(nl)
    k_w, _ = jax.random.split(key)
    w = jax.random.uniform(k_w, recv.shape)
    new_buf = _compact(recv, cand, valid & is_new, w, n, rho_k)
    old_buf = _compact(recv, cand, valid & ~is_new, w, n, rho_k)
    # mark all forward new slots whose weight put them in the sample —
    # conservative approximation: mark accepted incidences like turbo
    sampled = jnp.zeros((n * k,), dtype=bool)
    # a forward slot is sampled if its incidence survived compaction; we
    # approximate with weight-rank acceptance probability rho_k/deg:
    deg_new = jax.ops.segment_sum(
        (valid & is_new).astype(jnp.int32), recv, num_segments=n
    )
    p = jnp.minimum(1.0, rho_k / jnp.maximum(deg_new, 1))[recv]
    acc = valid & is_new & (w < p)
    sampled = sampled.at[jnp.where(acc & is_fwd, slot, 0)].max(acc & is_fwd)
    return Candidates(new_buf, old_buf, sampled.reshape(n, k))


def selection_naive(
    key: jax.Array,
    nl: NeighborLists,
    rho_k: int,
) -> Candidates:
    """The paper's baseline: three explicit passes (reverse, union, sample)
    with materialized intermediates. Functionally identical output family;
    kept as the benchmark baseline for §4.1. The reverse adjacency is
    materialized into a bounded (n, r_max) buffer (r_max = 2k) — the
    'dynamically growing data structure' cost the fused versions avoid."""
    n, k = nl.idx.shape
    r_max = 2 * k
    recv, cand, is_new, valid, is_fwd, slot = _incidences(nl)
    # pass 1: materialize reverse adjacency (bounded stand-in for the
    # paper's dynamically-growing reverse lists)
    half = n * k
    rev_recv, rev_cand = recv[half:], cand[half:]
    rev_valid = valid[half:]
    k1, k2, k3 = jax.random.split(key, 3)
    rev_rnd = jax.random.uniform(k1, rev_recv.shape)
    rev_buf = _compact(rev_recv, rev_cand, rev_valid, rev_rnd, n, r_max)
    rev_new_buf = _compact(
        rev_recv, rev_cand, rev_valid & is_new[half:], rev_rnd, n, r_max
    )
    # pass 2: union with forward adjacency (flags carried per pool)
    union_idx = jnp.concatenate([nl.idx, rev_buf], axis=1)        # (n, 3k)
    in_rev_new = (rev_buf[:, :, None] == rev_new_buf[:, None, :]).any(-1)
    union_new = jnp.concatenate([nl.new, in_rev_new], axis=1)
    valid_u = union_idx >= 0

    # pass 3: sample rho_k per pool
    def sample(mask, kk):
        ww = jnp.where(mask, jax.random.uniform(kk, union_idx.shape), jnp.inf)
        order = jnp.argsort(ww, axis=1)[:, :rho_k]
        got = jnp.take_along_axis(union_idx, order, axis=1)
        ok = jnp.take_along_axis(ww, order, axis=1) < jnp.inf
        return jnp.where(ok, got, -1)

    new_buf = sample(valid_u & union_new, k2)
    old_buf = sample(valid_u & ~union_new, k3)
    # flag clearing: same policy as turbo (forward slots present in sample)
    sampled = (nl.idx[:, :, None] == new_buf[:, None, :]).any(-1) & nl.new
    return Candidates(new_buf, old_buf, sampled)
