"""Bounded, sorted neighbor lists — the vectorized form of NN-Descent's
per-node "heap" (paper §3.1 removes real heaps; so do we, for the same
reason on different hardware: heaps are pointer-chasing and cache-hostile
on CPU, and dynamically-shaped and scatter-hostile on TPU).

Representation: per node, k slots of (distance ascending, id), with
(inf, -1) for empty slots, plus a "new" flag per slot for NN-Descent's
incremental search.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


class NeighborLists(NamedTuple):
    dist: jax.Array   # (n, k) f32, ascending, inf = empty
    idx: jax.Array    # (n, k) i32, -1 = empty
    new: jax.Array    # (n, k) bool — not yet used in a join (incremental search)


def init_random(key: jax.Array, n: int, k: int) -> NeighborLists:
    """Uniform random initialization (paper §2), distances unevaluated (inf
    would break the merge ordering, so we store +big and mark all new;
    the first iteration's joins immediately replace them)."""
    idx = jax.random.randint(key, (n, k), 0, n, dtype=jnp.int32)
    # avoid self-loops: bump collisions by 1 (mod n)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    idx = jnp.where(idx == rows, (idx + 1) % n, idx)
    dist = jnp.full((n, k), jnp.float32(3.0e38))
    new = jnp.ones((n, k), dtype=bool)
    return NeighborLists(dist, idx, new)


def init_random_with_dists(
    key: jax.Array, x: jax.Array, k: int, *, backend: str = "auto"
) -> NeighborLists:
    """Random init with true distances evaluated (chunked)."""
    n = x.shape[0]
    nl = init_random(key, n, k)
    d = _gather_distances(x, nl.idx, backend=backend)
    order = jnp.argsort(d, axis=1)
    return NeighborLists(
        jnp.take_along_axis(d, order, axis=1),
        jnp.take_along_axis(nl.idx, order, axis=1),
        jnp.ones((n, k), dtype=bool),
    )


def _gather_distances(
    x: jax.Array, idx: jax.Array, *, backend: str = "auto"
) -> jax.Array:
    """d(x[i], x[idx[i, j]]) for all i, j — norm-expansion form."""
    xf = x.astype(jnp.float32)
    x2 = jnp.sum(xf * xf, axis=1)
    nb = xf[idx]                                     # (n, k, d)
    ab = jnp.einsum("nd,nkd->nk", xf, nb)
    out = x2[:, None] + x2[idx] - 2.0 * ab
    return jnp.maximum(out, 0.0)


def merge(
    nl: NeighborLists,
    cand_dist: jax.Array,
    cand_idx: jax.Array,
    cand_new: bool = True,
    *,
    backend: str = "auto",
) -> tuple[NeighborLists, jax.Array]:
    """Merge candidate (dist, id) pairs into the lists. Returns
    (updated lists, per-node accepted count). Accepted slots get the
    ``new`` flag; surviving slots keep theirs."""
    n, k = nl.dist.shape
    all_dist = jnp.concatenate([nl.dist, cand_dist], axis=1)
    all_idx = jnp.concatenate([nl.idx, cand_idx], axis=1)
    all_flag = jnp.concatenate(
        [nl.new, jnp.full(cand_idx.shape, cand_new)], axis=1
    )
    # invalidate duplicates (candidate already present / repeated candidate)
    c = cand_idx.shape[1]
    dup_graph = (cand_idx[:, :, None] == nl.idx[:, None, :]).any(-1)
    eq = cand_idx[:, :, None] == cand_idx[:, None, :]
    earlier = jnp.tril(jnp.ones((c, c), dtype=bool), k=-1)[None]
    dup = dup_graph | (eq & earlier).any(-1) | (cand_idx < 0)
    all_dist = all_dist.at[:, k:].set(jnp.where(dup, jnp.inf, cand_dist))

    order = jnp.argsort(all_dist, axis=1, stable=True)
    new_dist = jnp.take_along_axis(all_dist, order[:, :k], axis=1)
    new_idx = jnp.take_along_axis(all_idx, order[:, :k], axis=1)
    new_flag = jnp.take_along_axis(all_flag, order[:, :k], axis=1)
    accepted = (order[:, :k] >= k) & jnp.isfinite(new_dist)
    updated = jnp.sum(accepted, axis=1).astype(jnp.int32)
    return NeighborLists(new_dist, new_idx, new_flag), updated


def merge_kernel(
    nl: NeighborLists,
    cand_dist: jax.Array,
    cand_idx: jax.Array,
    *,
    backend: str = "auto",
) -> tuple[NeighborLists, jax.Array]:
    """Kernel-backed merge (flags recomputed as 'accepted == new')."""
    new_dist, new_idx, updated = ops.knn_merge(
        nl.dist, nl.idx, cand_dist, cand_idx, backend=backend
    )
    # a slot is new iff it was not already present in the old list
    was_old = (new_idx[:, :, None] == nl.idx[:, None, :]).any(-1)
    keep_flag = jnp.where(
        was_old,
        # carry the old flag for surviving slots
        _lookup_flags(nl, new_idx),
        True,
    )
    return NeighborLists(new_dist, new_idx, keep_flag & (new_idx >= 0)), updated


def _lookup_flags(nl: NeighborLists, ids: jax.Array) -> jax.Array:
    hit = ids[:, :, None] == nl.idx[:, None, :]
    return (hit & nl.new[:, None, :]).any(-1)


def merge_block(
    nl: NeighborLists,
    start: jax.Array,
    cand_dist: jax.Array,
    cand_idx: jax.Array,
    *,
    backend: str = "auto",
) -> tuple[NeighborLists, jax.Array]:
    """Chunked merge entry point: merge (R, c) candidates into the
    CONTIGUOUS row block [start, start+R) — the fused local join's
    receiver chunks (core/nn_descent.py local_join_fused). Receivers are
    rows, so no id dedup/scatter is needed: one dynamic slice in, the
    blocked merge kernel, one dynamic slice out. ``start`` must satisfy
    start + R <= n (the fused driver pads the lists to a chunk multiple).
    Returns (lists, (R,) accepted counts)."""
    r, _ = cand_dist.shape
    k = nl.dist.shape[1]
    sub_d = jax.lax.dynamic_slice(nl.dist, (start, 0), (r, k))
    sub_i = jax.lax.dynamic_slice(nl.idx, (start, 0), (r, k))
    sub_n = jax.lax.dynamic_slice(nl.new, (start, 0), (r, k))
    md, mi, upd = ops.knn_merge(
        sub_d, sub_i, cand_dist, cand_idx, backend=backend
    )
    old_sub = NeighborLists(sub_d, sub_i, sub_n)
    was_old = (mi[:, :, None] == sub_i[:, None, :]).any(-1)
    flag = jnp.where(
        was_old, _lookup_flags(old_sub, mi), True
    ) & (mi >= 0)
    return NeighborLists(
        jax.lax.dynamic_update_slice(nl.dist, md, (start, 0)),
        jax.lax.dynamic_update_slice(nl.idx, mi, (start, 0)),
        jax.lax.dynamic_update_slice(nl.new, flag, (start, 0)),
    ), upd


def merge_rows(
    nl: NeighborLists,
    rows: jax.Array,
    cand_dist: jax.Array,
    cand_idx: jax.Array,
    *,
    backend: str = "auto",
) -> tuple[NeighborLists, jax.Array]:
    """Frontier merge: merge (f, c) candidates into rows ``rows`` only
    (-1 = padding; ids must be unique). All flag bookkeeping happens on
    the gathered (f, k) sub-lists, so the cost is O(f), not O(n).
    Returns (lists, per-frontier-row accepted count)."""
    n, _ = nl.dist.shape
    ok = rows >= 0
    safe = jnp.where(ok, rows, 0)
    old_sub = NeighborLists(nl.dist[safe], nl.idx[safe], nl.new[safe])
    new_dist, new_idx, upd = ops.knn_merge_rows(
        nl.dist, nl.idx, rows, cand_dist, cand_idx, backend=backend
    )
    sub_i = new_idx[safe]
    was_old = (sub_i[:, :, None] == old_sub.idx[:, None, :]).any(-1)
    flag_sub = jnp.where(
        was_old, _lookup_flags(old_sub, sub_i), True
    ) & (sub_i >= 0)
    tgt = jnp.where(ok, rows, n)
    new_flag = nl.new.at[tgt].set(flag_sub, mode="drop")
    return NeighborLists(new_dist, new_idx, new_flag), upd


def purge_rows(
    nl: NeighborLists, rows: jax.Array, alive: jax.Array, *,
    backend: str = "auto",
) -> tuple[NeighborLists, jax.Array]:
    """Frontier purge: drop dead-target edges from rows ``rows`` only, and
    empty the lists of rows that are themselves dead (the online delete
    path puts both kinds on the compaction frontier). Survivors stay
    sorted/packed; freed slots become (inf, -1, False). Returns
    (lists, per-frontier-row removed count)."""
    n = alive.shape[0]
    ok = rows >= 0
    safe = jnp.where(ok, rows, 0)
    sub_i = nl.idx[safe]
    sub_valid = sub_i >= 0
    drop = sub_valid & ~alive[jnp.clip(sub_i, 0, n - 1)]
    drop |= sub_valid & ~alive[safe][:, None]       # dead row: clear it all
    new_dist, new_idx, removed = ops.knn_compact_rows(
        nl.dist, nl.idx, rows, drop, backend=backend
    )
    sub_new = new_idx[safe]
    flag_sub = _lookup_flags(
        NeighborLists(nl.dist[safe], sub_i, nl.new[safe]), sub_new
    ) & (sub_new >= 0)
    tgt = jnp.where(ok, rows, n)
    new_flag = nl.new.at[tgt].set(flag_sub, mode="drop")
    return NeighborLists(new_dist, new_idx, new_flag), removed


def purge(
    nl: NeighborLists, alive: jax.Array, *, backend: str = "auto"
) -> tuple[NeighborLists, jax.Array]:
    """Remove edges pointing at dead nodes (``alive[idx] == False``).

    Survivors stay sorted and packed to the front; freed slots become
    (inf, -1, False). Returns (lists, per-node removed count) — the online
    delete path (core/online.py) refills rows where removed > 0."""
    n = alive.shape[0]
    valid = nl.idx >= 0
    drop = valid & ~alive[jnp.clip(nl.idx, 0, n - 1)]
    new_dist, new_idx, removed = ops.knn_compact(
        nl.dist, nl.idx, drop, backend=backend
    )
    flag = _lookup_flags(nl, new_idx) & (new_idx >= 0)
    return NeighborLists(new_dist, new_idx, flag), removed


def mark_sampled_old(nl: NeighborLists, sampled_mask: jax.Array) -> NeighborLists:
    """Clear the 'new' flag of forward slots that were sampled this round
    (NN-Descent incremental search: a pair is joined at most once)."""
    return nl._replace(new=nl.new & ~sampled_mask)
