"""Deterministic, seedable fault injection for the serving path.

The serving stack (persist, distributed dispatch, the search boundary)
has graceful-degradation code that only ever runs when something breaks
— which means it only ever runs in production unless the failures can be
scripted. This module is that script: a :class:`FaultPlan` is a seeded
registry of :class:`FaultSpec` entries keyed by *site* (a string like
``"persist.write"``), and every injectable site in the codebase calls
:func:`fire` at its boundary. With no plan active, :func:`fire` is a
single ``is None`` check — zero hot-path cost, and nothing in this
module touches jax, so importing it never pulls in the runtime.

Sites currently consulted (grep for ``faults.fire`` to audit):

  * ``persist.write``  — raise :class:`InjectedFault` (an ``OSError``)
    inside ``write_snapshot`` before the COMMIT marker lands, so the
    snapshot directory is left uncommitted.
  * ``persist.torn``   — truncate one array file of an otherwise
    complete snapshot *after* writing it (``arg`` = filename substring
    to tear, default: first ``.npy``), modelling a torn page / partial
    flush that COMMIT ordering alone cannot catch.
  * ``persist.rename`` — fail the quarantine rename in
    ``restore_store``'s fallback path.
  * ``shard.dead``     — mark shard ``arg`` (an int or list of ints)
    unavailable in ``graph_search_sharded``.
  * ``shard.slow``     — report shard ``arg`` as exceeding the dispatch
    timeout (treated like dead: degraded, not blocking).
  * ``shard.degrade``  — inflate shard ``arg``'s per-dispatch latency
    sample (``arg`` = shard index, ``(shard, factor)``, or a list of
    either; default factor 10x) as seen by the ``ShardBreaker`` circuit
    breaker in ``graph_search_sharded`` — a chronically slow (not dead)
    shard, so the breaker's EWMA trip/half-open-probe path is
    exercisable without a genuinely slow device.
  * ``sched.burst``    — amplify one arrival in
    ``serve/scheduler.RetrievalScheduler.submit`` into a burst of
    ``arg`` (default 8) injected copies, so admission-control shedding
    is drivable from a seeded plan (byte-identical burst schedules).
  * ``sched.stall``    — advance the retrieval scheduler's deadline
    clock by ``arg`` (default 0.05) seconds at the next dispatch — a
    simulated stall (GC pause, slow kernel) that makes queued-deadline
    expiry and the ``max_rounds_deadline`` budget cut deterministic.
  * ``router.rebuild`` — fail the lazy router rebuild in
    ``_maybe_rebuild_router`` (store keeps serving the stale router).

Determinism: a spec with ``prob < 1.0`` draws from a per-site
``random.Random`` seeded by ``(plan.seed, site)``; two runs with the
same plan see byte-identical fault schedules. ``times``/``after`` gate
on a per-site monotonically increasing event counter, so "fail the
second and third writes" is expressible without probability at all.

Usage::

    plan = FaultPlan(seed=0, specs=(
        FaultSpec(site="persist.write", times=2),
        FaultSpec(site="shard.dead", arg=1),
    ))
    with plan.active():
        ...  # injected sites misbehave deterministically

``poison_batch`` lives here too: it manufactures the adversarial query
batches (NaN / Inf / wrong dimensionality) that the admission checks in
``graph_search`` / ``knn_logits`` must catch.
"""
from __future__ import annotations

import contextlib
import random
import threading
from dataclasses import dataclass, field


class InjectedFault(OSError):
    """Raised by an injected fault site. Subclasses ``OSError`` so code
    that treats transient I/O errors as retryable (``SnapshotWriter``)
    exercises its real retry path against injections."""


@dataclass(frozen=True)
class FaultSpec:
    """One scripted fault.

    site:  which injection point (see module docstring).
    mode:  site-specific flavour; the default ``"error"`` raises
           :class:`InjectedFault` (or, for shard sites, marks the shard
           dead). ``persist.torn`` ignores mode.
    prob:  per-event trigger probability (deterministic per-site RNG).
    times: fire at most this many times (None = unlimited).
    after: skip the first ``after`` matching events (0-indexed), so
           "fail the 3rd write" is ``after=2, times=1``.
    arg:   site-specific payload — shard index/indices for ``shard.*``,
           filename substring for ``persist.torn``.
    """
    site: str
    mode: str = "error"
    prob: float = 1.0
    times: int | None = None
    after: int = 0
    arg: object = None


@dataclass
class FaultPlan:
    """A seeded set of fault specs plus per-site trigger accounting."""
    seed: int = 0
    specs: tuple = ()
    _counts: dict = field(default_factory=dict, repr=False)
    _fired: dict = field(default_factory=dict, repr=False)
    _rngs: dict = field(default_factory=dict, repr=False)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    def check(self, site: str):
        """Return the triggering FaultSpec for this event at ``site``,
        or None. Advances the per-site event counter either way."""
        with self._lock:
            event = self._counts.get(site, 0)
            self._counts[site] = event + 1
            for i, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                if event < spec.after:
                    continue
                key = (site, i)
                if spec.times is not None and \
                        self._fired.get(key, 0) >= spec.times:
                    continue
                if spec.prob < 1.0:
                    rng = self._rngs.get(site)
                    if rng is None:
                        rng = random.Random((self.seed, site).__repr__())
                        self._rngs[site] = rng
                    if rng.random() >= spec.prob:
                        continue
                self._fired[key] = self._fired.get(key, 0) + 1
                return spec
        return None

    def fired(self, site: str | None = None) -> int:
        """How many injections actually triggered (for assertions)."""
        with self._lock:
            return sum(n for (s, _), n in self._fired.items()
                       if site is None or s == site)

    @contextlib.contextmanager
    def active(self):
        """Install this plan globally for the duration of the block."""
        activate(self)
        try:
            yield self
        finally:
            deactivate()


# The active plan. Module-level so every site pays one ``is None`` test
# when chaos is off; tests/benches install a plan via ``plan.active()``.
_PLAN: FaultPlan | None = None


def activate(plan: FaultPlan) -> None:
    global _PLAN
    _PLAN = plan


def deactivate() -> None:
    global _PLAN
    _PLAN = None


def fire(site: str):
    """Consult the active plan at an injection site.

    Returns the triggering :class:`FaultSpec` (caller decides how to
    misbehave — most sites ``raise InjectedFault(...)``), or None.
    """
    if _PLAN is None:
        return None
    return _PLAN.check(site)


def maybe_raise(site: str) -> None:
    """``fire`` + raise for sites whose only failure mode is an error."""
    spec = fire(site)
    if spec is not None:
        raise InjectedFault(f"injected fault at {site}")


def dead_shards(n_shards: int) -> list:
    """Collect the shard indices the active plan marks dead or slow
    (slow-past-timeout degrades identically to dead at the dispatch
    layer). Returns a sorted list of valid indices; [] when inactive."""
    if _PLAN is None:
        return []
    out = set()
    for site in ("shard.dead", "shard.slow"):
        spec = fire(site)
        if spec is None:
            continue
        arg = spec.arg
        idxs = arg if isinstance(arg, (list, tuple)) else [arg]
        for i in idxs:
            if i is not None and 0 <= int(i) < n_shards:
                out.add(int(i))
    return sorted(out)


def degrade_factors(n_shards: int) -> dict:
    """Per-shard latency inflation factors from the active plan's
    ``shard.degrade`` spec (the chronically-SLOW-shard injection the
    circuit breaker watches for). ``arg`` forms: shard index (default
    10x), ``(shard, factor)``, or a list of either. Returns {} when
    inactive or the spec does not fire this event."""
    if _PLAN is None:
        return {}
    spec = fire("shard.degrade")
    if spec is None:
        return {}
    arg = spec.arg
    if isinstance(arg, tuple) and len(arg) == 2 \
            and isinstance(arg[1], float):
        items = [arg]                     # one bare (shard, factor) pair
    elif isinstance(arg, (list, tuple)):
        items = list(arg)
    else:
        items = [arg]
    out = {}
    for it in items:
        if isinstance(it, (list, tuple)):
            s, f = int(it[0]), float(it[1])
        else:
            s, f = int(it), 10.0
        if 0 <= s < n_shards:
            out[s] = f
    return out


def poison_batch(queries, mode: str):
    """Manufacture an adversarial query batch from a clean one.

    mode: "nan" poisons a few rows with NaN, "inf" with +/-Inf,
    "dim" appends a feature column (dimensionality mismatch).
    Imports numpy lazily so the module stays runtime-free otherwise.
    """
    import numpy as np
    q = np.array(queries, dtype=np.float32, copy=True)
    if mode == "dim":
        return np.concatenate([q, q[:, :1]], axis=1)
    bad = max(1, q.shape[0] // 8)
    if mode == "nan":
        q[:bad, 0] = np.nan
    elif mode == "inf":
        q[:bad, ::2] = np.inf
        q[:bad, 1::2] = -np.inf
    else:
        raise ValueError(f"unknown poison mode {mode!r}")
    return q
