"""Mixed-precision datastore for the two-stage distance path.

The paper's restriction to l2 makes blocked distance evaluation possible
(§3.3); storing the corpus in int8 or bf16 makes the same blocks 2-4x
denser in arithmetic and 2-4x lighter in memory traffic — the GPU-scale
kNN-graph trick (Wang et al.) applied to this repo's serving, build and
online hot paths. The contract everywhere is **two-stage**: candidate
*scoring* runs on the quantized rows (kernels/l2_quant.py), and the
surviving candidates are re-ranked with the exact fp32 kernel before
anything is returned — quantization can cost a bounded sliver of recall
(a true neighbor missing the candidate pool) but never a wrong distance.

Quantization is symmetric per-row int8 — the same scheme as the gradient
compressor (train/compression.py), generalized here to row-blocked scales
(``quantize_sym_int8``; the compressor's flat per-block layout is the
``block=None`` case applied to its reshaped buffer). bf16 is the second
mode: no scales, half the bytes of fp32, and native MXU inputs.

A ``QuantizedStore`` is the quantized mirror of a feature array (corpus
rows or a query block): stored rows, per-row dequant scales, and cached
squared norms OF THE STORED (quantized) values. The norms must come from
the quantized values, not the fp32 originals, so the norm-expansion form
``q2 + c2 - 2*s_q*s_c*(q_i8 . c_i8)`` is self-consistent: the quantized
distance of a point to itself is exactly 0 and near-identical points
cannot go negative beyond rounding (the cancellation guard, cf.
kernels/ref.py pairwise_sq_l2).

The store is capacity-doubling compatible with core/online.py's
``MutableKNNStore``: rows scatter-update in place (``update_rows``) and
capacity grows by quantizing the same ``_FILL`` rows the fp32 arrays pad
with (``grow``) — shapes change only on a doubling, so jitted consumers
recompile only when the fp32 store does.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.layout import ceil_to


_EPS = 1e-30     # scale floor: all-zero rows dequantize to zero, not NaN


def mirror_width(d: int, dp: int) -> int:
    """Feature width of a quantized mirror of a (n, dp) fp32 array whose
    logical dim is ``d`` (columns d..dp are the zero padding of
    layout.pad_features, which contributes nothing to any distance).

    The fp32 serving layout pads to the 128-lane quantum (layout.py); the
    mirror keeps only the logical dims padded to the int8 tile quantum —
    the full 128 lanes on TPU (Pallas int8 tiles are (32, 128)
    layout-native), a 32-column quantum elsewhere (the oracle path has no
    lane constraint, and narrower rows are pure bandwidth/flop savings —
    at d=64 the fp32 path tiles 128 columns, the mirror 64: the scoring
    stage does half the arithmetic on top of the 4x byte cut). Never
    wider than the fp32 array itself.
    """
    quantum = 128 if jax.default_backend() == "tpu" else 32
    return min(dp, ceil_to(max(d, 1), quantum))


def quantize_sym_int8(x: jax.Array, *, block: int | None = None):
    """Symmetric int8 quantization of (n, d) rows in feature-axis blocks.

    ``block=None`` uses one block per row (per-row scales, the datastore
    layout); otherwise ``block`` must divide d and scales are per
    (row, feature-block). Returns (q (n, d) int8, scale (n, d/block) f32)
    with scale = max|x| / 127 per block (floored at 1e-30) — the same
    scheme as train/compression.py's flat gradient quantizer, which is
    this function applied per row of its (n_blocks, block) buffer.
    """
    x = x.astype(jnp.float32)
    n, d = x.shape
    if block is None:
        block = d
    if d % block:
        raise ValueError(f"block {block} does not divide feature dim {d}")
    xb = x.reshape(n, d // block, block)
    scale = jnp.max(jnp.abs(xb), axis=2) / 127.0           # (n, d/block)
    scale = jnp.maximum(scale, _EPS)
    q = jnp.clip(jnp.round(xb / scale[:, :, None]), -127, 127)
    return q.reshape(n, d).astype(jnp.int8), scale


class QuantizedStore(NamedTuple):
    """Quantized mirror of a feature array (see module docstring).

    ``data`` dtype selects the mode: int8 rows carry per-row fp32 dequant
    scales; bf16 rows carry all-ones scales (kept so both modes share one
    epilogue formula and one pytree shape). ``x2`` is the squared norm of
    the stored (quantized) rows, NOT of the fp32 originals.
    """

    data: jax.Array    # (cap, dp) int8 | bfloat16 stored rows
    scale: jax.Array   # (cap,) f32 per-row dequant scale (ones for bf16)
    x2: jax.Array      # (cap,) f32 squared norms of the STORED rows

    @property
    def mode(self) -> str:
        return "int8" if self.data.dtype == jnp.int8 else "bf16"


def quantize_corpus(x: jax.Array, mode: str,
                    width: int | None = None) -> QuantizedStore:
    """Quantize feature rows (n, dp) into a QuantizedStore. jit-safe.

    ``width`` (see ``mirror_width``) stores only the leading ``width``
    columns — callers that know the logical dim drop the fp32 layout's
    zero padding; columns beyond ``width`` MUST be zero on rows whose
    distances matter (true for layout.pad_features padding; the online
    store's fill rows violate it harmlessly — they are masked everywhere
    and stay enormous at any width)."""
    x = x.astype(jnp.float32)
    if width is not None and width < x.shape[1]:
        x = x[:, :width]
    if mode == "int8":
        q, scale = quantize_sym_int8(x)
        scale = scale[:, 0]
        qf = q.astype(jnp.float32)
        x2 = (scale * scale) * jnp.sum(qf * qf, axis=1)
        return QuantizedStore(q, scale, x2)
    if mode == "bf16":
        b = x.astype(jnp.bfloat16)
        bf = b.astype(jnp.float32)
        return QuantizedStore(
            b, jnp.ones((x.shape[0],), jnp.float32), jnp.sum(bf * bf, axis=1)
        )
    raise ValueError(f"unknown quantization mode {mode!r} (int8 | bf16)")


def dequantize(qs: QuantizedStore) -> jax.Array:
    """Stored rows back to f32 (the value the quantized kernels 'see')."""
    return qs.data.astype(jnp.float32) * qs.scale[:, None]


def update_rows(qs: QuantizedStore, rows: jax.Array,
                x_new: jax.Array) -> QuantizedStore:
    """Scatter-quantize ``x_new`` (m, dp) into the store at ``rows`` (m,)
    — the online insert's incremental mirror update (rows are sliced to
    the mirror's width). jit-safe; -1 rows are dropped."""
    upd = quantize_corpus(x_new, qs.mode, width=qs.data.shape[1])
    tgt = jnp.where(rows >= 0, rows, qs.data.shape[0])
    return QuantizedStore(
        qs.data.at[tgt].set(upd.data, mode="drop"),
        qs.scale.at[tgt].set(upd.scale, mode="drop"),
        qs.x2.at[tgt].set(upd.x2, mode="drop"),
    )


def grow(qs: QuantizedStore, new_cap: int, fill: float) -> QuantizedStore:
    """Capacity-double alongside MutableKNNStore: pad to ``new_cap`` rows
    holding the quantized form of the fp32 store's ``fill`` coordinates
    (far-away rows that are never anyone's neighbor; masked by alive/ids
    everywhere regardless)."""
    cap, w = qs.data.shape
    if new_cap <= cap:
        return qs
    pad = quantize_corpus(
        jnp.full((new_cap - cap, w), fill, jnp.float32), qs.mode
    )
    return QuantizedStore(
        jnp.concatenate([qs.data, pad.data]),
        jnp.concatenate([qs.scale, pad.scale]),
        jnp.concatenate([qs.x2, pad.x2]),
    )
