"""Memory-layout helpers (paper §3.3 "mem-align", TPU form).

The paper pads/aligns points to 256-bit AVX2 boundaries. The TPU analog is
(8, 128) VREG tiling and 128-lane MXU alignment: we pad the feature axis to
a multiple of 128 and the point axis to a multiple of 8 so every gather and
matmul tile is layout-native. Zero padding is exact for squared-l2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


LANE = 128
SUBLANE = 8


def ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_features(x: jax.Array, lane: int = LANE) -> jax.Array:
    """Pad (n, d) -> (n, ceil(d/lane)*lane) with zeros (exact for sq-l2)."""
    n, d = x.shape
    dp = ceil_to(d, lane)
    if dp == d:
        return x
    return jnp.pad(x, ((0, 0), (0, dp - d)))


def pad_points(x: jax.Array, mult: int = SUBLANE) -> tuple[jax.Array, int]:
    """Pad point axis to a multiple; returns (padded, original_n).

    Padded rows are set to +large coordinates so they are never anyone's
    nearest neighbor while keeping distances finite (no inf propagation
    through the MXU path).
    """
    n, d = x.shape
    np_ = ceil_to(n, mult)
    if np_ == n:
        return x, n
    fill = jnp.full((np_ - n, d), 1e6, dtype=x.dtype)
    return jnp.concatenate([x, fill], axis=0), n
