"""Synthetic datasets from the paper §4 (offline stand-ins for MNIST/Audio).

  * single/multi Gaussian ("Synthetic Gaussian Dataset"): covariance 2*I_d;
    non-single variant centers one Gaussian on each canonical basis vector.
  * clustered ("Synthetic Clustered Dataset"): c well-separated Gaussians
    so the paper's clustered assumption holds w.h.p.
  * mnist_like / audio_like: match the real datasets' (n, d, clusteredness)
    — 70'000 x 784 with 10 clusters, 54'387 x 192 with mild structure —
    since the real files are not downloadable in this container (noted in
    EXPERIMENTS.md; all recall/locality claims are validated on these).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gaussian(key, n: int, d: int, *, single: bool = True) -> jax.Array:
    cov_scale = jnp.sqrt(2.0)
    if single:
        return cov_scale * jax.random.normal(key, (n, d), jnp.float32)
    k1, k2 = jax.random.split(key)
    which = jax.random.randint(k1, (n,), 0, d)
    means = jnp.eye(d, dtype=jnp.float32)[which]
    return means + cov_scale * jax.random.normal(k2, (n, d), jnp.float32)


def clustered(
    key, n: int, d: int, c: int, *, sep: float = 12.0, labels: bool = False
):
    """c Gaussian clusters, means sep apart, unit covariance: the paper's
    clustered assumption holds w.h.p."""
    k1, k2, k3 = jax.random.split(key, 3)
    means = sep * jax.random.normal(k1, (c, d), jnp.float32)
    which = jax.random.randint(k2, (n,), 0, c)
    x = means[which] + jax.random.normal(k3, (n, d), jnp.float32)
    # shuffle so input order reveals nothing about clusters (paper req.)
    perm = jax.random.permutation(jax.random.fold_in(key, 7), n)
    x = x[perm]
    if labels:
        return x, which[perm]
    return x


def mnist_like(key, n: int = 70_000, d: int = 784) -> jax.Array:
    x, _ = clustered(key, n, d, 10, sep=4.0, labels=True)
    return jnp.clip(jnp.abs(x) * 0.25, 0.0, 1.0)


def audio_like(key, n: int = 54_387, d: int = 192) -> jax.Array:
    return clustered(key, n, d, 40, sep=2.0)
