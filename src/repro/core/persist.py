"""Datastore persistence: versioned snapshot/restore of the online store.

The paper's whole value proposition is that the K-NN graph build is
expensive enough to optimize (NN-Descent over blocked l2) — which is
exactly why a serving deployment must never pay that O(n·k·d) build
again on restart, and why a streaming store that lives only in device
memory cannot afford to lose hours of inserts to a crash. This module
snapshots the complete ``MutableKNNStore`` — features, cached norms,
neighbor lists (dist/idx/new), tombstone mask, the quantized mirror
(``QuantizedStore`` data/scales/norms) and the coarse ``Router``
(centroids, member lists, mini-graph, assignment/drift counters) — and
restores it bit-identically, so a cold start serves the same results as
the process that died (gated in CI: ``benchmarks/bench_persist.py``).

Layout (step 4096 of a snapshot directory)::

    snap_dir/
      step_00004096/
        manifest.json        # format version, shapes/dtypes, config echo,
                             # live/tombstone counts — never the data
        x.npy  x2.npy  nl_dist.npy  nl_idx.npy  nl_new.npy  alive.npy
        qs_data.npy  qs_scale.npy  qs_x2.npy        # precision != f32
        router_centroids.npy ... router_stale.npy   # router attached
        values.npy                                  # datastore values
        COMMIT               # commit marker, written (and fsynced) LAST

Crash safety follows the checkpoint idiom (cf. train/checkpoint.py):
every per-array file and the manifest are written first, then the
``COMMIT`` marker is fsynced into place — a snapshot is visible to
``latest_snapshot`` only once the marker exists, so a partially-written
directory (writer crashed mid-dump) is skipped on load, never half-read.
Restores validate each array against the manifest (shape + dtype) and
refuse a ``format_version`` they do not understand rather than
misinterpreting bytes.

**Async snapshots.** ``SnapshotWriter`` hands the capture to a background
thread so the insert path never blocks on disk: the store's arrays are
immutable (every insert/delete builds NEW arrays), so holding references
IS a consistent point-in-time capture — the writer fetches them to host
and serializes while streaming inserts keep mutating the (new) store.
One write is in flight at a time; errors surface on the next save/wait.
A ``keep`` knob retains the last N committed snapshots.

**Quantized-first cold start** (``restore_store(quantized_first=True)``):
load the 4x-smaller int8 mirror first and serve two-stage quantized-only
(the fp32 "re-rank" stage reads the dequantized mirror rows, so returned
distances are quantized-accurate, not exact) while a background thread
streams the fp32 rows in; ``Fp32Loader.apply`` swaps the exact rows into
the store, re-enabling exact fp32 re-rank. Cold-start to first query is
bounded by the mirror bytes, not the full fp32 corpus.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core.heap import NeighborLists
from repro.core.online import MutableKNNStore, OnlineConfig
from repro.core.quantize import QuantizedStore, dequantize
from repro.core.router import Router, RouterConfig

FORMAT_VERSION = 1

_COMMIT = "COMMIT"
_MANIFEST = "manifest.json"
_BF16 = np.dtype(jnp.bfloat16)


class SnapshotError(RuntimeError):
    """A snapshot could not be read: missing, partial, corrupted, or a
    format this build refuses to reinterpret."""


# ---------------------------------------------------------------------------
# low-level snapshot format: named arrays + manifest + commit marker
# ---------------------------------------------------------------------------


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def write_snapshot(directory: str, step: int, arrays: dict, meta: dict,
                   *, keep: int = 0) -> str:
    """Write one snapshot: per-array ``.npy`` files + ``manifest.json``,
    then the fsynced ``COMMIT`` marker LAST (the levanter/checkpoint
    idiom: a directory without the marker is invisible to loads).
    ``keep`` > 0 garbage-collects all but the newest ``keep`` committed
    snapshots. Returns the committed step directory."""
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    # Stage into a sibling dir (its ``.tmp`` suffix keeps it invisible to
    # list_snapshots) and only swap it into place once OUR commit marker
    # is on disk. A re-snapshot of an already-committed step — the
    # scheduler re-uses step=store.n whenever no inserts landed between
    # snapshots — must never destroy the committed copy before the
    # replacement is durable: a mid-write crash or disk error leaves the
    # old committed directory untouched.
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    faults.maybe_raise("persist.write")
    index = {}
    for name, arr in arrays.items():
        a = np.asarray(arr)
        logical = str(a.dtype)
        if a.dtype == _BF16:
            # npy headers can't describe bfloat16 portably — store the
            # raw bits and record the logical dtype in the manifest
            a = a.view(np.uint16)
        np.save(os.path.join(tmp, name + ".npy"), a)
        index[name] = {
            "file": name + ".npy",
            "shape": list(a.shape),
            "dtype": logical,
        }
    manifest = {
        "format_version": FORMAT_VERSION,
        "step": step,
        "time": time.time(),
        "arrays": index,
        **meta,
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok\n")
        f.flush()
        os.fsync(f.fileno())
    old = None
    if os.path.isdir(final):
        # committed (or stale partial) predecessor: move it aside, swap
        # the staged dir in, THEN drop the predecessor — at every
        # instant at least one committed copy of this step exists
        old = final + ".old"
        shutil.rmtree(old, ignore_errors=True)
        os.rename(final, old)
    os.rename(tmp, final)
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)
    _tear(final)
    if keep:
        gc_snapshots(directory, keep)
    return final


def _tear(final: str) -> None:
    """``persist.torn`` injection: truncate one array file of the
    now-committed snapshot — a torn page the COMMIT ordering cannot
    catch, which read-side manifest validation (and restore fallback)
    must. No-op unless a fault plan scripts it."""
    spec = faults.fire("persist.torn")
    if spec is None:
        return
    pat = spec.arg if isinstance(spec.arg, str) else ""
    for fn in sorted(os.listdir(final)):
        if fn.endswith(".npy") and pat in fn:
            fp = os.path.join(final, fn)
            with open(fp, "r+b") as f:
                f.truncate(max(os.path.getsize(fp) // 2, 1))
            return


def list_snapshots(directory: str) -> list[int]:
    """Committed snapshot steps, ascending. Directories without the
    commit marker (a writer died mid-dump) are ignored."""
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if not d.startswith("step_"):
            continue
        p = os.path.join(directory, d)
        if not (os.path.exists(os.path.join(p, _COMMIT))
                and os.path.exists(os.path.join(p, _MANIFEST))):
            continue
        try:
            out.append(int(d.split("_", 1)[1]))
        except ValueError:
            continue
    return sorted(out)


def latest_snapshot(directory: str) -> int | None:
    """Newest committed step in ``directory`` (None when empty)."""
    steps = list_snapshots(directory)
    return steps[-1] if steps else None


def gc_snapshots(directory: str, keep: int) -> None:
    """Drop all but the newest ``keep`` committed snapshots."""
    for s in list_snapshots(directory)[:-keep] if keep else []:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)


def read_snapshot(directory: str, step: int | None = None, *,
                  only: set | None = None,
                  skip: set | frozenset = frozenset()):
    """Read one committed snapshot. ``only``/``skip`` select a subset of
    the named arrays (e.g. the quantized-first cold start skips the fp32
    features). Returns (step, {name: np.ndarray}, manifest).

    Raises ``SnapshotError`` when no committed snapshot exists, the
    manifest's format version is not one this build understands, or an
    array file is unreadable / disagrees with the manifest's shape or
    dtype (truncated or corrupted file — named in the error)."""
    if step is None:
        step = latest_snapshot(directory)
        if step is None:
            raise SnapshotError(
                f"no committed snapshot under {directory!r} (directories "
                f"without a {_COMMIT} marker are ignored)"
            )
    d = _step_dir(directory, step)
    if not os.path.exists(os.path.join(d, _COMMIT)):
        raise SnapshotError(
            f"snapshot {d} has no {_COMMIT} marker — partial write, "
            "refusing to load"
        )
    try:
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise SnapshotError(f"unreadable manifest {d}/{_MANIFEST}: {e}") \
            from e
    ver = manifest.get("format_version")
    if ver != FORMAT_VERSION:
        raise SnapshotError(
            f"snapshot {d} has format version {ver!r}; this build reads "
            f"version {FORMAT_VERSION} — refusing to reinterpret its bytes"
        )
    arrays = {}
    for name, info in manifest["arrays"].items():
        if only is not None and name not in only:
            continue
        if name in skip:
            continue
        fp = os.path.join(d, info["file"])
        try:
            a = np.load(fp)
        except Exception as e:
            raise SnapshotError(
                f"corrupt snapshot array {fp}: {e}"
            ) from e
        if info["dtype"] == "bfloat16":
            a = a.view(_BF16)
        if list(a.shape) != list(info["shape"]) \
                or str(a.dtype) != info["dtype"]:
            raise SnapshotError(
                f"snapshot array {fp} holds {a.dtype}{a.shape}, manifest "
                f"says {info['dtype']}{tuple(info['shape'])} — truncated "
                "or corrupted file"
            )
        arrays[name] = a
    return step, arrays, manifest


# ---------------------------------------------------------------------------
# MutableKNNStore capture / rebuild
# ---------------------------------------------------------------------------

_ROUTER_FIELDS = ("centroids", "c2", "graph", "assign", "counts", "stale")


def _cfg_echo(cfg: OnlineConfig) -> dict:
    return dataclasses.asdict(cfg)          # RouterConfig nests as a dict


def _cfg_from_echo(echo: dict) -> OnlineConfig:
    echo = dict(echo)
    rd = echo.pop("router", None)
    # filter to known fields: format_version gates real layout changes,
    # this just keeps a same-version echo robust to knob additions
    ofields = {f.name for f in dataclasses.fields(OnlineConfig)}
    rfields = {f.name for f in dataclasses.fields(RouterConfig)}
    router = None if rd is None else RouterConfig(
        **{k: v for k, v in rd.items() if k in rfields})
    return OnlineConfig(
        **{k: v for k, v in echo.items() if k in ofields},
        router=router,
    )


def capture_store(store: MutableKNNStore, *, values=None):
    """Flatten a store (plus an optional row-aligned ``values`` array —
    the kNN-LM datastore's token ids) into (arrays, manifest meta). The
    arrays are the live device buffers: immutable, so holding them IS a
    consistent capture that later inserts cannot mutate."""
    arrays = {
        "x": store.x,
        "x2": store.x2,
        "nl_dist": store.nl.dist,
        "nl_idx": store.nl.idx,
        "nl_new": store.nl.new,
        "alive": store.alive,
    }
    if store.qs is not None:
        arrays["qs_data"] = store.qs.data
        arrays["qs_scale"] = store.qs.scale
        arrays["qs_x2"] = store.qs.x2
    if store.router is not None:
        for f in _ROUTER_FIELDS:
            arrays[f"router_{f}"] = getattr(store.router, f)
        arrays["router_members_dist"] = store.router.members.dist
        arrays["router_members_idx"] = store.router.members.idx
        arrays["router_members_new"] = store.router.members.new
    if values is not None:
        arrays["values"] = values
    live = int(jnp.sum(store.alive))
    meta = {
        "kind": "mutable_store",
        "n": int(store.n),
        "d": int(store.d),
        "dp": int(store.x.shape[1]),
        "k": int(store.k),
        "capacity": int(store.capacity),
        "live": live,
        "tombstones": int(store.n) - live,
        "precision": store.cfg.precision,
        # the metric is echoed TOP-LEVEL (not only inside the config
        # echo) and validated on restore: rows are stored in the
        # metric's transformed space, so restoring them under another
        # metric would serve silently wrong distances. mips_m is the
        # augmentation bound the stored rows were transformed with —
        # without it, post-restore inserts could not be made consistent.
        "metric": store.cfg.metric,
        "mips_m": float(store.mips_m),
        "has_qs": store.qs is not None,
        "has_router": store.router is not None,
        "config": _cfg_echo(store.cfg),
    }
    return arrays, meta


def _rebuild_qs(arrays: dict) -> QuantizedStore:
    return QuantizedStore(
        jnp.asarray(arrays["qs_data"]),
        jnp.asarray(arrays["qs_scale"]),
        jnp.asarray(arrays["qs_x2"]),
    )


def _rebuild_router(arrays: dict) -> Router:
    return Router(
        centroids=jnp.asarray(arrays["router_centroids"]),
        c2=jnp.asarray(arrays["router_c2"]),
        graph=jnp.asarray(arrays["router_graph"]),
        members=NeighborLists(
            jnp.asarray(arrays["router_members_dist"]),
            jnp.asarray(arrays["router_members_idx"]),
            jnp.asarray(arrays["router_members_new"]),
        ),
        assign=jnp.asarray(arrays["router_assign"]),
        counts=jnp.asarray(arrays["router_counts"]),
        stale=jnp.asarray(arrays["router_stale"]),
    )


def _metric_meta(manifest: dict, cfg: OnlineConfig) -> float:
    """Validate the top-level metric echo against the config echo and
    return the mips augmentation bound. Pre-metric snapshots (same
    format version, older writer) carry neither key — they are l2."""
    met = manifest.get("metric", "l2")
    if met != cfg.metric:
        raise SnapshotError(
            f"snapshot metric echo {met!r} disagrees with its config "
            f"echo {cfg.metric!r} — refusing to serve transformed rows "
            "under the wrong metric"
        )
    return float(manifest.get("mips_m", 0.0))


def rebuild_store(arrays: dict, manifest: dict):
    """Inverse of ``capture_store``: (store, values-or-None). The
    metric echo is validated (``_metric_meta``) and the mips bound
    restored, so post-restore inserts augment exactly like pre-snapshot
    ones did."""
    cfg = _cfg_from_echo(manifest["config"])
    store = MutableKNNStore(
        x=jnp.asarray(arrays["x"]),
        x2=jnp.asarray(arrays["x2"]),
        nl=NeighborLists(
            jnp.asarray(arrays["nl_dist"]),
            jnp.asarray(arrays["nl_idx"]),
            jnp.asarray(arrays["nl_new"]),
        ),
        alive=jnp.asarray(arrays["alive"]),
        n=int(manifest["n"]),
        d=int(manifest["d"]),
        cfg=cfg,
        qs=_rebuild_qs(arrays) if "qs_data" in arrays else None,
        router=_rebuild_router(arrays)
        if "router_centroids" in arrays else None,
        mips_m=_metric_meta(manifest, cfg),
    )
    values = jnp.asarray(arrays["values"]) if "values" in arrays else None
    return store, values


def snapshot_store(store: MutableKNNStore, directory: str, step: int, *,
                   values=None, keep: int = 0) -> str:
    """Synchronous one-shot snapshot (use ``SnapshotWriter`` to overlap
    serialization with streaming inserts). Returns the step directory."""
    arrays, meta = capture_store(store, values=values)
    return write_snapshot(directory, step, arrays, meta, keep=keep)


class Fp32Loader:
    """Background fp32 feature load for the quantized-first cold start:
    started by ``restore_store(quantized_first=True)``, finished by
    ``apply`` (blocks until the read completes, then swaps the exact
    ``x``/``x2`` into the store — re-enabling exact fp32 re-rank)."""

    def __init__(self, directory: str, step: int):
        self._directory = directory
        self._step = step
        self._arrays: dict | None = None
        self._error: Exception | None = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            _, self._arrays, _ = read_snapshot(
                self._directory, self._step, only={"x", "x2"})
        except Exception as e:          # surfaced by apply()
            self._error = e

    @property
    def done(self) -> bool:
        return not self._thread.is_alive()

    def apply(self, store: MutableKNNStore) -> MutableKNNStore:
        self._thread.join()
        if self._error is not None:
            raise self._error
        return dataclasses.replace(
            store,
            x=jnp.asarray(self._arrays["x"]),
            x2=jnp.asarray(self._arrays["x2"]),
        )


class Restored(NamedTuple):
    store: MutableKNNStore
    values: Any                 # row-aligned values array or None
    step: int
    manifest: dict
    fp32_loader: Fp32Loader | None   # quantized-first restores only
    fallback_from: tuple = ()   # newer committed steps that failed
    #                             validation and were skipped (quarantined)


def _quarantine(directory: str, step: int, err: Exception) -> None:
    """Move a committed-but-unreadable snapshot aside (rename, never
    delete: its bytes are the only forensic evidence, and a smarter
    reader may yet salvage it). A failed rename degrades to a warning —
    the fallback restore proceeds either way."""
    src = _step_dir(directory, step)
    dst = src + ".bad"
    i = 0
    while os.path.exists(dst):
        i += 1
        dst = src + f".bad{i}"
    try:
        faults.maybe_raise("persist.rename")
        os.rename(src, dst)
        warnings.warn(
            f"snapshot step {step} failed validation ({err}); "
            f"quarantined to {dst}", RuntimeWarning, stacklevel=3)
    except OSError as rename_err:
        warnings.warn(
            f"snapshot step {step} failed validation ({err}) and could "
            f"not be quarantined ({rename_err}); falling back anyway",
            RuntimeWarning, stacklevel=3)


def restore_store(directory: str, step: int | None = None, *,
                  quantized_first: bool = False) -> Restored:
    """Restore a ``MutableKNNStore`` snapshot (the newest committed step
    when ``step`` is None).

    When ``step`` is None and the newest committed snapshot fails
    validation (torn array file, corrupt manifest, unknown format), the
    restore degrades per-snapshot: the bad directory is quarantined by
    rename (never deleted — in particular never the last remaining
    committed snapshot, which is only ever touched if it itself fails)
    and the next-older committed step is tried, newest-first, until one
    loads. The skipped steps are reported in ``Restored.fallback_from``.
    An explicit ``step`` fails hard — the caller asked for those exact
    bytes.

    ``quantized_first=True`` is the fast cold start: only the int8/bf16
    mirror (4x/2x smaller than the fp32 rows) plus graph/masks are read
    before the store is usable — its ``x`` holds the DEQUANTIZED mirror
    rows (zero-padded back to the serving layout), so searches run
    two-stage quantized-only (re-rank included) immediately, with
    quantized-accurate distances. The returned ``fp32_loader`` streams
    the exact rows in on a background thread; ``fp32_loader.apply(store)``
    swaps them in. Requires the snapshot to carry a quantized mirror."""
    skip = {"x", "x2"} if quantized_first else frozenset()
    if step is not None:
        payload = read_snapshot(directory, step, skip=skip)
        return _rebuild_restored(directory, payload, quantized_first)
    steps = list_snapshots(directory)
    if not steps:
        raise SnapshotError(
            f"no committed snapshot under {directory!r} (directories "
            f"without a {_COMMIT} marker are ignored)"
        )
    skipped = []
    last_err: SnapshotError | None = None
    for s in reversed(steps):
        # only the READ phase falls back: a snapshot whose bytes are
        # intact but whose contents don't match the caller's request
        # (kind mismatch, missing quantized mirror) raises through from
        # _rebuild_restored without being quarantined
        try:
            payload = read_snapshot(directory, s, skip=skip)
        except SnapshotError as e:
            last_err = e
            _quarantine(directory, s, e)
            skipped.append(s)
            continue
        restored = _rebuild_restored(directory, payload, quantized_first)
        if skipped:
            restored = restored._replace(fallback_from=tuple(skipped))
        return restored
    raise SnapshotError(
        f"every committed snapshot under {directory!r} failed "
        f"validation (steps {list(reversed(steps))})"
    ) from last_err


def _rebuild_restored(directory: str, payload: tuple,
                      quantized_first: bool) -> Restored:
    if not quantized_first:
        step, arrays, manifest = payload
        if manifest.get("kind") != "mutable_store":
            raise SnapshotError(
                f"snapshot kind {manifest.get('kind')!r} is not a "
                "mutable_store snapshot"
            )
        store, values = rebuild_store(arrays, manifest)
        return Restored(store, values, step, manifest, None)

    step, arrays, manifest = payload
    if manifest.get("kind") != "mutable_store":
        raise SnapshotError(
            f"snapshot kind {manifest.get('kind')!r} is not a "
            "mutable_store snapshot"
        )
    if "qs_data" not in arrays:
        raise SnapshotError(
            "quantized-first restore needs a quantized mirror in the "
            f"snapshot, but step {step} under {directory!r} has none "
            "(store built with precision='f32')"
        )
    qs = _rebuild_qs(arrays)
    cap, w = qs.data.shape
    dp = int(manifest["dp"])
    xq = dequantize(qs)              # (cap, w) — what the kernels "see"
    x = jnp.zeros((cap, dp), jnp.float32).at[:, :w].set(xq)
    cfg = _cfg_from_echo(manifest["config"])
    store = MutableKNNStore(
        x=x,
        x2=qs.x2,                    # norms of the dequantized rows
        nl=NeighborLists(
            jnp.asarray(arrays["nl_dist"]),
            jnp.asarray(arrays["nl_idx"]),
            jnp.asarray(arrays["nl_new"]),
        ),
        alive=jnp.asarray(arrays["alive"]),
        n=int(manifest["n"]),
        d=int(manifest["d"]),
        cfg=cfg,
        qs=qs,
        router=_rebuild_router(arrays)
        if "router_centroids" in arrays else None,
        mips_m=_metric_meta(manifest, cfg),
    )
    values = jnp.asarray(arrays["values"]) if "values" in arrays else None
    return Restored(store, values, step, manifest,
                    Fp32Loader(directory, step))


# ---------------------------------------------------------------------------
# KNNDatastore (static) capture / rebuild — same format, kind tag differs
# ---------------------------------------------------------------------------


def capture_datastore(ds):
    """Flatten a static kNN-LM datastore (duck-typed: ``keys``,
    ``values``, ``graph_idx``, optional ``qstore``/``router``) into
    (arrays, meta) — ``serve/knn_lm.KNNDatastore.snapshot``'s body."""
    arrays = {
        "keys": ds.keys,
        "values": ds.values,
        "graph_idx": ds.graph_idx,
    }
    if getattr(ds, "qstore", None) is not None:
        arrays["qs_data"] = ds.qstore.data
        arrays["qs_scale"] = ds.qstore.scale
        arrays["qs_x2"] = ds.qstore.x2
    router = getattr(ds, "router", None)
    if router is not None:
        for f in _ROUTER_FIELDS:
            arrays[f"router_{f}"] = getattr(router, f)
        arrays["router_members_dist"] = router.members.dist
        arrays["router_members_idx"] = router.members.idx
        arrays["router_members_new"] = router.members.new
    meta = {
        "kind": "knn_datastore",
        "n": int(ds.keys.shape[0]),
        "d": int(ds.keys.shape[1]),
        "k": int(ds.graph_idx.shape[1]),
        "has_qs": getattr(ds, "qstore", None) is not None,
        "has_router": router is not None,
        # metric echo: keys are stored TRANSFORMED, so a restore must
        # serve them under the same metric (defaults cover pre-metric
        # snapshots — format unchanged, old snapshots stay loadable)
        "metric": getattr(ds, "metric", "l2"),
        "mips_m": float(getattr(ds, "mips_m", 0.0)),
        "build_stats": {k: v for k, v in
                        getattr(ds, "build_stats", {}).items()
                        if isinstance(v, (int, float, str, bool))},
    }
    return arrays, meta


def rebuild_datastore(arrays: dict, manifest: dict) -> dict:
    """Inverse of ``capture_datastore``: the constructor kwargs of a
    ``KNNDatastore`` (minus ``build_stats``, which the caller stamps)."""
    if manifest.get("kind") != "knn_datastore":
        raise SnapshotError(
            f"snapshot kind {manifest.get('kind')!r} is not a "
            "knn_datastore snapshot"
        )
    return {
        "keys": jnp.asarray(arrays["keys"]),
        "values": jnp.asarray(arrays["values"]),
        "graph_idx": jnp.asarray(arrays["graph_idx"]),
        "qstore": _rebuild_qs(arrays) if "qs_data" in arrays else None,
        "router": _rebuild_router(arrays)
        if "router_centroids" in arrays else None,
        "metric": manifest.get("metric", "l2"),
        "mips_m": float(manifest.get("mips_m", 0.0)),
    }


# ---------------------------------------------------------------------------
# async writer
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SnapshotWriter:
    """Non-blocking snapshots that interleave with streaming inserts.

    ``save`` captures the store's (immutable) device arrays on the caller
    thread — a reference grab, not a copy — and hands host fetch +
    serialization to a background thread, so the insert path never waits
    on disk. One write is in flight at a time: a second ``save`` first
    joins the previous one (and re-raises its error, if any). ``keep``
    retains the newest N committed snapshots.

    Transient disk errors (``OSError``: full volume draining, flaky
    network mount) are retried ``retries`` times with capped exponential
    backoff starting at ``backoff_s`` before surfacing — the staged
    write in ``write_snapshot`` makes a failed attempt leave no trace,
    so a retry starts clean."""

    directory: str
    keep: int = 3
    async_write: bool = True
    retries: int = 2
    backoff_s: float = 0.05

    def __post_init__(self):
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, store: MutableKNNStore, step: int, *, values=None,
             wait: bool = False) -> None:
        self.wait()                      # one outstanding write at a time
        arrays, meta = capture_store(store, values=values)

        def write():
            delay = self.backoff_s
            for attempt in range(self.retries + 1):
                try:
                    return write_snapshot(self.directory, step, arrays,
                                          meta, keep=self.keep)
                except OSError:
                    if attempt == self.retries:
                        raise
                    time.sleep(delay)
                    delay = min(delay * 2.0, 1.0)

        if self.async_write and not wait:
            def run():
                try:
                    write()
                except Exception as e:   # surfaced on next save/wait
                    self._error = e
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        """Join the in-flight write; re-raise its error, if any."""
        err = self.poll()
        if err is not None:
            raise err

    def poll(self) -> Exception | None:
        """Join the in-flight write and RETURN its error (None when
        clean) instead of raising — the drain path uses this so a
        failed *periodic* background write cannot abort the *final*
        snapshot that supersedes it."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        err, self._error = self._error, None
        return err
