"""Distributed K-NN-graph construction (the scale-out layer the paper's
single-core scope stops short of; DESIGN.md assumption change #4).

Points are sharded over the mesh's ``data`` axis under shard_map. Global
ids are ``shard * n_local + row``. Three collective patterns:

  * exact_knn_sharded — blocked brute force: the local block of features
    ring-rotates (collective_permute) P-1 times; each step every shard
    evaluates an (n_local x n_local) blocked-distance tile and folds the
    top-k into its running lists. Peak memory O(n_local * d); validates
    recall of the approximate build.
  * nn_descent_sharded_iteration — one NN-Descent iteration where
      - candidate features are fetched by the same feature ring (each
        shard absorbs the rows it sampled as the owning block passes), and
      - update routing is an all_to_all: each evaluated pair is bucketed
        by its receiver's owner shard and exchanged in fixed-size buckets.
  * reorder_sharded — the paper's greedy reorder run shard-locally on the
    locally-owned subgraph, followed by one all_gather of the per-shard
    permutations so every shard can rewrite its neighbor ids.
  * graph_search_sharded — the serving-side entry: replicated query
    blocks run the fused batched beam search on every shard's local
    subgraph, and one all_gather + top-k merges the per-shard results
    into global top-k (core/graph_search.py holds the per-shard search).

The per-shard inner work reuses the exact same selection/merge/blocked
kernels as the single-chip path. After the sampled iterations converge,
``build_knn_graph_sharded`` runs the same terminal polish rounds as the
single-chip build (``polish_sharded_round`` — exhaustive k*k
neighbor-of-neighbor join with the fused ``knn_join_select`` reduction,
neighbor lists and features fetched via the request-routed all_to_all).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import faults, heap, selection
from repro.core.graph_search import SearchConfig, graph_search

# jax.shard_map landed in 0.5; fall back to the experimental module on
# 0.4.x (same semantics — check_vma was called check_rep there)
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _xp_shard_map

    def shard_map(f=None, /, **kw):
        kw["check_rep"] = kw.pop("check_vma", False)
        if f is None:
            return functools.partial(_xp_shard_map, **kw)
        return _xp_shard_map(f, **kw)
from repro.core.heap import NeighborLists
from repro.core.nn_descent import DescentConfig, invert_candidates, pair_block
from repro.kernels import ops


def _ring_perm(axis: str, size: int):
    return [(i, (i + 1) % size) for i in range(size)]


def exact_knn_sharded(mesh: Mesh, x: jax.Array, k: int, *, axis: str = "data"):
    """Exact k-NN over points sharded along ``axis``. x: (n, d) global.

    Returns (dist (n, k), idx (n, k) global ids), sharded like x.
    """
    P_ = mesh.shape[axis]
    n, d = x.shape
    assert n % P_ == 0, (n, P_)
    n_local = n // P_

    def shard_fn(x_local):
        p = jax.lax.axis_index(axis)
        my_ids = p * n_local + jnp.arange(n_local, dtype=jnp.int32)
        x_local = x_local.astype(jnp.float32)
        x2_local = jnp.sum(x_local * x_local, axis=1)

        nl_d = jax.lax.pvary(jnp.full((n_local, k), jnp.inf, jnp.float32), (axis,))
        nl_i = jax.lax.pvary(jnp.full((n_local, k), -1, jnp.int32), (axis,))

        def step(s, carry):
            nl_d, nl_i, block, block2 = carry
            owner = (p - s) % P_
            ids = owner * n_local + jnp.arange(n_local, dtype=jnp.int32)
            dist = jnp.maximum(
                x2_local[:, None] + block2[None, :] - 2.0 * x_local @ block.T,
                0.0,
            )
            dist = jnp.where(ids[None, :] == my_ids[:, None], jnp.inf, dist)
            neg, top = jax.lax.top_k(-dist, k)
            cand_i = ids[top]
            nld, nli, _ = _merge_topk(nl_d, nl_i, -neg, cand_i, k)
            block = jax.lax.ppermute(block, axis, _ring_perm(axis, P_))
            block2 = jax.lax.ppermute(block2, axis, _ring_perm(axis, P_))
            return nld, nli, block, block2

        nl_d, nl_i, _, _ = jax.lax.fori_loop(
            0, P_, step, (nl_d, nl_i, x_local, x2_local)
        )
        return nl_d, nl_i

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=P(axis, None),
        out_specs=(P(axis, None), P(axis, None)),
    )
    return fn(x)


def _merge_topk(nl_d, nl_i, cand_d, cand_i, k):
    nl = NeighborLists(nl_d, nl_i, jnp.zeros_like(nl_i, dtype=bool))
    merged, upd = heap.merge(nl, cand_d, cand_i, cand_new=False)
    return merged.dist, merged.idx, upd


def _fetch_features_ring(x_local, needed_ids, axis: str, P_: int, n_local: int):
    """Gather features for arbitrary global ids via the feature ring.
    needed_ids: (m,) int32 (clipped >=0); returns (m, d) rows."""
    m = needed_ids.shape[0]
    d = x_local.shape[1]
    p = jax.lax.axis_index(axis)
    out = jax.lax.pvary(jnp.zeros((m, d), x_local.dtype), (axis,))

    def step(s, carry):
        out, block = carry
        owner = (p - s) % P_
        local = needed_ids - owner * n_local
        hit = (local >= 0) & (local < n_local)
        rows = block[jnp.clip(local, 0, n_local - 1)]
        out = jnp.where(hit[:, None], rows, out)
        block = jax.lax.ppermute(block, axis, _ring_perm(axis, P_))
        return out, block

    out, _ = jax.lax.fori_loop(0, P_, step, (out, x_local))
    return out


def fetch_rows_a2a(x_local, ids, *, axis: str, P_: int, n_local: int,
                   cap: int):
    """Request-routed feature fetch (§Perf iteration on the ring fetch).

    The ring fetch rewrites the whole (m, d) output buffer P times —
    O(P*m*d) HBM traffic. Here each shard instead SENDS its needed ids to
    their owners (one all_to_all of (P, cap) ids), owners gather rows
    locally, and one reverse all_to_all returns them in the same bucket
    positions — O(cap*P*d) traffic total, independent of P's effect on
    passes. Overflow beyond ``cap`` per destination is dropped and
    reported in the returned mask (sampling noise, like every other
    bounded buffer in NN-Descent).

    Returns (rows (m, d), ok (m,) bool).
    """
    m = ids.shape[0]
    d = x_local.shape[1]
    p = jax.lax.axis_index(axis)
    base = p * n_local
    valid = ids >= 0
    dest = jnp.clip(ids // n_local, 0, P_ - 1)
    dest_k = jnp.where(valid, dest, P_)
    order = jnp.argsort(dest_k)
    dest_s = dest_k[order]
    ids_s = ids[order]
    first = jnp.searchsorted(dest_s, jnp.arange(P_ + 1), side="left")
    pos = jnp.arange(m) - first[jnp.clip(dest_s, 0, P_)]
    req = jnp.full((P_, cap), -1, jnp.int32)
    req = req.at[dest_s, pos].set(ids_s, mode="drop")

    got = jax.lax.all_to_all(req[:, None, :], axis, split_axis=0,
                             concat_axis=0, tiled=False)[:, 0, :]
    # rows requested FROM me (global ids owned here; -1 = empty slot)
    loc = got - base
    ok_here = (loc >= 0) & (loc < n_local)
    rows = x_local[jnp.clip(loc, 0, n_local - 1)]
    zero = jnp.zeros((), x_local.dtype)       # dtype-safe fill (works for
    rows = jnp.where(ok_here[..., None], rows, zero)     # int rows too)
    back = jax.lax.all_to_all(rows[:, None], axis, split_axis=0,
                              concat_axis=0, tiled=False)[:, 0]

    in_bucket = (dest_s < P_) & (pos >= 0) & (pos < cap)
    fetched = back[jnp.clip(dest_s, 0, P_ - 1), jnp.clip(pos, 0, cap - 1)]
    out = jnp.zeros((m, d), x_local.dtype)
    out = out.at[order].set(jnp.where(in_bucket[:, None], fetched, zero))
    ok = jnp.zeros((m,), bool).at[order].set(in_bucket)
    return out, ok & (ids >= 0)


def nn_descent_sharded_iteration(
    key: jax.Array,
    x_local: jax.Array,       # (n_local, d)
    x2_local: jax.Array,      # (n_local,)
    nl: NeighborLists,        # local rows, GLOBAL neighbor ids
    cfg: DescentConfig,
    *,
    axis: str,
    P_: int,
    fetch: str = "a2a",       # a2a (optimized) | ring (baseline)
):
    """One sharded NN-Descent iteration (call under shard_map)."""
    n_local, k = nl.idx.shape
    p = jax.lax.axis_index(axis)
    base = p * n_local

    # ---- selection runs on LOCAL receiver rows; incidences whose receiver
    # is remote are routed by all_to_all before compaction.
    local_nl = NeighborLists(nl.dist, nl.idx, nl.new)
    recv, cand, is_new, valid, is_fwd, slot = selection._incidences(local_nl)
    # forward incidences: receiver = local row (global id base+row).
    half = n_local * k
    recv = jnp.concatenate(
        [base + recv[:half], recv[half:]]  # second half already global ids
    )
    cand = jnp.concatenate([cand[:half], base + cand[half:]])

    # turbosampling accept (reverse degree approximated by local counts
    # all-reduced — global degree of each node needs its incidences which
    # are distributed; we segment-sum into the owner's (n_local,) slice)
    owner_rows = recv - base
    deg_new_local = jax.ops.segment_sum(
        (valid & is_new).astype(jnp.int32),
        jnp.where((owner_rows >= 0) & (owner_rows < n_local), owner_rows, n_local),
        num_segments=n_local + 1,
    )[:n_local]
    # remote-receiver incidences counted on their owner via psum of bincount
    # over the global id space is O(n) — instead each shard uses k (forward
    # degree) + its local reverse count as the |N| estimate. Exact global
    # degree costs one extra all_to_all; the estimate only perturbs the
    # accept probability (sampling stays unbiased per pool).
    deg_new = k + deg_new_local
    k_acc, k_rnd, key = jax.random.split(key, 3)
    p_new = jnp.minimum(1.0, cfg.rho_k / jnp.maximum(deg_new, 1))
    u = jax.random.uniform(k_acc, recv.shape)
    p_edge = p_new[jnp.clip(owner_rows, 0, n_local - 1)]
    p_edge = jnp.where(
        (owner_rows >= 0) & (owner_rows < n_local), p_edge, cfg.rho_k / (2.0 * k)
    )
    acc_new = valid & is_new & (u < p_edge)
    acc_old = valid & ~is_new & (u < p_edge)

    # route accepted incidences to receiver owners (fixed buckets)
    cap = max(2 * cfg.rho_k * max(n_local // max(P_, 1), 1), 8)
    def route(acc_mask, subkey):
        payload = jnp.stack([recv, cand], axis=1)
        return _all_to_all_route(
            payload, acc_mask, recv // n_local, P_, cap, axis, subkey
        )

    k_r1, k_r2, key = jax.random.split(key, 3)
    got_new = route(acc_new, k_r1)        # (P_*cap, 2) rows targeting me
    got_old = route(acc_old, k_r2)

    def compact(got, c):
        r = got[:, 0]
        valid_r = r >= 0
        rl = jnp.where(valid_r, r - base, -1)
        rnd = jax.random.uniform(jax.random.fold_in(key, c), r.shape)
        from repro.core.selection import _compact
        return _compact(rl, got[:, 1], valid_r, rnd, n_local, c)

    cand_new = compact(got_new, cfg.rho_k)
    cand_old = compact(got_old, cfg.rho_k)

    # clear sampled forward flags (local slots whose incidence was accepted)
    sampled = jnp.zeros((n_local * k,), bool)
    fwd_acc = acc_new[:half] & is_fwd[:half]
    sampled = sampled.at[jnp.where(fwd_acc, slot[:half], 0)].max(fwd_acc)
    nl = heap.mark_sampled_old(nl, sampled.reshape(n_local, k))

    # ---- fetch candidate features, evaluate pair distances
    cn, co = cand_new, cand_old
    flat = jnp.concatenate([cn.reshape(-1), co.reshape(-1)])
    if fetch == "a2a":
        cap_f = max(2 * flat.shape[0] // max(P_, 1), 16)
        feats, fok = fetch_rows_a2a(
            x_local, flat, axis=axis, P_=P_, n_local=n_local, cap=cap_f)
        # candidates whose fetch overflowed the bucket: invalidate
        okn = fok[: cn.size].reshape(cn.shape)
        oko = fok[cn.size:].reshape(co.shape)
        cn = jnp.where(okn, cn, -1)
        co = jnp.where(oko, co, -1)
    else:
        feats = _fetch_features_ring(
            x_local, jnp.clip(flat, 0, P_ * n_local - 1), axis, P_, n_local
        )
    d_feat = feats.shape[1]
    xg_n = feats[: cn.size].reshape(n_local, -1, d_feat)
    xg_o = feats[cn.size :].reshape(n_local, -1, d_feat)
    x2_n = jnp.sum(xg_n * xg_n, axis=-1)
    x2_o = jnp.sum(xg_o * xg_o, axis=-1)
    vn, vo = cn >= 0, co >= 0

    d_nn = pair_block(xg_n, x2_n, xg_n, x2_n)
    d_no = pair_block(xg_n, x2_n, xg_o, x2_o)

    cn_b, co_b = cn.shape[1], co.shape[1]
    iu = jnp.triu_indices(cn_b, k=1)
    a_nn, b_nn = cn[:, iu[0]], cn[:, iu[1]]
    dd_nn = d_nn[:, iu[0], iu[1]]
    ok_nn = vn[:, iu[0]] & vn[:, iu[1]] & (a_nn != b_nn)
    a_no = jnp.broadcast_to(cn[:, :, None], (n_local, cn_b, co_b)).reshape(n_local, -1)
    b_no = jnp.broadcast_to(co[:, None, :], (n_local, cn_b, co_b)).reshape(n_local, -1)
    dd_no = d_no.reshape(n_local, -1)
    ok_no = (
        jnp.broadcast_to(vn[:, :, None], (n_local, cn_b, co_b)).reshape(n_local, -1)
        & jnp.broadcast_to(vo[:, None, :], (n_local, cn_b, co_b)).reshape(n_local, -1)
        & (a_no != b_no)
    )
    a = jnp.concatenate([a_nn, b_nn, a_no, b_no], axis=1).reshape(-1)
    b = jnp.concatenate([b_nn, a_nn, b_no, a_no], axis=1).reshape(-1)
    dd = jnp.concatenate([dd_nn, dd_nn, dd_no, dd_no], axis=1).reshape(-1)
    ok = jnp.concatenate([ok_nn, ok_nn, ok_no, ok_no], axis=1).reshape(-1)

    # ---- route updates to receiver owners, merge locally. The received
    # (receiver, candidate, dist) rows go through the fused knn_join
    # routing (invert incidences -> gather -> top-merge_k select) instead
    # of a (receiver, dist) lexsort — the same kernel family as the
    # single-chip local join.
    k_u, key = jax.random.split(key)
    payload = jnp.stack([a, b, _f32_bits(dd)], axis=1)
    cap_u = max(4 * cfg.merge_k * max(n_local // max(P_, 1), 1), 8)
    got = _all_to_all_route(payload, ok, a // n_local, P_, cap_u, axis, k_u)
    r = got[:, 0]
    valid_r = r >= 0
    rl = jnp.where(valid_r, r - base, -1)
    dd_r = jnp.where(valid_r, _bits_f32(got[:, 2]), jnp.inf)
    # per-receiver source buffer: 2x the expected load (cap_u routes
    # ~4*merge_k rows per receiver on average). Overflow drops the
    # FARTHEST incoming rows per receiver (distance-prioritized, closing
    # the ROADMAP watch item); hub-heavy meshes can still raise
    # DescentConfig.join_src to widen the buffer.
    s_cap = cfg.join_src or 8 * cfg.merge_k
    rows_of, _ = invert_candidates(
        rl[:, None], n_local, s_cap, prio=dd_r[:, None]
    )
    ok_r = rows_of >= 0
    safe_r = jnp.where(ok_r, rows_of, 0)
    gd = jnp.where(ok_r, dd_r[safe_r], jnp.inf)
    gi = jnp.where(ok_r, got[:, 1][safe_r], -1)
    cd, ci = ops.knn_join_select(
        gd, gi, jnp.full((n_local,), jnp.inf), c=cfg.merge_k,
        backend=cfg.backend,
    )
    nl, upd = heap.merge(nl, cd, ci, cand_new=True)
    n_evals = jnp.sum(ok_nn) + jnp.sum(ok_no)
    total_upd = jax.lax.psum(jnp.sum(upd), axis)
    total_ev = jax.lax.psum(n_evals, axis)
    return nl, total_upd, total_ev


def polish_sharded_round(
    x_local: jax.Array,       # (n_local, d) f32
    x2_local: jax.Array,      # (n_local,)
    nl: NeighborLists,        # local rows, GLOBAL neighbor ids
    *,
    axis: str,
    P_: int,
    merge_c: int,             # select width before the merge (<= k*k)
    backend: str = "auto",    # kernel dispatch (DescentConfig.backend)
):
    """One sharded exhaustive local-join polish round (call under
    shard_map) — the port of core/nn_descent.py polish_iteration: every
    local row joins against ALL k*k of its neighbors-of-neighbors
    (forward direction, unsampled). Neighbor LISTS of remote neighbors
    and then the candidates' FEATURES are both fetched with the
    request-routed all_to_all (``fetch_rows_a2a``); candidates whose
    fetch overflowed its bucket are dropped (bounded-buffer sampling
    noise). The k*k candidate row is reduced by the fused
    ``knn_join_select`` kernel before the bounded merge, exactly like the
    single-chip fused polish. Returns (nl, accepted, evals) — the counts
    psum'd over the mesh."""
    n_local, k = nl.idx.shape
    p = jax.lax.axis_index(axis)
    base = p * n_local
    my_ids = base + jnp.arange(n_local, dtype=jnp.int32)

    ni = nl.idx                                           # (n_local, k)
    cap_l = max(4 * (n_local * k) // max(P_, 1), 16)
    lists, ok_l = fetch_rows_a2a(
        nl.idx, ni.reshape(-1), axis=axis, P_=P_, n_local=n_local,
        cap=cap_l,
    )                                                     # (n_local*k, k)
    nb = lists.reshape(n_local, k * k)
    src_ok = jnp.broadcast_to(
        ((ni >= 0) & ok_l.reshape(n_local, k))[:, :, None], (n_local, k, k)
    ).reshape(n_local, k * k)

    cap_f = max(4 * (n_local * k * k) // max(P_, 1), 16)
    feats, ok_f = fetch_rows_a2a(
        x_local, nb.reshape(-1), axis=axis, P_=P_, n_local=n_local,
        cap=cap_f,
    )                                                     # (n_local*k*k, d)
    ok = (
        src_ok
        & (nb >= 0)
        & ok_f.reshape(n_local, k * k)
        & (nb != my_ids[:, None])
    )
    feats = feats.reshape(n_local, k * k, -1)
    dd = x2_local[:, None] + jnp.sum(feats * feats, axis=-1) - 2.0 * (
        jnp.einsum("nd,ncd->nc", x_local, feats,
                   preferred_element_type=jnp.float32)
    )
    dd = jnp.where(ok, jnp.maximum(dd, 0.0), jnp.inf)
    evals = jnp.sum(ok)
    cd, ci = ops.knn_join_select(
        dd, jnp.where(ok, nb, -1), nl.dist[:, -1], c=merge_c,
        backend=backend,
    )
    nl, upd = heap.merge(nl, cd, ci)
    return nl, jax.lax.psum(jnp.sum(upd), axis), jax.lax.psum(evals, axis)


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Knobs for the per-shard latency circuit breaker."""
    alpha: float = 0.3        # EWMA weight of the newest latency sample
    trip_ratio: float = 3.0   # open when ewma > ratio * median(others)
    min_samples: int = 3      # samples before a shard is allowed to trip
    probe_every: int = 4      # while open, probe every N dispatches
    recover_ratio: float = 1.5
    #                         # a half-open probe closes the breaker when
    #                         # its sample <= ratio * median(others)


class ShardBreaker:
    """Per-shard latency circuit breaker for ``graph_search_sharded``.

    A shard that is chronically SLOW (overloaded host, thermal throttle,
    degraded link) is worse than a dead one: it drags every dispatch's
    tail latency while contributing nothing a survivor could not. The
    breaker watches a per-shard latency EWMA; when a shard's EWMA
    exceeds ``trip_ratio`` x the median of the other shards' EWMAs (a
    scale-invariant trip — no wall-clock constant to mistune), the
    breaker OPENS and the shard is handed to the PR-8 ``dead_shards``
    degraded-merge path: answers keep flowing from survivors, recall
    degrades, nothing stalls. While open, every ``probe_every``-th
    dispatch is a HALF-OPEN probe: the shard is re-included once, and a
    healthy sample (<= ``recover_ratio`` x the others' median) closes
    the breaker again.

    The breaker is deliberately clock-free: it consumes latency samples
    via :meth:`observe` and never reads ``time`` itself, so tests drive
    it with synthetic numbers and the ``shard.degrade`` fault site
    (``core/faults.py``) inflates real samples deterministically —
    trip/probe/recover are all exercisable without a slow device. One
    :meth:`excluded` + one :meth:`observe` pair per dispatch;
    ``graph_search_sharded(breaker=...)`` does both.

    The breaker never excludes EVERY shard: with all breakers open the
    least-bad shard (lowest EWMA) stays in the dispatch, so serving can
    never self-inflict the all-dead empty answer.
    """

    def __init__(self, n_shards: int, cfg: BreakerConfig | None = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = n_shards
        self.cfg = cfg or BreakerConfig()
        self.ewma: list = [None] * n_shards
        self.samples = [0] * n_shards
        self.open = [False] * n_shards
        self._opened_at = [0] * n_shards    # dispatch counter at open
        self._probing: set = set()          # half-open this dispatch
        self.dispatches = 0
        self.trips = 0
        self.probes = 0
        self.recoveries = 0

    def _median_others(self, shard: int):
        vals = sorted(
            e for s, e in enumerate(self.ewma)
            if s != shard and e is not None and not self.open[s]
        )
        if not vals:
            return None
        return vals[len(vals) // 2]

    def excluded(self) -> list:
        """Shards to treat as dead for the NEXT dispatch (advances the
        dispatch counter; open shards due for their half-open probe are
        re-included and remembered as probing)."""
        self.dispatches += 1
        self._probing = set()
        out = []
        for s in range(self.n_shards):
            if not self.open[s]:
                continue
            age = self.dispatches - self._opened_at[s]
            if age > 0 and age % max(1, self.cfg.probe_every) == 0:
                self._probing.add(s)        # half-open: let one through
                self.probes += 1
            else:
                out.append(s)
        if len(out) == self.n_shards:       # never exclude every shard
            best = min(out, key=lambda s: self.ewma[s] or 0.0)
            out.remove(best)
        return out

    def observe(self, latencies) -> None:
        """Fold per-shard latency samples (seconds) from the dispatch
        that :meth:`excluded` opened. ``latencies``: {shard: seconds} —
        excluded shards simply have no entry. Closed shards update their
        EWMA and may trip; probing shards close on a healthy sample and
        re-arm the probe timer otherwise."""
        a = self.cfg.alpha
        for s, lat in dict(latencies).items():
            s = int(s)
            if not (0 <= s < self.n_shards):
                continue
            lat = float(lat)
            prev = self.ewma[s]
            self.ewma[s] = lat if prev is None else (1 - a) * prev + a * lat
            self.samples[s] += 1
            med = self._median_others(s)
            if self.open[s]:
                if s in self._probing and med is not None \
                        and lat <= self.cfg.recover_ratio * med:
                    self.open[s] = False
                    self.recoveries += 1
                    # forget the degraded EWMA: the shard re-enters on
                    # probation with its healthy probe sample
                    self.ewma[s] = lat
                    self.samples[s] = 1
                else:
                    self._opened_at[s] = self.dispatches
            elif (self.samples[s] >= self.cfg.min_samples
                  and med is not None
                  and self.ewma[s] > self.cfg.trip_ratio * med):
                self.open[s] = True
                self._opened_at[s] = self.dispatches
                self.trips += 1
        self._probing = set()

    def stats(self) -> dict:
        return {
            "dispatches": self.dispatches,
            "open_shards": [s for s in range(self.n_shards)
                            if self.open[s]],
            "ewma": [None if e is None else float(e) for e in self.ewma],
            "trips": self.trips,
            "probes": self.probes,
            "recoveries": self.recoveries,
        }


def graph_search_sharded(
    mesh: Mesh,
    x: jax.Array,           # (n, d) corpus, sharded by rows over ``axis``
    graph_idx: jax.Array,   # (n, k) per-shard subgraph, LOCAL neighbor ids
    queries: jax.Array,     # (q, d) replicated query batch
    *,
    k_out: int = 10,
    cfg: SearchConfig | None = None,
    key: jax.Array | None = None,
    axis: str = "data",
    router=None,            # core/router.Router over the GLOBAL corpus
    route_p: int = 0,       # shards searched per query (0 = all: legacy)
    route_cap: int = 0,     # per-shard routed-query buffer (0 = auto)
    with_stats: bool = False,
    dead_shards=None,       # shard indices known unavailable (timed-out
    #                         or lost); merged with any active FaultPlan
    #                         ("shard.dead"/"shard.slow" sites)
    breaker: ShardBreaker | None = None,
    #                         # latency circuit breaker: its open shards
    #                         # join ``dead`` for this dispatch, and the
    #                         # dispatch's wall time feeds back into its
    #                         # per-shard EWMAs (see _breaker_feed)
):
    """Sharded serving entry for the fused batched search: corpus rows are
    sharded over the mesh's ``axis``; each shard holds a K-NN subgraph
    over its OWN rows (neighbor ids are shard-local — e.g. each shard's
    slice built independently, or a global build restricted to local
    edges). Every query block runs the shard-local fused search
    (core/graph_search.py — the per-shard call is the same jitted blocked
    multi-expansion path as the single-chip entry), local hits are lifted
    to global ids (shard * n_local + row).

    **Replicated dispatch** (``route_p=0`` or no ``router``): every query
    searches every shard, one all_gather + top-k folds the P per-shard
    lists — per-query work is O(P).

    **Routed dispatch** (``router`` over the global corpus + 0 < route_p
    < P): centroid→shard affinity (the minimum query-centroid distance
    among each shard's centroids, shard of a centroid = majority shard of
    its member rows) picks the top-``route_p`` shards per query; each
    shard searches only the queries routed to it, from a compacted
    (route_cap, ·) buffer, seeded with the router's member rows that live
    on that shard (holes fall back to a shard-local random draw). The
    all_gather moves (P, route_cap, k_out) compacted buffers instead of
    (P, q, k_out), and the partial merge folds only each query's
    ``route_p`` shard lists — per-query distance work drops from P shards
    to p. ``route_cap`` bounds per-shard load (default ~4x the balanced
    expectation); overflow queries lose that shard's contribution
    (bounded-buffer sampling noise; ``with_stats`` exposes the drop
    count).

    ``cfg.precision`` threads straight through: with "int8"/"bf16" each
    shard quantizes its LOCAL rows inside the shard_map body and runs the
    two-stage scoring + fp32 re-rank per shard, so the gathered per-shard
    distances are already exact fp32 and the global top-k merge needs no
    precision awareness at all. (Serving loops that re-search a static
    sharded corpus should hoist the per-shard quantization into a cached
    mirror like MutableKNNStore does; this entry re-quantizes per call.)

    **Degraded dispatch** (``dead_shards`` non-empty, or a FaultPlan
    marks shards dead/slow-past-timeout): the driver re-merges from the
    SURVIVING shards instead of raising — replicated dispatch drops the
    dead shards' gathered lists before the top-k fold; routed dispatch
    re-routes by pushing dead shards' affinity to +inf, so each query's
    top-``route_p`` set prefers live shards (a dead shard that still
    lands in the set, e.g. route_p > live shards, contributes nothing to
    the merge). Stats gain ``degraded_shards`` (the dead list) and
    ``cover_frac`` (the fraction of per-query shard work answered by
    live shards: live/P replicated; routed, the mean liveness of each
    query's PRE-reroute affinity set — the post-reroute set is all-live
    by construction). All shards dead answers every query empty — degraded
    recall, never an exception.

    ``cfg.metric`` selects the distance ("l2" | "cosine" | "mips") the
    same way the single-chip entry does: the CORPUS must already be
    transformed (rows normalized for cosine; the sqrt(M^2 - |x|^2)
    augmented coordinate appended for MIPS — build the sharded corpus
    through ``core.metric.transform_corpus`` before slicing it over the
    mesh), and this driver applies the matching QUERY-side transform
    once, before admission/routing, so the per-shard fused searches and
    the global top-k merge stay pure squared-l2. Returned distances are
    transformed-space l2 — convert with
    ``core.metric.similarity_from_dist`` when native-metric scores are
    needed.

    Returns (dist (q, k_out), idx (q, k_out) global ids), replicated —
    plus a stats dict (fanout/shards/routed/searched/dropped queries)
    when ``with_stats``.
    """
    from repro.core import metric as metric_mod
    from repro.core.graph_search import _admit_queries, _batch_key, \
        _mask_bad_rows
    cfg = cfg or SearchConfig()
    # query-side metric transform runs HERE (driver level): per-shard
    # graph_search calls re-apply it, which is a no-op by construction
    # (normalization is idempotent; MIPS queries are already at the
    # augmented width so the zero-pad branch never fires again)
    if cfg.metric == "cosine":
        queries = metric_mod.normalize_rows(queries.astype(jnp.float32))
    elif cfg.metric == "mips" and queries.ndim == 2 \
            and queries.shape[1] < x.shape[1]:
        queries = jnp.pad(
            queries, ((0, 0), (0, x.shape[1] - queries.shape[1])))
    else:
        metric_mod.check_metric(cfg.metric)
    # admission runs HERE, on the concrete batch — graph_search inside
    # the shard_map bodies sees tracers and skips its own check
    queries, bad_rows = _admit_queries(queries, x.shape[1], cfg.strict)
    # no shared-constant entry fallback (same contract as graph_search):
    # keyless calls derive the entry key from the query batch content, so
    # repeated serving batches don't reuse identical per-shard entries
    key = _batch_key(queries) if key is None else key
    P_ = mesh.shape[axis]
    n = x.shape[0]
    assert n % P_ == 0, (n, P_)
    n_local = n // P_
    dead_set = {int(s) for s in (dead_shards or ())
                if 0 <= int(s) < P_} | set(faults.dead_shards(P_))
    if breaker is not None:
        # one excluded()/observe() pair per dispatch: open shards join
        # the degraded-merge path exactly like dead ones
        dead_set |= set(breaker.excluded())
    dead = sorted(dead_set)
    live_mask = jnp.ones((P_,), bool)
    if dead:
        live_mask = live_mask.at[jnp.asarray(dead, jnp.int32)].set(False)
    n_live = P_ - len(dead)
    # the subgraph contract is checkable and cheap to check (this is a
    # python-level driver): GLOBAL ids — e.g. build_knn_graph_sharded
    # output fed in directly — would be silently clipped into garbage
    # adjacency inside the shard-local search
    if int(jnp.max(graph_idx)) >= n_local:
        raise ValueError(
            f"graph_idx holds ids >= n_local ({n_local}): "
            "graph_search_sharded expects shard-LOCAL neighbor ids (each "
            "shard's subgraph over its own rows), not the global ids "
            "build_knn_graph_sharded emits — subtract each shard's base "
            "(shard * n_local) and drop cross-shard edges first"
        )
    routed = router is not None and 0 < route_p < P_
    if not routed:
        @functools.partial(
            shard_map,
            mesh=mesh,
            in_specs=(P(), P(axis, None), P(axis, None), P(), P()),
            out_specs=(P(), P()),
            check_vma=False,
        )
        def fn(key, x_local, gi_local, q, live):
            p = jax.lax.axis_index(axis)
            base = p * n_local
            kk = jax.random.fold_in(key, p)
            d, i = graph_search(x_local, gi_local, q, k_out=k_out, key=kk,
                                cfg=cfg)
            gi = jnp.where(i >= 0, base + i, -1)
            ds = jax.lax.all_gather(d, axis)             # (P, q, k_out)
            is_ = jax.lax.all_gather(gi, axis)
            # survivors-only merge: a dead shard's gathered list is
            # masked out wholesale before the top-k fold
            ds = jnp.where(live[:, None, None], ds, jnp.inf)
            is_ = jnp.where(live[:, None, None], is_, -1)
            alld = jnp.moveaxis(ds, 0, 1).reshape(q.shape[0], -1)
            alli = jnp.moveaxis(is_, 0, 1).reshape(q.shape[0], -1)
            alld = jnp.where(alli >= 0, alld, jnp.inf)
            neg, pos = jax.lax.top_k(-alld, k_out)
            out_i = jnp.take_along_axis(alli, pos, axis=1)
            return jnp.where(out_i >= 0, -neg, jnp.inf), out_i

        t0 = time.monotonic()
        out_d, out_i = fn(key, x, graph_idx, queries, live_mask)
        if breaker is not None:
            jax.block_until_ready(out_d)
            _breaker_feed(breaker, time.monotonic() - t0, P_, dead)
        out_d, out_i = _mask_bad_rows(out_d, out_i, bad_rows)
        if with_stats:
            q_n = queries.shape[0]
            stats = {
                "fanout": P_, "shards": P_,
                "routed_queries": q_n * n_live,
                "searched_queries": q_n * n_live, "dropped_queries": 0,
                "degraded_shards": dead,
                "cover_frac": n_live / P_,
            }
            if breaker is not None:
                stats["breaker"] = breaker.stats()
            return out_d, out_i, stats
        return out_d, out_i

    # ---- routed dispatch: replicated precompute (one small centroid
    # tile per batch), then a compacted per-shard search + partial merge
    q_n = queries.shape[0]
    qf = queries.astype(jnp.float32)
    dqc = ops.pairwise_sq_l2(qf, router.centroids, backend=cfg.backend)
    # shard of a centroid = majority shard of its member rows (centroids
    # live in feature space, not the id space — members pin them down)
    mem = router.members.idx                              # (c, m)
    ms = jnp.where(mem >= 0, mem // n_local, -1)
    votes = (ms[:, :, None] == jnp.arange(P_)[None, None, :]).sum(1)
    shard_of = jnp.argmax(votes, axis=1)                  # (c,)
    # query→shard affinity: best centroid distance among the shard's
    # centroids (+inf for shards that own no centroid)
    aff = jax.ops.segment_min(dqc.T, shard_of, num_segments=P_).T  # (q, P)
    # re-route past dead shards: +inf affinity pushes them out of every
    # query's top-p set whenever enough live shards exist. cover_frac
    # reports against the PRE-reroute affinity set (the shards the
    # query wanted) — the post-reroute set is all-live by construction.
    _, want_shards = jax.lax.top_k(-aff, route_p)         # (q, p)
    aff = jnp.where(live_mask[None, :], aff, jnp.inf)
    _, top_shards = jax.lax.top_k(-aff, route_p)          # (q, p)
    t = min(cfg.router_t, router.centroids.shape[0])
    _, top_cent = jax.lax.top_k(-dqc, t)                  # (q, t)
    # per-query entry candidates, nearest-member-major (global ids)
    entg = jnp.moveaxis(mem[top_cent], 1, 2).reshape(q_n, -1)  # (q, t*m)
    e_w = min(cfg.beam, n_local)
    cap_q = route_cap or min(
        q_n, max(32, -((-4 * q_n * route_p) // P_))
    )
    cap_q = min(cap_q, q_n)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis, None), P(), P(), P(), P()),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )
    def fn_routed(key, x_local, gi_local, q, tsh, eg, live):
        p = jax.lax.axis_index(axis)
        base = p * n_local
        kk = jax.random.fold_in(key, p)
        # queries routed to this shard, compacted into a cap_q buffer;
        # a dead shard searches nothing (and its gathered buffer is
        # excluded from every query's partial merge below)
        mine = (tsh == p).any(axis=1) & live[p]           # (q,)
        qids = jnp.nonzero(mine, size=cap_q, fill_value=-1)[0]
        qids = qids.astype(jnp.int32)
        ok_q = qids >= 0
        safe_q = jnp.where(ok_q, qids, 0)
        qsel = q[safe_q]                                  # (cap_q, d)
        # this shard's slice of the routed entry candidates, local ids,
        # valid entries compacted to the front (stable argsort)
        egl = eg[safe_q] - base                           # (cap_q, t*m)
        w = egl.shape[1]
        ve = ok_q[:, None] & (eg[safe_q] >= 0) & (egl >= 0) & (egl < n_local)
        ar = jnp.arange(w, dtype=jnp.int32)[None, :]
        order = jnp.argsort(jnp.where(ve, ar, w + ar), axis=1)
        ent = jnp.take_along_axis(jnp.where(ve, egl, -1), order, axis=1)
        if w >= e_w:
            ent = ent[:, :e_w]
        else:
            ent = jnp.pad(ent, ((0, 0), (0, e_w - w)), constant_values=-1)
        # holes (few/no router members on this shard) fall back to a
        # shard-local keyed draw — same no-replacement draw as the
        # single-chip path
        rnd = jax.lax.top_k(
            jax.random.uniform(kk, (n_local,)), e_w
        )[1].astype(jnp.int32)
        ent = jnp.where(ent >= 0, ent, rnd[None, :])
        d, i = graph_search(x_local, gi_local, qsel, k_out=k_out,
                            entry=ent, key=kk, cfg=cfg)
        gi = jnp.where((i >= 0) & ok_q[:, None], base + i, -1)
        d = jnp.where(gi >= 0, d, jnp.inf)
        # inverse map: query id -> its slot in this shard's buffer
        gpos = jnp.full((q_n,), -1, jnp.int32).at[
            jnp.where(ok_q, qids, q_n)
        ].set(jnp.arange(cap_q, dtype=jnp.int32), mode="drop")
        ds = jax.lax.all_gather(d, axis)                  # (P, cap_q, k)
        is_ = jax.lax.all_gather(gi, axis)
        gp = jax.lax.all_gather(gpos, axis)               # (P, q)
        # partial merge: each query folds ONLY its route_p shard lists
        pp = gp[tsh, jnp.arange(q_n)[:, None]]            # (q, p)
        ppc = jnp.clip(pp, 0, cap_q - 1)
        cd = ds[tsh, ppc]                                 # (q, p, k_out)
        ci = is_[tsh, ppc]
        hit = (pp >= 0)[:, :, None] & (ci >= 0) & live[tsh][:, :, None]
        cd = jnp.where(hit, cd, jnp.inf).reshape(q_n, -1)
        ci = jnp.where(hit, ci, -1).reshape(q_n, -1)
        neg, pos = jax.lax.top_k(-cd, k_out)
        out_i = jnp.take_along_axis(ci, pos, axis=1)
        out_d = jnp.where(out_i >= 0, -neg, jnp.inf)
        searched = jax.lax.psum(jnp.sum(ok_q.astype(jnp.int32)), axis)
        routed_q = jax.lax.psum(jnp.sum(mine.astype(jnp.int32)), axis)
        return out_d, out_i, searched, routed_q

    t0 = time.monotonic()
    out_d, out_i, searched, routed_q = fn_routed(
        key, x, graph_idx, queries, top_shards, entg, live_mask
    )
    if breaker is not None:
        jax.block_until_ready(out_d)
        _breaker_feed(breaker, time.monotonic() - t0, P_, dead)
    out_d, out_i = _mask_bad_rows(out_d, out_i, bad_rows)
    if with_stats:
        stats = {
            "fanout": route_p, "shards": P_,
            "routed_queries": int(routed_q),
            "searched_queries": int(searched),
            "dropped_queries": int(routed_q) - int(searched),
            "degraded_shards": dead,
            "cover_frac": float(jnp.mean(
                live_mask[want_shards].astype(jnp.float32))),
        }
        if breaker is not None:
            stats["breaker"] = breaker.stats()
        return out_d, out_i, stats
    return out_d, out_i


def _breaker_feed(breaker: ShardBreaker, dt: float, P_: int, dead) -> None:
    """Attribute one dispatch's wall time to its live shards and fold the
    samples into the breaker. A fused shard_map dispatch yields no
    per-shard clocks, so the driver charges every live shard the total
    wall time — neutral for the ratio-based trip (uniform samples move
    every EWMA identically); the deterministic skew comes from the
    ``shard.degrade`` fault site, which inflates specific shards'
    samples. Deployments with per-shard RPC timings should skip this
    helper and call ``breaker.observe`` with the real per-shard numbers.
    """
    dead = set(dead)
    lat = {s: dt for s in range(P_) if s not in dead}
    for s, f in faults.degrade_factors(P_).items():
        if s in lat:
            lat[s] *= f
    breaker.observe(lat)


def _f32_bits(x):
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def _bits_f32(x):
    return jax.lax.bitcast_convert_type(x.astype(jnp.int32), jnp.float32)


def _all_to_all_route(payload, mask, dest, P_, cap, axis, key):
    """Route rows of ``payload`` (m, w) to shard ``dest`` (m,) over ``axis``.
    Fixed per-destination capacity ``cap``; overflow rows are dropped
    (sampling noise, same contract as buffer compaction elsewhere).
    Returns (P_*cap, w) rows received, invalid rows marked by -1 in col 0."""
    m, w = payload.shape
    dest = jnp.where(mask, dest, P_)
    rnd = jax.random.uniform(key, (m,))
    order = jnp.lexsort((rnd, dest))
    dest_s = dest[order]
    pay_s = payload[order]
    first = jnp.searchsorted(dest_s, jnp.arange(P_ + 1), side="left")
    pos = jnp.arange(m) - first[jnp.clip(dest_s, 0, P_)]
    buckets = jnp.full((P_, cap, w), -1, dtype=payload.dtype)
    buckets = buckets.at[dest_s, pos].set(pay_s, mode="drop")
    got = jax.lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0, tiled=False)
    return got.reshape(P_ * cap, w)


def make_sharded_iteration_lowerable(mesh: Mesh, *, n: int, d: int, k: int,
                                     rho: float = 1.0,
                                     fetch: str = "a2a"):
    """Lowerable form of one sharded NN-Descent iteration for the dry-run.

    The K-NN build is a pure data-parallel workload, so the production
    mesh's two axes are flattened into one 'data' axis (all 256/512 chips
    shard points). Returns (lowered, model_flops) where model_flops is the
    paper's cost model for the iteration's distance evaluations in the
    MXU expansion form (2d flops/pair/direction).
    """
    import numpy as _np
    devs = _np.array(mesh.devices).reshape(-1)
    flat = jax.sharding.Mesh(devs, ("data",))
    P_ = devs.size
    assert n % P_ == 0
    n_local = n // P_
    cfg = DescentConfig(k=k, rho=rho, reorder=False)

    @functools.partial(
        shard_map,
        mesh=flat,
        in_specs=(P(), P("data", None), P("data", None), P("data", None),
                  P("data", None)),
        out_specs=((P("data", None), P("data", None), P("data", None)),
                   P(), P()),
        check_vma=False,
    )
    def iter_fn(key, x_local, d_, i_, n_):
        x_local = x_local.astype(jnp.float32)
        x2_local = jnp.sum(x_local * x_local, axis=1)
        p = jax.lax.axis_index("data")
        kk = jax.random.fold_in(key, p)
        nl_local = NeighborLists(d_, i_, n_ > 0)
        nl2, upd, ev = nn_descent_sharded_iteration(
            kk, x_local, x2_local, nl_local, cfg, axis="data", P_=P_,
            fetch=fetch)
        return (nl2.dist, nl2.idx, nl2.new.astype(jnp.int8)), upd, ev

    sds = jax.ShapeDtypeStruct
    abstract = (
        sds((), jax.random.key(0).dtype),
        sds((n, d), jnp.float32),
        sds((n, k), jnp.float32),
        sds((n, k), jnp.int32),
        sds((n, k), jnp.int8),
    )
    lowered = jax.jit(iter_fn).lower(*abstract)
    rho_k = cfg.rho_k
    pairs_per_node = rho_k * (rho_k - 1) / 2 + rho_k * rho_k
    model_flops = n * pairs_per_node * 2.0 * d
    return lowered, model_flops


def build_knn_graph_sharded(
    mesh: Mesh,
    x: jax.Array,
    k: int = 20,
    *,
    cfg: DescentConfig | None = None,
    key: jax.Array | None = None,
    axis: str = "data",
):
    """Driver: sharded NN-Descent. Returns (dist, idx-global, iters)."""
    cfg = cfg or DescentConfig(k=k, reorder=False)
    key = jax.random.key(0) if key is None else key
    P_ = mesh.shape[axis]
    n, d = x.shape
    n_local = n // P_

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(axis, None)),
        out_specs=(P(axis, None), P(axis, None), P()),
        check_vma=False,
    )
    def init_fn(key, x_local):
        p = jax.lax.axis_index(axis)
        kk = jax.random.fold_in(key, p)
        idx = jax.random.randint(kk, (n_local, k), 0, n, dtype=jnp.int32)
        my = p * n_local + jnp.arange(n_local, dtype=jnp.int32)[:, None]
        idx = jnp.where(idx == my, (idx + 1) % n, idx)
        x_local = x_local.astype(jnp.float32)
        feats = _fetch_features_ring(x_local, idx.reshape(-1), axis, P_, n_local)
        feats = feats.reshape(n_local, k, -1)
        dist = jnp.maximum(
            jnp.sum(x_local * x_local, axis=1)[:, None]
            + jnp.sum(feats * feats, axis=-1)
            - 2.0 * jnp.einsum("nd,nkd->nk", x_local, feats),
            0.0,
        )
        order = jnp.argsort(dist, axis=1)
        return (
            jnp.take_along_axis(dist, order, axis=1),
            jnp.take_along_axis(idx, order, axis=1),
            jnp.zeros((), jnp.int32),
        )

    dist0, idx0, _ = init_fn(key, x)
    nl = NeighborLists(dist0, idx0, jnp.ones_like(idx0, dtype=bool))

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(
            P(), P(axis, None), P(axis, None), P(axis, None), P(axis, None),
        ),
        out_specs=(
            (P(axis, None), P(axis, None), P(axis, None)), P(), P(),
        ),
        check_vma=False,
    )
    def iter_fn(key, x_local, d_, i_, n_):
        x_local = x_local.astype(jnp.float32)
        x2_local = jnp.sum(x_local * x_local, axis=1)
        p = jax.lax.axis_index(axis)
        kk = jax.random.fold_in(key, p)
        nl_local = NeighborLists(d_, i_, n_ > 0)
        nl2, upd, ev = nn_descent_sharded_iteration(
            kk, x_local, x2_local, nl_local, cfg, axis=axis, P_=P_,
            fetch=getattr(cfg, "fetch", "a2a"),
        )
        return (nl2.dist, nl2.idx, nl2.new.astype(jnp.int8)), upd, ev

    total_ev = 0
    for it in range(cfg.max_iters):
        key, k_it = jax.random.split(key)
        (d_, i_, nf), upd, ev = iter_fn(
            k_it, x, nl.dist, nl.idx, nl.new.astype(jnp.int8)
        )
        nl = NeighborLists(d_, i_, nf > 0)
        total_ev += int(ev)
        if int(upd) <= cfg.delta * n * k:
            break

    # terminal polish rounds (quality parity with the single-chip build:
    # see DescentConfig.polish / nn_descent.polish_iteration)
    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis, None)),
        out_specs=(
            (P(axis, None), P(axis, None), P(axis, None)), P(), P(),
        ),
        check_vma=False,
    )
    def polish_fn(x_local, d_, i_, n_):
        x_local = x_local.astype(jnp.float32)
        x2_local = jnp.sum(x_local * x_local, axis=1)
        nl_local = NeighborLists(d_, i_, n_ > 0)
        nl2, upd, ev = polish_sharded_round(
            x_local, x2_local, nl_local, axis=axis, P_=P_,
            merge_c=min(6 * k, k * k), backend=cfg.backend,
        )
        return (nl2.dist, nl2.idx, nl2.new.astype(jnp.int8)), upd, ev

    polish_updates = []
    for _p in range(cfg.polish):
        (d_, i_, nf), upd_p, ev_p = polish_fn(
            x, nl.dist, nl.idx, nl.new.astype(jnp.int8)
        )
        nl = NeighborLists(d_, i_, nf > 0)
        total_ev += int(ev_p)
        polish_updates.append(int(upd_p))
    return nl.dist, nl.idx, {
        "iters": it + 1,
        "dist_evals": total_ev,
        "polish_updates": tuple(polish_updates),
    }
