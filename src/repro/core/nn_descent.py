"""NN-Descent (Dong et al., WWW'11) — fixed-shape JAX implementation with
the paper's optimizations (turbosampling selection, blocked distance
evaluation, greedy memory reordering).

One iteration (jitted, static shapes):
  1. selection (core/selection.py): bounded new/old candidate buffers
  2. local joins: all new x new and new x old candidate pairs get their
     squared-l2 distance via the norm-expansion (MXU) form with cached
     squared norms — the batched counterpart of kernels/l2_blocked.py
  3. update routing: each evaluated pair is a candidate for BOTH endpoints.
     The FUSED path (``DescentConfig.backend`` auto/pallas/interpret,
     ``local_join_fused``) keeps routing receiver-local: the per-row pair
     tensor is computed by the blocked ``knn_join_dists`` kernel, one
     stable argsort of the n*C candidate incidences tells every receiver
     which (row, slot) positions list it (``invert_candidates``), each
     receiver gathers its incoming distance rows and the
     ``knn_join_select`` kernel reduces them to the best merge_k under the
     k-th-distance prefilter; receivers are then contiguous rows, so the
     merge is a sort-free chunked block merge (heap.merge_block). The REF
     path (backend="ref") keeps the seed implementation — flatten all
     pairs into an O(n*C^2) (receiver, candidate, dist) list, global
     (receiver, dist) lexsort (``compact_pairs``), one dense merge — and
     serves as the parity oracle for the fused path.
  4. convergence: stop when accepted updates < delta * n * k

The driver runs iterations from Python so the greedy reorder (paper §3.2)
can permute the point array between iterations (the permutation changes
array contents, not shapes, so the jitted iteration is reused).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import heap, quantize, selection
from repro.core import metric as metric_mod
from repro.core.heap import NeighborLists
from repro.core.layout import pad_features
from repro.core.quantize import QuantizedStore
from repro.core.reorder import apply_permutation, greedy_reorder
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class DescentConfig:
    k: int = 20
    rho: float = 0.5           # sample rate: rho*k candidates per pool
    max_iters: int = 12
    delta: float = 0.001       # stop when updates < delta*n*k (paper §2)
    merge_size: int = 0        # merge buffer per node (0 = 3*k)
    selection: str = "turbo"   # turbo | heap | naive  (paper's 3 tiers)
    reorder: bool = True       # paper §3.2 greedy reordering
    reorder_after: int = 1     # run reorder after this iteration (1 = paper)
    polish: int = 2            # terminal full local-join rounds: after the
                               # sampled iterations stop, join every node
                               # against ALL k*k neighbors-of-neighbors
                               # (unsampled). The sampled descent converges
                               # to a local optimum missing a thin tail of
                               # edges; the exhaustive polish recovers most
                               # of it for n*k^2 evals per round.
    backend: str = "auto"      # kernel dispatch (auto|pallas|interpret use
                               # the fused local join; "ref" keeps the
                               # global-lexsort compact_pairs oracle path)
    block_k: int = 512         # feature-axis block for norm expansion
    fetch: str = "a2a"         # distributed feature fetch: a2a | ring
    join_chunk: int = 2048     # fused join: receiver rows per chunk
    join_src: int = 0          # fused join: per-receiver source-incidence
                               # buffer (0 = 2*C); overflow beyond it is
                               # dropped (bounded-buffer sampling noise,
                               # like every other buffer in NN-Descent)
    metric: str = "l2"         # l2 | cosine | mips — realized by the
                               # input-side reductions of core/metric.py
                               # (cosine: row-normalize; mips: augmented
                               # coordinate, d -> d+1) applied ONCE at
                               # build entry; every join/select/merge
                               # below stays pure squared l2. Graph
                               # distances come back in the TRANSFORMED
                               # space — monotone in the native metric.
                               # All backends, "ref" included.
    precision: str = "f32"     # f32 | bf16 | int8 — candidate-SCORING
                               # dtype of the sampled local joins
                               # (kernels/l2_quant.py over a quantized
                               # corpus mirror). Quantized builds re-rank
                               # every surviving list fp32 after the
                               # sampled iterations (rerank_lists) and run
                               # the terminal polish rounds fp32, so the
                               # returned graph distances stay exact.
                               # backend="ref" (the lexsort parity oracle)
                               # is always fp32 and ignores this knob.

    @property
    def rho_k(self) -> int:
        return max(1, int(round(self.rho * self.k)))

    @property
    def merge_k(self) -> int:
        return self.merge_size or 3 * self.k


@dataclasses.dataclass
class DescentStats:
    iters: int = 0
    dist_evals: int = 0
    updates: tuple = ()
    polish_updates: tuple = ()
    reordered: bool = False
    # online-update frontier accounting (core/online.py): how many store
    # rows the update actually touched (actual / after chunk padding) —
    # the observable that update cost is O(frontier), not O(n)
    frontier_rows: int = 0
    padded_rows: int = 0

    def flops(self, d: int) -> int:
        """Paper §2 cost model: d subs + d mults + (d-1) adds per eval."""
        return self.dist_evals * (3 * d - 1)


_SELECT: dict[str, Callable] = {
    "turbo": selection.selection_turbo,
    "heap": selection.selection_heap,
    "naive": selection.selection_naive,
}


def pair_block(xg: jax.Array, x2g: jax.Array, yg: jax.Array, y2g: jax.Array):
    """Batched norm-expansion distances: (n,a,d)x(n,b,d) -> (n,a,b)."""
    ab = jnp.einsum(
        "nad,nbd->nab", xg, yg, preferred_element_type=jnp.float32
    )
    out = x2g[:, :, None] + y2g[:, None, :] - 2.0 * ab
    return jnp.maximum(out, 0.0)


def compact_pairs(recv, cand, dist, n: int, c: int):
    """Group flattened (receiver, candidate, dist) updates into per-node
    (n, c) buffers keeping the c best (smallest distance) per receiver."""
    valid = recv >= 0
    key_recv = jnp.where(valid, recv, n)
    order = jnp.lexsort((dist, key_recv))
    recv_s = key_recv[order]
    cand_s = cand[order]
    dist_s = dist[order]
    first = jnp.searchsorted(recv_s, jnp.arange(n + 1), side="left")
    pos = jnp.arange(recv_s.shape[0]) - first[jnp.clip(recv_s, 0, n)]
    out_i = jnp.full((n, c), -1, dtype=jnp.int32)
    out_d = jnp.full((n, c), jnp.inf, dtype=jnp.float32)
    out_i = out_i.at[recv_s, pos].set(cand_s, mode="drop")
    out_d = out_d.at[recv_s, pos].set(dist_s, mode="drop")
    return out_d, out_i


def invert_candidates(
    cands: jax.Array, n_univ: int, src_cap: int,
    prio: jax.Array | None = None,
):
    """Invert (row -> candidate) incidences: for every candidate id in
    [0, n_univ), the (row, slot) positions that list it, compacted into
    (n_univ, src_cap) padded buffers (-1 tail). Overflow beyond src_cap:
    with ``prio`` (same shape as ``cands``, e.g. a distance) the LOWEST
    priority incidences are kept per candidate — the old smallest-
    (row, slot) policy was a systematic bias against late rows on
    hub-heavy buffers; without ``prio`` the old deterministic id order
    is preserved (pure adjacency inversions have no distance to rank by).

    One stable (arg|lex)sort of the n*C incidence ids — the only sort
    left in the fused build hot path, ~pairs/C times smaller than the
    retired global pair sort."""
    nr, c = cands.shape
    flat = cands.reshape(-1)
    key = jnp.where(flat >= 0, flat, n_univ)
    if prio is None:
        order = jnp.argsort(key, stable=True).astype(jnp.int32)
    else:
        # candidate-major, priority-minor; lexsort is stable so equal
        # priorities still fall back to the old (row, slot) order
        order = jnp.lexsort((prio.reshape(-1), key)).astype(jnp.int32)
    rs = key[order]
    first = jnp.searchsorted(rs, jnp.arange(n_univ + 1))
    pos = jnp.arange(nr * c) - first[jnp.clip(rs, 0, n_univ)]
    rows_of = jnp.full((n_univ, src_cap), -1, jnp.int32)
    slot_of = jnp.full((n_univ, src_cap), -1, jnp.int32)
    rows_of = rows_of.at[rs, pos].set(order // c, mode="drop")
    slot_of = slot_of.at[rs, pos].set(order % c, mode="drop")
    return rows_of, slot_of


def local_join_fused(
    x: jax.Array,          # (n, dp) feature-padded points
    x2: jax.Array,         # (n,) cached squared norms
    nl: NeighborLists,
    cn: jax.Array,         # (n, Cn) new candidates
    co: jax.Array,         # (n, Co) old candidates
    cfg: DescentConfig,
    qs: QuantizedStore | None = None,   # quantized corpus mirror
):
    """Fused local join + update routing (no flattened pair list, no
    global lexsort): blocked pair-distance kernel -> incidence inversion
    -> per-receiver gather + prefiltered top-merge_k select kernel ->
    chunked block merge. Returns (nl, accepted, evals).

    With ``qs`` given and ``cfg.precision`` quantized, the pair tensor is
    scored by the int8/bf16 kernel over the quantized rows (2-4x fewer
    gathered bytes per candidate) — the build face of the two-stage path;
    the driver re-ranks the final lists fp32 (``rerank_lists``)."""
    n, k = nl.idx.shape
    cands = jnp.concatenate([cn, co], axis=1)        # (n, C)
    c_all = cands.shape[1]
    valid = cands >= 0
    safe = jnp.where(valid, cands, 0)
    ids = jnp.where(valid, cands, -1)
    if cfg.precision != "f32" and qs is not None:
        x2g = jnp.where(valid, qs.x2[safe], 0.0)
        if cfg.precision == "int8":
            dists, ev = ops.knn_join_dists_q8(
                qs.data[safe], qs.scale[safe], x2g, ids, cn=cn.shape[1],
                backend=cfg.backend,
            )                                        # (n, C, C), (n,)
        else:
            dists, ev = ops.knn_join_dists_bf16(
                qs.data[safe], x2g, ids, cn=cn.shape[1],
                backend=cfg.backend,
            )
    else:
        xg = x[safe]                                 # (n, C, dp)
        x2g = jnp.where(valid, x2[safe], 0.0)
        dists, ev = ops.knn_join_dists(
            xg, x2g, ids, cn=cn.shape[1], backend=cfg.backend
        )                                            # (n, C, C), (n,)

    kth = nl.dist[:, -1]
    s_cap = cfg.join_src or 2 * c_all
    # overflow priority: each (row, slot) incidence contributes the row's
    # pair distances to the candidate — rank it by the best distance it
    # can offer, so buffer overflow drops the least useful incidences
    # instead of the highest (row, slot)
    inc_prio = dists.min(axis=2)                     # (n, C)
    rows_of, slot_of = invert_candidates(cands, n, s_cap, prio=inc_prio)

    # receiver chunks: pad everything to a chunk multiple so every merge
    # is a full in-bounds block (padding rows have no incidences -> no-op)
    r = min(cfg.join_chunk, ((n + 7) // 8) * 8)
    npad = ((n + r - 1) // r) * r
    pad = npad - n
    rows_of = jnp.pad(rows_of, ((0, pad), (0, 0)), constant_values=-1)
    slot_of = jnp.pad(slot_of, ((0, pad), (0, 0)), constant_values=-1)
    kth_p = jnp.pad(kth, (0, pad))
    nl_p = NeighborLists(
        jnp.pad(nl.dist, ((0, pad), (0, 0)), constant_values=jnp.inf),
        jnp.pad(nl.idx, ((0, pad), (0, 0)), constant_values=-1),
        jnp.pad(nl.new, ((0, pad), (0, 0))),
    )
    d_flat = dists.reshape(n * c_all, c_all)

    def body(j, carry):
        nl_j, upd = carry
        sl = jax.lax.dynamic_slice(rows_of, (j * r, 0), (r, s_cap))
        so = jax.lax.dynamic_slice(slot_of, (j * r, 0), (r, s_cap))
        ok = sl >= 0
        lin = jnp.where(ok, sl * c_all + so, 0)
        gd = jnp.where(ok[:, :, None], d_flat[lin], jnp.inf)
        gi = jnp.where(ok[:, :, None], ids[jnp.where(ok, sl, 0)], -1)
        kth_j = jax.lax.dynamic_slice(kth_p, (j * r,), (r,))
        cd, ci = ops.knn_join_select(
            gd.reshape(r, s_cap * c_all),
            gi.reshape(r, s_cap * c_all),
            kth_j, c=cfg.merge_k, backend=cfg.backend,
        )
        nl_j, u = heap.merge_block(nl_j, j * r, cd, ci,
                                   backend=cfg.backend)
        return nl_j, upd + jnp.sum(u)

    nl_p, upd = jax.lax.fori_loop(
        0, npad // r, body, (nl_p, jnp.zeros((), jnp.int32))
    )
    nl = NeighborLists(nl_p.dist[:n], nl_p.idx[:n], nl_p.new[:n])
    return nl, upd, jnp.sum(ev)


@functools.partial(jax.jit, static_argnames=("cfg",))
def nn_descent_iteration(
    key: jax.Array,
    x: jax.Array,          # (n, d) — feature-padded
    x2: jax.Array,         # (n,) cached squared norms (beyond-paper reuse)
    nl: NeighborLists,
    cfg: DescentConfig,
    qs: QuantizedStore | None = None,   # quantized mirror (precision != f32)
):
    n, k = nl.idx.shape
    cands = _SELECT[cfg.selection](key, nl, cfg.rho_k)
    nl = heap.mark_sampled_old(nl, cands.sampled_fwd)

    cn = cands.new_idx          # (n, Cn)
    co = cands.old_idx          # (n, Co)
    if cfg.backend != "ref":
        return local_join_fused(x, x2, nl, cn, co, cfg, qs)
    vn = cn >= 0
    vo = co >= 0
    xg_n = x[jnp.where(vn, cn, 0)]
    xg_o = x[jnp.where(vo, co, 0)]
    x2_n = jnp.where(vn, x2[jnp.where(vn, cn, 0)], 0.0)
    x2_o = jnp.where(vo, x2[jnp.where(vo, co, 0)], 0.0)

    d_nn = pair_block(xg_n, x2_n, xg_n, x2_n)   # (n, Cn, Cn)
    d_no = pair_block(xg_n, x2_n, xg_o, x2_o)   # (n, Cn, Co)

    cn_b = cn.shape[1]
    co_b = co.shape[1]
    iu = jnp.triu_indices(cn_b, k=1)
    # --- new x new (unordered pairs i<j, both directions)
    a_nn = cn[:, iu[0]]
    b_nn = cn[:, iu[1]]
    dd_nn = d_nn[:, iu[0], iu[1]]
    ok_nn = vn[:, iu[0]] & vn[:, iu[1]] & (a_nn != b_nn)
    # --- new x old (all pairs, both directions)
    a_no = jnp.broadcast_to(cn[:, :, None], (n, cn_b, co_b)).reshape(n, -1)
    b_no = jnp.broadcast_to(co[:, None, :], (n, cn_b, co_b)).reshape(n, -1)
    dd_no = d_no.reshape(n, -1)
    ok_no = (
        jnp.broadcast_to(vn[:, :, None], (n, cn_b, co_b)).reshape(n, -1)
        & jnp.broadcast_to(vo[:, None, :], (n, cn_b, co_b)).reshape(n, -1)
        & (a_no != b_no)
    )

    a = jnp.concatenate([a_nn, b_nn, a_no, b_no], axis=1).reshape(-1)
    b = jnp.concatenate([b_nn, a_nn, b_no, a_no], axis=1).reshape(-1)
    dd = jnp.concatenate([dd_nn, dd_nn, dd_no, dd_no], axis=1).reshape(-1)
    ok = jnp.concatenate([ok_nn, ok_nn, ok_no, ok_no], axis=1).reshape(-1)

    # receiver-side prefilter: only pairs beating the receiver's current
    # k-th distance can change the graph (saves the sort+merge cost)
    kth = nl.dist[:, -1]
    ok &= dd < kth[jnp.where(ok, a, 0)]
    recv = jnp.where(ok, a, -1)

    cand_d, cand_i = compact_pairs(recv, b, dd, n, cfg.merge_k)
    nl, upd = heap.merge(nl, cand_d, cand_i, cand_new=True)

    n_evals = jnp.sum(ok_nn) + jnp.sum(ok_no)   # unordered evaluations
    return nl, jnp.sum(upd), n_evals


@functools.partial(jax.jit, static_argnames=("backend",))
def polish_iteration(
    x: jax.Array,          # (n, d) — feature-padded
    x2: jax.Array,         # (n,) cached squared norms
    nl: NeighborLists,
    backend: str = "auto",
):
    """One exhaustive local-join round: every node joins against ALL k*k
    of its neighbors-of-neighbors (no sampling, forward direction). Run
    after the sampled iterations terminate — the stochastic descent
    converges to a local optimum that still misses a thin tail of edges
    reachable within two hops, and the unsampled join recovers them for a
    flat n*k^2 evaluations. Returns (nl, accepted, evals).

    With a non-"ref" backend the k*k candidate row is reduced by the
    fused ``knn_join_select`` kernel (k-th-distance prefilter + partial
    top-6k) before the merge, so the bounded-list merge runs at width 6k
    instead of k*k — the same fused-selection idea as the sampled
    iterations. 6k (not 3k) because NoN rows are heavily duplicated in
    clustered data and the merge dedups: at 3k the duplicates crowd out
    enough distinct candidates to cost ~0.7% recall on the 512-pt
    regression; at 6k the fused polish matches the full-width oracle.
    backend="ref" keeps the direct full-width merge (oracle).
    """
    n, k = nl.idx.shape
    ni = nl.idx
    nb = ni[jnp.clip(ni, 0, n - 1)].reshape(n, k * k)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    src_ok = jnp.broadcast_to(
        (ni >= 0)[:, :, None], (n, k, k)
    ).reshape(n, k * k)
    ok = src_ok & (nb >= 0) & (nb != rows)
    cx = x[jnp.clip(nb, 0, n - 1)]
    dd = x2[:, None] + x2[jnp.clip(nb, 0, n - 1)] - 2.0 * jnp.einsum(
        "nd,ncd->nc", x, cx, preferred_element_type=jnp.float32
    )
    dd = jnp.where(ok, jnp.maximum(dd, 0.0), jnp.inf)
    evals = jnp.sum(ok)
    if backend == "ref":
        nl, upd = heap.merge(nl, dd, jnp.where(ok, nb, -1))
        return nl, jnp.sum(upd), evals
    cd, ci = ops.knn_join_select(
        dd, jnp.where(ok, nb, -1), nl.dist[:, -1],
        c=min(6 * k, k * k), backend=backend,
    )
    nl, upd = heap.merge(nl, cd, ci)
    return nl, jnp.sum(upd), evals


@functools.partial(jax.jit, static_argnames=("backend",))
def rerank_lists(
    x: jax.Array,          # (n, d) — feature-padded
    x2: jax.Array,         # (n,) cached squared norms
    nl: NeighborLists,
    backend: str = "auto",
):
    """Exact fp32 re-rank of every neighbor list: recompute d(row, idx)
    with the EXISTING fp32 serving kernel (one (n, k) blocked tile) and
    re-sort each row. The second stage of a quantized build — quantized
    scoring decides which edges survive (bounded recall noise), this pass
    makes the stored distances and within-row order exact before the fp32
    polish rounds extend them. Cost: n*k distance evaluations."""
    n, k = nl.idx.shape
    safe = jnp.clip(nl.idx, 0, n - 1)
    dd = ops.knn_search_dists(
        x, x2, x[safe], jnp.where(nl.idx >= 0, x2[safe], 0.0), nl.idx,
        backend=backend,
    )                                                 # (n, k)
    order = jnp.argsort(dd, axis=1, stable=True)      # +inf (invalid) last
    return NeighborLists(
        jnp.take_along_axis(dd, order, axis=1),
        jnp.take_along_axis(nl.idx, order, axis=1),
        jnp.take_along_axis(nl.new, order, axis=1),
    )


def build_knn_graph(
    x: jax.Array,
    k: int = 20,
    *,
    cfg: DescentConfig | None = None,
    key: jax.Array | None = None,
    callback: Callable | None = None,
):
    """Build an approximate K-NN graph of x (n, d).

    Returns (dist (n,k) f32 ascending, idx (n,k) i32 in ORIGINAL ids,
    stats). Deterministic given ``key``.

    ``cfg.metric`` selects l2 (default) / cosine / mips: the raw rows
    are reduced to an l2-equivalent form once, here (core/metric.py),
    and the whole descent below runs unchanged on the transformed rows.
    Returned distances are transformed-space squared l2 — neighbor ORDER
    is the native metric's; convert values with
    ``metric.similarity_from_dist`` if needed.
    """
    cfg = cfg or DescentConfig(k=k)
    if cfg.k != k:
        cfg = dataclasses.replace(cfg, k=k)
    key = jax.random.key(0) if key is None else key
    n = x.shape[0]
    x, _ = metric_mod.transform_corpus(x, cfg.metric)
    xp = pad_features(x.astype(jnp.float32))
    x2 = jnp.sum(xp * xp, axis=1)

    # two-stage quantized build: the sampled joins score on a quantized
    # corpus mirror (at the mirror's own width — the fp32 layout's zero
    # feature padding is dropped); rerank_lists + the polish rounds
    # restore exact fp32
    quant = cfg.precision != "f32" and cfg.backend != "ref"
    qs = (quantize.quantize_corpus(
        xp, cfg.precision,
        width=quantize.mirror_width(x.shape[1], xp.shape[1]))
        if quant else None)

    k_init, key = jax.random.split(key)
    nl = heap.init_random_with_dists(k_init, xp, cfg.k)
    stats = DescentStats(dist_evals=n * cfg.k)
    # running permutation: perm[new_pos] = original id
    perm = jnp.arange(n, dtype=jnp.int32)

    updates = []
    for it in range(cfg.max_iters):
        key, k_it = jax.random.split(key)
        nl, upd, ev = nn_descent_iteration(k_it, xp, x2, nl, cfg, qs)
        upd = int(upd)
        stats.dist_evals += int(ev)
        updates.append(upd)
        stats.iters = it + 1
        if callback is not None:
            callback(it, upd, nl)
        if cfg.reorder and it + 1 == cfg.reorder_after:
            sigma, sigma_inv = greedy_reorder(nl)
            xp, nl = apply_permutation(xp, nl, sigma, sigma_inv)
            x2 = x2[sigma_inv]
            perm = perm[sigma_inv]
            if quant:
                # per-row quantization permutes exactly — no requantize
                qs = QuantizedStore(qs.data[sigma_inv],
                                    qs.scale[sigma_inv],
                                    qs.x2[sigma_inv])
            stats.reordered = True
        if upd <= cfg.delta * n * cfg.k:
            break
    stats.updates = tuple(updates)

    # stage two of a quantized build: exact fp32 re-rank of the surviving
    # lists, so the polish rounds below merge against exact distances and
    # the returned graph never carries a quantized value
    if quant:
        nl = rerank_lists(xp, x2, nl, cfg.backend)
        stats.dist_evals += n * cfg.k

    # terminal polish (see DescentConfig.polish / polish_iteration)
    polish_updates = []
    for _p in range(cfg.polish):
        nl, upd_p, ev_p = polish_iteration(xp, x2, nl, cfg.backend)
        polish_updates.append(int(upd_p))
        stats.dist_evals += int(ev_p)
    stats.polish_updates = tuple(polish_updates)

    # map back to original ids: row r describes original node perm[r]
    dist = jnp.zeros_like(nl.dist).at[perm].set(nl.dist)
    idx = jnp.full_like(nl.idx, -1).at[perm].set(
        jnp.where(nl.idx >= 0, perm[jnp.clip(nl.idx, 0, n - 1)], -1)
    )
    return dist, idx, stats
