"""Online K-NN graph updates: insert / delete without a full rebuild.

The paper's NN-Descent builds a *static* graph; a serving datastore must
absorb new points and retire stale ones while queries keep flowing. This
module adds that, built from the same primitives as the offline build:

  * ``knn_insert(store, new_points)`` — each new point is *seeded* by a
    greedy ``graph_search`` over the existing graph (the serving-side
    structure already answers "who is near q?"), then refined by a
    **localized NN-Descent**: a few friend-of-a-friend rounds that join
    each new point against the neighbors of its current neighbors
    (Dong et al.'s local-join restricted to the touched frontier), using
    the offline build's fused ``knn_join_select`` routing for the
    reverse-edge repair (``_route_reverse`` — invert incidences, gather,
    prefiltered top-c; no pair sort). Convergence is fast for the same
    reason NN-Descent's is: a
    neighbor of a neighbor is likely a neighbor, so a handful of seed
    candidates is enough to pull in the true neighborhood.

  * ``knn_delete(store, ids)`` — tombstones rows (``alive`` mask), purges
    the dead targets out of every *affected* neighbor list with the
    chunked ``knn_compact`` kernel, and refills the holes of affected rows
    from their surviving neighbors' lists (one friend-of-a-friend merge
    round).

  * ``MutableKNNStore`` — capacity-doubling padded arrays (features,
    squared norms, neighbor lists, alive mask). Shapes only change on a
    doubling, so the jitted insert/delete/search computations are reused
    across steady-state streaming updates instead of recompiling per call.

**Frontier compaction.** Every update step operates on an explicit,
compacted frontier of affected row ids instead of masking over the dense
store: the frontier (``graph_search.expand_frontier`` for inserts, a
dead-edge scan for deletes) is gathered into padded chunks of
``OnlineConfig.chunk`` rows, the merge/compact kernels run per chunk
(``kernels.ops.knn_merge_rows`` / ``knn_compact_rows``), and results are
scattered back. Update cost therefore scales with the frontier size, not
the store size — the friend-of-a-friend principle says a localized change
only propagates along a small frontier, so stores can grow past 10^5 rows
without updates going dense. The only O(n) work left per update is
bitwise mask bookkeeping (no distance evaluations). Setting
``OnlineConfig(frontier=False)`` keeps the same semantics but puts every
allocated row on the delete frontier — the dense baseline used by
``benchmarks/bench_online.py`` to measure the compaction win.

Cost accounting mirrors the offline build: both entry points return a
``DescentStats`` whose ``dist_evals`` counts (an upper bound on) distance
evaluations, and whose ``frontier_rows`` / ``padded_rows`` record how many
store rows the update actually touched (see ``tests/test_online.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core import faults, heap, quantize
from repro.core import metric as metric_mod
from repro.core.graph_search import SearchConfig, expand_frontier, graph_search
from repro.core.heap import NeighborLists
from repro.core.layout import pad_features
from repro.core.quantize import QuantizedStore
from repro.core.nn_descent import (
    DescentConfig,
    DescentStats,
    build_knn_graph,
    compact_pairs,
    invert_candidates,
)
from repro.core.router import (
    Router,
    RouterConfig,
    build_router,
    needs_rebuild,
    router_delete,
    router_insert,
)
from repro.kernels import ops

_FILL = 1e6   # coordinate fill for unallocated rows (cf. layout.pad_points)


@dataclasses.dataclass(frozen=True)
class OnlineConfig:
    beam: int = 32            # seeding graph-search pool width
    seed_rounds: int = 24     # seeding graph-search expansion budget
    seed_expand: int = 4      # fused search: pool nodes expanded per round
                              # (SearchConfig.expand for seeding + queries)
    q_block: int = 256        # fused search: queries per block (the
                              # serving-side compile-once quantum; see
                              # serve/scheduler.py knn_q_block plumbing)
    refine_rounds: int = 2    # localized friend-of-a-friend rounds
    self_join: bool = True    # all-pairs join within the inserted batch
    self_join_max: int = 512  # skip the O(m^2) self-join beyond this m
    merge_mult: int = 2       # reverse-merge buffer = merge_mult * k
    backend: str = "auto"     # kernel dispatch for the chunked
                              # merge/compact kernels (ops.knn_merge_rows /
                              # ops.knn_compact_rows)
    chunk: int = 1024         # frontier chunk: padded row-id buffers are
                              # rounded up to a multiple of this, and the
                              # delete path processes one chunk at a time
    frontier: bool = True     # False = dense baseline: every allocated row
                              # goes on the delete frontier (bench only)
    frontier_mult: int = 4    # insert reverse-frontier cap, in units of
                              # m*k (the 2-hop closure is truncated to
                              # min(cap, frontier_mult*m*k) rows)
    route_src: int = 0        # fused reverse routing: per-receiver
                              # source-incidence buffer (0 = 2*merge_mult*k;
                              # overflow is dropped — bounded-buffer
                              # sampling noise, cf. DescentConfig.join_src)
    metric: str = "l2"        # l2 | cosine | mips — the store keeps its
                              # rows in the metric's l2-equivalent form
                              # (core/metric.py: cosine rows normalized,
                              # mips rows augmented d -> d+1 with the
                              # bound in MutableKNNStore.mips_m), applied
                              # once where rows enter (from_graph /
                              # knn_insert) so the kernels, the quantized
                              # mirror, and the router all work per
                              # metric unchanged. Searches transform
                              # queries per batch; distances come back
                              # transformed-space l2 (monotone in the
                              # native metric).
    precision: str = "f32"    # f32 | bf16 | int8 — the store keeps a
                              # quantized mirror (core/quantize.py) that
                              # candidate SCORING reads on the query and
                              # insert-seeding search paths (two-stage:
                              # the final pool re-ranks fp32, so returned
                              # distances stay exact). The mirror updates
                              # incrementally with inserts and grows with
                              # the capacity doubling; the localized
                              # refinement joins stay fp32 (they touch
                              # O(frontier) rows — bandwidth is not their
                              # bottleneck; the graph's stored distances
                              # stay exact for free).
    router: RouterConfig | None = None
                              # coarse routing layer (core/router.py):
                              # when set, the store keeps a centroid
                              # router that seeds every search with
                              # hierarchical entry points, maintained
                              # incrementally on insert/delete and
                              # rebuilt lazily past the drift threshold.
                              # Frozen (hashable) — OnlineConfig is a
                              # static jit argument of the stitch path.


@dataclasses.dataclass(frozen=True)
class MutableKNNStore:
    """Growable K-NN graph store. Rows [0, n) are allocated; ``alive``
    marks the live ones (False = tombstoned or unallocated)."""

    x: jax.Array          # (cap, dp) feature-padded points, stored in
                          # cfg.metric's l2-equivalent transformed form
    x2: jax.Array         # (cap,) cached squared norms
    nl: NeighborLists     # (cap, k) bounded neighbor lists
    alive: jax.Array      # (cap,) bool
    n: int                # allocation high-water mark
    d: int                # logical RAW feature dim (what callers hand
                          # insert/search; mips stores d+1 internally)
    cfg: OnlineConfig
    qs: QuantizedStore | None = None  # quantized mirror of ``x``
                                      # (cfg.precision != "f32" only)
    router: Router | None = None      # coarse routing layer
                                      # (cfg.router is not None only)
    mips_m: float = 0.0   # mips augmentation bound M (cfg.metric="mips"
                          # only; echoed/validated by core/persist.py).
                          # Set at build, or at the FIRST insert of a
                          # store that started empty; later inserts
                          # share it (over-norm rows clamp + warn).

    @property
    def capacity(self) -> int:
        return self.x.shape[0]

    @property
    def k(self) -> int:
        return self.nl.idx.shape[1]

    @property
    def graph_idx(self) -> jax.Array:
        return self.nl.idx

    def live_count(self) -> int:
        return int(jnp.sum(self.alive))

    @classmethod
    def from_graph(
        cls,
        x: jax.Array,
        dist: jax.Array,
        idx: jax.Array,
        *,
        cfg: OnlineConfig | None = None,
    ) -> "MutableKNNStore":
        """Wrap an offline ``build_knn_graph`` result (original id
        space). ``x`` is the RAW (untransformed) corpus: under
        cfg.metric the same reduction the build applied is applied here
        (same rows, same mips bound M), so the stored rows match the
        graph's transformed-space distances exactly."""
        cfg = cfg or OnlineConfig()
        n, d = x.shape
        x, mips_m = metric_mod.transform_corpus(x, cfg.metric)
        xp = pad_features(x.astype(jnp.float32))
        cap = _next_capacity(n)
        store = cls(
            x=jnp.full((cap, xp.shape[1]), _FILL, jnp.float32).at[:n].set(xp),
            x2=jnp.zeros((cap,), jnp.float32),
            nl=NeighborLists(
                jnp.full((cap, idx.shape[1]), jnp.inf, jnp.float32)
                .at[:n].set(dist.astype(jnp.float32)),
                jnp.full((cap, idx.shape[1]), -1, jnp.int32)
                .at[:n].set(idx.astype(jnp.int32)),
                jnp.zeros((cap, idx.shape[1]), bool),
            ),
            alive=jnp.zeros((cap,), bool).at[:n].set(True),
            n=n,
            d=d,
            cfg=cfg,
            mips_m=mips_m,
        )
        store = dataclasses.replace(
            store, x2=jnp.sum(store.x * store.x, axis=1)
        )
        if cfg.precision != "f32":
            store = dataclasses.replace(
                store,
                qs=quantize.quantize_corpus(
                    store.x, cfg.precision,
                    # the mirror's logical dim is the TRANSFORMED one
                    # (mips appends a coordinate) — x was reduced above
                    width=quantize.mirror_width(x.shape[1],
                                                store.x.shape[1]),
                ),
            )
        if cfg.router is not None:
            store = dataclasses.replace(
                store,
                router=build_router(
                    store.x, cfg=cfg.router, key=jax.random.key(29),
                    alive=store.alive, x2=store.x2, backend=cfg.backend,
                ),
            )
        return store

    @classmethod
    def empty(
        cls,
        d: int,
        *,
        k: int = 20,
        cfg: OnlineConfig | None = None,
    ) -> "MutableKNNStore":
        """A store with no rows: every search answers empty (+inf/-1)
        and the first ``knn_insert`` acts as a first build (all seeds
        miss, so the batch self-join links the graph). A configured
        router attaches lazily via ``ensure_router`` once rows exist —
        there is nothing to cluster yet. Under cfg.metric="mips" the
        augmentation bound M is unknown until rows exist — the first
        ``knn_insert`` sets ``mips_m`` from its batch."""
        cfg = cfg or OnlineConfig()
        d_t = metric_mod.transformed_dim(d, cfg.metric)
        dp = pad_features(jnp.zeros((1, d_t), jnp.float32)).shape[1]
        store = cls(
            x=jnp.full((8, dp), _FILL, jnp.float32),
            x2=jnp.full((8,), dp * _FILL * _FILL, jnp.float32),
            nl=NeighborLists(
                jnp.full((8, k), jnp.inf, jnp.float32),
                jnp.full((8, k), -1, jnp.int32),
                jnp.zeros((8, k), bool),
            ),
            alive=jnp.zeros((8,), bool),
            n=0,
            d=d,
            cfg=cfg,
        )
        if cfg.precision != "f32":
            store = dataclasses.replace(
                store,
                qs=quantize.quantize_corpus(
                    store.x, cfg.precision,
                    width=quantize.mirror_width(d_t, dp),
                ),
            )
        return store

    @classmethod
    def build(
        cls,
        x: jax.Array,
        k: int = 20,
        *,
        cfg: OnlineConfig | None = None,
        descent: DescentConfig | None = None,
        key: jax.Array | None = None,
    ) -> tuple["MutableKNNStore", DescentStats]:
        """Offline build + wrap. Returns (store, build stats). ``x`` is
        RAW rows; ``cfg.metric`` propagates into the DescentConfig so
        the build and the store apply the same reduction (each to the
        raw input, exactly once)."""
        cfg = cfg or OnlineConfig()
        dcfg = descent or DescentConfig(k=k, rho=1.0, max_iters=15)
        if dcfg.k != k:
            dcfg = dataclasses.replace(dcfg, k=k)
        if dcfg.metric != cfg.metric:
            dcfg = dataclasses.replace(dcfg, metric=cfg.metric)
        dist, idx, stats = build_knn_graph(x, k=k, cfg=dcfg, key=key)
        return cls.from_graph(x, dist, idx, cfg=cfg), stats

    def search(
        self,
        queries: jax.Array,
        *,
        k_out: int = 10,
        beam: int = 32,
        rounds: int = 24,
        key: jax.Array | None = None,
        cfg: SearchConfig | None = None,
        filter_ids: jax.Array | None = None,
    ):
        """Batched query path: fused blocked graph search that never
        returns a tombstoned or unallocated row. The store's cached norm
        vector is passed through (no per-call x2 recomputation); ``cfg``
        overrides the default SearchConfig built from the kwargs and the
        store's backend / expansion / query-block knobs (its ``metric``
        is always forced to the store's — rows are stored transformed,
        searching them under another metric would be silent garbage).

        Queries come in RAW (store.d features, any metric) and are
        reduced here/in graph_search; returned distances are
        transformed-space squared l2 (metric.similarity_from_dist
        converts back). ``filter_ids`` is a per-call predicate mask —
        (rows,) shared or (q, rows) per query, sized to ``store.n`` or
        the full capacity (shorter masks are False-padded: unallocated
        rows are inadmissible anyway) — filtered rows are never
        returned, exactly like tombstones."""
        if cfg is None:
            cfg = SearchConfig(
                beam=beam, rounds=rounds, expand=self.cfg.seed_expand,
                q_block=self.cfg.q_block, backend=self.cfg.backend,
                precision=self.cfg.precision,
            )
        if cfg.metric != self.cfg.metric:
            cfg = dataclasses.replace(cfg, metric=self.cfg.metric)
        if filter_ids is not None:
            filter_ids = jnp.asarray(filter_ids, bool)
            short = self.capacity - filter_ids.shape[-1]
            if short > 0:
                pad = [(0, 0)] * (filter_ids.ndim - 1) + [(0, short)]
                filter_ids = jnp.pad(filter_ids, pad,
                                     constant_values=False)
        q = _pad_to(metric_mod.transform_queries(queries, self.cfg.metric),
                    self.x.shape[1])
        return graph_search(
            self.x, self.nl.idx, q, k_out=k_out, key=key,
            alive=self.alive, x2=self.x2, cfg=cfg, qstore=self.qs,
            router=self.router, filter_ids=filter_ids,
        )


def _next_capacity(n: int) -> int:
    cap = 8
    while cap < n:
        cap *= 2
    return cap


def _ceil_chunk(f: int, chunk: int, cap: int) -> int:
    """Round a frontier size up to whole padded chunks, capped at cap."""
    return min(cap, ((max(f, 1) + chunk - 1) // chunk) * chunk)


def _pad_to(x: jax.Array, dp: int) -> jax.Array:
    xp = pad_features(x.astype(jnp.float32))
    if xp.shape[1] != dp:
        raise ValueError(
            f"feature dim {x.shape[1]} pads to {xp.shape[1]}, store has {dp}"
        )
    return xp


def _grown(store: MutableKNNStore, need: int) -> MutableKNNStore:
    """Double capacity until ``need`` rows fit (amortized O(1) growth;
    shapes change only on a doubling so jitted update steps are reused)."""
    cap = store.capacity
    if need <= cap:
        return store
    new_cap = cap
    while new_cap < need:
        new_cap *= 2
    pad = new_cap - cap
    k = store.k
    dp = store.x.shape[1]
    return dataclasses.replace(
        store,
        qs=(None if store.qs is None
            else quantize.grow(store.qs, new_cap, _FILL)),
        router=(None if store.router is None
                else store.router._replace(assign=jnp.concatenate(
                    [store.router.assign,
                     jnp.full((pad,), -1, jnp.int32)]
                ))),
        x=jnp.concatenate(
            [store.x, jnp.full((pad, dp), _FILL, jnp.float32)]
        ),
        x2=jnp.concatenate(
            [store.x2, jnp.full((pad,), dp * _FILL * _FILL, jnp.float32)]
        ),
        nl=NeighborLists(
            jnp.concatenate(
                [store.nl.dist, jnp.full((pad, k), jnp.inf, jnp.float32)]
            ),
            jnp.concatenate(
                [store.nl.idx, jnp.full((pad, k), -1, jnp.int32)]
            ),
            jnp.concatenate([store.nl.new, jnp.zeros((pad, k), bool)]),
        ),
        alive=jnp.concatenate([store.alive, jnp.zeros((pad,), bool)]),
    )


def _frontier_slots(fids: jax.Array, recv: jax.Array) -> jax.Array:
    """Map receiver row ids into frontier-local slots. ``fids`` is an
    ascending padded id buffer (expand_frontier's layout: valid prefix,
    -1 tail); receivers not on the frontier map to -1 (dropped)."""
    big = jnp.iinfo(jnp.int32).max
    fs = jnp.where(fids >= 0, fids, big)
    slot = jnp.searchsorted(fs, recv)
    slot_c = jnp.clip(slot, 0, fids.shape[0] - 1)
    hit = (recv >= 0) & (fs[slot_c] == recv)
    return jnp.where(hit, slot_c.astype(jnp.int32), -1)


def _route_reverse(
    nl: NeighborLists,
    fids: jax.Array,       # (f,) frontier row-id buffer (ascending, -1 tail)
    recv: jax.Array,       # (m, w) receiver ids per source row (-1 invalid)
    dd: jax.Array,         # (m, w) pair distances (+inf on invalid)
    src_ids: jax.Array,    # (m,) source (new point) row ids
    c: int,                # candidate width handed to the frontier merge
    s_cap: int,            # per-receiver source-incidence buffer
    backend: str,
    prefilter: bool,
):
    """Fused reverse-edge routing (the online face of the knn_join kernel
    family): instead of pushing all (receiver, source, dist) pairs through
    a (receiver, dist) lexsort (``compact_pairs``), each frontier receiver
    inverts its incidences, gathers its incoming distances, and the
    ``knn_join_select`` kernel reduces them to the best ``c`` under the
    receiver's k-th-distance prefilter. Returns (f, c) candidate buffers
    aligned with ``fids`` for heap.merge_rows."""
    f = fids.shape[0]
    m, w = recv.shape
    lrecv = _frontier_slots(fids, recv.reshape(-1)).reshape(m, w)
    # overflow keeps the closest incoming edges per receiver, not the
    # smallest (row, slot) — hub receivers on hub-heavy inserts no longer
    # systematically drop late sources
    rows_of, slot_of = invert_candidates(lrecv, f, s_cap, prio=dd)
    ok = rows_of >= 0
    lin = jnp.where(ok, rows_of * w + slot_of, 0)
    gd = jnp.where(ok, dd.reshape(-1)[lin], jnp.inf)        # (f, s_cap)
    gi = jnp.where(ok, src_ids[jnp.where(ok, rows_of, 0)], -1)
    if prefilter:
        safe = jnp.where(fids >= 0, fids, 0)
        kth = jnp.where(fids >= 0, nl.dist[safe, -1], 0.0)
    else:
        kth = jnp.full((f,), jnp.inf)
    return ops.knn_join_select(gd, gi, kth, c=c, backend=backend)


# ---------------------------------------------------------------------------
# insert
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def _insert_stitch(
    x: jax.Array,
    x2: jax.Array,
    nl: NeighborLists,
    alive: jax.Array,
    q: jax.Array,          # (m, dp) new points
    ids: jax.Array,        # (m,) their row ids
    seed_d: jax.Array,     # (m, k) graph-search seed distances
    seed_i: jax.Array,     # (m, k) graph-search seed ids
    cfg: OnlineConfig,
):
    """Stitch m new rows into the graph and run the localized refinement.

    All reverse-edge repair runs on a compacted frontier: the 1-hop
    closure of the new rows for the seed merge, the 2-hop closure per
    refinement round — gathered into padded chunks and merged with the
    chunked kernels, never a dense pass over the store.

    Returns (x, x2, nl, alive, extra dist evals, per-round accepted,
    frontier rows touched, padded rows processed)."""
    cap, k = nl.idx.shape
    m = ids.shape[0]
    c = cfg.merge_mult * k
    chunk = max(1, min(cfg.chunk, cap))
    q2 = jnp.sum(q * q, axis=1)

    x = x.at[ids].set(q)
    x2 = x2.at[ids].set(q2)
    alive = alive.at[ids].set(True)
    seed_ok = seed_i >= 0
    nl = NeighborLists(
        nl.dist.at[ids].set(jnp.where(seed_ok, seed_d, jnp.inf)),
        nl.idx.at[ids].set(jnp.where(seed_ok, seed_i, -1)),
        nl.new.at[ids].set(seed_ok),
    )

    evals = jnp.zeros((), jnp.int32)
    f_rows = jnp.zeros((), jnp.int32)
    p_rows = jnp.zeros((), jnp.int32)
    upds = []

    # reverse-merge the seed edges: each new point is a candidate for the
    # rows that seeded it (distances already evaluated by the search).
    # Receivers all sit on the 1-hop closure of the new rows, which fits
    # exactly in m*(k+1) frontier slots — no truncation.
    f_seed = _ceil_chunk(min(cap, m * (k + 1)), chunk, cap)
    s_cap = cfg.route_src or 2 * c
    fids, _ = expand_frontier(nl.idx, ids, hops=1, capacity=f_seed)
    cd, ci = _route_reverse(
        nl, fids, jnp.where(seed_ok, seed_i, -1),
        jnp.where(seed_ok, seed_d, jnp.inf), ids, c, s_cap,
        cfg.backend, prefilter=False,
    )
    nl, upd0 = heap.merge_rows(nl, fids, cd, ci, backend=cfg.backend)
    upds.append(jnp.sum(upd0))
    f_rows += jnp.sum(fids >= 0, dtype=jnp.int32)
    p_rows += f_seed

    # all-pairs join within the inserted batch: a streamed batch is often
    # self-similar (new points are each other's nearest neighbors) and the
    # seed search only sees pre-existing rows
    if cfg.self_join and 1 < m <= cfg.self_join_max:
        d_qq = q2[:, None] + q2[None, :] - 2.0 * (
            q @ q.T
        )
        off = ~jnp.eye(m, dtype=bool)
        d_qq = jnp.where(off, jnp.maximum(d_qq, 0.0), jnp.inf)
        cand = jnp.where(off, jnp.broadcast_to(ids[None, :], (m, m)), -1)
        nl, upd_sj = heap.merge_rows(nl, ids, d_qq, cand,
                                     backend=cfg.backend)
        evals += m * (m - 1) // 2
        upds[-1] = upds[-1] + jnp.sum(upd_sj)
        f_rows += m
        p_rows += m

    # localized NN-Descent: friend-of-a-friend rounds over the frontier
    f_rev = _ceil_chunk(min(cap, cfg.frontier_mult * m * k), chunk, cap)
    for _r in range(cfg.refine_rounds):
        ni = nl.idx[ids]                                    # (m, k)
        nb = nl.idx[jnp.clip(ni, 0, cap - 1)]               # (m, k, k)
        # receivers of this round's reverse edges all sit on the 2-hop
        # closure of the new rows (cand = neighbors-of-neighbors); the
        # frontier buffer is that closure, truncated to f_rev rows
        fids_r, _ = expand_frontier(
            nl.idx, ids, hops=2, capacity=f_rev, alive=alive
        )
        cand = nb.reshape(m, k * k)
        src_ok = jnp.broadcast_to(
            (ni >= 0)[:, :, None], (m, k, k)
        ).reshape(m, k * k)
        ok = (
            src_ok
            & (cand >= 0)
            & alive[jnp.clip(cand, 0, cap - 1)]
            & (cand != ids[:, None])
        )
        ok &= ~(cand[:, :, None] == ni[:, None, :]).any(-1)  # already linked
        cx = x[jnp.clip(cand, 0, cap - 1)]                   # (m, kk, dp)
        dd = q2[:, None] + x2[jnp.clip(cand, 0, cap - 1)] - 2.0 * jnp.einsum(
            "md,mcd->mc", q, cx, preferred_element_type=jnp.float32
        )
        dd = jnp.where(ok, jnp.maximum(dd, 0.0), jnp.inf)
        evals += jnp.sum(ok, dtype=jnp.int32)

        # forward: candidates into the new rows' lists
        nl, upd_f = heap.merge_rows(
            nl, ids, dd, jnp.where(ok, cand, -1), backend=cfg.backend
        )

        # reverse: the new point is a candidate for every touched row that
        # it beats (receiver-side prefilter, applied inside the fused
        # select kernel — as in nn_descent's local_join_fused)
        cd, ci = _route_reverse(
            nl, fids_r, jnp.where(ok, cand, -1), dd, ids, c, s_cap,
            cfg.backend, prefilter=True,
        )
        nl, upd_r = heap.merge_rows(nl, fids_r, cd, ci, backend=cfg.backend)
        upds.append(jnp.sum(upd_f) + jnp.sum(upd_r))
        # count rows actually on the compacted buffer (the closure may be
        # truncated to f_rev, and truncated rows are never touched)
        f_rows += m + jnp.sum(fids_r >= 0, dtype=jnp.int32)
        p_rows += m + f_rev

    return x, x2, nl, alive, evals, jnp.stack(upds), f_rows, p_rows


def knn_insert(
    store: MutableKNNStore,
    new_points: jax.Array,
    *,
    key: jax.Array | None = None,
) -> tuple[MutableKNNStore, DescentStats]:
    """Insert ``new_points`` (m, d) into the store. Deterministic given
    ``key`` (the only randomness is the seed search's entry points).

    ``new_points`` are RAW rows; the store's metric reduction is applied
    here (cosine: normalize; mips: augment with the store's bound
    ``mips_m`` — rows that outgrow it clamp with a RuntimeWarning, and a
    store that started ``empty`` sets the bound from its first batch),
    so the seeding search, the FoaF refinement, the quantized mirror
    update and the router maintenance below all run metric-unchanged.

    Returns (store, stats); ``stats.dist_evals`` is an upper bound on the
    distance evaluations spent (the seed-search term is the analytic bound
    beam + rounds*k per query; the refinement term is exact).
    """
    cfg = store.cfg
    k = store.k
    m = int(new_points.shape[0])
    if m == 0:
        return store, DescentStats(iters=0, dist_evals=0)
    key = jax.random.key(0) if key is None else key
    if new_points.shape[1] != store.d:
        raise ValueError(
            f"new points have dim {new_points.shape[1]}, store has {store.d}"
        )
    mips_m = store.mips_m
    if cfg.metric == "mips" and store.n == 0 and mips_m == 0.0:
        # a store built via ``empty`` has no bound yet — its first batch
        # defines M (later batches share it, clamping past it)
        mips_m = metric_mod.mips_max_norm(new_points)
        store = dataclasses.replace(store, mips_m=mips_m)
    new_t, _ = metric_mod.transform_corpus(
        new_points, cfg.metric, mips_m=mips_m if cfg.metric == "mips"
        else None)
    q = _pad_to(new_t, store.x.shape[1])
    store = _grown(store, store.n + m)
    ids = jnp.arange(store.n, store.n + m, dtype=jnp.int32)

    beam = max(cfg.beam, k)
    scfg = SearchConfig(
        beam=beam, rounds=cfg.seed_rounds, expand=cfg.seed_expand,
        q_block=cfg.q_block, backend=cfg.backend,
        precision=cfg.precision, metric=cfg.metric,
    )
    seed_d, seed_i = graph_search(
        store.x, store.nl.idx, q, k_out=k, key=key, alive=store.alive,
        x2=store.x2, cfg=scfg, qstore=store.qs, router=store.router,
    )
    # analytic eval bound: beam entry distances + k per expanded node (the
    # fused path expands in chunks of seed_expand, so round the budget up
    # to whole rounds; backend="ref" expands exactly seed_rounds nodes);
    # a quantized seeding search re-ranks its final pool fp32 — beam more
    scfg_quant = scfg.precision != "f32" and scfg.backend != "ref"
    seed_evals = m * ((2 if scfg_quant else 1) * beam
                     + (cfg.seed_rounds if cfg.backend == "ref"
                        else scfg.n_rounds * cfg.seed_expand) * k)

    x, x2, nl, alive, evals, upds, f_rows, p_rows = _insert_stitch(
        store.x, store.x2, store.nl, store.alive, q, ids, seed_d, seed_i,
        cfg,
    )
    qs = store.qs if store.qs is None else quantize.update_rows(
        store.qs, ids, q
    )
    router = store.router
    if router is not None:
        router = router_insert(router, ids, q, backend=cfg.backend)
        router = _maybe_rebuild_router(
            router, x, x2, alive, cfg, jax.random.fold_in(key, 911)
        )
    stats = DescentStats(
        iters=cfg.refine_rounds,
        dist_evals=seed_evals + int(evals),
        updates=tuple(int(u) for u in upds),
        frontier_rows=int(f_rows),
        padded_rows=int(p_rows),
    )
    return (
        dataclasses.replace(
            store, x=x, x2=x2, nl=nl, alive=alive, n=store.n + m, qs=qs,
            router=router,
        ),
        stats,
    )


def _maybe_rebuild_router(
    router: Router,
    x: jax.Array,
    x2: jax.Array,
    alive: jax.Array,
    cfg: OnlineConfig,
    key: jax.Array,
) -> Router:
    """Lazy drift rebuild: incremental maintenance keeps the router exact
    w.r.t. assignments/members, but the CENTROIDS slowly stop describing
    the data as the corpus churns — past the drift threshold, refit.

    A failed rebuild degrades, never crashes: the incremental router is
    stale but still *correct* as an entry-point heuristic (holes fall
    back to random draws inside graph_search), so the store keeps
    serving from it — degraded recall beats a dead insert path. The
    rebuild is re-attempted on the next insert that crosses the
    threshold."""
    rcfg = cfg.router or RouterConfig()
    if needs_rebuild(router, int(jnp.sum(alive)), rcfg):
        try:
            faults.maybe_raise("router.rebuild")
            return build_router(
                x, cfg=rcfg, key=key, alive=alive, x2=x2,
                backend=cfg.backend,
            )
        except Exception as e:
            warnings.warn(
                f"router rebuild failed ({e}); serving continues from "
                "the stale router", RuntimeWarning, stacklevel=2)
    return router


def ensure_router(
    store: MutableKNNStore,
    rcfg: RouterConfig | None = None,
    *,
    key: jax.Array | None = None,
) -> MutableKNNStore:
    """Idempotently attach a router to an existing store (serving-side
    plumbing: ContinuousBatcher / MutableKNNDatastore opt in without
    rebuilding the store). The router clusters the store's TRANSFORMED
    rows, so routed entries are correct under any ``cfg.metric`` with
    no per-metric routing code."""
    if store.router is not None:
        return store
    rcfg = rcfg or store.cfg.router or RouterConfig()
    return dataclasses.replace(
        store,
        cfg=dataclasses.replace(store.cfg, router=rcfg),
        router=build_router(
            store.x, cfg=rcfg,
            key=jax.random.key(29) if key is None else key,
            alive=store.alive, x2=store.x2, backend=store.cfg.backend,
        ),
    )


# ---------------------------------------------------------------------------
# delete
# ---------------------------------------------------------------------------


@jax.jit
def _delete_need(idx: jax.Array, alive: jax.Array) -> jax.Array:
    """Rows needing compaction after a tombstone: rows that reference a
    dead id, plus newly-dead rows that still hold a list. One O(n*k)
    bitwise scan — no distance evaluations; everything downstream runs on
    the compacted frontier this mask defines."""
    cap = alive.shape[0]
    valid = idx >= 0
    dead_tgt = valid & ~alive[jnp.clip(idx, 0, cap - 1)]
    return dead_tgt.any(axis=1) | (valid.any(axis=1) & ~alive)


@functools.partial(jax.jit, static_argnames=("backend",))
def _purge_chunk(
    nl: NeighborLists,
    rows: jax.Array,
    alive: jax.Array,
    backend: str,
):
    """One padded chunk of the tombstone purge (heap.purge_rows)."""
    return heap.purge_rows(nl, rows, alive, backend=backend)


@functools.partial(jax.jit, static_argnames=("backend",))
def _refill_chunk(
    x: jax.Array,
    x2: jax.Array,
    nl: NeighborLists,
    idx0: jax.Array,       # (cap, k) post-purge snapshot (read-only)
    alive: jax.Array,
    rows: jax.Array,       # (chunk,) frontier row ids, -1 = padding
    removed: jax.Array,    # (chunk,) per-row purge removal count
    backend: str,
):
    """Refill one padded chunk of affected rows from their surviving
    neighbors' lists (one friend-of-a-friend round). Candidate reads come
    from the post-purge snapshot ``idx0`` so chunk processing order cannot
    change the result (all chunks see the same graph state).

    Returns (nl, dist evals, accepted, orphan count in this chunk)."""
    cap, k = nl.idx.shape
    f = rows.shape[0]
    ok_row = rows >= 0
    safe = jnp.where(ok_row, rows, 0)
    refill = ok_row & alive[safe] & (removed > 0)

    ni = idx0[safe]                                        # (f, k)
    nb = idx0[jnp.clip(ni, 0, cap - 1)].reshape(f, k * k)
    src_ok = jnp.broadcast_to(
        (ni >= 0)[:, :, None], (f, k, k)
    ).reshape(f, k * k)
    ok = (
        refill[:, None]
        & src_ok
        & (nb >= 0)
        & alive[jnp.clip(nb, 0, cap - 1)]
        & (nb != safe[:, None])
    )
    ok &= ~(nb[:, :, None] == ni[:, None, :]).any(-1)
    cx = x[jnp.clip(nb, 0, cap - 1)]
    dd = x2[safe][:, None] + x2[jnp.clip(nb, 0, cap - 1)] - 2.0 * jnp.einsum(
        "fd,fcd->fc", x[safe], cx, preferred_element_type=jnp.float32
    )
    dd = jnp.where(ok, jnp.maximum(dd, 0.0), jnp.inf)
    evals = jnp.sum(ok, dtype=jnp.int32)
    nl, upd = heap.merge_rows(
        nl, rows, dd, jnp.where(ok, nb, -1), backend=backend
    )

    orphan = ok_row & alive[safe] & ~(nl.idx[safe] >= 0).any(axis=1)
    return nl, evals, jnp.sum(upd), jnp.sum(orphan, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("merge_c",))
def _reconnect_orphans(
    x: jax.Array,
    x2: jax.Array,
    nl: NeighborLists,
    alive: jax.Array,
    merge_c: int,
):
    """Reconnect orphans: a live row whose ENTIRE neighborhood died has no
    surviving neighbors to refill from (and its inbound edges were purged
    too) — re-anchor it to k deterministic live rows, both directions, so
    it stays reachable by graph search. Rare (requires a whole
    neighborhood to die at once), so this runs as a separate pass only
    when a refill chunk reports orphans."""
    cap, k = nl.idx.shape
    rows = jnp.arange(cap, dtype=jnp.int32)
    orphan = alive & ~(nl.idx >= 0).any(axis=1)
    anchor_score = jnp.where(alive & ~orphan, (cap - rows).astype(jnp.float32),
                             -1.0)
    _, anchors = jax.lax.top_k(anchor_score, k)          # lowest live ids
    ok2 = (
        orphan[:, None]
        & alive[anchors][None, :]
        & ~orphan[anchors][None, :]
        & (anchors[None, :] != rows[:, None])
    )
    dd2 = x2[:, None] + x2[anchors][None, :] - 2.0 * (
        x @ x[anchors].T
    )
    dd2 = jnp.where(ok2, jnp.maximum(dd2, 0.0), jnp.inf)
    evals = jnp.sum(ok2, dtype=jnp.int32)
    anc = jnp.broadcast_to(anchors[None, :], (cap, k))
    nl, upd2 = heap.merge(nl, dd2, jnp.where(ok2, anc, -1))
    # reverse edges: the anchors adopt the orphan so it is reachable.
    # This cold path keeps compact_pairs (exact by-distance truncation):
    # every orphan targets the SAME k anchors, so the per-receiver
    # in-degree is unbounded and a bounded source buffer could drop the
    # closest orphans — the fused routing's contract doesn't fit here.
    recv = jnp.where(ok2, anc, -1).reshape(-1)
    src = jnp.broadcast_to(rows[:, None], (cap, k)).reshape(-1)
    cd, ci = compact_pairs(recv, src, dd2.reshape(-1), cap, merge_c)
    nl, upd3 = heap.merge(nl, cd, ci)
    return nl, evals, jnp.sum(upd2) + jnp.sum(upd3)


def knn_delete(
    store: MutableKNNStore,
    ids: jax.Array,
) -> tuple[MutableKNNStore, DescentStats]:
    """Tombstone ``ids`` and patch every neighbor list that pointed at
    them. Deleted rows are never returned by ``store.search`` and never
    re-enter any list; their slots are not reused (capacity is monotone).

    The purge + refill run over the compacted frontier of affected rows
    (rows referencing a dead id, plus the dead rows themselves), gathered
    into ``cfg.chunk``-row padded chunks — O(frontier) work, not O(n).
    With ``cfg.frontier=False`` every allocated row is processed (the
    dense baseline; identical results).

    Metric/filter behavior: refill distances are computed over the
    store's already-transformed rows, so deletion is metric-correct
    with no per-metric code; downstream, a tombstoned row exits every
    search exactly like a filtered one (id -1 -> +inf in the kernel
    epilogue) — ``filter_ids`` masks compose with tombstones, they do
    not replace them.
    """
    cfg = store.cfg
    ids = jnp.asarray(ids, jnp.int32)
    alive = store.alive.at[ids].set(False)
    cap = store.capacity
    chunk = max(1, min(cfg.chunk, cap))

    router = store.router
    if router is not None:
        # the alive mask changed on EVERY return path below — maintain
        # the router here, before the early no-frontier exit
        router = router_delete(router, ids, alive, backend=cfg.backend)
        router = _maybe_rebuild_router(
            router, store.x, store.x2, alive, cfg,
            jax.random.fold_in(jax.random.key(31), int(ids.shape[0])),
        )

    if cfg.frontier:
        need = _delete_need(store.nl.idx, alive)
        f = int(jnp.sum(need))
        if f == 0:
            return (
                dataclasses.replace(store, alive=alive, router=router),
                DescentStats(iters=0, dist_evals=0, frontier_rows=0,
                             padded_rows=0),
            )
        n_chunks = (f + chunk - 1) // chunk
        fids = jnp.nonzero(
            need, size=n_chunks * chunk, fill_value=-1
        )[0].astype(jnp.int32)
    else:
        f = store.n
        n_chunks = (f + chunk - 1) // chunk
        ar = jnp.arange(n_chunks * chunk, dtype=jnp.int32)
        fids = jnp.where(ar < f, ar, -1)

    nl = store.nl
    removed = []
    for j in range(n_chunks):
        rows = jax.lax.dynamic_slice_in_dim(fids, j * chunk, chunk)
        nl, rm = _purge_chunk(nl, rows, alive, cfg.backend)
        removed.append(rm)

    idx0 = nl.idx      # post-purge snapshot: all refill chunks read this
    evals = jnp.zeros((), jnp.int32)
    upd = jnp.zeros((), jnp.int32)
    orphans = jnp.zeros((), jnp.int32)
    for j in range(n_chunks):
        rows = jax.lax.dynamic_slice_in_dim(fids, j * chunk, chunk)
        nl, ev, up, orp = _refill_chunk(
            store.x, store.x2, nl, idx0, alive, rows, removed[j],
            cfg.backend,
        )
        evals += ev
        upd += up
        orphans += orp

    if int(orphans) > 0:
        nl, ev2, up2 = _reconnect_orphans(
            store.x, store.x2, nl, alive, cfg.merge_mult * store.k
        )
        evals += ev2
        upd += up2

    stats = DescentStats(
        iters=1, dist_evals=int(evals), updates=(int(upd),),
        frontier_rows=f, padded_rows=n_chunks * chunk,
    )
    return (
        dataclasses.replace(store, nl=nl, alive=alive, router=router),
        stats,
    )
