"""Ground truth + recall metrics (paper §2: 'recall is used to measure how
close the K-NNG approximation is to the true K-NNG'; >99% on all datasets).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops


@functools.partial(
    jax.jit, static_argnames=("k", "chunk", "backend", "exclude_self"))
def brute_force_knn(
    x: jax.Array,
    queries: jax.Array,
    k: int,
    *,
    chunk: int = 1024,
    backend: str = "auto",
    exclude_self: bool = True,
):
    """Exact k-NN of ``queries`` against corpus ``x`` (squared l2).

    Chunked over queries through the blocked distance kernel; (dist, idx)
    ascending. ``exclude_self`` requires queries IS the corpus (row i of
    the queries is row i of the corpus; excluded by index, since the norm
    expansion's self-distance carries cancellation error). Pass
    exclude_self=False for a separate query set.
    """
    if exclude_self and queries.shape[0] != x.shape[0]:
        raise ValueError(
            "exclude_self=True assumes queries IS the corpus "
            f"(row-aligned); got {queries.shape[0]} queries vs "
            f"{x.shape[0]} corpus rows — pass exclude_self=False"
        )
    nq = queries.shape[0]
    pad = (-nq) % chunk
    qp = jnp.pad(queries, ((0, pad), (0, 0)))

    def one(args):
        qc, off = args
        d = ops.pairwise_sq_l2(qc, x, backend=backend)
        if exclude_self:
            # identity exclusion, not a distance threshold: the norm
            # expansion's self-distance carries cancellation error well
            # above any epsilon (float32, large norms), and a threshold
            # would also drop true duplicate points from the ground truth
            rows = off * chunk + jnp.arange(chunk)
            d = jnp.where(
                rows[:, None] == jnp.arange(x.shape[0])[None, :], jnp.inf, d
            )
        neg_d, idx = jax.lax.top_k(-d, k)
        return -neg_d, idx

    qs = qp.reshape(-1, chunk, qp.shape[1])
    dist, idx = jax.lax.map(
        one, (qs, jnp.arange(qs.shape[0], dtype=jnp.int32))
    )
    dist = dist.reshape(-1, k)[:nq]
    idx = idx.reshape(-1, k)[:nq]
    return dist, idx.astype(jnp.int32)


def recall_at_k(approx_idx: jax.Array, true_idx: jax.Array) -> float:
    """|approx ∩ true| / k averaged over rows."""
    hit = (approx_idx[:, :, None] == true_idx[:, None, :]).any(-1)
    hit &= approx_idx >= 0
    return float(jnp.mean(jnp.sum(hit, axis=1) / true_idx.shape[1]))


def distance_recall(
    approx_dist: jax.Array, true_dist: jax.Array, eps: float = 1e-6
) -> float:
    """Tie-tolerant recall: an approx neighbor counts if its distance is
    within eps of the true k-th distance (handles duplicate points)."""
    kth = true_dist[:, -1][:, None]
    ok = (approx_dist <= kth * (1 + eps) + eps) & jnp.isfinite(approx_dist)
    return float(jnp.mean(jnp.sum(ok, axis=1) / true_dist.shape[1]))
