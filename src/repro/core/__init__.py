"""The paper's contribution: fast K-NN-graph construction (NN-Descent with
turbosampling selection, greedy memory reordering, and blocked distance
evaluation), single-chip and mesh-sharded."""
from repro.core.faults import (
    FaultPlan,
    FaultSpec,
    InjectedFault,
    poison_batch,
)
from repro.core.graph_search import SearchConfig, graph_search
from repro.core.heap import NeighborLists
from repro.core.nn_descent import (
    DescentConfig,
    DescentStats,
    build_knn_graph,
    nn_descent_iteration,
)
from repro.core.online import (
    MutableKNNStore,
    OnlineConfig,
    ensure_router,
    knn_delete,
    knn_insert,
)
from repro.core.persist import (
    SnapshotError,
    SnapshotWriter,
    latest_snapshot,
    restore_store,
    snapshot_store,
)
from repro.core.quantize import (
    QuantizedStore,
    dequantize,
    quantize_corpus,
    quantize_sym_int8,
)
from repro.core.recall import brute_force_knn, distance_recall, recall_at_k
from repro.core.reorder import (
    apply_permutation,
    greedy_reorder,
    locality_stats,
    window_cluster_purity,
)
from repro.core.router import Router, RouterConfig, build_router

__all__ = [
    "DescentConfig",
    "DescentStats",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "MutableKNNStore",
    "NeighborLists",
    "OnlineConfig",
    "QuantizedStore",
    "Router",
    "RouterConfig",
    "SearchConfig",
    "SnapshotError",
    "SnapshotWriter",
    "apply_permutation",
    "brute_force_knn",
    "build_knn_graph",
    "build_router",
    "dequantize",
    "distance_recall",
    "ensure_router",
    "quantize_corpus",
    "quantize_sym_int8",
    "graph_search",
    "greedy_reorder",
    "knn_delete",
    "knn_insert",
    "latest_snapshot",
    "locality_stats",
    "nn_descent_iteration",
    "poison_batch",
    "recall_at_k",
    "restore_store",
    "snapshot_store",
    "window_cluster_purity",
]
