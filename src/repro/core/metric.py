"""Metric generality via input-side reductions to squared l2.

The entire fused kernel family (the norm-expansion distance tiles, the
partial top-C select, the quantized mirror scoring) is written for ONE
metric: squared l2. That is not a restriction in practice, because the
two metrics embedding-retrieval workloads actually ask for both reduce
to l2 by transforming the INPUTS — so every kernel, every store layout,
and the whole two-stage precision machinery are reused unchanged:

  * **cosine** — row-normalize. On unit vectors
    ``|q - x|^2 = 2 - 2*cos(q, x)``, so l2 order IS descending-cosine
    order and ``cos = 1 - d2/2`` recovers the similarity exactly.
    Corpus rows are normalized once at build/insert; queries once per
    batch at the search boundary.

  * **mips** (maximum inner product) — the augmented-coordinate
    reduction (Bachrach et al., RecSys'14): pick ``M >= max_i |x_i|``,
    append ``sqrt(M^2 - |x|^2)`` to every corpus row and a literal 0 to
    every query. Then ``|q^ - x^|^2 = |q|^2 + M^2 - 2<q, x>`` — constant
    per query plus a constant, minus twice the inner product — so
    ascending l2 over the augmented vectors IS descending inner product,
    and ``ip = (|q|^2 + M^2 - d2) / 2`` recovers it exactly. The store
    carries ``M`` (``MutableKNNStore.mips_m``, echoed by persistence);
    inserted rows with ``|x| > M`` get their augmented coordinate
    clamped to 0 with a RuntimeWarning — their recovered inner products
    stay exact (the clamp only weakens their l2 ORDER consistency by
    the overshoot, it never corrupts other rows).

  * **l2** — the identity; the default; what the paper benchmarks.

The transforms are *input-side*: ``transform_corpus`` runs once where
rows enter a store (``MutableKNNStore.from_graph`` / ``knn_insert``,
``build_knn_graph``), ``transform_queries`` runs once per batch inside
``graph_search`` — downstream of both, the blocked kernels see plain
rows and plain squared-l2 and cannot tell the metric apart. The
quantized mirror quantizes the TRANSFORMED rows, so int8/bf16 two-stage
search works per metric for free; the router's k-means clusters the
transformed rows, so routed seeding does too.

Returned distances are always the transformed-space squared l2 —
monotone in the native metric, so ranking consumers (recall, knn-LM
softmax weighting) need no conversion; ``similarity_from_dist`` converts
when the caller wants the native cosine / inner-product values.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

METRICS = ("l2", "cosine", "mips")

_EPS = 1e-12   # zero-row guard: a zero row normalizes to zero, not NaN


def check_metric(metric: str) -> str:
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; expected one of "
                         f"{METRICS}")
    return metric


def normalize_rows(x: jax.Array) -> jax.Array:
    """Row-normalize to unit l2 norm (the cosine reduction). Zero rows
    stay zero (eps floor) instead of going NaN; rows that are ALREADY
    exactly unit norm divide by exactly 1.0, so pre-normalized data is
    bit-identical under the transform (tests/test_property.py pins
    this)."""
    x = x.astype(jnp.float32)
    n2 = jnp.sum(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(jnp.maximum(n2, _EPS))


def mips_max_norm(x: jax.Array) -> float:
    """The augmentation bound M: the max row norm of the corpus."""
    if x.shape[0] == 0:
        return 0.0
    return float(jnp.sqrt(jnp.max(jnp.sum(
        x.astype(jnp.float32) ** 2, axis=-1))))


def mips_augment(x: jax.Array, m: float) -> jax.Array:
    """Append the augmented coordinate ``sqrt(M^2 - |x|^2)`` per corpus
    row (d -> d+1). Rows with ``|x| > M`` (inserts that outgrow the
    build-time bound) clamp the coordinate to 0 with a RuntimeWarning:
    the recovered inner products stay exact, only those rows' l2 order
    degrades by the overshoot."""
    x = x.astype(jnp.float32)
    n2 = jnp.sum(x * x, axis=-1)
    slack = m * m - n2
    if x.shape[0] and not isinstance(x, jax.core.Tracer):
        over = int(jnp.sum(slack < -1e-6 * max(m * m, 1.0)))
        if over:
            warnings.warn(
                f"mips insert: {over} row(s) exceed the store's "
                f"augmentation bound M={m:.4g}; their augmented "
                "coordinate is clamped to 0 (inner products stay exact, "
                "their traversal order degrades by the overshoot)",
                RuntimeWarning, stacklevel=3)
    aug = jnp.sqrt(jnp.maximum(slack, 0.0))
    return jnp.concatenate([x, aug[:, None]], axis=-1)


def transform_corpus(
    x: jax.Array, metric: str, *, mips_m: float | None = None
) -> tuple[jax.Array, float]:
    """Metric reduction of corpus rows (run ONCE where rows enter a
    store or a build — the transforms are not idempotent for mips).
    Returns ``(x_t, mips_m)``; ``mips_m`` is 0.0 except under mips,
    where it is the augmentation bound used (pass the store's bound for
    inserts so the batch shares the build-time M)."""
    check_metric(metric)
    if metric == "l2":
        return x.astype(jnp.float32), 0.0
    if metric == "cosine":
        return normalize_rows(x), 0.0
    m = mips_max_norm(x) if mips_m is None else mips_m
    return mips_augment(x, m), m


def transform_queries(q: jax.Array, metric: str) -> jax.Array:
    """Metric reduction of query rows: cosine normalizes (idempotent up
    to fp — exactly idempotent on unit rows), mips appends the literal 0
    coordinate (d -> d+1; no bound needed on the query side)."""
    check_metric(metric)
    q = q.astype(jnp.float32)
    if metric == "l2":
        return q
    if metric == "cosine":
        return normalize_rows(q)
    return jnp.concatenate(
        [q, jnp.zeros((*q.shape[:-1], 1), jnp.float32)], axis=-1)


def similarity_from_dist(
    dist: jax.Array,
    metric: str,
    *,
    q2: jax.Array | None = None,
    mips_m: float = 0.0,
) -> jax.Array:
    """Convert transformed-space squared-l2 distances back to the native
    similarity: cosine ``1 - d2/2``; mips ``(|q|^2 + M^2 - d2) / 2``
    (``q2`` = squared norms of the RAW queries, broadcast against
    ``dist``); l2 returns the distances unchanged (it has no similarity
    form). Empty slots (+inf distance) come back -inf similarity, so
    descending-similarity order keeps them last."""
    check_metric(metric)
    if metric == "l2":
        return dist
    if metric == "cosine":
        sim = 1.0 - dist / 2.0
    else:
        if q2 is None:
            raise ValueError("mips similarity needs q2 (raw-query "
                             "squared norms)")
        q2 = jnp.asarray(q2, jnp.float32)
        if q2.ndim == dist.ndim - 1:
            q2 = q2[..., None]
        sim = (q2 + mips_m * mips_m - dist) / 2.0
    return jnp.where(jnp.isfinite(dist), sim, -jnp.inf)


def transformed_dim(d: int, metric: str) -> int:
    """Logical feature dim after the reduction (mips appends one)."""
    check_metric(metric)
    return d + 1 if metric == "mips" else d


def filter_frac(filter_ids: jax.Array | None, n: int | None = None) -> float:
    """Fraction of corpus rows a filter mask admits (1.0 = unfiltered).
    Accepts the (n,) shared or (q, n) per-query layouts of
    ``graph_search(filter_ids=...)``; the stat serving/bench lanes
    report next to recall (selective filters cost recall — see
    docs/METRICS.md)."""
    if filter_ids is None:
        return 1.0
    return float(jnp.mean(jnp.asarray(filter_ids, bool)))
