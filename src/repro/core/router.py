"""Coarse routing layer: centroids + mini-graph for hierarchical entries.

Fixes the large-n recall collapse: uniform-random entry seeding strands
the fused beam far from the query (recall ~0.49 at n=1e5 where the 2k
smoke hits 0.96), and sharded serving replicated every query to every
shard. The router is a small k-means centroid set built with the repo's
own blocked l2 kernels, plus per-centroid member lists (nearest corpus
rows) and a tiny exact k-NN mini-graph over the centroids. Two roles:

- entry seeding: ``route_entries`` turns a query batch into per-query
  beam seeds — the nearest members of the query's top-t centroids —
  which ``graph_search`` uses instead of uniform-random draws.
- shard routing: ``graph_search_sharded`` uses centroid→shard affinity
  to dispatch each query to only the top-p shards (fan-out P → p).

The router lives alongside ``MutableKNNStore`` and is maintained
incrementally on insert/delete (assignment + member-list updates), with
a lazy full rebuild once accumulated drift passes ``rebuild_frac`` of
the live count.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import heap
from repro.core.nn_descent import compact_pairs
from repro.core.recall import brute_force_knn
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Routing-layer knobs. Frozen/hashable: nested inside OnlineConfig,
    which is a static jit argument of the stitch/purge kernels."""
    n_centroids: int = 0       # 0 = auto: ~sqrt(live), clipped to [16, 1024]
    iters: int = 8             # Lloyd iterations (on the subsample)
    sample: int = 32768        # subsample size for the Lloyd fit
    members: int = 32          # member-list width per centroid
    graph_k: int = 8           # centroid mini-graph degree
    top_t: int = 4             # centroids probed per query at search time
    rebuild_frac: float = 0.25  # stale/live ratio that triggers a rebuild


class Router(NamedTuple):
    centroids: jax.Array        # (c, dp) f32, feature-padded like the store
    c2: jax.Array               # (c,) cached squared norms
    graph: jax.Array            # (c, g) i32 centroid mini-graph, -1 padded
    members: heap.NeighborLists  # (c, m) nearest corpus rows per centroid
    assign: jax.Array           # (cap,) i32 centroid per row, -1 = dead
    counts: jax.Array           # (c,) i32 live members per centroid
    stale: jax.Array            # () i32 mutations since last full build


def resolve_centroids(live: int, cfg: RouterConfig) -> int:
    if cfg.n_centroids > 0:
        return min(cfg.n_centroids, max(live, 1))
    return int(min(1024, max(16, round(max(live, 1) ** 0.5))))


@functools.partial(jax.jit, static_argnames=("c", "iters"))
def _lloyd(xs: jax.Array, c: int, iters: int) -> jax.Array:
    """Lloyd's k-means on the (already sampled) rows. Empty clusters keep
    their previous centroid — with a random-shuffled init that is rare
    and harmless (the empty centroid simply attracts no entries)."""
    cent = xs[:c]

    def body(cent, _):
        d = jnp.maximum(
            jnp.sum(xs * xs, 1)[:, None]
            + jnp.sum(cent * cent, 1)[None, :]
            - 2.0 * xs @ cent.T,
            0.0,
        )
        a = jnp.argmin(d, axis=1)
        sums = jax.ops.segment_sum(xs, a, num_segments=c)
        cnt = jax.ops.segment_sum(
            jnp.ones((xs.shape[0],), jnp.float32), a, num_segments=c
        )
        new = jnp.where(
            cnt[:, None] > 0, sums / jnp.maximum(cnt, 1.0)[:, None], cent
        )
        return new, None

    cent, _ = jax.lax.scan(body, cent, None, length=iters)
    return cent


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def _assign_all(x, x2, cent, c2, *, chunk: int = 4096, backend: str = "auto"):
    """Nearest centroid of every store row, chunked through the blocked
    distance tile. Returns ((cap,) dist, (cap,) idx)."""
    cap, dp = x.shape
    pad = (-cap) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    x2p = jnp.pad(x2, (0, pad))

    def one(args):
        xc, x2c = args
        d, i = ops.centroid_assign(xc, x2c, cent, c2, t=1, backend=backend)
        return d[:, 0], i[:, 0]

    d, i = jax.lax.map(
        one, (xp.reshape(-1, chunk, dp), x2p.reshape(-1, chunk))
    )
    return d.reshape(-1)[:cap], i.reshape(-1)[:cap]


def build_router(
    x: jax.Array,
    *,
    cfg: RouterConfig | None = None,
    key: jax.Array,
    alive: jax.Array | None = None,
    x2: jax.Array | None = None,
    backend: str = "auto",
) -> Router:
    """Fit centroids on a live subsample, assign every live row, compact
    per-centroid member lists, and build the exact centroid mini-graph.
    All distance work goes through the blocked l2 dispatch."""
    cfg = cfg or RouterConfig()
    cap = x.shape[0]
    x = x.astype(jnp.float32)
    if x2 is None:
        x2 = jnp.sum(x * x, axis=1)
    live = cap if alive is None else int(jnp.sum(alive))
    c = resolve_centroids(live, cfg)

    # keyed-top-k live subsample (dead rows weighted out); duplicated
    # tail rows when live < sample only add benign weight to Lloyd
    s = min(cfg.sample, cap)
    w = jax.random.uniform(key, (cap,))
    if alive is not None:
        w = jnp.where(alive, w, -1.0)
    wv, sample_ids = jax.lax.top_k(w, s)
    sample_ids = jnp.where(wv > 0.0, sample_ids, sample_ids[0])
    cent = _lloyd(x[sample_ids], min(c, s), cfg.iters)
    if cent.shape[0] < c:      # degenerate tiny corpus: pad with repeats
        cent = jnp.concatenate(
            [cent, jnp.broadcast_to(cent[:1], (c - cent.shape[0], cent.shape[1]))]
        )
    c2 = jnp.sum(cent * cent, axis=1)

    d_assign, assign = _assign_all(x, x2, cent, c2, backend=backend)
    if alive is not None:
        assign = jnp.where(alive, assign, -1)
        d_assign = jnp.where(alive, d_assign, jnp.inf)
    assign = assign.astype(jnp.int32)
    counts = (
        jnp.zeros((c,), jnp.int32)
        .at[jnp.clip(assign, 0, c - 1)]
        .add((assign >= 0).astype(jnp.int32))
    )

    m = min(cfg.members, cap)
    md, mi = compact_pairs(
        assign, jnp.arange(cap, dtype=jnp.int32), d_assign, c, m
    )
    members = heap.NeighborLists(md, mi, jnp.zeros_like(mi, dtype=bool))

    g = min(cfg.graph_k, c - 1)
    if g > 0:
        gd, gi = brute_force_knn(cent, cent, g, backend=backend)
        graph = jnp.where(jnp.isfinite(gd), gi, -1).astype(jnp.int32)
    else:
        graph = jnp.full((c, 1), -1, jnp.int32)

    return Router(
        centroids=cent, c2=c2, graph=graph, members=members,
        assign=assign, counts=counts, stale=jnp.zeros((), jnp.int32),
    )


def top_centroids(
    router: Router, queries: jax.Array, t: int, *, backend: str = "auto"
):
    """Top-t nearest centroids per query (exact — c is small by
    construction, <= ~1024, so one blocked tile beats a graph walk)."""
    q = queries.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=1)
    t = min(t, router.centroids.shape[0])
    return ops.centroid_assign(
        q, q2, router.centroids, router.c2, t=t, backend=backend
    )


def route_entries(
    router: Router,
    queries: jax.Array,
    beam: int,
    *,
    t: int = 4,
    backend: str = "auto",
) -> jax.Array:
    """Per-query beam seeds: the member rows of the query's top-t
    centroids, nearest-member-major (slot-major interleave so every
    probed centroid contributes its closest members first). (nq, beam)
    i32, -1 = hole (caller falls back to a random draw per hole)."""
    _, top = top_centroids(router, queries, t, backend=backend)  # (nq, t)
    mem = router.members.idx[top]                                # (nq, t, m)
    ent = jnp.moveaxis(mem, 1, 2).reshape(queries.shape[0], -1)  # (nq, m*t)
    if ent.shape[1] >= beam:
        ent = ent[:, :beam]
    else:
        ent = jnp.pad(
            ent, ((0, 0), (0, beam - ent.shape[1])), constant_values=-1
        )
    return ent.astype(jnp.int32)


def router_insert(
    router: Router, ids: jax.Array, q: jax.Array, *, backend: str = "auto"
) -> Router:
    """Incremental insert maintenance: assign each new row to its nearest
    centroid, bump counts, and merge the rows into that centroid's member
    list (grouped via compact_pairs — several inserts may share a
    centroid, so the dense merge is used; c is small)."""
    q = q.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=1)
    d, ci = ops.centroid_assign(
        q, q2, router.centroids, router.c2, t=1, backend=backend
    )
    ci0, d0 = ci[:, 0], d[:, 0]
    assign = router.assign.at[ids].set(ci0, mode="drop")
    counts = router.counts.at[ci0].add(1, mode="drop")
    c = router.centroids.shape[0]
    w = max(1, min(router.members.idx.shape[1], int(ids.shape[0])))
    cd, cid = compact_pairs(ci0, ids.astype(jnp.int32), d0, c, w)
    members, _ = heap.merge(router.members, cd, cid, False, backend=backend)
    return router._replace(
        assign=assign, counts=counts, members=members,
        stale=router.stale + jnp.int32(ids.shape[0]),
    )


def router_delete(
    router: Router, ids: jax.Array, alive: jax.Array, *,
    backend: str = "auto",
) -> Router:
    """Incremental delete maintenance: release assignments, decrement
    counts, purge dead rows from the member lists."""
    old = router.assign[ids]
    valid = old >= 0
    counts = router.counts.at[jnp.where(valid, old, 0)].add(
        -valid.astype(jnp.int32), mode="drop"
    )
    assign = router.assign.at[ids].set(-1, mode="drop")
    members, _ = heap.purge(router.members, alive, backend=backend)
    return router._replace(
        assign=assign, counts=counts, members=members,
        stale=router.stale + jnp.int32(ids.shape[0]),
    )


def needs_rebuild(router: Router, live: int, cfg: RouterConfig) -> bool:
    """Lazy rebuild policy: accumulated insert/delete drift past
    ``rebuild_frac`` of the live count means the centroids no longer
    describe the data — rebuild on the next mutation."""
    return int(router.stale) > cfg.rebuild_frac * max(int(live), 1)
