"""Greedy reordering heuristic — paper §3.2, Algorithm 1, implemented
exactly (one pass over the K-NN graph, simultaneous maintenance of the
permutation and its inverse so no inversion pass is ever needed).

sigma maps node id -> memory position; sigma_inv maps position -> node id.
For each position i we try to place one of the nearest neighbors of THE
NODE CURRENTLY AT POSITION i (ascending distance order, which the bounded
lists already maintain) at position i+1:
    if sigma(t) <  i+1: already well-placed, try next neighbor
    if sigma(t) == i+1: done for this i
    if sigma(t) >  i+1: swap t into position i+1, done for this i

Reading note: the paper's Algorithm 1 prints ``a_i <- sorted(adj_G(i))``,
which taken literally (adjacency of node ID i) provably does NOT cluster
a shuffled input — position i+1 then holds a neighbor of node-id i, and
consecutive node ids are random, so consecutive positions stay random
(we measured purity == 1/c). The text's intent ("whichever node sigma
maps onto i+1 ... should be close in data space to node i", i.e. the
node at SPOT i) and the paper's own Fig. 4 require the chain form
``adj_G(sigma_inv(i))`` — that is what we implement, and it reproduces
Fig. 4 (early-window purity >> 1/c, decaying tail).

On TPU the loop is a lax.fori_loop whose body does O(1) dynamic
scatter-updates (no full-array selects), so the whole pass is O(n*k) like
the paper's. The permutation is then applied ONCE to the point array and
graph state (paper: "the copying itself is done all at once").
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.heap import NeighborLists


@jax.jit
def greedy_reorder(nl: NeighborLists) -> tuple[jax.Array, jax.Array]:
    """Returns (sigma, sigma_inv), each (n,) int32."""
    n, k = nl.idx.shape
    sigma = jnp.arange(n, dtype=jnp.int32)
    sigma_inv = jnp.arange(n, dtype=jnp.int32)

    def inner(j, st):
        sigma, sigma_inv, done, i = st
        # adjacency of the node occupying position i (chain form — see
        # module docstring), neighbors visited in ascending distance
        t = nl.idx[sigma_inv[i], j]
        act = (~done) & (t >= 0)
        st_t = sigma[jnp.clip(t, 0, n - 1)]
        swap = act & (st_t > i + 1)
        stop = act & (st_t == i + 1)
        u = sigma_inv[i + 1]
        # conditional O(1) writes: disabled writes go out of bounds -> drop
        nwrite = jnp.int32(n)
        t_w = jnp.where(swap, t, nwrite)
        u_w = jnp.where(swap, u, nwrite)
        sigma = sigma.at[t_w].set(i + 1, mode="drop")
        sigma = sigma.at[u_w].set(st_t, mode="drop")
        p1_w = jnp.where(swap, i + 1, nwrite)
        p2_w = jnp.where(swap, st_t, nwrite)
        sigma_inv = sigma_inv.at[p1_w].set(t, mode="drop")
        sigma_inv = sigma_inv.at[p2_w].set(u, mode="drop")
        done = done | stop | swap
        return sigma, sigma_inv, done, i

    def body(i, carry):
        sigma, sigma_inv = carry
        sigma, sigma_inv, _, _ = jax.lax.fori_loop(
            0, k, inner, (sigma, sigma_inv, False, i)
        )
        return sigma, sigma_inv

    sigma, sigma_inv = jax.lax.fori_loop(0, n - 1, body, (sigma, sigma_inv))
    return sigma, sigma_inv


@jax.jit
def apply_permutation(
    x: jax.Array, nl: NeighborLists, sigma: jax.Array, sigma_inv: jax.Array
) -> tuple[jax.Array, NeighborLists]:
    """Permute points + graph state into the new memory order (one pass).

    Row at new position p holds old node sigma_inv[p]; neighbor ids are
    rewritten through sigma so the graph stays consistent.
    """
    n = x.shape[0]
    x_new = x[sigma_inv]
    idx = nl.idx[sigma_inv]
    idx = jnp.where(idx >= 0, sigma[jnp.clip(idx, 0, n - 1)], -1)
    return x_new, NeighborLists(nl.dist[sigma_inv], idx, nl.new[sigma_inv])


def locality_stats(nl: NeighborLists, block: int = 128) -> dict:
    """The cachegrind stand-in (DESIGN.md assumption change #3): fraction
    of graph edges whose endpoints fall in the same ``block`` of rows
    (= both ends inside one kernel tile / HBM burst neighborhood) and the
    mean |i - j| gather spread. Higher in-block fraction after reordering
    == the paper's LL-miss reduction."""
    n, k = nl.idx.shape
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
    valid = nl.idx >= 0
    same = (rows // block) == (nl.idx // block)
    frac = jnp.sum(same & valid) / jnp.maximum(jnp.sum(valid), 1)
    # float accumulation: the summed |i-j| exceeds int32 past ~1e5 rows
    spread = jnp.sum(
        jnp.where(valid, jnp.abs(rows - nl.idx), 0).astype(jnp.float32)
    ) / jnp.maximum(jnp.sum(valid), 1)
    return {
        "in_block_fraction": float(frac),
        "mean_gather_spread": float(spread),
        "block": block,
    }


def window_cluster_purity(
    labels: jax.Array, sigma: jax.Array, window: int = 2000, stride: int = 200
):
    """Paper Fig. 4: per-window dominant-cluster fraction along the
    reordered axis. labels: (n,) int cluster ids; sigma: node -> position."""
    n = labels.shape[0]
    order = jnp.zeros((n,), dtype=labels.dtype).at[sigma].set(labels)
    starts = list(range(0, int(n) - window + 1, stride))
    purities = []
    n_clusters = int(jnp.max(labels)) + 1
    for s in starts:
        w = order[s : s + window]
        counts = jnp.bincount(w, length=n_clusters)
        purities.append(float(jnp.max(counts) / window))
    return starts, purities
