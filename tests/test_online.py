"""Online subsystem (core/online.py + serve wiring): insert quality and
cost vs. a full rebuild, tombstone semantics, determinism, frontier
compaction (oracle parity of the chunked gather/scatter dispatch,
O(frontier) delete cost, frontier-vs-dense result parity), and the
growable kNN-LM datastore / scheduler capture path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DescentConfig,
    brute_force_knn,
    build_knn_graph,
    datasets,
    recall_at_k,
)
from repro.core.graph_search import expand_frontier
from repro.core.online import (
    MutableKNNStore,
    OnlineConfig,
    knn_delete,
    knn_insert,
)
from repro.kernels import ref
from repro.kernels.knn_merge import (
    knn_compact_blocked,
    knn_compact_rows_blocked,
    knn_merge_rows_blocked,
)
from repro.serve import ContinuousBatcher, MutableKNNDatastore, Request, knn_logits

K = 10
DCFG = DescentConfig(k=K, rho=1.0, max_iters=15)


@pytest.fixture(scope="module")
def blob_split():
    """~512-point Gaussian-blob corpus + a 10% insert batch (the paper's
    clustered setting, small enough for the fast tier)."""
    x = datasets.clustered(jax.random.key(3), 563, 16, 8)
    return x[:512], x[512:]


@pytest.fixture(scope="module")
def base_store(blob_split):
    x0, _ = blob_split
    dist, idx, _ = build_knn_graph(x0, k=K, cfg=DCFG, key=jax.random.key(1))
    return MutableKNNStore.from_graph(x0, dist, idx, cfg=OnlineConfig())


def test_insert_recall_and_cost(blob_split, base_store):
    """Acceptance criterion: inserting 10% new points reaches >= 0.85
    recall on the combined corpus at < 25% of the distance evaluations of
    a from-scratch build (both counted via DescentStats.dist_evals)."""
    x0, xn = blob_split
    store, ins = knn_insert(base_store, xn, key=jax.random.key(2))
    combined = jnp.concatenate([x0, xn], axis=0)
    _, _, rebuild = build_knn_graph(
        combined, k=K, cfg=DCFG, key=jax.random.key(1))
    _, true_idx = brute_force_knn(combined, combined, K)
    r = recall_at_k(store.nl.idx[:combined.shape[0]], true_idx)
    assert r >= 0.85, r
    assert ins.dist_evals < 0.25 * rebuild.dist_evals, (
        ins.dist_evals, rebuild.dist_evals)


def test_insert_grows_capacity(blob_split, base_store):
    _, xn = blob_split
    assert base_store.capacity == 512
    store, _ = knn_insert(base_store, xn, key=jax.random.key(2))
    assert store.capacity == 1024
    assert store.n == 563
    assert store.live_count() == 563


def test_insert_deterministic(blob_split, base_store):
    _, xn = blob_split
    a, sa = knn_insert(base_store, xn, key=jax.random.key(7))
    b, sb = knn_insert(base_store, xn, key=jax.random.key(7))
    assert jnp.array_equal(a.nl.idx, b.nl.idx)
    assert jnp.array_equal(a.nl.dist, b.nl.dist)
    assert sa.dist_evals == sb.dist_evals


def test_delete_never_returns_tombstoned(blob_split, base_store):
    x0, _ = blob_split
    dead = jnp.arange(0, 64, dtype=jnp.int32)
    store, _ = knn_delete(base_store, dead)
    # no list edge targets a dead node
    tgt = store.nl.idx
    bad = (tgt[:, :, None] == dead[None, None, :]).any(-1) & (tgt >= 0)
    assert int(bad.sum()) == 0
    # queries (including the deleted points themselves) never surface a
    # tombstoned id, and the patched graph still answers fully
    _, idx = store.search(x0[:96], k_out=5, key=jax.random.key(0))
    got = np.asarray(idx)
    assert not np.isin(got[got >= 0], np.asarray(dead)).any()
    assert (got >= 0).mean() == 1.0


def test_delete_then_insert_roundtrip(blob_split, base_store):
    """Tombstoned rows stay dead across later inserts."""
    x0, xn = blob_split
    dead = jnp.asarray([3, 99, 500], jnp.int32)
    store, _ = knn_delete(base_store, dead)
    store, _ = knn_insert(store, xn, key=jax.random.key(2))
    assert not bool(store.alive[dead].any())
    tgt = store.nl.idx
    bad = (tgt[:, :, None] == dead[None, None, :]).any(-1) & (tgt >= 0)
    assert int(bad.sum()) == 0


def test_delete_reconnects_orphaned_rows():
    """A live row whose entire neighborhood dies must keep a non-empty
    list (re-anchored to live rows) instead of dropping out of the graph."""
    key = jax.random.key(0)
    # two far-apart blobs; kill all of blob B except one point
    a = jax.random.normal(key, (96, 8))
    b = 100.0 + jax.random.normal(jax.random.fold_in(key, 1), (32, 8))
    x = jnp.concatenate([a, b])
    dist, idx, _ = build_knn_graph(x, k=8,
                                   cfg=DescentConfig(k=8, rho=1.0,
                                                     max_iters=10),
                                   key=jax.random.key(1))
    store = MutableKNNStore.from_graph(x, dist, idx)
    survivor = 96
    dead = jnp.arange(97, 128, dtype=jnp.int32)
    store, _ = knn_delete(store, dead)
    nbrs = store.nl.idx[survivor]
    assert int((nbrs >= 0).sum()) > 0          # reconnected, not orphaned
    assert bool(store.alive[jnp.clip(nbrs, 0, None)][nbrs >= 0].all())


def test_compact_kernel_matches_oracle():
    rng = np.random.RandomState(0)
    n, k = 37, 8
    d = np.sort(rng.rand(n, k).astype(np.float32), axis=1)
    i = rng.randint(-1, 50, size=(n, k)).astype(np.int32)
    # exercise the init_random placeholder distance (3e38, a valid entry
    # that must survive) and empty slots (inf)
    d[5, -1] = 3.0e38
    i[5, -1] = 42
    d[6, -1] = np.inf
    drop = rng.rand(n, k) < 0.3
    drop[5, -1] = False
    rd, ri, rr = ref.knn_compact(
        jnp.asarray(d), jnp.asarray(i), jnp.asarray(drop))
    kd, ki, kr = knn_compact_blocked(
        jnp.asarray(d), jnp.asarray(i), jnp.asarray(drop), tm=16,
        interpret=True)
    assert jnp.array_equal(ri, ki)
    assert jnp.array_equal(rr, kr)
    assert jnp.array_equal(jnp.isinf(rd), jnp.isinf(kd))
    assert jnp.array_equal(jnp.where(jnp.isinf(rd), 0.0, rd),
                           jnp.where(jnp.isinf(kd), 0.0, kd))


# ---------------------------------------------------------------------------
# frontier compaction (the chunked gather/scatter dispatch)
# ---------------------------------------------------------------------------


def _random_lists(rng, n, k, hi):
    d = np.sort(rng.rand(n, k).astype(np.float32), axis=1)
    i = rng.randint(-1, hi, size=(n, k)).astype(np.int32)
    return jnp.asarray(d), jnp.asarray(i)


def test_merge_rows_kernel_matches_oracle():
    """Chunked gather/scatter merge: pallas (interpret) vs. pure-jnp
    oracle, including padding slots and out-of-frontier passthrough."""
    rng = np.random.RandomState(1)
    n, k, f, c = 41, 6, 16, 9
    cur_d, cur_i = _random_lists(rng, n, k, 60)
    rows = np.full((f,), -1, np.int32)
    picks = rng.choice(n, size=f - 3, replace=False)
    rows[:f - 3] = np.sort(picks)
    cand_d = rng.rand(f, c).astype(np.float32)
    cand_i = rng.randint(-1, 60, size=(f, c)).astype(np.int32)
    args = (cur_d, cur_i, jnp.asarray(rows), jnp.asarray(cand_d),
            jnp.asarray(cand_i))
    rd, ri, ru = ref.knn_merge_rows(*args)
    kd, ki, ku = knn_merge_rows_blocked(*args, tm=8, interpret=True)
    assert jnp.array_equal(ri, ki)
    assert jnp.array_equal(ru, ku)
    assert jnp.allclose(jnp.where(jnp.isinf(rd), 0.0, rd),
                        jnp.where(jnp.isinf(kd), 0.0, kd))
    # rows off the frontier are bit-identical to the input
    off = np.setdiff1d(np.arange(n), rows[rows >= 0])
    assert jnp.array_equal(ri[off], cur_i[off])
    assert jnp.array_equal(rd[off], cur_d[off])


def test_compact_rows_kernel_matches_oracle():
    rng = np.random.RandomState(2)
    n, k, f = 29, 8, 12
    cur_d, cur_i = _random_lists(rng, n, k, 40)
    rows = np.full((f,), -1, np.int32)
    rows[:f - 2] = np.sort(rng.choice(n, size=f - 2, replace=False))
    drop = rng.rand(f, k) < 0.4
    args = (cur_d, cur_i, jnp.asarray(rows), jnp.asarray(drop))
    rd, ri, rr = ref.knn_compact_rows(*args)
    kd, ki, kr = knn_compact_rows_blocked(*args, tm=8, interpret=True)
    assert jnp.array_equal(ri, ki)
    assert jnp.array_equal(rr, kr)
    assert jnp.array_equal(jnp.isinf(rd), jnp.isinf(kd))
    off = np.setdiff1d(np.arange(n), rows[rows >= 0])
    assert jnp.array_equal(ri[off], cur_i[off])


def test_expand_frontier_closure():
    """1- and 2-hop closures over a known tiny graph, with truncation."""
    idx = jnp.asarray([[1, -1], [2, -1], [3, -1], [3, -1]], jnp.int32)
    seeds = jnp.asarray([0], jnp.int32)
    ids1, mask1 = expand_frontier(idx, seeds, hops=1, capacity=4)
    assert np.asarray(ids1).tolist() == [0, 1, -1, -1]
    ids2, mask2 = expand_frontier(idx, seeds, hops=2, capacity=4)
    assert np.asarray(ids2).tolist() == [0, 1, 2, -1]
    # alive filter drops rows; truncation keeps the smallest ids
    alive = jnp.asarray([True, False, True, True])
    ids3, _ = expand_frontier(idx, seeds, hops=3, capacity=2, alive=alive)
    assert np.asarray(ids3).tolist() == [0, 2]
    assert bool(mask1[1]) and not bool(mask1[2])
    assert bool(mask2[2])


def test_delete_refill_touches_o_frontier_rows(blob_split):
    """The tentpole's receipt: delete-refill processes O(frontier) rows —
    the padded-chunk row count tracks the affected set, not the store
    size. The same 8-row delete on a 4x bigger store must process the
    same number of padded rows (and far fewer than the store holds)."""
    x0, _ = blob_split                       # 512 points
    xbig = datasets.clustered(jax.random.key(9), 2048, 16, 8)
    cfg = OnlineConfig(chunk=64)
    dead = jnp.arange(17, 25, dtype=jnp.int32)
    for name, pts in (("small", x0), ("big", xbig)):
        dist, idx, _ = build_knn_graph(pts, k=K, cfg=DCFG,
                                       key=jax.random.key(1))
        store = MutableKNNStore.from_graph(pts, dist, idx, cfg=cfg)
        _, st = knn_delete(store, dead)
        assert st.frontier_rows <= st.padded_rows
        # padding never adds more than one chunk
        assert st.padded_rows <= st.frontier_rows + 64
        # the frontier is the dead rows plus their inbound pointers — a
        # degree-bounded set that does NOT scale with the store: the same
        # bound holds on the 512-row and the 2048-row store
        assert st.frontier_rows <= 4 * int(dead.shape[0]) * K, (
            name, st.frontier_rows)
        assert st.padded_rows < pts.shape[0] // 2, (name, st.padded_rows)


def test_delete_frontier_matches_dense(blob_split, base_store):
    """The dense baseline (frontier=False) and the compacted frontier
    path run the same per-row semantics — results must be identical."""
    dead = jnp.concatenate([
        jnp.arange(0, 40, dtype=jnp.int32),
        jnp.asarray([200, 201, 202, 511], jnp.int32),
    ])
    sf = dataclasses.replace(
        base_store, cfg=dataclasses.replace(base_store.cfg, frontier=True,
                                            chunk=128))
    sd = dataclasses.replace(
        base_store, cfg=dataclasses.replace(base_store.cfg, frontier=False,
                                            chunk=128))
    out_f, st_f = knn_delete(sf, dead)
    out_d, st_d = knn_delete(sd, dead)
    assert jnp.array_equal(out_f.nl.idx, out_d.nl.idx)
    assert jnp.array_equal(out_f.nl.dist, out_d.nl.dist)
    assert jnp.array_equal(out_f.alive, out_d.alive)
    # identical distance work, far fewer rows processed
    assert st_f.dist_evals == st_d.dist_evals
    assert st_f.padded_rows < st_d.padded_rows


def test_insert_reports_frontier_accounting(blob_split, base_store):
    _, xn = blob_split
    store, st = knn_insert(base_store, xn, key=jax.random.key(2))
    assert st.frontier_rows > 0
    assert st.padded_rows >= st.frontier_rows
    # one padded chunk per merge stage at most (the store is smaller than
    # the chunk quantum here, so every stage is capacity-bounded)
    cfg = base_store.cfg
    stages = 2 + 2 * cfg.refine_rounds   # seed + self-join + 2 per round
    assert st.padded_rows <= stages * store.capacity


def test_mutable_datastore_append_changes_retrieval():
    vocab, dk = 16, 8
    keys0 = jax.random.normal(jax.random.key(0), (128, dk))
    vals0 = jax.random.randint(jax.random.key(1), (128,), 0, vocab)
    ds = MutableKNNDatastore.build(keys0, vals0, k=8, key=jax.random.key(2))
    center = jnp.full((dk,), 5.0)
    newk = center + 0.05 * jax.random.normal(jax.random.key(3), (16, dk))
    ds2, _ = ds.append(newk, jnp.full((16,), 7, vals0.dtype),
                       key=jax.random.key(4))
    # the inserted cluster sits far from the base corpus (no inbound
    # edges), so reachability rides on the entry draw: thread an explicit
    # entry key like a serving loop would (see graph_search's key contract)
    lp = knn_logits(ds2, center[None], vocab, k=4, key=jax.random.key(5))
    assert int(jnp.argmax(lp[0])) == 7


def test_scheduler_capture_grows_datastore():
    vocab, dk = 16, 8
    keys0 = jax.random.normal(jax.random.key(0), (64, dk))
    vals0 = jax.random.randint(jax.random.key(1), (64,), 0, vocab)
    ds = MutableKNNDatastore.build(keys0, vals0, k=8, key=jax.random.key(2))
    proj = jax.random.normal(jax.random.key(5), (vocab, dk))

    def prefill_fn(toks):
        return jnp.ones((1, vocab)), None, toks.shape[1]

    def step_fn(cache, toks, lengths):
        lg = jax.nn.one_hot((toks[:, 0] * 3 + lengths) % vocab, vocab) * 4.0
        return lg, cache

    b = ContinuousBatcher(
        2, step_fn, prefill_fn, lambda c, i, o, l: c,
        knn_store=ds, knn_capture=lambda lg: lg @ proj, knn_chunk=8,
        knn_q_block=16)
    # the serving query-block knob rewrites the store's search quantum
    assert b.knn_store.store.cfg.q_block == 16
    for r in range(3):
        b.submit(Request(rid=r, prompt=np.array([1, 2, 3], np.int32),
                         max_new=8))
    b.run(None)
    # 3 requests x 8 tokens, minus the un-captured prefill token each
    assert b.knn_store.store.n == ds.store.n + 21
    assert b.knn_store.store.live_count() == ds.store.n + 21


def test_expand_frontier_overflow_prefers_near_hops():
    """Overflow regression for the frontier truncation: the kept rows
    must be the ones FEWEST hops from the seeds — the old smallest-id
    policy dropped the whole 1-hop ring here in favor of far 2-hop rows
    that happened to carry small ids."""
    idx = jnp.full((10, 2), -1, jnp.int32)
    idx = idx.at[0].set(jnp.asarray([8, 9]))     # seed -> high-id 1-hop
    idx = idx.at[8].set(jnp.asarray([1, 2]))     # ... -> low-id 2-hop
    idx = idx.at[9].set(jnp.asarray([3, -1]))
    seeds = jnp.asarray([0], jnp.int32)
    ids, mask = expand_frontier(idx, seeds, hops=2, capacity=3)
    # closure is {0, 8, 9, 1, 2, 3}; id-biased truncation kept {0, 1, 2}
    assert np.asarray(ids).tolist() == [0, 8, 9]
    # the mask stays exact regardless of truncation
    assert bool(mask[1]) and bool(mask[2]) and bool(mask[3])
    assert int(mask.sum()) == 6


# ---------------------------------------------------------------------------
# degenerate stores: empty / fully tombstoned (robustness hardening)
# ---------------------------------------------------------------------------


def test_empty_store_searches_empty_and_insert_is_first_build():
    """MutableKNNStore.empty: searches answer empty instead of raising,
    and the first insert acts as a first build — the batch self-join
    links the graph so the inserted points retrieve each other."""
    store = MutableKNNStore.empty(16, k=K)
    assert store.n == 0 and store.live_count() == 0
    q = jax.random.normal(jax.random.key(0), (6, 16))
    d, i = store.search(q, k_out=5, key=jax.random.key(1))
    assert (np.asarray(i) == -1).all()
    x = datasets.clustered(jax.random.key(2), 64, 16, 4)
    store, _ = knn_insert(store, x, key=jax.random.key(3))
    assert store.n == 64 and store.live_count() == 64
    _, idx = store.search(x[:16], k_out=1, key=jax.random.key(4))
    assert (np.asarray(idx)[:, 0] == np.arange(16)).all()


def test_empty_store_quantized_insert_roundtrip():
    store = MutableKNNStore.empty(
        16, k=K, cfg=OnlineConfig(precision="int8"))
    x = datasets.clustered(jax.random.key(2), 48, 16, 4)
    store, _ = knn_insert(store, x, key=jax.random.key(3))
    assert store.qs is not None and store.live_count() == 48
    _, idx = store.search(x[:8], k_out=1, key=jax.random.key(4))
    assert (np.asarray(idx)[:, 0] == np.arange(8)).all()


def test_fully_tombstoned_store_insert_relinks(blob_split, base_store):
    """Deleting EVERY row then inserting must behave like a first
    insert: no dead id ever resurfaces, the new batch is retrievable."""
    x0, xn = blob_split
    dead = jnp.arange(base_store.n, dtype=jnp.int32)
    store, _ = knn_delete(base_store, dead)
    assert store.live_count() == 0
    d, i = store.search(x0[:8], k_out=5, key=jax.random.key(0))
    assert (np.asarray(i) == -1).all()
    store, _ = knn_insert(store, xn, key=jax.random.key(1))
    assert store.live_count() == xn.shape[0]
    _, idx = store.search(xn, k_out=1, key=jax.random.key(2))
    got = np.asarray(idx)[:, 0]
    assert (got >= base_store.n).all()      # only the new rows surface
    assert (got == np.arange(xn.shape[0]) + base_store.n).all()
