"""Online subsystem (core/online.py + serve wiring): insert quality and
cost vs. a full rebuild, tombstone semantics, determinism, and the
growable kNN-LM datastore / scheduler capture path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DescentConfig,
    brute_force_knn,
    build_knn_graph,
    datasets,
    recall_at_k,
)
from repro.core.online import (
    MutableKNNStore,
    OnlineConfig,
    knn_delete,
    knn_insert,
)
from repro.kernels import ref
from repro.kernels.knn_merge import knn_compact_blocked
from repro.serve import ContinuousBatcher, MutableKNNDatastore, Request, knn_logits

K = 10
DCFG = DescentConfig(k=K, rho=1.0, max_iters=15)


@pytest.fixture(scope="module")
def blob_split():
    """~512-point Gaussian-blob corpus + a 10% insert batch (the paper's
    clustered setting, small enough for the fast tier)."""
    x = datasets.clustered(jax.random.key(3), 563, 16, 8)
    return x[:512], x[512:]


@pytest.fixture(scope="module")
def base_store(blob_split):
    x0, _ = blob_split
    dist, idx, _ = build_knn_graph(x0, k=K, cfg=DCFG, key=jax.random.key(1))
    return MutableKNNStore.from_graph(x0, dist, idx, cfg=OnlineConfig())


def test_insert_recall_and_cost(blob_split, base_store):
    """Acceptance criterion: inserting 10% new points reaches >= 0.85
    recall on the combined corpus at < 25% of the distance evaluations of
    a from-scratch build (both counted via DescentStats.dist_evals)."""
    x0, xn = blob_split
    store, ins = knn_insert(base_store, xn, key=jax.random.key(2))
    combined = jnp.concatenate([x0, xn], axis=0)
    _, _, rebuild = build_knn_graph(
        combined, k=K, cfg=DCFG, key=jax.random.key(1))
    _, true_idx = brute_force_knn(combined, combined, K)
    r = recall_at_k(store.nl.idx[:combined.shape[0]], true_idx)
    assert r >= 0.85, r
    assert ins.dist_evals < 0.25 * rebuild.dist_evals, (
        ins.dist_evals, rebuild.dist_evals)


def test_insert_grows_capacity(blob_split, base_store):
    _, xn = blob_split
    assert base_store.capacity == 512
    store, _ = knn_insert(base_store, xn, key=jax.random.key(2))
    assert store.capacity == 1024
    assert store.n == 563
    assert store.live_count() == 563


def test_insert_deterministic(blob_split, base_store):
    _, xn = blob_split
    a, sa = knn_insert(base_store, xn, key=jax.random.key(7))
    b, sb = knn_insert(base_store, xn, key=jax.random.key(7))
    assert jnp.array_equal(a.nl.idx, b.nl.idx)
    assert jnp.array_equal(a.nl.dist, b.nl.dist)
    assert sa.dist_evals == sb.dist_evals


def test_delete_never_returns_tombstoned(blob_split, base_store):
    x0, _ = blob_split
    dead = jnp.arange(0, 64, dtype=jnp.int32)
    store, _ = knn_delete(base_store, dead)
    # no list edge targets a dead node
    tgt = store.nl.idx
    bad = (tgt[:, :, None] == dead[None, None, :]).any(-1) & (tgt >= 0)
    assert int(bad.sum()) == 0
    # queries (including the deleted points themselves) never surface a
    # tombstoned id, and the patched graph still answers fully
    _, idx = store.search(x0[:96], k_out=5, key=jax.random.key(0))
    got = np.asarray(idx)
    assert not np.isin(got[got >= 0], np.asarray(dead)).any()
    assert (got >= 0).mean() == 1.0


def test_delete_then_insert_roundtrip(blob_split, base_store):
    """Tombstoned rows stay dead across later inserts."""
    x0, xn = blob_split
    dead = jnp.asarray([3, 99, 500], jnp.int32)
    store, _ = knn_delete(base_store, dead)
    store, _ = knn_insert(store, xn, key=jax.random.key(2))
    assert not bool(store.alive[dead].any())
    tgt = store.nl.idx
    bad = (tgt[:, :, None] == dead[None, None, :]).any(-1) & (tgt >= 0)
    assert int(bad.sum()) == 0


def test_delete_reconnects_orphaned_rows():
    """A live row whose entire neighborhood dies must keep a non-empty
    list (re-anchored to live rows) instead of dropping out of the graph."""
    key = jax.random.key(0)
    # two far-apart blobs; kill all of blob B except one point
    a = jax.random.normal(key, (96, 8))
    b = 100.0 + jax.random.normal(jax.random.fold_in(key, 1), (32, 8))
    x = jnp.concatenate([a, b])
    dist, idx, _ = build_knn_graph(x, k=8,
                                   cfg=DescentConfig(k=8, rho=1.0,
                                                     max_iters=10),
                                   key=jax.random.key(1))
    store = MutableKNNStore.from_graph(x, dist, idx)
    survivor = 96
    dead = jnp.arange(97, 128, dtype=jnp.int32)
    store, _ = knn_delete(store, dead)
    nbrs = store.nl.idx[survivor]
    assert int((nbrs >= 0).sum()) > 0          # reconnected, not orphaned
    assert bool(store.alive[jnp.clip(nbrs, 0, None)][nbrs >= 0].all())


def test_compact_kernel_matches_oracle():
    rng = np.random.RandomState(0)
    n, k = 37, 8
    d = np.sort(rng.rand(n, k).astype(np.float32), axis=1)
    i = rng.randint(-1, 50, size=(n, k)).astype(np.int32)
    # exercise the init_random placeholder distance (3e38, a valid entry
    # that must survive) and empty slots (inf)
    d[5, -1] = 3.0e38
    i[5, -1] = 42
    d[6, -1] = np.inf
    drop = rng.rand(n, k) < 0.3
    drop[5, -1] = False
    rd, ri, rr = ref.knn_compact(
        jnp.asarray(d), jnp.asarray(i), jnp.asarray(drop))
    kd, ki, kr = knn_compact_blocked(
        jnp.asarray(d), jnp.asarray(i), jnp.asarray(drop), tm=16,
        interpret=True)
    assert jnp.array_equal(ri, ki)
    assert jnp.array_equal(rr, kr)
    assert jnp.array_equal(jnp.isinf(rd), jnp.isinf(kd))
    assert jnp.array_equal(jnp.where(jnp.isinf(rd), 0.0, rd),
                           jnp.where(jnp.isinf(kd), 0.0, kd))


def test_mutable_datastore_append_changes_retrieval():
    vocab, dk = 16, 8
    keys0 = jax.random.normal(jax.random.key(0), (128, dk))
    vals0 = jax.random.randint(jax.random.key(1), (128,), 0, vocab)
    ds = MutableKNNDatastore.build(keys0, vals0, k=8, key=jax.random.key(2))
    center = jnp.full((dk,), 5.0)
    newk = center + 0.05 * jax.random.normal(jax.random.key(3), (16, dk))
    ds2, _ = ds.append(newk, jnp.full((16,), 7, vals0.dtype),
                       key=jax.random.key(4))
    lp = knn_logits(ds2, center[None], vocab, k=4)
    assert int(jnp.argmax(lp[0])) == 7


def test_scheduler_capture_grows_datastore():
    vocab, dk = 16, 8
    keys0 = jax.random.normal(jax.random.key(0), (64, dk))
    vals0 = jax.random.randint(jax.random.key(1), (64,), 0, vocab)
    ds = MutableKNNDatastore.build(keys0, vals0, k=8, key=jax.random.key(2))
    proj = jax.random.normal(jax.random.key(5), (vocab, dk))

    def prefill_fn(toks):
        return jnp.ones((1, vocab)), None, toks.shape[1]

    def step_fn(cache, toks, lengths):
        lg = jax.nn.one_hot((toks[:, 0] * 3 + lengths) % vocab, vocab) * 4.0
        return lg, cache

    b = ContinuousBatcher(
        2, step_fn, prefill_fn, lambda c, i, o, l: c,
        knn_store=ds, knn_capture=lambda lg: lg @ proj, knn_chunk=8)
    for r in range(3):
        b.submit(Request(rid=r, prompt=np.array([1, 2, 3], np.int32),
                         max_new=8))
    b.run(None)
    # 3 requests x 8 tokens, minus the un-captured prefill token each
    assert b.knn_store.store.n == ds.store.n + 21
    assert b.knn_store.store.live_count() == ds.store.n + 21
