"""Shared fixtures. NOTE: no XLA_FLAGS here — tests that need multiple
host devices live in test_distributed.py / test_sharding.py which run in
a forked subprocess via the `forked_devices` helper; everything else sees
the real single CPU device (per the dry-run isolation requirement)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def run_with_devices(code: str, n: int = 8, timeout: int = 900) -> str:
    """Run ``code`` in a fresh interpreter with n forced host devices.
    Returns stdout; raises on nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}")
    return r.stdout
