"""Mixed-precision datastore + two-stage distance path (core/quantize.py,
kernels/l2_quant.py, SearchConfig/DescentConfig/OnlineConfig.precision):
quantize/dequantize round-trip error bounds, int8/bf16 kernel-vs-oracle
parity on odd shapes and near-identical points (cancellation guard),
two-stage search parity vs backend="ref" fp32 under tombstones, the
returned-distances-stay-exact contract, and seeded int8 recall pins."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DescentConfig,
    SearchConfig,
    brute_force_knn,
    build_knn_graph,
    datasets,
    dequantize,
    quantize_corpus,
    quantize_sym_int8,
    recall_at_k,
)
from repro.core.graph_search import graph_search
from repro.core.online import MutableKNNStore, OnlineConfig, knn_delete, knn_insert
from repro.core.quantize import QuantizedStore, grow, update_rows
from repro.kernels import ref
from repro.kernels.l2_quant import (
    knn_join_dists_bf16_blocked,
    knn_join_dists_q8_blocked,
    knn_search_dists_bf16_blocked,
    knn_search_dists_q8_blocked,
)

K = 10


# ---------------------------------------------------------------------------
# quantize / dequantize round-trip
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound():
    """Symmetric per-row int8: |x - deq(q)| <= scale/2 elementwise, with
    scale = max|row| / 127 (round-to-nearest), and the cached norms match
    the dequantized rows exactly."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(37, 24).astype(np.float32) * 10.0)
    qs = quantize_corpus(x, "int8")
    deq = np.asarray(dequantize(qs))
    scale = np.abs(np.asarray(x)).max(axis=1) / 127.0
    err = np.abs(deq - np.asarray(x))
    assert (err <= scale[:, None] * 0.5 + 1e-6).all(), err.max()
    # norms are of the STORED rows (self-consistency of the expansion)
    np.testing.assert_allclose(
        np.asarray(qs.x2), (deq * deq).sum(axis=1), rtol=1e-5)


def test_int8_roundtrip_blockwise_and_compression_layout():
    """quantize_sym_int8 with feature blocks bounds error per block; the
    gradient compressor's flat layout is the per-row case."""
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(8, 32).astype(np.float32))
    q, scale = quantize_sym_int8(x, block=8)
    assert q.shape == (8, 32) and scale.shape == (8, 4)
    deq = np.asarray(q, np.float32).reshape(8, 4, 8) * np.asarray(
        scale)[:, :, None]
    err = np.abs(deq.reshape(8, 32) - np.asarray(x))
    assert (err <= np.asarray(scale).repeat(8, axis=1) * 0.5 + 1e-6).all()
    with pytest.raises(ValueError):
        quantize_sym_int8(x, block=7)


def test_bf16_roundtrip_error_bound():
    """bf16 keeps 8 mantissa bits: relative error <= 2^-8 per element."""
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(16, 16).astype(np.float32) * 100.0)
    qs = quantize_corpus(x, "bf16")
    assert qs.mode == "bf16"
    deq = np.asarray(dequantize(qs))
    rel = np.abs(deq - np.asarray(x)) / np.maximum(np.abs(np.asarray(x)),
                                                   1e-6)
    assert rel.max() <= 2.0 ** -8, rel.max()


def test_zero_rows_quantize_finite():
    """All-zero rows hit the scale floor, not a division by zero."""
    qs = quantize_corpus(jnp.zeros((4, 8)), "int8")
    assert np.isfinite(np.asarray(qs.scale)).all()
    np.testing.assert_array_equal(np.asarray(dequantize(qs)),
                                  np.zeros((4, 8)))


def test_update_rows_and_grow():
    """The online-store mirror contract: scatter-quantize in place,
    capacity growth pads with the fp32 store's fill rows."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(8, 16).astype(np.float32))
    qs = quantize_corpus(x, "int8")
    xn = jnp.asarray(rng.randn(2, 16).astype(np.float32))
    qs2 = update_rows(qs, jnp.asarray([1, 5]), xn)
    ref_rows = quantize_corpus(xn, "int8")
    np.testing.assert_array_equal(np.asarray(qs2.data[1]),
                                  np.asarray(ref_rows.data[0]))
    np.testing.assert_array_equal(np.asarray(qs2.data[5]),
                                  np.asarray(ref_rows.data[1]))
    np.testing.assert_array_equal(np.asarray(qs2.data[0]),
                                  np.asarray(qs.data[0]))
    # -1 rows are dropped, not scattered
    qs3 = update_rows(qs, jnp.asarray([-1, 2]), xn)
    np.testing.assert_array_equal(np.asarray(qs3.data[0]),
                                  np.asarray(qs.data[0]))
    g = grow(qs, 16, 1e6)
    assert g.data.shape == (16, 16)
    np.testing.assert_array_equal(np.asarray(g.data[:8]),
                                  np.asarray(qs.data))
    assert float(g.x2[12]) > 1e11     # fill rows stay far away


# ---------------------------------------------------------------------------
# kernel vs oracle parity (interpret mode), odd shapes + cancellation
# ---------------------------------------------------------------------------

def _quant_rows(rng, n, dp):
    x = rng.randn(n, dp).astype(np.float32)
    return quantize_corpus(jnp.asarray(x), "int8")


@pytest.mark.parametrize("nq,w,dp,tq", [
    (37, 23, 16, 16),    # nq not a multiple of the query block, odd W
    (16, 64, 32, 16),    # exact blocks
    (5, 7, 8, 8),        # single padded block
])
def test_search_q8_kernel_matches_oracle(nq, w, dp, tq):
    rng = np.random.RandomState(nq + w)
    qq = _quant_rows(rng, nq, dp)
    cr = _quant_rows(rng, nq * w, dp)
    ids = jnp.asarray(rng.randint(-1, 99, size=(nq, w)).astype(np.int32))
    ids = ids.at[2 % nq].set(-1)
    lin = jnp.arange(nq * w).reshape(nq, w)
    cq, cs = cr.data[lin], cr.scale[lin]
    c2 = jnp.where(ids >= 0, cr.x2[lin], 0.0)
    rd = ref.knn_search_dists_q8(qq.data, qq.scale, qq.x2, cq, cs, c2, ids)
    kd = knn_search_dists_q8_blocked(qq.data, qq.scale, qq.x2, cq, cs, c2,
                                     ids, tq=tq, interpret=True)
    np.testing.assert_array_equal(np.isinf(rd), np.isinf(kd))
    np.testing.assert_allclose(np.where(np.isinf(rd), 0.0, rd),
                               np.where(np.isinf(kd), 0.0, kd),
                               rtol=1e-5, atol=1e-4)
    assert bool(jnp.isinf(kd[2 % nq]).all())


@pytest.mark.parametrize("nq,w,dp,tq", [(37, 23, 16, 16), (5, 7, 8, 8)])
def test_search_bf16_kernel_matches_oracle(nq, w, dp, tq):
    rng = np.random.RandomState(nq)
    q = quantize_corpus(jnp.asarray(rng.randn(nq, dp).astype(np.float32)),
                        "bf16")
    cr = quantize_corpus(
        jnp.asarray(rng.randn(nq * w, dp).astype(np.float32)), "bf16")
    ids = jnp.asarray(rng.randint(-1, 99, size=(nq, w)).astype(np.int32))
    lin = jnp.arange(nq * w).reshape(nq, w)
    cg = cr.data[lin]
    c2 = jnp.where(ids >= 0, cr.x2[lin], 0.0)
    rd = ref.knn_search_dists_bf16(q.data, q.x2, cg, c2, ids)
    kd = knn_search_dists_bf16_blocked(q.data, q.x2, cg, c2, ids, tq=tq,
                                       interpret=True)
    np.testing.assert_array_equal(np.isinf(rd), np.isinf(kd))
    np.testing.assert_allclose(np.where(np.isinf(rd), 0.0, rd),
                               np.where(np.isinf(kd), 0.0, kd),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n,c,cn,dp,tb", [
    (13, 9, 4, 16, 8),    # odd everything
    (8, 6, 6, 8, 8),      # all-new prefix
])
def test_join_q8_kernel_matches_oracle(n, c, cn, dp, tb):
    rng = np.random.RandomState(n + c)
    rows = _quant_rows(rng, n * c, dp)
    ids = jnp.asarray(rng.randint(-1, 50, size=(n, c)).astype(np.int32))
    ids = ids.at[1].set(-1)                     # an all-invalid row
    lin = jnp.arange(n * c).reshape(n, c)
    xq, xs = rows.data[lin], rows.scale[lin]
    x2g = jnp.where(ids >= 0, rows.x2[lin], 0.0)
    rd, rev = ref.knn_join_dists_q8(xq, xs, x2g, ids, cn)
    kd, kev = knn_join_dists_q8_blocked(xq, xs, x2g, ids, cn=cn, tb=tb,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(rev), np.asarray(kev))
    np.testing.assert_array_equal(np.isinf(rd), np.isinf(kd))
    np.testing.assert_allclose(np.where(np.isinf(rd), 0.0, rd),
                               np.where(np.isinf(kd), 0.0, kd),
                               rtol=1e-5, atol=1e-4)
    assert int(kev[1]) == 0


def test_join_bf16_kernel_matches_oracle():
    rng = np.random.RandomState(7)
    n, c, cn, dp = 11, 7, 3, 16
    rows = quantize_corpus(
        jnp.asarray(rng.randn(n * c, dp).astype(np.float32)), "bf16")
    ids = jnp.asarray(rng.randint(-1, 40, size=(n, c)).astype(np.int32))
    lin = jnp.arange(n * c).reshape(n, c)
    xg = rows.data[lin]
    x2g = jnp.where(ids >= 0, rows.x2[lin], 0.0)
    rd, rev = ref.knn_join_dists_bf16(xg, x2g, ids, cn)
    kd, kev = knn_join_dists_bf16_blocked(xg, x2g, ids, cn=cn, tb=8,
                                          interpret=True)
    np.testing.assert_array_equal(np.asarray(rev), np.asarray(kev))
    np.testing.assert_allclose(np.where(np.isinf(rd), 0.0, rd),
                               np.where(np.isinf(kd), 0.0, kd),
                               rtol=1e-5, atol=1e-4)


def test_near_identical_points_cancellation_guard():
    """Near-identical high-norm rows: the quantized expansion must come
    out finite, >= 0 (clamped), tiny for the near-duplicate pair, and
    kernel == oracle. Self-distance (same stored row) must be exactly 0
    before masking — the reason norms are cached from the QUANTIZED rows.
    """
    base = np.full((1, 16), 1000.0, np.float32)
    pts = np.concatenate([base, base + 1e-3, base * -1.0], axis=0)
    qs = quantize_corpus(jnp.asarray(pts), "int8")
    ids = jnp.asarray([[0, 1, 2]], np.int32)
    lin = jnp.arange(3)[None]
    xq, xs = qs.data[lin], qs.scale[lin]
    x2g = qs.x2[lin]
    rd, _ = ref.knn_join_dists_q8(xq, xs, x2g, ids, 3)
    kd, _ = knn_join_dists_q8_blocked(xq, xs, x2g, ids, cn=3, tb=8,
                                      interpret=True)
    valid = np.isfinite(np.asarray(rd))
    assert (np.asarray(rd)[valid] >= 0.0).all()
    np.testing.assert_allclose(np.where(valid, np.asarray(rd), 0.0),
                               np.where(valid, np.asarray(kd), 0.0),
                               rtol=1e-5, atol=1e-4)
    # rows 0/1 quantize to the same int8 codes at this scale: the
    # quantized distance must be exactly 0, never negative garbage
    assert float(rd[0, 0, 1]) < 1e-3
    # the search tile agrees: d(q, q) == 0 for a row scored against itself
    sd = ref.knn_search_dists_q8(
        qs.data[:1], qs.scale[:1], qs.x2[:1], xq, xs, x2g,
        jnp.asarray([[0, 1, 2]], np.int32))
    assert float(sd[0, 0]) == 0.0


# ---------------------------------------------------------------------------
# two-stage search: parity with the fp32 ref oracle + exact distances
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built_store():
    x = datasets.clustered(jax.random.key(0), 512, 16, 4)
    cfg = OnlineConfig(precision="int8")
    store, _ = MutableKNNStore.build(
        x, k=K, cfg=cfg, descent=DescentConfig(k=K, rho=1.0, max_iters=15))
    return x, store


def test_two_stage_tombstone_parity_with_ref(built_store):
    """int8 two-stage search on a store with tombstones: never returns a
    dead or unallocated row, recall stays within 0.03 of the fp32
    backend="ref" oracle at the same budget, and every returned distance
    is the EXACT fp32 distance (the re-rank contract)."""
    x, store = built_store
    store, _ = knn_delete(store, jnp.arange(40, 80))
    q = x[:128] + 0.02 * jax.random.normal(jax.random.key(1), (128, 16))
    key = jax.random.key(2)
    scfg = SearchConfig(beam=32, rounds=24, expand=4, precision="int8")
    d_q, i_q = store.search(q, k_out=K, key=key, cfg=scfg)
    rcfg = SearchConfig(beam=32, rounds=24, backend="ref")
    _, i_r = store.search(q, k_out=K, key=key, cfg=rcfg)

    alive = np.asarray(store.alive)
    i_qn = np.asarray(i_q)
    assert (i_qn < store.capacity).all()
    assert alive[np.where(i_qn >= 0, i_qn, 0)][i_qn >= 0].all()
    assert not np.isin(i_qn, np.arange(40, 80)).any()

    # ground truth over live rows only
    live = np.where(alive[:512])[0]
    _, ti = brute_force_knn(x[jnp.asarray(live)], q, K,
                            exclude_self=False)
    ti = jnp.asarray(live)[ti]
    r_quant = float(recall_at_k(i_q, ti))
    r_ref = float(recall_at_k(i_r, ti))
    assert r_quant >= r_ref - 0.03, (r_quant, r_ref)

    # the re-rank contract: returned distances are exact fp32
    xs = np.asarray(store.x)
    qp = np.zeros((128, xs.shape[1]), np.float32)
    qp[:, :16] = np.asarray(q)
    sel = i_qn >= 0
    true_d = ((qp[:, None, :] - xs[np.where(sel, i_qn, 0)]) ** 2).sum(-1)
    np.testing.assert_allclose(np.asarray(d_q)[sel], true_d[sel],
                               rtol=1e-4, atol=1e-3)


def test_two_stage_all_precisions_shapes(built_store):
    """Odd batch sizes through every precision return valid shapes and
    ascending distances."""
    x, store = built_store
    for prec in ("int8", "bf16"):
        cfg = SearchConfig(beam=16, rounds=8, expand=4, precision=prec)
        d, i = store.search(x[:37] + 0.01, k_out=5, key=jax.random.key(3),
                            cfg=cfg)
        assert d.shape == (37, 5) and i.shape == (37, 5)
        dn = np.asarray(d)
        assert (np.diff(np.where(np.isfinite(dn), dn, 1e30), axis=1)
                >= -1e-6).all()


def test_insert_updates_quantized_mirror(built_store):
    """knn_insert keeps the int8 mirror row-aligned with the fp32 store
    (including across a capacity doubling)."""
    x, store = built_store
    new = datasets.clustered(jax.random.key(4), 600, 16, 4) + 5.0
    store2, _ = knn_insert(store, new, key=jax.random.key(5))
    assert store2.qs is not None
    assert store2.qs.data.shape[0] == store2.capacity
    deq = np.asarray(store2.qs.data, np.float32) * np.asarray(
        store2.qs.scale)[:, None]
    # the mirror stores only the logical dims (zero feature padding
    # dropped — quantize.mirror_width); compare on the mirror's width
    w = store2.qs.data.shape[1]
    xs = np.asarray(store2.x)[:, :w]
    scale = np.abs(xs).max(axis=1) / 127.0
    err = np.abs(deq[:store2.n] - xs[:store2.n])
    assert (err <= scale[:store2.n, None] * 0.5 + 1e-5).all()


def test_seeded_512pt_int8_recall_pin():
    """Seeded end-to-end pin: int8 two-stage search on a 512-pt clustered
    corpus. The fp32 fused pin (test_search) is 0.97; quantized scoring
    may cost a bounded sliver — pin at 0.96."""
    x = datasets.clustered(jax.random.key(11), 512, 32, 4)
    dist, idx, _ = build_knn_graph(
        x, k=K, cfg=DescentConfig(k=K, rho=1.0, max_iters=15),
        key=jax.random.key(12))
    q = x + 0.01 * jax.random.normal(jax.random.key(13), x.shape)
    _, ti = brute_force_knn(x, q, K, exclude_self=False)
    cfg = SearchConfig(beam=32, rounds=24, expand=4, precision="int8")
    _, gi = graph_search(x, idx, q, k_out=K, key=jax.random.key(14),
                         cfg=cfg)
    assert float(recall_at_k(gi, ti)) >= 0.96


def test_quantized_build_recall_and_exact_distances():
    """DescentConfig.precision="int8": the two-stage build stays within
    0.02 recall of the fp32 build on the same corpus/key, and the
    returned graph distances are exact fp32 (rerank_lists + fp32 polish).
    """
    x = datasets.clustered(jax.random.key(21), 512, 16, 4)
    _, ti = brute_force_knn(x, x, K)
    base = DescentConfig(k=K, rho=1.0, max_iters=12)
    _, idx_f, _ = build_knn_graph(x, k=K, cfg=base, key=jax.random.key(22))
    qcfg = dataclasses.replace(base, precision="int8")
    dist_q, idx_q, _ = build_knn_graph(x, k=K, cfg=qcfg,
                                       key=jax.random.key(22))
    r_f = float(recall_at_k(idx_f, ti))
    r_q = float(recall_at_k(idx_q, ti))
    assert r_q >= r_f - 0.02, (r_q, r_f)

    xs = np.asarray(x)
    i_n = np.asarray(idx_q)
    d_n = np.asarray(dist_q)
    sel = i_n >= 0
    true_d = ((xs[:, None, :] - xs[np.where(sel, i_n, 0)]) ** 2).sum(-1)
    np.testing.assert_allclose(d_n[sel], true_d[sel], rtol=1e-4, atol=1e-3)


def test_ref_backend_ignores_precision():
    """backend="ref" is the fp32 oracle: precision must be a no-op."""
    x = datasets.clustered(jax.random.key(31), 256, 8, 2)
    _, idx, _ = build_knn_graph(
        x, k=K, cfg=DescentConfig(k=K, rho=1.0, max_iters=8),
        key=jax.random.key(32))
    q = x[:32]
    key = jax.random.key(33)
    d0, i0 = graph_search(x, idx, q, k_out=5, key=key,
                          cfg=SearchConfig(backend="ref"))
    d1, i1 = graph_search(x, idx, q, k_out=5, key=key,
                          cfg=SearchConfig(backend="ref", precision="int8"))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_knn_lm_precision_datastores():
    """KNNDatastore.build(precision=...) caches a quantized mirror whose
    mode drives knn_logits' two-stage search — at the CALL's beam/rounds
    (no pinned cfg silently overriding the budget) — and returns finite
    log-probs."""
    from repro.serve.knn_lm import KNNDatastore, knn_logits
    x = datasets.clustered(jax.random.key(41), 256, 16, 2)
    vals = jax.random.randint(jax.random.key(42), (256,), 0, 50)
    ds = KNNDatastore.build(x, vals, k=8, precision="int8",
                            cfg=DescentConfig(k=8, rho=1.0, max_iters=6))
    assert ds.qstore is not None and ds.qstore.mode == "int8"
    assert ds.search_cfg is None     # precision rides on the mirror
    lp = knn_logits(ds, x[:16], vocab=50, k=4, beam=24, rounds=16,
                    key=jax.random.key(43))
    assert lp.shape == (16, 50)
    assert bool(jnp.isfinite(lp).all())


def test_search_wrong_mode_cache_requantizes():
    """A cached mirror of the WRONG mode must not be scored as raw codes
    by the other kernel: graph_search re-quantizes fresh, so recall
    matches a cache-free quantized search exactly."""
    x = datasets.clustered(jax.random.key(61), 256, 8, 2)
    _, idx, _ = build_knn_graph(
        x, k=8, cfg=DescentConfig(k=8, rho=1.0, max_iters=8),
        key=jax.random.key(62))
    key = jax.random.key(63)
    cfg = SearchConfig(beam=16, rounds=16, expand=4, precision="bf16")
    wrong = quantize_corpus(x, "int8")       # int8 cache, bf16 search
    d0, i0 = graph_search(x, idx, x[:32], k_out=5, key=key, cfg=cfg)
    d1, i1 = graph_search(x, idx, x[:32], k_out=5, key=key, cfg=cfg,
                          qstore=wrong)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.slow
def test_graph_search_sharded_threads_precision():
    """cfg.precision flows through the sharded serving entry: each shard
    quantizes its local rows inside the shard_map body and re-ranks fp32,
    so the merged global top-k carries exact distances. Single-device
    mesh — the tracing/threading is what is under test. Slow tier like
    every shard_map test (the dev container's jax lacks jax.shard_map)."""
    from jax.sharding import Mesh

    from repro.core.distributed import graph_search_sharded
    x = datasets.clustered(jax.random.key(51), 256, 8, 2)
    _, idx, _ = build_knn_graph(
        x, k=8, cfg=DescentConfig(k=8, rho=1.0, max_iters=6),
        key=jax.random.key(52))
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    q = x[:16] + 0.01
    cfg = SearchConfig(beam=16, rounds=8, expand=4, precision="int8")
    d, i = graph_search_sharded(mesh, x, idx, q, k_out=5, cfg=cfg,
                                key=jax.random.key(53))
    assert d.shape == (16, 5) and i.shape == (16, 5)
    xs, i_n, d_n = np.asarray(x), np.asarray(i), np.asarray(d)
    sel = i_n >= 0
    true_d = ((np.asarray(q)[:, None, :] - xs[np.where(sel, i_n, 0)]) ** 2
              ).sum(-1)
    np.testing.assert_allclose(d_n[sel], true_d[sel], rtol=1e-4, atol=1e-3)


def test_pytree_roundtrip():
    """QuantizedStore must pass through jit as a pytree."""
    qs = quantize_corpus(jnp.ones((4, 8)), "int8")
    out = jax.jit(lambda s: QuantizedStore(s.data, s.scale * 2.0, s.x2))(qs)
    assert out.mode == "int8"
    np.testing.assert_allclose(np.asarray(out.scale),
                               np.asarray(qs.scale) * 2.0)
