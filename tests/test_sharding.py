"""Sharding rules + HLO cost analyzer unit tests (single device)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_cost import analyze, parse_module
from repro.models.sharding import logical_to_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_fsdp_tp():
    spec = logical_to_spec(("d_model", "d_ff"), SINGLE, dims=(4096, 11008))
    assert spec == P("data", "model")


def test_batch_multi_pod():
    spec = logical_to_spec(("batch", None), MULTI, dims=(256, 4096))
    assert spec == P(("pod", "data"), None)


def test_divisibility_fallback():
    # 14 heads don't divide the 16-way model axis -> replicated
    spec = logical_to_spec(("d_model", "heads", None), SINGLE,
                           dims=(896, 14, 64))
    assert spec == P("data", None, None)


def test_kv_seq_falls_to_model_when_data_taken():
    # decode_32k: batch takes data, kv_seq falls through to model
    spec = logical_to_spec(("batch", "kv_seq", "kv_heads", None), SINGLE,
                           dims=(128, 32768, 4, 128))
    assert spec == P("data", "model", None, None)


def test_kv_seq_prefers_data_when_free():
    # long_500k: batch=1 can't shard -> kv_seq gets data, heads get model
    spec = logical_to_spec(("batch", "kv_seq", "kv_heads", None), SINGLE,
                           dims=(1, 524288, 16, 128))
    assert spec == P(None, "data", "model", None)


def test_expert_cap_uses_both_axes_when_no_ep():
    # granite: 40 experts don't divide model -> capacity spans data+model
    spec = logical_to_spec(("experts", "expert_cap", None), SINGLE,
                           dims=(40, 262144, 1536))
    assert spec == P(None, ("data", "model"), None)


def test_ep_when_divisible():
    spec = logical_to_spec(("experts", "expert_cap", None), SINGLE,
                           dims=(64, 122880, 2048))
    assert spec == P("model", "data", None)


# ---------------------------------------------------------------------------
# HLO cost analyzer
# ---------------------------------------------------------------------------

def test_analyzer_matches_xla_on_loop_free():
    def f(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2
    sh = jax.ShapeDtypeStruct
    c = jax.jit(f).lower(sh((256, 512), jnp.float32),
                         sh((512, 1024), jnp.float32),
                         sh((1024, 256), jnp.float32)).compile()
    got = analyze(c.as_text())
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert abs(got.flops - ca["flops"]) / ca["flops"] < 0.02
    assert abs(got.bytes - ca["bytes accessed"]) / ca["bytes accessed"] < 0.3


def test_analyzer_multiplies_trip_counts():
    def f(x):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    got = analyze(c.as_text())
    want = 10 * 2 * 128 ** 3
    assert abs(got.flops - want) / want < 0.01


def test_analyzer_nested_loops():
    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    got = analyze(c.as_text())
    want = 15 * 2 * 64 ** 3
    assert abs(got.flops - want) / want < 0.01


def test_analyzer_sliced_scan_weights_not_overcounted():
    """The scan-stacked-weights case: per-iteration traffic must reflect
    the SLICE, not the full stack (the fusion aliasing fix)."""
    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c
    sh = jax.ShapeDtypeStruct
    c = jax.jit(f).lower(sh((6, 256, 256), jnp.float32),
                         sh((256, 256), jnp.float32)).compile()
    got = analyze(c.as_text())
    ideal = 6 * 3 * 256 * 256 * 4        # per iter: read w, read c, write c
    assert got.bytes < 6 * ideal, got.bytes   # not the 24x naive blowup


def test_parse_module_computation_count():
    def f(x):
        return jnp.sum(jnp.tanh(x) ** 2)
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64,), jnp.float32)).compile()
    comps = parse_module(c.as_text())
    assert any(n.startswith("main") for n in comps)
