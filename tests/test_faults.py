"""core/faults.py + the graceful-degradation sites it scripts:
deterministic fault accounting, snapshot-write retries, torn-snapshot
restore fallback with quarantine, and poisoned-batch manufacture."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults, persist
from repro.core.faults import FaultPlan, FaultSpec, InjectedFault
from repro.core.online import MutableKNNStore, OnlineConfig


def _store(n=64, d=8, k=6):
    x = jax.random.normal(jax.random.key(0), (n, d), jnp.float32)
    store, _ = MutableKNNStore.build(x, k=k, cfg=OnlineConfig(),
                                     key=jax.random.key(1))
    return store


def test_plan_off_by_default():
    assert faults.fire("persist.write") is None
    assert faults.dead_shards(4) == []


def test_plan_times_and_after_accounting():
    plan = FaultPlan(specs=(
        FaultSpec(site="persist.write", after=1, times=2),
    ))
    with plan.active():
        hits = [faults.fire("persist.write") is not None for _ in range(5)]
    # event 0 skipped (after=1), events 1 and 2 fire (times=2), then done
    assert hits == [False, True, True, False, False]
    assert plan.fired("persist.write") == 2
    # deactivated on context exit
    assert faults.fire("persist.write") is None


def test_plan_prob_deterministic():
    def run(seed):
        plan = FaultPlan(seed=seed, specs=(
            FaultSpec(site="persist.write", prob=0.5),
        ))
        with plan.active():
            return [faults.fire("persist.write") is not None
                    for _ in range(32)]
    a, b = run(7), run(7)
    assert a == b                      # same seed → same schedule
    assert any(a) and not all(a)       # prob actually gates
    assert run(8) != a                 # different seed → different draws


def test_dead_shards_merges_dead_and_slow():
    plan = FaultPlan(specs=(
        FaultSpec(site="shard.dead", arg=1),
        FaultSpec(site="shard.slow", arg=[3, 99]),   # 99 out of range
    ))
    with plan.active():
        assert faults.dead_shards(4) == [1, 3]


def test_poison_batch_modes():
    q = np.zeros((8, 4), np.float32)
    nanb = faults.poison_batch(q, "nan")
    infb = faults.poison_batch(q, "inf")
    dimb = faults.poison_batch(q, "dim")
    assert np.isnan(nanb).any() and np.isfinite(nanb[-1]).all()
    assert np.isinf(infb).any()
    assert dimb.shape == (8, 5)
    with pytest.raises(ValueError, match="poison mode"):
        faults.poison_batch(q, "nope")


def test_writer_retry_absorbs_transient_error(tmp_path):
    """An injected write failure on the first attempt is retried with
    backoff and the snapshot still commits — no error surfaces."""
    store = _store()
    w = persist.SnapshotWriter(str(tmp_path), retries=2, backoff_s=0.01)
    plan = FaultPlan(specs=(FaultSpec(site="persist.write", times=1),))
    with plan.active():
        w.save(store, 1, wait=True)
    assert plan.fired("persist.write") == 1
    assert persist.list_snapshots(str(tmp_path)) == [1]


def test_writer_surfaces_persistent_error(tmp_path):
    """More consecutive failures than retries → the error surfaces, and
    no partial directory is visible to loads."""
    store = _store()
    w = persist.SnapshotWriter(str(tmp_path), retries=1, backoff_s=0.01)
    plan = FaultPlan(specs=(FaultSpec(site="persist.write", times=5),))
    with plan.active(), pytest.raises(InjectedFault):
        w.save(store, 1, wait=True)
    assert persist.list_snapshots(str(tmp_path)) == []


def test_restore_falls_back_past_torn_snapshot(tmp_path):
    """The newest committed snapshot has a torn array file: restore
    quarantines it by rename (never deletes) and lands on the next-older
    committed step, bit-identically."""
    store = _store()
    persist.snapshot_store(store, str(tmp_path), 1)
    from repro.core.online import knn_insert
    extra = jax.random.normal(jax.random.key(9), (5, 8), jnp.float32)
    store2, _ = knn_insert(store, extra, key=jax.random.key(10))
    plan = FaultPlan(specs=(FaultSpec(site="persist.torn", arg="x.npy"),))
    with plan.active():
        persist.snapshot_store(store2, str(tmp_path), 2)
    assert persist.list_snapshots(str(tmp_path)) == [1, 2]
    with pytest.warns(RuntimeWarning, match="quarantined"):
        r = persist.restore_store(str(tmp_path))
    assert r.step == 1
    assert r.fallback_from == (2,)
    assert (np.asarray(r.store.x) == np.asarray(store.x)).all()
    assert (np.asarray(r.store.nl.idx) == np.asarray(store.nl.idx)).all()
    # the torn directory was renamed aside, not deleted
    assert persist.list_snapshots(str(tmp_path)) == [1]
    assert os.path.isdir(os.path.join(str(tmp_path), "step_00000002.bad"))


def test_restore_fallback_survives_failed_quarantine(tmp_path):
    """Quarantine rename injected to fail: the bad snapshot stays in
    place, the fallback still lands on the older committed step."""
    store = _store()
    persist.snapshot_store(store, str(tmp_path), 1)
    plan = FaultPlan(specs=(
        FaultSpec(site="persist.torn", arg="x.npy"),
        FaultSpec(site="persist.rename"),
    ))
    with plan.active():
        persist.snapshot_store(store, str(tmp_path), 2)
        with pytest.warns(RuntimeWarning, match="could not be quarantined"):
            r = persist.restore_store(str(tmp_path))
    assert r.step == 1
    assert os.path.isdir(os.path.join(str(tmp_path), "step_00000002"))


def test_restore_all_bad_raises(tmp_path):
    store = _store()
    plan = FaultPlan(specs=(FaultSpec(site="persist.torn", arg="x.npy"),))
    with plan.active():
        persist.snapshot_store(store, str(tmp_path), 1)
    with pytest.warns(RuntimeWarning), \
            pytest.raises(persist.SnapshotError, match="every committed"):
        persist.restore_store(str(tmp_path))


def test_explicit_step_fails_hard_no_fallback(tmp_path):
    """An explicit step is a request for those exact bytes — corruption
    raises instead of silently answering from another step."""
    store = _store()
    persist.snapshot_store(store, str(tmp_path), 1)
    plan = FaultPlan(specs=(FaultSpec(site="persist.torn", arg="x.npy"),))
    with plan.active():
        persist.snapshot_store(store, str(tmp_path), 2)
    with pytest.raises(persist.SnapshotError):
        persist.restore_store(str(tmp_path), step=2)
    # nothing quarantined on the explicit-step path
    assert persist.list_snapshots(str(tmp_path)) == [1, 2]
