"""Fused local join (kernels/knn_join.py + core/nn_descent.py
local_join_fused): kernel-vs-oracle parity, end-to-end parity against the
retained compact_pairs+heap.merge lexsort path, and the quality pin on
the seeded 512-pt regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import datasets, heap
from repro.core.layout import pad_features
from repro.core.nn_descent import (
    DescentConfig,
    build_knn_graph,
    compact_pairs,
    invert_candidates,
    local_join_fused,
    nn_descent_iteration,
    pair_block,
    polish_iteration,
)
from repro.core.recall import brute_force_knn, recall_at_k
from repro.kernels import ref
from repro.kernels.knn_join import (
    knn_join_dists_blocked,
    knn_join_select_blocked,
)


def _assert_lists_match(got_d, got_i, want_d, want_i, atol=1e-4):
    """Neighbor lists equal: idx exact, dist within fp32 tolerance."""
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    gd = np.where(np.isinf(got_d), 0.0, np.asarray(got_d))
    wd = np.where(np.isinf(want_d), 0.0, np.asarray(want_d))
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=atol)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,c,cn,dp,tb", [
    (37, 12, 5, 16, 16),     # n not a multiple of the row block
    (64, 8, 8, 32, 32),      # all candidates "new"
    (10, 6, 0, 8, 4),        # all candidates "old" -> no valid pairs
])
def test_join_dists_kernel_matches_oracle(n, c, cn, dp, tb):
    rng = np.random.RandomState(n + c)
    xg = jnp.asarray(rng.randn(n, c, dp).astype(np.float32))
    ids = jnp.asarray(rng.randint(-1, 4 * n, size=(n, c)).astype(np.int32))
    ids = ids.at[3].set(-1)                      # an all-invalid row
    x2g = jnp.where(ids >= 0, jnp.sum(xg * xg, axis=-1), 0.0)
    rd, rev = ref.knn_join_dists(xg, x2g, ids, cn)
    kd, kev = knn_join_dists_blocked(xg, x2g, ids, cn=cn, tb=tb,
                                     interpret=True)
    np.testing.assert_array_equal(np.isinf(rd), np.isinf(kd))
    np.testing.assert_allclose(np.where(np.isinf(rd), 0.0, rd),
                               np.where(np.isinf(kd), 0.0, kd),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(rev, kev)
    assert int(rev[3]) == 0
    if cn == 0:
        assert int(rev.sum()) == 0               # old x old never evaluated


@pytest.mark.parametrize("n,w,c,tr", [
    (37, 23, 9, 16),         # n not a multiple of the row block
    (16, 5, 12, 8),          # c > W (padded selection)
    (50, 40, 40, 32),        # c == W
])
def test_join_select_kernel_matches_oracle(n, w, c, tr):
    rng = np.random.RandomState(n + w)
    gd = jnp.asarray(rng.rand(n, w).astype(np.float32))
    gd = jnp.where(jnp.asarray(rng.rand(n, w) < 0.2), jnp.inf, gd)
    gi = jnp.asarray(rng.randint(-1, 99, size=(n, w)).astype(np.int32))
    kth = jnp.asarray(rng.rand(n).astype(np.float32) * 1.5)
    sd, si = ref.knn_join_select(gd, gi, kth, c)
    bd, bi = knn_join_select_blocked(gd, gi, kth, c=c, tr=tr,
                                     interpret=True)
    np.testing.assert_array_equal(si, bi)
    np.testing.assert_array_equal(np.isinf(sd), np.isinf(bd))
    np.testing.assert_allclose(np.where(np.isinf(sd), 0.0, sd),
                               np.where(np.isinf(bd), 0.0, bd), rtol=1e-6)


def test_join_select_prefilter_strict():
    """Only candidates strictly better than kth survive (ties rejected,
    matching the lexsort path's `dd < kth` prefilter)."""
    gd = jnp.asarray([[0.5, 0.3, 0.7]], jnp.float32)
    gi = jnp.asarray([[1, 2, 3]], jnp.int32)
    kth = jnp.asarray([0.5], jnp.float32)
    sd, si = ref.knn_join_select(gd, gi, kth, 3)
    assert np.asarray(si).tolist() == [[2, -1, -1]]
    bd, bi = knn_join_select_blocked(gd, gi, kth, c=3, tr=8, interpret=True)
    np.testing.assert_array_equal(si, bi)


def test_invert_candidates_roundtrip():
    """Every (row, slot) incidence lands in its candidate's buffer, in
    (row, slot) order, with -1 padding after."""
    cands = jnp.asarray([[2, 0, -1], [2, 2, 1], [0, -1, 0]], jnp.int32)
    rows_of, slot_of = invert_candidates(cands, 3, 4)
    r = np.asarray(rows_of)
    s = np.asarray(slot_of)
    assert r[0].tolist() == [0, 2, 2, -1] and s[0].tolist() == [1, 0, 2, -1]
    assert r[1].tolist() == [1, -1, -1, -1] and s[1].tolist() == [2, -1, -1, -1]
    assert r[2].tolist() == [0, 1, 1, -1] and s[2].tolist() == [0, 0, 1, -1]
    # overflow keeps the smallest (row, slot) incidences
    rows_of, slot_of = invert_candidates(cands, 3, 2)
    assert np.asarray(rows_of)[0].tolist() == [0, 2]


# ---------------------------------------------------------------------------
# fused local join vs the retained lexsort path (compact_pairs + merge)
# ---------------------------------------------------------------------------

def _ref_local_join(x, x2, nl, cn, co, cfg):
    """The seed pipeline (nn_descent_iteration's backend="ref" body),
    replicated as the oracle: flatten pairs -> prefilter -> global
    (receiver, dist) lexsort -> dense merge."""
    n, k = nl.idx.shape
    vn = cn >= 0
    vo = co >= 0
    xg_n = x[jnp.where(vn, cn, 0)]
    xg_o = x[jnp.where(vo, co, 0)]
    x2_n = jnp.where(vn, x2[jnp.where(vn, cn, 0)], 0.0)
    x2_o = jnp.where(vo, x2[jnp.where(vo, co, 0)], 0.0)
    d_nn = pair_block(xg_n, x2_n, xg_n, x2_n)
    d_no = pair_block(xg_n, x2_n, xg_o, x2_o)
    cn_b, co_b = cn.shape[1], co.shape[1]
    iu = jnp.triu_indices(cn_b, k=1)
    a_nn, b_nn = cn[:, iu[0]], cn[:, iu[1]]
    dd_nn = d_nn[:, iu[0], iu[1]]
    ok_nn = vn[:, iu[0]] & vn[:, iu[1]] & (a_nn != b_nn)
    a_no = jnp.broadcast_to(cn[:, :, None], (n, cn_b, co_b)).reshape(n, -1)
    b_no = jnp.broadcast_to(co[:, None, :], (n, cn_b, co_b)).reshape(n, -1)
    dd_no = d_no.reshape(n, -1)
    ok_no = (
        jnp.broadcast_to(vn[:, :, None], (n, cn_b, co_b)).reshape(n, -1)
        & jnp.broadcast_to(vo[:, None, :], (n, cn_b, co_b)).reshape(n, -1)
        & (a_no != b_no)
    )
    a = jnp.concatenate([a_nn, b_nn, a_no, b_no], axis=1).reshape(-1)
    b = jnp.concatenate([b_nn, a_nn, b_no, a_no], axis=1).reshape(-1)
    dd = jnp.concatenate([dd_nn, dd_nn, dd_no, dd_no], axis=1).reshape(-1)
    ok = jnp.concatenate([ok_nn, ok_nn, ok_no, ok_no], axis=1).reshape(-1)
    kth = nl.dist[:, -1]
    ok &= dd < kth[jnp.where(ok, a, 0)]
    recv = jnp.where(ok, a, -1)
    cand_d, cand_i = compact_pairs(recv, b, dd, n, cfg.merge_k)
    nl, upd = heap.merge(nl, cand_d, cand_i, cand_new=True)
    return nl, jnp.sum(upd), jnp.sum(ok_nn) + jnp.sum(ok_no)


@pytest.mark.parametrize("n,k,chunk", [
    (150, 8, 64),     # n not a multiple of the receiver chunk
    (64, 6, 64),      # single exact chunk
    (97, 5, 256),     # chunk larger than n
])
def test_fused_join_matches_lexsort_path(n, k, chunk):
    """idx exact / dist within fp32 tol / upd+evals exact vs. the
    compact_pairs oracle, including all-invalid candidate rows and
    C < merge_k."""
    rng = np.random.RandomState(n)
    x = jnp.asarray(rng.randn(n, 24).astype(np.float32))
    xp = pad_features(x)
    x2 = jnp.sum(xp * xp, axis=1)
    nl = heap.init_random_with_dists(jax.random.key(1), xp, k)
    c_half = k  # C = 2k < merge_k = 3k
    cn = rng.randint(-1, n, size=(n, c_half)).astype(np.int32)
    co = rng.randint(-1, n, size=(n, c_half)).astype(np.int32)
    cn[5] = -1
    co[5] = -1                                  # all-invalid candidate row
    co[6] = -1                                  # new-only row
    cn, co = jnp.asarray(cn), jnp.asarray(co)
    cfg = DescentConfig(k=k, join_chunk=chunk, join_src=4 * 2 * c_half)
    got_nl, got_upd, got_ev = jax.jit(
        local_join_fused, static_argnames=("cfg",)
    )(xp, x2, nl, cn, co, cfg)
    want_nl, want_upd, want_ev = _ref_local_join(xp, x2, nl, cn, co, cfg)
    _assert_lists_match(got_nl.dist, got_nl.idx, want_nl.dist, want_nl.idx)
    assert int(got_upd) == int(want_upd)
    assert int(got_ev) == int(want_ev)


def test_fused_iteration_matches_ref_backend():
    """One full nn_descent_iteration, fused vs backend='ref', same key:
    identical selection -> identical lists/counts."""
    x = datasets.clustered(jax.random.key(0), 300, 16, 4)
    xp = pad_features(x.astype(jnp.float32))
    x2 = jnp.sum(xp * xp, axis=1)
    nl0 = heap.init_random_with_dists(jax.random.key(2), xp, 8)
    key = jax.random.key(3)
    cfg = DescentConfig(k=8, rho=1.0, join_src=64)
    nlf, uf, ef = nn_descent_iteration(key, xp, x2, nl0, cfg)
    nlr, ur, er = nn_descent_iteration(
        key, xp, x2, nl0, dataclasses.replace(cfg, backend="ref"))
    _assert_lists_match(nlf.dist, nlf.idx, nlr.dist, nlr.idx)
    assert int(uf) == int(ur)
    assert int(ef) == int(er)


def test_fused_polish_matches_ref_backend():
    """polish_iteration fused-select vs direct full-width merge."""
    x = datasets.gaussian(jax.random.key(4), 256, 16)
    xp = pad_features(x.astype(jnp.float32))
    x2 = jnp.sum(xp * xp, axis=1)
    nl = heap.init_random_with_dists(jax.random.key(6), xp, 6)
    nlf, uf, ef = polish_iteration(xp, x2, nl, "auto")
    nlr, ur, er = polish_iteration(xp, x2, nl, "ref")
    _assert_lists_match(nlf.dist, nlf.idx, nlr.dist, nlr.idx)
    assert int(ef) == int(er)
    assert int(uf) == int(ur)


def test_fused_build_deterministic_and_seeded_recall():
    """Acceptance pin: the fused build path reaches recall >= 0.993 on
    the seeded 512-pt regression (the lexsort path's measured value),
    and stays deterministic given the key."""
    x = datasets.clustered(jax.random.key(11), 512, 16, 8)
    _, ti = brute_force_knn(x, x, 10)
    cfg = DescentConfig(k=10, rho=1.0, max_iters=15)
    _, idx, _ = build_knn_graph(x, k=10, cfg=cfg, key=jax.random.key(5))
    r = recall_at_k(idx, ti)
    assert r >= 0.993, r
    _, idx2, _ = build_knn_graph(x, k=10, cfg=cfg, key=jax.random.key(5))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))


def test_invert_candidates_overflow_prefers_near_pairs():
    """Distance-prioritized overflow: when a candidate's incidence buffer
    overflows, the kept incidences must be the NEAREST sources, not the
    smallest (row, slot) — the id-biased policy systematically dropped
    late close pairs on hub-heavy rounds."""
    # 8 rows all propose candidate 0; priorities DECREASE with row id, so
    # the id-biased policy keeps exactly the wrong half
    cands = jnp.zeros((8, 1), jnp.int32)
    prio = jnp.asarray(np.arange(8, 0, -1, dtype=np.float32)).reshape(8, 1)
    rows_of, _ = invert_candidates(cands, 1, 4)
    assert sorted(np.asarray(rows_of)[0].tolist()) == [0, 1, 2, 3]
    rows_of, slot_of = invert_candidates(cands, 1, 4, prio=prio)
    kept = np.asarray(rows_of)[0]
    assert sorted(kept.tolist()) == [4, 5, 6, 7], kept
    assert (np.asarray(slot_of)[0] == 0).all()
    # no-overflow behavior is unchanged by a prio argument
    r1, s1 = invert_candidates(cands, 1, 8)
    r2, s2 = invert_candidates(cands, 1, 8, prio=prio)
    assert sorted(np.asarray(r1)[0].tolist()) == sorted(
        np.asarray(r2)[0].tolist())
