"""Hypothesis property tests on the system's invariants.

``hypothesis`` is an optional test dependency (see pyproject.toml);
skip the whole module instead of erroring collection when it's absent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import heap, selection
from repro.core.heap import NeighborLists
from repro.core.reorder import greedy_reorder
from repro.kernels import ref
from repro.train.compression import dequantize_int8, quantize_int8

_settings = settings(max_examples=25, deadline=None)


@given(
    n=st.integers(2, 40), k=st.integers(1, 8), c=st.integers(1, 10),
    seed=st.integers(0, 2**16),
)
@_settings
def test_merge_invariants(n, k, c, seed):
    """Merged lists are sorted, dedup'd, and the update count equals the
    number of NEW ids that entered the list."""
    rng = np.random.RandomState(seed)
    cur_d = np.sort(rng.rand(n, k).astype(np.float32), axis=1)
    cur_i = np.zeros((n, k), np.int32)
    for r in range(n):
        cur_i[r] = rng.choice(10 * n, size=k, replace=False)
    cand_d = rng.rand(n, c).astype(np.float32)
    cand_i = rng.randint(-1, 10 * n, size=(n, c)).astype(np.int32)
    nl = NeighborLists(jnp.asarray(cur_d), jnp.asarray(cur_i),
                       jnp.zeros((n, k), bool))
    out, upd = heap.merge(nl, jnp.asarray(cand_d), jnp.asarray(cand_i))
    d = np.asarray(out.dist)
    i = np.asarray(out.idx)
    # sorted
    assert (np.diff(d, axis=1) >= 0).all()
    # dedup within each row (ignore empty)
    for r in range(n):
        ids = i[r][i[r] >= 0]
        assert len(set(ids.tolist())) == len(ids)
    # update count == #new ids present that were not in the old list
    for r in range(n):
        newcomers = set(i[r][i[r] >= 0].tolist()) - set(cur_i[r].tolist())
        assert int(upd[r]) == len(newcomers)


@given(n=st.integers(2, 64), k=st.integers(1, 6), seed=st.integers(0, 999))
@_settings
def test_greedy_reorder_always_permutation(n, k, seed):
    """Algorithm 1 must output a valid permutation + exact inverse for ANY
    graph (including self-loops / duplicate neighbor ids)."""
    rng = np.random.RandomState(seed)
    idx = rng.randint(-1, n, size=(n, k)).astype(np.int32)
    dist = np.sort(rng.rand(n, k).astype(np.float32), axis=1)
    nl = NeighborLists(jnp.asarray(dist), jnp.asarray(idx),
                       jnp.zeros((n, k), bool))
    sigma, sigma_inv = jax.jit(greedy_reorder)(nl)
    s = np.asarray(sigma)
    si = np.asarray(sigma_inv)
    assert sorted(s.tolist()) == list(range(n))
    assert (s[si] == np.arange(n)).all()


@given(
    m=st.integers(1, 24), nn=st.integers(1, 24), d=st.integers(1, 40),
    seed=st.integers(0, 999),
)
@_settings
def test_norm_expansion_equals_diff_form(m, nn, d, seed):
    """||a-b||^2 expansion == diff-square-sum (paper's FMA ladder) within
    fp32 tolerance, and never negative."""
    rng = np.random.RandomState(seed)
    a = rng.randn(m, d).astype(np.float32)
    b = rng.randn(nn, d).astype(np.float32)
    got = ref.pairwise_sq_l2(jnp.asarray(a), jnp.asarray(b))
    want = ref.pairwise_sq_l2_diff(jnp.asarray(a), jnp.asarray(b))
    assert float(jnp.min(got)) >= 0.0
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    n=st.integers(4, 32), k=st.integers(2, 6), rho_k=st.integers(1, 8),
    seed=st.integers(0, 999),
)
@_settings
def test_selection_buffers_valid(n, k, rho_k, seed):
    """Turbosampling candidate buffers: ids in range, no candidate for a
    node is the node itself via forward edges... and buffer occupancy is
    bounded by rho_k."""
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    nl = heap.init_random(k1, n, k)
    cands = selection.selection_turbo(k2, nl, rho_k)
    for buf in (cands.new_idx, cands.old_idx):
        b = np.asarray(buf)
        assert b.shape == (n, rho_k)
        assert ((b >= -1) & (b < n)).all()


@given(
    n=st.integers(1, 24), w=st.integers(1, 40), c=st.integers(1, 12),
    seed=st.integers(0, 2**16),
)
@_settings
def test_join_select_invariants(n, w, c, seed):
    """The fused local join's in-kernel top-C selection: output is sorted
    ascending, exactly the c best prefiltered entries (set-equal to a
    numpy reference), padded with (inf, -1), and the blocked kernel
    (interpret) agrees with the oracle bit-for-bit on indices."""
    rng = np.random.RandomState(seed)
    gd = rng.rand(n, w).astype(np.float32)
    gd[rng.rand(n, w) < 0.15] = np.inf
    gi = rng.randint(-1, 200, size=(n, w)).astype(np.int32)
    kth = (rng.rand(n).astype(np.float32) * 1.5)
    sd, si = ref.knn_join_select(
        jnp.asarray(gd), jnp.asarray(gi), jnp.asarray(kth), c)
    from repro.kernels.knn_join import knn_join_select_blocked
    bd, bi = knn_join_select_blocked(
        jnp.asarray(gd), jnp.asarray(gi), jnp.asarray(kth), c=c, tr=8,
        interpret=True)
    assert np.array_equal(np.asarray(si), np.asarray(bi))
    sd_np = np.asarray(sd)
    si_np = np.asarray(si)
    fin = np.isfinite(sd_np)
    # sorted ascending, padding at the tail (finite pad value: inf-inf
    # diffs are nan and would poison the comparison)
    padded = np.where(fin, sd_np, np.float32(3.0e38))
    assert (np.diff(padded, axis=1) >= 0).all()
    assert (si_np[~fin] == -1).all()
    for r in range(n):
        ok = (gi[r] >= 0) & (gd[r] < kth[r])
        want = np.sort(gd[r][ok])[:c]
        got = sd_np[r][fin[r]]
        assert got.shape == want.shape
        np.testing.assert_allclose(got, want, rtol=1e-6)
        # the returned ids carry the selected entries' distances
        for j in np.nonzero(fin[r])[0]:
            assert (gd[r][gi[r] == si_np[r][j]] == sd_np[r][j]).any()


@given(
    n=st.integers(8, 48), k=st.integers(2, 5), d=st.integers(2, 12),
    nq=st.integers(1, 9), expand=st.integers(1, 6), beam=st.integers(4, 12),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=15, deadline=None)
def test_fused_search_multi_expansion_selection(n, k, d, nq, expand, beam,
                                                seed):
    """The fused batched search's multi-expansion selection: for ANY graph
    (random ids, including broken/duplicate edges), query batch and alive
    mask, the returned ids per query are unique, distance-ascending, alive,
    and every valid id pairs with a finite distance."""
    from repro.core.graph_search import SearchConfig, graph_search
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(n, d).astype(np.float32))
    idx = jnp.asarray(rng.randint(-1, n, size=(n, k)).astype(np.int32))
    alive = jnp.asarray(rng.rand(n) < 0.7)
    q = jnp.asarray(rng.randn(nq, d).astype(np.float32))
    cfg = SearchConfig(beam=beam, rounds=2 * expand, expand=expand,
                       q_block=4)
    dd, ii = graph_search(x, idx, q, k_out=min(4, beam),
                          key=jax.random.key(seed), alive=alive, cfg=cfg)
    dd = np.asarray(dd)
    ii = np.asarray(ii)
    fin = np.isfinite(dd)
    assert ((ii >= 0) == fin).all()
    padded = np.where(fin, dd, np.float32(3.0e38))
    assert (np.diff(padded, axis=1) >= 0).all()
    a = np.asarray(alive)
    for r in range(ii.shape[0]):
        ids = ii[r][ii[r] >= 0]
        assert len(set(ids.tolist())) == len(ids)
        assert a[ids].all()


@given(seed=st.integers(0, 999), scale=st.floats(1e-3, 1e3),
       nelem=st.integers(1, 2000))
@_settings
def test_int8_error_feedback_bounded(seed, scale, nelem):
    """Quantization residual is bounded by half a quant step per block."""
    rng = np.random.RandomState(seed)
    x = (rng.randn(nelem) * scale).astype(np.float32)
    q, s, meta = quantize_int8(jnp.asarray(x), block=256)
    recon = dequantize_int8(q, s, meta)
    err = np.abs(np.asarray(recon) - x)
    step = np.repeat(np.asarray(s)[:, 0], 256)[:nelem]
    assert (err <= step * 0.5 + 1e-7).all()


@given(d=st.sampled_from([4, 16, 64]), seed=st.integers(0, 999))
@settings(max_examples=10, deadline=None)
def test_cosine_bitwise_l2_on_unit_rows(d, seed):
    """Cosine is implemented as row normalization + the unchanged l2
    path (core/metric.py): on inputs whose rows are EXACTLY unit norm
    (entries +-1/sqrt(d) with d a power of 4, so both the entries and
    the row norms are exact in fp32), normalization divides by exactly
    1.0 and the cosine search must be BIT-identical to the l2 search —
    same distances, same ids, zero numeric drift from the reduction."""
    from repro.core.graph_search import SearchConfig, graph_search
    rng = np.random.RandomState(seed)
    n, nq, k = 64, 8, 4
    s = np.float32(1.0 / np.sqrt(d))
    x = ((rng.randint(0, 2, size=(n, d)) * 2 - 1) * s).astype(np.float32)
    q = ((rng.randint(0, 2, size=(nq, d)) * 2 - 1) * s).astype(np.float32)
    idx = jnp.asarray(rng.randint(0, n, size=(n, k)).astype(np.int32))
    outs = {}
    for met in ("l2", "cosine"):
        cfg = SearchConfig(beam=8, rounds=6, q_block=8, metric=met)
        outs[met] = graph_search(jnp.asarray(x), idx, jnp.asarray(q),
                                 k_out=4, key=jax.random.key(seed),
                                 cfg=cfg)
    assert np.array_equal(np.asarray(outs["l2"][1]),
                          np.asarray(outs["cosine"][1]))
    assert np.array_equal(np.asarray(outs["l2"][0]),
                          np.asarray(outs["cosine"][0]))


@given(n=st.integers(8, 48), d=st.integers(2, 12), nq=st.integers(1, 8),
       seed=st.integers(0, 2**16))
@_settings
def test_mips_reduction_matches_ip_oracle(n, d, nq, seed):
    """The MIPS augmentation (core/metric.py): transformed-space squared
    l2 must recover the exact inner product through
    ``similarity_from_dist`` and preserve the IP ranking — against a
    brute-force q @ x.T oracle, for ANY data."""
    from repro.core import metric as metric_mod
    rng = np.random.RandomState(seed)
    x = rng.randn(n, d).astype(np.float32) * 2.0
    q = rng.randn(nq, d).astype(np.float32)
    xt, m = metric_mod.transform_corpus(jnp.asarray(x), "mips")
    qt = metric_mod.transform_queries(jnp.asarray(q), "mips")
    dist = ref.pairwise_sq_l2(qt, xt)                      # (nq, n)
    q2 = jnp.sum(jnp.asarray(q) ** 2, axis=1)
    sim = metric_mod.similarity_from_dist(dist, "mips", q2=q2[:, None],
                                          mips_m=m)
    ip = q @ x.T
    scale = max(1.0, float(np.abs(ip).max()))
    np.testing.assert_allclose(np.asarray(sim), ip,
                               atol=2e-4 * scale, rtol=0)
    # ranking: the min-distance row is a max-IP row (within fp32 slack)
    best = np.asarray(jnp.argmin(dist, axis=1))
    for r in range(nq):
        assert ip[r, best[r]] >= ip[r].max() - 1e-3 * scale


@given(
    n=st.integers(16, 48), k=st.integers(2, 5), d=st.integers(2, 10),
    nq=st.integers(1, 8), seed=st.integers(0, 2**16),
    precision=st.sampled_from(["f32", "int8"]),
    per_query=st.booleans(),
)
@settings(max_examples=15, deadline=None)
def test_filtered_search_never_leaks(n, k, d, nq, seed, precision,
                                     per_query):
    """Filtered search (graph_search ``filter_ids``): for ANY graph,
    tombstone mask, precision mode and predicate — shared (n,) or
    per-query (q, n) — no returned id is ever filtered-out or dead
    (zero leakage), and valid ids still pair with finite distances."""
    from repro.core.graph_search import SearchConfig, graph_search
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(n, d).astype(np.float32))
    idx = jnp.asarray(rng.randint(-1, n, size=(n, k)).astype(np.int32))
    alive = jnp.asarray(rng.rand(n) < 0.8)
    q = jnp.asarray(rng.randn(nq, d).astype(np.float32))
    if per_query:
        filt = jnp.asarray(rng.rand(nq, n) < 0.5)
    else:
        filt = jnp.asarray(rng.rand(n) < 0.5)
    cfg = SearchConfig(beam=8, rounds=6, q_block=4, precision=precision)
    dd, ii = graph_search(x, idx, q, k_out=4, key=jax.random.key(seed),
                          alive=alive, filter_ids=filt, cfg=cfg)
    dd, ii = np.asarray(dd), np.asarray(ii)
    assert ((ii >= 0) == np.isfinite(dd)).all()
    a = np.asarray(alive)
    f = np.asarray(filt)
    for r in range(nq):
        ids = ii[r][ii[r] >= 0]
        assert a[ids].all(), "leaked a tombstoned row"
        frow = f[r] if per_query else f
        assert frow[ids].all(), "leaked a filtered-out row"


@given(seed=st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_sampling_probability_expectation(seed):
    """Paper §3.1: per-edge accept with prob rho_k/|N| gives E[#sampled] ~
    rho_k when |N| >= rho_k (the heap-free equivalence argument)."""
    key = jax.random.key(seed)
    n, k, rho_k = 256, 12, 6
    k1, k2 = jax.random.split(key)
    nl = heap.init_random(k1, n, k)
    cands = selection.selection_turbo(k2, nl, rho_k)
    occ = float(jnp.mean(jnp.sum(cands.new_idx >= 0, axis=1)))
    # forward+reverse degree ~ 2k = 24 >= rho_k, so E[accepted] ~= rho_k
    # per node, clipped by the buffer to <= rho_k
    assert occ > rho_k * 0.55, occ
