"""Fused batched graph search (kernels/knn_search.py +
core/graph_search.py): kernel-vs-oracle parity over odd shapes, fused
vs. backend="ref" behavior parity (tombstone masking, output invariants),
and the seeded 512-pt recall pin on the fused serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DescentConfig,
    SearchConfig,
    brute_force_knn,
    build_knn_graph,
    datasets,
    recall_at_k,
)
from repro.core.graph_search import graph_search
from repro.core.online import MutableKNNStore, OnlineConfig, knn_delete
from repro.kernels import ref
from repro.kernels.knn_search import knn_search_dists_blocked

K = 10
DCFG = DescentConfig(k=K, rho=1.0, max_iters=15)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nq,w,dp,tq", [
    (37, 23, 16, 16),    # nq not a multiple of the query block, odd W
    (16, 64, 32, 16),    # exact blocks
    (5, 7, 8, 8),        # single padded block
])
def test_search_dists_kernel_matches_oracle(nq, w, dp, tq):
    rng = np.random.RandomState(nq + w)
    q = jnp.asarray(rng.randn(nq, dp).astype(np.float32))
    cg = jnp.asarray(rng.randn(nq, w, dp).astype(np.float32))
    ids = jnp.asarray(rng.randint(-1, 99, size=(nq, w)).astype(np.int32))
    ids = ids.at[2].set(-1)                     # an all-dead candidate row
    q2 = jnp.sum(q * q, axis=1)
    c2 = jnp.where(ids >= 0, jnp.sum(cg * cg, axis=-1), 0.0)
    rd = ref.knn_search_dists(q, q2, cg, c2, ids)
    kd = knn_search_dists_blocked(q, q2, cg, c2, ids, tq=tq,
                                  interpret=True)
    np.testing.assert_array_equal(np.isinf(rd), np.isinf(kd))
    np.testing.assert_allclose(np.where(np.isinf(rd), 0.0, rd),
                               np.where(np.isinf(kd), 0.0, kd),
                               rtol=1e-5, atol=1e-4)
    assert bool(jnp.isinf(kd[2]).all())


def test_search_dists_kernel_masks_match_brute():
    """Valid entries equal the plain pairwise distance."""
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(6, 12).astype(np.float32))
    x = jnp.asarray(rng.randn(30, 12).astype(np.float32))
    ids = jnp.asarray(rng.randint(0, 30, size=(6, 9)).astype(np.int32))
    cg = x[ids]
    q2 = jnp.sum(q * q, axis=1)
    c2 = jnp.sum(cg * cg, axis=-1)
    got = ref.knn_search_dists(q, q2, cg, c2, ids)
    want = ref.pairwise_sq_l2(q, x)
    want = jnp.take_along_axis(want, ids, axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused search vs the ref loop
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built_graph():
    x = datasets.clustered(jax.random.key(11), 512, 16, 8)
    dist, idx, _ = build_knn_graph(x, k=K, cfg=DCFG, key=jax.random.key(5))
    return x, dist, idx


def _invariants(d, i, alive=None):
    d = np.asarray(d)
    i = np.asarray(i)
    fin = np.isfinite(d) & (d < 1e38)
    # padding is (-1, inf/big) and distances ascend over the valid prefix
    assert ((i >= 0) == fin).all()
    dpad = np.where(fin, d, np.float32(3.0e38))
    assert (np.diff(dpad, axis=1) >= 0).all()
    for r in range(i.shape[0]):
        v = i[r][i[r] >= 0]
        assert len(set(v.tolist())) == len(v)       # unique ids
    if alive is not None:
        a = np.asarray(alive)
        assert a[i[i >= 0]].all()                   # only live ids


@pytest.mark.parametrize("nq,cfg", [
    # q not a multiple of the block
    (37, SearchConfig(beam=16, rounds=16, expand=4, q_block=16)),
    # E*k > beam: the select/merge must bound the candidate tile
    (8, SearchConfig(beam=8, rounds=12, expand=4, q_block=8)),
    # E > unexpanded pool entries; single round budget
    (5, SearchConfig(beam=4, rounds=2, expand=8, q_block=4)),
])
def test_fused_search_odd_shapes(built_graph, nq, cfg):
    x, _, idx = built_graph
    q = x[:nq] + 0.01
    d, i = graph_search(x, idx, q, k_out=4, key=jax.random.key(0), cfg=cfg)
    assert d.shape == (nq, 4) and i.shape == (nq, 4)
    _invariants(d, i)
    assert (np.asarray(i) >= 0).mean() == 1.0       # pool always fills


def test_fixed_block_matches_bucketed(built_graph):
    """fixed_block=True (the SLO-bench baseline that pads every batch to
    the full q_block) must be semantically identical to the bucketed
    ladder — only the padded block shape differs."""
    from repro.core.graph_search import q_block_bucket
    x, _, idx = built_graph
    q = x[:7] + 0.01
    outs = {}
    for fixed in (False, True):
        cfg = SearchConfig(beam=16, rounds=12, expand=3, q_block=64,
                           fixed_block=fixed)
        qb = q_block_bucket(7, cfg)
        assert qb == (64 if fixed else 8)
        d, i = graph_search(x, idx, q, k_out=5, key=jax.random.key(4),
                            cfg=cfg)
        outs[fixed] = (np.asarray(d), np.asarray(i))
    np.testing.assert_array_equal(outs[False][1], outs[True][1])
    np.testing.assert_allclose(outs[False][0], outs[True][0],
                               rtol=1e-5, atol=1e-5)


def test_fused_interpret_matches_jnp_dispatch(built_graph):
    """backend="interpret" (every Pallas kernel body under the
    interpreter) must agree with the default jnp-oracle dispatch
    end-to-end, bit-for-bit on indices."""
    x, _, idx = built_graph
    q = x[:16] + 0.01
    outs = {}
    for backend in ("auto", "interpret"):
        cfg = SearchConfig(beam=16, rounds=12, expand=3, q_block=8,
                           backend=backend)
        d, i = graph_search(x, idx, q, k_out=5, key=jax.random.key(2),
                            cfg=cfg)
        outs[backend] = (np.asarray(d), np.asarray(i))
    np.testing.assert_array_equal(outs["auto"][1], outs["interpret"][1])
    np.testing.assert_allclose(
        np.where(np.isfinite(outs["auto"][0]), outs["auto"][0], 0.0),
        np.where(np.isfinite(outs["interpret"][0]),
                 outs["interpret"][0], 0.0),
        rtol=1e-5, atol=1e-5)


def test_fused_empty_query_batch(built_graph):
    """An idle serving tick (zero queries) returns empty, like the ref
    path and the pre-fused implementation."""
    x, _, idx = built_graph
    d, i = graph_search(x, idx, x[:0], k_out=5, key=jax.random.key(0))
    assert d.shape == (0, 5) and i.shape == (0, 5)


def test_fused_matches_ref_recall(built_graph):
    """Same expansion budget -> the fused multi-expansion path must match
    the one-node-per-round oracle's recall within a hair."""
    x, _, idx = built_graph
    q = x[:128] + 0.01
    _, ti = brute_force_knn(x, q, K, exclude_self=False)
    rs = {}
    for backend in ("auto", "ref"):
        cfg = SearchConfig(beam=32, rounds=24, expand=4, backend=backend)
        _, gi = graph_search(x, idx, q, k_out=K, key=jax.random.key(3),
                             cfg=cfg)
        rs[backend] = recall_at_k(gi, ti)
    assert rs["auto"] >= rs["ref"] - 0.02, rs


def test_fused_search_seeded_recall_pin(built_graph):
    """Acceptance pin: the fused serving path holds >= 0.97 recall on the
    seeded 512-pt regression at the default serving budget."""
    x, _, idx = built_graph
    q = x[:256] + 0.01
    _, ti = brute_force_knn(x, q, K, exclude_self=False)
    d, i = graph_search(x, idx, q, k_out=K, key=jax.random.key(2),
                        cfg=SearchConfig(beam=32, rounds=24, expand=4))
    r = recall_at_k(i, ti)
    assert r >= 0.97, r
    # deterministic given the key
    d2, i2 = graph_search(x, idx, q, k_out=K, key=jax.random.key(2),
                          cfg=SearchConfig(beam=32, rounds=24, expand=4))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i2))


def test_batch_content_derived_entries(built_graph):
    """No silent shared-constant entry points: two different batches with
    no key draw different entries (content-derived), while the same batch
    stays deterministic."""
    x, _, idx = built_graph
    cfg = SearchConfig(beam=8, rounds=4, expand=2)
    qa = x[:16] + 0.01
    qb = x[16:32] + 0.01
    da1, ia1 = graph_search(x, idx, qa, k_out=4, cfg=cfg)
    da2, ia2 = graph_search(x, idx, qa, k_out=4, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(ia1), np.asarray(ia2))
    # different content -> different entry draw -> (with a tiny beam and
    # budget) almost surely different result sets for at least one query
    db, ib = graph_search(x, idx, qb, k_out=4, cfg=cfg)
    assert not np.array_equal(np.asarray(ia1), np.asarray(ib))


# ---------------------------------------------------------------------------
# tombstone / alive masking parity
# ---------------------------------------------------------------------------

def test_fused_tombstone_parity_with_ref(built_graph):
    """With a tombstone mask, the fused path and backend="ref" both never
    surface a dead id, keep every slot filled from live rows, and agree
    on recall against the alive-filtered truth."""
    x, dist, idx = built_graph
    n = x.shape[0]
    store = MutableKNNStore.from_graph(x, dist, idx, cfg=OnlineConfig())
    dead = jnp.arange(0, 64, dtype=jnp.int32)
    store, _ = knn_delete(store, dead)
    q = x[:96] + 0.01

    # alive-filtered brute-force truth
    d_all = ref.pairwise_sq_l2(q, x.astype(jnp.float32))
    d_all = jnp.where(store.alive[:n][None, :], d_all, jnp.inf)
    _, ti = jax.lax.top_k(-d_all, 5)

    recalls = {}
    for backend in ("auto", "ref"):
        d, i = store.search(
            q, k_out=5, key=jax.random.key(0),
            cfg=SearchConfig(beam=32, rounds=24, backend=backend),
        )
        got = np.asarray(i)
        assert not np.isin(got[got >= 0], np.asarray(dead)).any(), backend
        assert (got >= 0).mean() == 1.0, backend
        if backend == "auto":
            _invariants(d, i, alive=store.alive[:n])
        recalls[backend] = recall_at_k(i, ti)
    assert recalls["auto"] >= recalls["ref"] - 0.05, recalls


def test_fused_all_dead_returns_empty(built_graph):
    x, _, idx = built_graph
    alive = jnp.zeros((x.shape[0],), bool)
    d, i = graph_search(x, idx, x[:5], k_out=5, key=jax.random.key(0),
                        alive=alive, cfg=SearchConfig(beam=8, rounds=4))
    assert (np.asarray(i) == -1).all()
    assert np.isinf(np.asarray(d)).all()


def test_empty_corpus_returns_empty():
    """A store before its first insert: zero allocated rows answer every
    query with the empty result instead of a degenerate gather."""
    d, i = graph_search(jnp.zeros((0, 16)), jnp.zeros((0, K), jnp.int32),
                        jnp.ones((7, 16)), k_out=5, key=jax.random.key(0))
    assert d.shape == (7, 5) and i.shape == (7, 5)
    assert (np.asarray(i) == -1).all()
    assert np.isinf(np.asarray(d)).all()


def test_admission_sanitizes_poisoned_rows(built_graph):
    """Default (strict=False): NaN/Inf rows are sanitized — their
    results come back empty, the CLEAN rows' results are bit-identical
    to the unpoisoned batch (no NaN reaches the pool merge)."""
    x, _, idx = built_graph
    q = np.array(x[:16], np.float32)
    clean_d, clean_i = graph_search(x, idx, jnp.asarray(q), k_out=5,
                                    key=jax.random.key(3))
    bad = q.copy()
    bad[0, 0] = np.nan
    bad[3, :] = np.inf
    with pytest.warns(RuntimeWarning, match="sanitized 2"):
        d, i = graph_search(x, idx, jnp.asarray(bad), k_out=5,
                            key=jax.random.key(3))
    d, i = np.asarray(d), np.asarray(i)
    assert (i[0] == -1).all() and (i[3] == -1).all()
    assert np.isinf(d[0]).all() and np.isinf(d[3]).all()
    ok = [r for r in range(16) if r not in (0, 3)]
    assert np.isfinite(d[ok]).all()
    _invariants(d[ok], i[ok])


def test_admission_strict_rejects_poisoned_batch(built_graph):
    x, _, idx = built_graph
    bad = np.array(x[:8], np.float32)
    bad[2, 1] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        graph_search(x, idx, jnp.asarray(bad), k_out=5,
                     key=jax.random.key(3), cfg=SearchConfig(strict=True))


def test_admission_rejects_dim_mismatch(built_graph):
    """A wrong-dimensionality batch always rejects (both strict modes):
    there is no safe way to guess which features the caller meant."""
    x, _, idx = built_graph
    bad = jnp.ones((4, x.shape[1] + 1))
    for cfg in (SearchConfig(strict=False), SearchConfig(strict=True)):
        with pytest.raises(ValueError, match="feature dim"):
            graph_search(x, idx, bad, k_out=5, key=jax.random.key(0),
                         cfg=cfg)


def test_deadline_degrades_not_crashes(built_graph):
    """max_rounds_deadline: an already-expired time slice cuts the
    budget of every block after the first — results stay VALID (the
    invariants hold, every query answered), only recall may degrade."""
    x, _, idx = built_graph
    q = x[:64] + 0.01
    cfg = SearchConfig(beam=16, rounds=24, q_block=16,
                       max_rounds_deadline=1e-9)
    d, i = graph_search(x, idx, q, k_out=5, key=jax.random.key(2), cfg=cfg)
    assert i.shape == (64, 5)
    assert (np.asarray(i) >= 0).all()
    _invariants(d, i)
    # and a generous slice changes nothing vs. the undeadlined config
    lazy = SearchConfig(beam=16, rounds=24, q_block=16,
                        max_rounds_deadline=60.0)
    d0, i0 = graph_search(x, idx, q, k_out=5, key=jax.random.key(2),
                          cfg=SearchConfig(beam=16, rounds=24, q_block=16))
    d1, i1 = graph_search(x, idx, q, k_out=5, key=jax.random.key(2),
                          cfg=lazy)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    assert (np.asarray(d0) == np.asarray(d1)).all()


def test_search_cfg_threads_through_knn_logits():
    """serve/knn_lm: cfg + key thread to the store search and the result
    distribution reacts to retrieval."""
    from repro.serve import MutableKNNDatastore, knn_logits
    vocab, dk = 16, 8
    keys0 = jax.random.normal(jax.random.key(0), (128, dk))
    vals0 = jnp.full((128,), 7, jnp.int32)
    ds = MutableKNNDatastore.build(keys0, vals0, k=8, key=jax.random.key(2),
                                   q_block=32)
    assert ds.store.cfg.q_block == 32
    lp = knn_logits(ds, keys0[:4] + 0.01, vocab, k=4,
                    key=jax.random.key(9),
                    cfg=SearchConfig(beam=16, rounds=8, expand=2))
    assert (jnp.argmax(lp, -1) == 7).all()


# ---------------------------------------------------------------------------
# entry-point seeding regressions
# ---------------------------------------------------------------------------

def test_batch_key_distinguishes_permuted_batches():
    """The content-derived entry key must not be permutation-invariant: a
    shuffled copy of a batch used to hash identically (plain jnp.sum) and
    reuse the same entry points; the position-weighted fold breaks that
    while identical batches stay deterministic."""
    from repro.core.graph_search import _batch_key, _draw_entries
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(8, 16).astype(np.float32))
    perm = rng.permutation(8)
    qp = q[jnp.asarray(perm)]
    k1, k2 = _batch_key(q), _batch_key(qp)
    assert not jnp.array_equal(jax.random.key_data(k1),
                               jax.random.key_data(k2))
    e1 = _draw_entries(k1, 512, 16, None)
    e2 = _draw_entries(k2, 512, 16, None)
    assert not jnp.array_equal(e1, e2)
    # determinism: the same batch maps to the same key
    assert jnp.array_equal(jax.random.key_data(k1),
                           jax.random.key_data(_batch_key(q)))


def test_draw_entries_no_duplicates():
    """Both branches (alive=None and masked) must sample WITHOUT
    replacement — the retired randint draw produced duplicate ids whose
    pool-merge dedup silently wasted beam slots."""
    from repro.core.graph_search import _draw_entries
    key = jax.random.key(5)
    e = np.asarray(_draw_entries(key, 64, 32, None))
    assert e.shape == (32,)
    assert len(set(e.tolist())) == 32
    assert ((e >= 0) & (e < 64)).all()
    alive = jnp.arange(64) % 2 == 0
    ea = np.asarray(_draw_entries(key, 64, 32, alive))
    assert len(set(ea.tolist())) == 32
    assert (ea % 2 == 0).all()              # live rows only
    # width clamps to n when beam > n
    small = np.asarray(_draw_entries(key, 8, 32, None))
    assert small.shape == (8,) and len(set(small.tolist())) == 8
