"""Training substrate: loss decreases, optimizer math, checkpoint
save/restore/atomicity, NaN-guard + rollback, straggler watchdog,
gradient compression, data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline, pack_documents, semantic_order
from repro.data.pipeline import SyntheticLMSource
from repro.models import init_tree, model_schema
from repro.train import OptimizerConfig, TrainConfig, make_train_step
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import Checkpointer
from repro.train.compression import dequantize_int8, ef_accumulate
from repro.train.fault import FaultPolicy, StragglerWatchdog, elastic_mesh


@pytest.fixture(scope="module")
def small_setup():
    cfg = get_smoke_config("yi-6b")
    params = init_tree(jax.random.key(0), model_schema(cfg))
    state = opt_mod.init(params)
    dc = DataConfig(seq_len=64, global_batch=4, vocab=cfg.vocab, prefetch=0)
    pipe = TokenPipeline(dc, process_index=0, process_count=1)
    return cfg, params, state, pipe


def test_loss_decreases(small_setup):
    cfg, params, state, pipe = small_setup
    tc = TrainConfig(opt=OptimizerConfig(lr=2e-3, warmup_steps=3,
                                         total_steps=30))
    step = jax.jit(make_train_step(cfg, tc))
    losses = []
    for i, b in zip(range(25), pipe):
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_adamw_matches_reference():
    """Our AdamW == hand-rolled numpy reference on a tiny problem."""
    oc = OptimizerConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                         weight_decay=0.01, grad_clip=0.0,
                         warmup_steps=0, total_steps=10**9,
                         schedule="constant")
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.5, 0.5, -1.0])}
    st = opt_mod.init(p)
    p1, st1, _ = opt_mod.apply(oc, p, st, g)
    # reference
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    want = np.asarray(p["w"]) - 0.1 * (
        mh / (np.sqrt(vh) + 1e-8) + 0.01 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)


def test_lr_schedule_shape():
    oc = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                         min_lr_ratio=0.1)
    lrs = [float(opt_mod.learning_rate(oc, jnp.int32(s)))
           for s in [0, 9, 10, 55, 99]]
    assert lrs[0] < 0.2                   # warmup
    assert abs(lrs[2] - 1.0) < 0.01       # peak
    assert lrs[3] < lrs[2]                # decaying
    assert abs(lrs[4] - 0.1) < 0.02       # floor


def test_nan_guard_skips_update(small_setup):
    cfg, params, state, pipe = small_setup
    tc = TrainConfig(opt=OptimizerConfig(lr=1e-3))
    step = jax.jit(make_train_step(cfg, tc))
    batch = next(iter(pipe))
    # poison the params so the loss is NaN
    bad = jax.tree.map(lambda x: x * jnp.nan, params)
    p1, s1, m = step(bad, state, batch)
    assert int(m["skipped"]) == 1
    # params unchanged (identity update)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(bad)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip(tmp_path, small_setup):
    cfg, params, state, _ = small_setup
    ck = Checkpointer(str(tmp_path), every=1, async_write=False)
    ck.save(7, params, state)
    assert ck.latest_step() == 7
    like = {"params": params, "opt_state": state}
    step, tree = ck.load(like=like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path, small_setup):
    cfg, params, state, _ = small_setup
    ck = Checkpointer(str(tmp_path), every=1, keep=2, async_write=True)
    for s in (1, 2, 3, 4):
        ck.save(s, params, state)
    ck.wait()
    ck._gc()
    steps = ck._list_steps()
    assert max(steps) == 4 and len(steps) <= 2


def test_checkpoint_ignores_partial(tmp_path, small_setup):
    """A crashed write (tmp dir, no manifest) must be invisible."""
    cfg, params, state, _ = small_setup
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(5, params, state)
    os.makedirs(tmp_path / "step_00000009.tmp")
    (tmp_path / "step_00000009.tmp" / "host_00000.npz").write_bytes(b"junk")
    assert ck.latest_step() == 5


def test_fault_policy_rolls_back(tmp_path, small_setup):
    cfg, params, state, _ = small_setup
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(10, params, state)
    fp = FaultPolicy(ck, max_consecutive_skips=2, max_restarts=3)
    bad = jax.tree.map(lambda x: x + 999.0, params)
    # two skipped steps in a row -> rollback to checkpoint
    p, s, rolled = fp.after_step(11, bad, state, {"skipped": 1})
    assert not rolled
    p, s, rolled = fp.after_step(12, bad, state, {"skipped": 1})
    assert rolled
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert fp.last_good_step == 10


def test_fault_policy_gives_up(tmp_path, small_setup):
    cfg, params, state, _ = small_setup
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(1, params, state)
    fp = FaultPolicy(ck, max_consecutive_skips=1, max_restarts=2)
    fp.after_step(2, params, state, {"skipped": 1})
    fp.after_step(3, params, state, {"skipped": 1})
    with pytest.raises(RuntimeError, match="unstable"):
        fp.after_step(4, params, state, {"skipped": 1})


def test_straggler_watchdog():
    import time
    dog = StragglerWatchdog(threshold=3.0, alpha=0.5)
    for _ in range(5):
        dog.step_start()
        time.sleep(0.01)
        assert not dog.step_end(0)
    dog.step_start()
    time.sleep(0.12)
    assert dog.step_end(6)
    assert dog.stragglers == 1


def test_elastic_mesh_shrinks():
    mesh = elastic_mesh(jax.devices(), model_axis=16)
    assert mesh.size == len(jax.devices())
    assert "model" in mesh.shape and "data" in mesh.shape


def test_ef_accumulate_preserves_sum():
    """int8 error-feedback accumulation: total equals fp32 sum within
    quant tolerance after the residual is folded in."""
    rng = np.random.RandomState(0)
    grads = [rng.randn(1000).astype(np.float32) * 0.01 for _ in range(8)]
    acc_q = acc_s = None
    residual = jnp.zeros(1000)
    for g in grads:
        acc_q, acc_s, residual = ef_accumulate(
            acc_q, acc_s, residual, jnp.asarray(g))
    meta = ((1000,), (-1000) % 256)
    total = np.asarray(dequantize_int8(acc_q, acc_s, meta)) + \
        np.asarray(residual)
    np.testing.assert_allclose(total, np.sum(grads, axis=0), atol=1e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_restartable():
    dc = DataConfig(seq_len=32, global_batch=4, vocab=128, prefetch=0)
    p1 = TokenPipeline(dc, process_index=0, process_count=1)
    it1 = iter(p1)
    b1 = [next(it1) for _ in range(3)]
    state = p1.state()
    b_next = next(it1)
    # restart from saved state
    p2 = TokenPipeline(dc, process_index=0, process_count=1)
    p2.restore(state)
    b2 = next(iter(p2))
    np.testing.assert_array_equal(np.asarray(b_next["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_pipeline_host_sharding_disjoint():
    dc = DataConfig(seq_len=32, global_batch=4, vocab=128, prefetch=0)
    a = next(iter(TokenPipeline(dc, process_index=0, process_count=2)))
    b = next(iter(TokenPipeline(dc, process_index=1, process_count=2)))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(b["tokens"]))


def test_packing_labels_shifted():
    src = SyntheticLMSource(64, seed=1)
    rows, nxt = pack_documents(src, 0, 16, 2)
    assert rows.shape == (2, 17)
    dc = DataConfig(seq_len=16, global_batch=1, vocab=64, prefetch=0)
    p = TokenPipeline(dc, process_index=0, process_count=1)
    b = next(iter(p))
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][0, 1:]), np.asarray(b["labels"][0, :-1]))


def test_semantic_order_improves_locality():
    """data/ordering.py: the paper's C3 at corpus level."""
    from repro.core import datasets
    emb = datasets.clustered(jax.random.key(0), 512, 16, 8)
    order, stats = semantic_order(emb, k=8)
    assert sorted(order.tolist()) == list(range(512))
    assert stats["in_block_after"] > stats["in_block_before"]
