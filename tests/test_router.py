"""Coarse routing layer (core/router.py): build invariants, routed
entry seeding vs the uniform-random draw (the large-n recall pin),
parity knobs (router="off", backend="ref"), and incremental
insert/delete maintenance with the lazy drift rebuild."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    OnlineConfig,
    RouterConfig,
    SearchConfig,
    brute_force_knn,
    build_knn_graph,
    build_router,
    datasets,
    knn_delete,
    knn_insert,
    recall_at_k,
)
from repro.core.graph_search import graph_search
from repro.core.online import MutableKNNStore
from repro.core.router import (
    needs_rebuild,
    resolve_centroids,
    route_entries,
    router_delete,
    router_insert,
    top_centroids,
)


# ---------------------------------------------------------------------------
# build invariants
# ---------------------------------------------------------------------------

def test_router_build_invariants():
    x = datasets.clustered(jax.random.key(0), 1024, 16, 8)
    cfg = RouterConfig(n_centroids=32, sample=1024, members=16, graph_k=4)
    r = build_router(x, cfg=cfg, key=jax.random.key(1))
    c = r.centroids.shape[0]
    assert c == 32
    # every row assigned, counts account for every live row
    a = np.asarray(r.assign)
    assert ((a >= 0) & (a < c)).all()
    assert int(r.counts.sum()) == x.shape[0]
    cnt = np.bincount(a, minlength=c)
    np.testing.assert_array_equal(np.asarray(r.counts), cnt)
    # member lists hold rows of their own centroid, nearest-first
    mi = np.asarray(r.members.idx)
    md = np.asarray(r.members.dist)
    for ci in range(c):
        rows = mi[ci][mi[ci] >= 0]
        assert (a[rows] == ci).all()
        d = md[ci][mi[ci] >= 0]
        assert (np.diff(d) >= -1e-6).all()
    # mini-graph: valid degree, ids in range, no self loops
    g = np.asarray(r.graph)
    assert g.shape[1] == 4
    assert ((g >= -1) & (g < c)).all()
    assert (g != np.arange(c)[:, None]).all()
    assert int(r.stale) == 0


def test_router_build_with_tombstones():
    x = datasets.clustered(jax.random.key(2), 512, 8, 4)
    alive = jnp.arange(512) % 4 != 0          # kill every 4th row
    cfg = RouterConfig(n_centroids=16, sample=512, members=16)
    r = build_router(x, cfg=cfg, key=jax.random.key(3), alive=alive)
    a = np.asarray(r.assign)
    al = np.asarray(alive)
    assert (a[~al] == -1).all() and (a[al] >= 0).all()
    assert int(r.counts.sum()) == int(alive.sum())
    mi = np.asarray(r.members.idx)
    assert al[mi[mi >= 0]].all()              # members are live rows only


def test_resolve_centroids_policy():
    assert resolve_centroids(100, RouterConfig(n_centroids=32)) == 32
    assert resolve_centroids(8, RouterConfig(n_centroids=32)) == 8
    assert resolve_centroids(100, RouterConfig()) == 16       # floor
    assert resolve_centroids(10**8, RouterConfig()) == 1024   # ceiling
    assert resolve_centroids(65536, RouterConfig()) == 256    # sqrt


def test_route_entries_shape_and_validity():
    x = datasets.clustered(jax.random.key(4), 512, 8, 4)
    cfg = RouterConfig(n_centroids=8, sample=512, members=8)
    r = build_router(x, cfg=cfg, key=jax.random.key(5))
    q = x[:6] + 0.01
    ent = route_entries(r, q, 32, t=2)
    assert ent.shape == (6, 32) and ent.dtype == jnp.int32
    e = np.asarray(ent)
    assert ((e >= -1) & (e < 512)).all()
    # the first entries are members of the query's top centroids
    _, top = top_centroids(r, q, 2)
    a = np.asarray(r.assign)
    tn = np.asarray(top)
    for qi in range(6):
        first = e[qi][e[qi] >= 0][:4]
        assert np.isin(a[first], tn[qi]).all()


# ---------------------------------------------------------------------------
# the large-n recall pin: routed seeding vs uniform-random entries
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def big_clustered():
    """64 well-separated clusters x 784 rows = 50176 rows (cluster-major
    layout), with per-cluster exact K-NN subgraphs — the adversarial
    shape for uniform-random seeding: no inter-cluster edges, so search
    only ever reaches clusters holding an entry point."""
    n_c, per, d, k = 64, 784, 16, 10
    key = jax.random.key(7)
    kc, kn = jax.random.split(key)
    cent = jax.random.normal(kc, (n_c, d)) * 12.0
    noise = jax.random.normal(kn, (n_c, per, d))
    x = (cent[:, None, :] + noise).reshape(n_c * per, d).astype(jnp.float32)

    @jax.jit
    def cluster_graph(xc):
        _, gi = brute_force_knn(xc, xc, k)
        return gi

    parts = [
        np.asarray(cluster_graph(x[c * per:(c + 1) * per])) + c * per
        for c in range(n_c)
    ]
    gidx = jnp.asarray(np.concatenate(parts).astype(np.int32))
    q = x[::196] + 0.01                       # 256 queries, all clusters
    _, ti = brute_force_knn(x, q, k, exclude_self=False)
    return x, gidx, q, ti


def test_routed_entries_fix_large_n_recall(big_clustered):
    """The tentpole's receipt in unit form: at n=5e4 with 64 clusters,
    beam-32 uniform-random entries reach ~half the clusters (recall
    collapses), routed entries from 256 centroids recover them."""
    x, gidx, q, ti = big_clustered
    cfg = SearchConfig(beam=32, rounds=24, expand=4)
    key = jax.random.key(11)
    _, ri = graph_search(x, gidx, q, k_out=10, key=key, cfg=cfg)
    rnd = float(recall_at_k(ri, ti))
    router = build_router(
        x, cfg=RouterConfig(n_centroids=256, iters=6), key=jax.random.key(13)
    )
    _, si = graph_search(x, gidx, q, k_out=10, key=key, cfg=cfg,
                         router=router)
    routed = float(recall_at_k(si, ti))
    assert rnd < 0.75, rnd       # the collapse is real at this shape
    assert routed >= 0.85, (routed, rnd)
    assert routed > rnd, (routed, rnd)


def test_router_off_and_ref_backend_keep_random_entries():
    """cfg.router="off" and backend="ref" must ignore the router — the
    parity oracle keeps the uniform-random entry contract."""
    x = datasets.clustered(jax.random.key(20), 512, 8, 4)
    _, gidx, _ = build_knn_graph(
        x, k=8, cfg=None, key=jax.random.key(21))
    router = build_router(
        x, cfg=RouterConfig(n_centroids=8, sample=512), key=jax.random.key(22)
    )
    key = jax.random.key(23)
    q = x[:16] + 0.01
    base_d, base_i = graph_search(x, gidx, q, k_out=8, key=key,
                                  cfg=SearchConfig(router="off"))
    off_d, off_i = graph_search(x, gidx, q, k_out=8, key=key,
                                cfg=SearchConfig(router="off"),
                                router=router)
    np.testing.assert_array_equal(base_i, off_i)
    np.testing.assert_array_equal(base_d, off_d)
    rcfg = SearchConfig(backend="ref")
    ref_d, ref_i = graph_search(x, gidx, q, k_out=8, key=key, cfg=rcfg)
    ref2_d, ref2_i = graph_search(x, gidx, q, k_out=8, key=key, cfg=rcfg,
                                  router=router)
    np.testing.assert_array_equal(ref_i, ref2_i)
    np.testing.assert_array_equal(ref_d, ref2_d)


# ---------------------------------------------------------------------------
# incremental maintenance + lazy drift rebuild (the online store path)
# ---------------------------------------------------------------------------

def _store_with_router(n=256, d=8, rebuild_frac=0.25):
    x = datasets.clustered(jax.random.key(30), n, d, 4)
    dist, idx, _ = build_knn_graph(x, k=8, cfg=None, key=jax.random.key(31))
    cfg = OnlineConfig(router=RouterConfig(
        n_centroids=16, sample=n, members=16, rebuild_frac=rebuild_frac))
    return MutableKNNStore.from_graph(x, dist, idx, cfg=cfg), x


def test_router_incremental_insert_and_delete():
    store, x = _store_with_router()
    assert store.router is not None and int(store.router.stale) == 0
    # small insert: incremental maintenance, no rebuild
    pts = x[:8] + 0.05
    store, _ = knn_insert(store, pts, key=jax.random.key(32))
    r = store.router
    assert int(r.stale) == 8
    new_ids = np.arange(256, 264)
    a = np.asarray(r.assign)
    assert (a[new_ids] >= 0).all()
    assert int(r.counts.sum()) == int(store.alive.sum())
    # the inserted rows joined their centroid's member list
    mi = np.asarray(r.members.idx)
    assert np.isin(new_ids, mi).any()
    # delete: assignments released, counts decremented, members purged
    dead = jnp.arange(0, 16, dtype=jnp.int32)
    store, _ = knn_delete(store, dead)
    r = store.router
    a = np.asarray(r.assign)
    assert (a[:16] == -1).all()
    assert int(r.counts.sum()) == int(store.alive.sum())
    mi = np.asarray(r.members.idx)
    assert not np.isin(np.arange(16), mi[mi >= 0]).any()


def test_router_rebuild_after_drift_burst():
    """An insert burst past rebuild_frac * live triggers the lazy full
    rebuild: stale resets and the router describes the grown corpus."""
    store, x = _store_with_router(rebuild_frac=0.25)
    pts = jnp.tile(x[:16], (6, 1)) + 0.03     # 96 > 0.25 * 352 post-insert
    store, _ = knn_insert(store, pts, key=jax.random.key(33))
    r = store.router
    assert int(r.stale) == 0                  # rebuilt
    assert int(r.counts.sum()) == int(store.alive.sum())
    a = np.asarray(r.assign)[:int(store.n)]
    assert (a >= 0).all()
    # rebuild keys member lists to live rows only
    mi = np.asarray(r.members.idx)
    alive = np.asarray(store.alive)
    assert alive[mi[mi >= 0]].all()


def test_router_rebuild_failure_serves_stale(recwarn):
    """Robustness: the drift threshold is crossed but the rebuild is
    injected to fail — the store keeps serving from the STALE router
    (degraded recall, no crash), and the next threshold crossing
    re-attempts the rebuild."""
    import warnings as _w
    from repro.core.faults import FaultPlan, FaultSpec
    store, x = _store_with_router(rebuild_frac=0.25)
    pts = jnp.tile(x[:16], (6, 1)) + 0.03     # past the drift threshold
    plan = FaultPlan(specs=(FaultSpec(site="router.rebuild", times=1),))
    with plan.active(), _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        store2, _ = knn_insert(store, pts, key=jax.random.key(33))
    assert plan.fired("router.rebuild") == 1
    assert any("stale router" in str(r.message) for r in rec)
    r = store2.router
    assert int(r.stale) > 0                   # NOT rebuilt — still stale
    # the stale router still serves: searches stay valid and live-only
    _, idx = store2.search(x[:32], k_out=5, key=jax.random.key(34))
    got = np.asarray(idx)
    assert (got >= 0).all()
    assert np.asarray(store2.alive)[got].all()
    # next insert crosses the threshold again; with no fault the rebuild
    # goes through and stale resets
    store3, _ = knn_insert(store2, x[:8] + 0.01, key=jax.random.key(35))
    assert int(store3.router.stale) == 0


def test_needs_rebuild_threshold():
    store, _ = _store_with_router()
    r = store.router
    cfg = store.cfg.router
    assert not needs_rebuild(r, 256, cfg)
    assert needs_rebuild(r._replace(stale=jnp.int32(65)), 256, cfg)
    assert not needs_rebuild(r._replace(stale=jnp.int32(64)), 256, cfg)


def test_store_search_uses_router(monkeypatch):
    """store.search threads the attached router into graph_search (routed
    seeding is on the serving path, not just the free function)."""
    store, x = _store_with_router()
    gs = importlib.import_module("repro.core.graph_search")
    seen = {}
    orig = gs.graph_search

    def spy(*args, **kw):
        seen["router"] = kw.get("router", None)
        return orig(*args, **kw)

    online = importlib.import_module("repro.core.online")
    monkeypatch.setattr(online, "graph_search", spy)
    store.search(x[:4] + 0.01, k_out=4, key=jax.random.key(34))
    assert seen["router"] is store.router
