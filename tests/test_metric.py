"""Metric-general search (l2 / cosine / mips) + filtered queries.

Deterministic counterparts of the hypothesis properties in
test_property.py (which skip when ``hypothesis`` is absent), plus the
plumbing that rides on them: store build/search per metric, the metric
echo in snapshots, the kNN-LM filter passthrough, and the scheduler's
admission-path result cache.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metric as metric_mod
from repro.core.graph_search import SearchConfig, graph_search
from repro.core.online import MutableKNNStore, OnlineConfig, knn_insert


# ---------------------------------------------------------------------------
# the reductions themselves
# ---------------------------------------------------------------------------


def test_cosine_reduction_recovers_cosine_similarity():
    rng = np.random.RandomState(0)
    x = rng.randn(64, 8).astype(np.float32) * 3.0
    q = rng.randn(5, 8).astype(np.float32)
    xt, _ = metric_mod.transform_corpus(jnp.asarray(x), "cosine")
    qt = metric_mod.transform_queries(jnp.asarray(q), "cosine")
    d = jnp.sum((qt[:, None, :] - xt[None]) ** 2, axis=-1)
    sim = metric_mod.similarity_from_dist(d, "cosine")
    xn = x / np.linalg.norm(x, axis=1, keepdims=True)
    qn = q / np.linalg.norm(q, axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(sim), qn @ xn.T, atol=2e-5)


def test_mips_reduction_recovers_inner_product():
    rng = np.random.RandomState(1)
    x = rng.randn(64, 8).astype(np.float32) * 2.0
    q = rng.randn(5, 8).astype(np.float32)
    xt, m = metric_mod.transform_corpus(jnp.asarray(x), "mips")
    assert xt.shape == (64, 9)
    qt = metric_mod.transform_queries(jnp.asarray(q), "mips")
    d = jnp.sum((qt[:, None, :] - xt[None]) ** 2, axis=-1)
    q2 = jnp.sum(jnp.asarray(q) ** 2, axis=1)[:, None]
    sim = metric_mod.similarity_from_dist(d, "mips", q2=q2, mips_m=m)
    ip = q @ x.T
    np.testing.assert_allclose(np.asarray(sim), ip,
                               atol=2e-4 * max(1.0, np.abs(ip).max()))


def test_cosine_bit_identical_to_l2_on_exact_unit_rows():
    """Entries +-1/sqrt(d) (d a power of 4) make rows EXACTLY unit in
    fp32: normalization divides by exactly 1.0, so the cosine search
    must match the l2 search bit for bit."""
    rng = np.random.RandomState(2)
    for d in (4, 16):
        s = np.float32(1.0 / np.sqrt(d))
        x = ((rng.randint(0, 2, size=(64, d)) * 2 - 1) * s
             ).astype(np.float32)
        q = ((rng.randint(0, 2, size=(8, d)) * 2 - 1) * s
             ).astype(np.float32)
        gi = jnp.asarray(rng.randint(0, 64, size=(64, 4), dtype=np.int32))
        out = {}
        for met in ("l2", "cosine"):
            cfg = SearchConfig(beam=8, rounds=6, q_block=8, metric=met)
            out[met] = graph_search(jnp.asarray(x), gi, jnp.asarray(q),
                                    k_out=4, key=jax.random.key(3),
                                    cfg=cfg)
        assert np.array_equal(np.asarray(out["l2"][1]),
                              np.asarray(out["cosine"][1]))
        assert np.array_equal(np.asarray(out["l2"][0]),
                              np.asarray(out["cosine"][0]))


def test_unknown_metric_rejected():
    with pytest.raises(ValueError, match="metric"):
        metric_mod.check_metric("dot")
    with pytest.raises(ValueError, match="metric"):
        graph_search(jnp.zeros((4, 2)), jnp.zeros((4, 2), jnp.int32),
                     jnp.zeros((1, 2)),
                     cfg=SearchConfig(metric="manhattan"))


# ---------------------------------------------------------------------------
# end-to-end store per metric
# ---------------------------------------------------------------------------


def _corpus(n=256, d=16, seed=0):
    return jax.random.normal(jax.random.key(seed), (n, d))


@pytest.mark.parametrize("met", ["cosine", "mips"])
def test_store_search_matches_native_metric_oracle(met):
    x = _corpus()
    q = x[:48] + 0.01 * jax.random.normal(jax.random.key(1), (48, 16))
    # MIPS concentrates true neighbors on large-norm hub rows, which
    # thins the reverse edges reaching them — it needs a denser graph
    # and wider beam for the same recall (see docs/METRICS.md)
    k = 20 if met == "mips" else 8
    store, _ = MutableKNNStore.build(
        x, k=k, cfg=OnlineConfig(metric=met, q_block=64))
    dd, ii = store.search(q, k_out=10, beam=64, rounds=24)
    if met == "cosine":
        xn = x / jnp.linalg.norm(x, axis=1, keepdims=True)
        qn = q / jnp.linalg.norm(q, axis=1, keepdims=True)
        oracle = jnp.argsort(-(qn @ xn.T), axis=1)[:, :10]
    else:
        oracle = jnp.argsort(-(q @ x.T), axis=1)[:, :10]
    hits = np.mean([
        len(set(np.asarray(ii[i]).tolist())
            & set(np.asarray(oracle[i]).tolist())) / 10
        for i in range(q.shape[0])
    ])
    assert hits >= 0.85, (met, hits)
    # returned distances are transformed-space l2: ascending + finite
    dd = np.asarray(dd)
    assert (np.diff(dd, axis=1) >= 0).all() and np.isfinite(dd).all()


def test_mips_insert_bootstraps_m_and_warns_on_overflow():
    cfg = OnlineConfig(metric="mips")
    store = MutableKNNStore.empty(16, cfg=cfg)
    x = _corpus(64, 16, 5)
    store, _ = knn_insert(store, x)
    assert store.mips_m > 0.0
    m0 = store.mips_m
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        store, _ = knn_insert(store, x * 10.0)   # rows exceed frozen M
    assert store.mips_m == m0                    # M never silently moves
    assert any("augmentation bound" in str(x.message) for x in w)


def test_metric_snapshot_echo_roundtrip(tmp_path):
    from repro.core import persist
    x = _corpus(128, 8, 3)
    store, _ = MutableKNNStore.build(
        x, k=6, cfg=OnlineConfig(metric="mips"))
    persist.snapshot_store(store, str(tmp_path), 1)
    res = persist.restore_store(str(tmp_path))
    assert res.store.cfg.metric == "mips"
    assert res.store.mips_m == store.mips_m
    q = x[:8]
    np.testing.assert_array_equal(
        np.asarray(store.search(q, k_out=5, key=jax.random.key(0))[1]),
        np.asarray(res.store.search(q, k_out=5, key=jax.random.key(0))[1]))


def test_metric_snapshot_mismatch_refused(tmp_path):
    from repro.core import persist
    x = _corpus(128, 8, 4)
    store, _ = MutableKNNStore.build(
        x, k=6, cfg=OnlineConfig(metric="cosine"))
    persist.snapshot_store(store, str(tmp_path), 1)
    import json, pathlib
    step = persist.latest_snapshot(str(tmp_path))
    mf = pathlib.Path(persist._step_dir(str(tmp_path), step),
                      "manifest.json")
    m = json.loads(mf.read_text())
    m["metric"] = "l2"                 # corrupt the top-level echo only
    mf.write_text(json.dumps(m))
    with pytest.raises(persist.SnapshotError, match="metric"):
        persist.restore_store(str(tmp_path))


# ---------------------------------------------------------------------------
# filtered search: zero leakage
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["auto", "ref"])
@pytest.mark.parametrize("per_query", [False, True])
def test_filter_never_leaks(backend, per_query):
    rng = np.random.RandomState(11)
    n, nq = 128, 16
    x = jnp.asarray(rng.randn(n, 6).astype(np.float32))
    gi = jnp.asarray(rng.randint(0, n, size=(n, 6), dtype=np.int32))
    q = jnp.asarray(rng.randn(nq, 6).astype(np.float32))
    alive = jnp.asarray(rng.rand(n) < 0.8)       # tombstones too
    if per_query:
        filt = jnp.asarray(rng.rand(nq, n) < 0.4)
    else:
        filt = jnp.asarray(rng.rand(n) < 0.4)
    cfg = SearchConfig(beam=16, rounds=8, q_block=8, backend=backend)
    dd, ii = graph_search(x, gi, q, k_out=8, key=jax.random.key(1),
                          alive=alive, filter_ids=filt, cfg=cfg)
    dd, ii = np.asarray(dd), np.asarray(ii)
    assert ((ii >= 0) == np.isfinite(dd)).all()
    a, f = np.asarray(alive), np.asarray(filt)
    for r in range(nq):
        ids = ii[r][ii[r] >= 0]
        assert a[ids].all()
        assert (f[r] if per_query else f)[ids].all()
    assert (ii >= 0).any()                       # not vacuously empty


def test_filter_int8_and_store_path_no_leak():
    x = _corpus(256, 8, 7)
    store, _ = MutableKNNStore.build(
        x, k=6, cfg=OnlineConfig(precision="int8"))
    q = x[:12]
    # per-query tenancy: query i sees only rows with id % 2 == i % 2
    ids = jnp.arange(store.capacity)
    filt = (ids[None, :] % 2) == (jnp.arange(12)[:, None] % 2)
    dd, ii = store.search(q, k_out=6, filter_ids=filt)
    ii = np.asarray(ii)
    for r in range(12):
        got = ii[r][ii[r] >= 0]
        assert got.size and (got % 2 == r % 2).all()


def test_filter_frac_stat():
    f = jnp.asarray([True, False, False, True])
    assert metric_mod.filter_frac(f) == pytest.approx(0.5)
    assert metric_mod.filter_frac(None) == 1.0


def test_knn_logits_filter_passthrough():
    from repro.serve.knn_lm import KNNDatastore, knn_logits
    x = _corpus(128, 8, 9)
    vals = jnp.arange(128) % 32
    ds = KNNDatastore.build(x, vals, k=6)
    q = x[:8]
    filt = jnp.arange(128) < 64      # only the first half is visible
    lp = knn_logits(ds, q, 32, k=4, filter_ids=filt)
    # tokens only reachable via rows >= 64 must carry zero kNN mass:
    # compare against an unfiltered run restricted the hard way
    lp_full = knn_logits(ds, q, 32, k=4)
    assert lp.shape == lp_full.shape == (8, 32)
    assert bool(jnp.all(jnp.isfinite(lp)))


# ---------------------------------------------------------------------------
# scheduler result cache
# ---------------------------------------------------------------------------


def test_scheduler_result_cache_hits_and_invalidation():
    from repro.serve.scheduler import RetrievalScheduler, SchedulerConfig
    calls = []

    def search_fn(q, cfg):
        calls.append(int(q.shape[0]))
        m = q.shape[0]
        return jnp.zeros((m, 4)), jnp.tile(jnp.arange(4), (m, 1))

    s = RetrievalScheduler(search_fn,
                           cfg=SchedulerConfig(result_cache=4))
    q = np.random.RandomState(3).randn(8).astype(np.float32)
    r1 = s.submit(q)
    s.run_until_drained()
    assert r1.done and len(calls) == 1
    r2 = s.submit(q)                 # duplicate: answered at admission
    assert r2.done and s.cache_hits == 1 and len(calls) == 1
    np.testing.assert_array_equal(r2.idx, r1.idx)
    s.invalidate_cache()             # owner mutated the corpus
    r3 = s.submit(q)
    s.run_until_drained()
    assert s.cache_hits == 1 and len(calls) == 2 and r3.done
    # LRU bound holds
    for i in range(100, 110):
        s.submit(np.random.RandomState(i).randn(8).astype(np.float32))
    s.run_until_drained()
    st = s.stats()
    assert st["cache_size"] <= 4 and st["cache_hits"] == 1


def test_scheduler_deadline_cut_dispatch_not_cached():
    from repro.serve.scheduler import RetrievalScheduler, SchedulerConfig

    def search_fn(q, cfg):
        m = q.shape[0]
        return jnp.zeros((m, 2)), jnp.zeros((m, 2), jnp.int32)

    s = RetrievalScheduler(search_fn,
                           cfg=SchedulerConfig(result_cache=4))
    s.submit(np.ones(4, np.float32), deadline_ms=10_000)
    s.run_until_drained()
    assert s.stats()["cache_size"] == 0
