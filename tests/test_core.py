"""The paper's algorithm: NN-Descent build quality, selection variants,
greedy reorder, graph search, recall — validated against the paper's own
claims (recall > 99% at the quality operating point; reorder recovers
clusters; locality metric improves)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import (
    DescentConfig,
    NeighborLists,
    apply_permutation,
    brute_force_knn,
    build_knn_graph,
    graph_search,
    greedy_reorder,
    locality_stats,
    recall_at_k,
    window_cluster_purity,
)
from repro.core import datasets, heap


@pytest.fixture(scope="module")
def clustered_data():
    x, labels = datasets.clustered(jax.random.key(0), 2048, 16, 8,
                                   labels=True)
    return x, labels


@pytest.fixture(scope="module")
def truth(clustered_data):
    x, _ = clustered_data
    return brute_force_knn(x, x, 20)


def test_recall_paper_claim(clustered_data, truth):
    """Paper §2: 'recall of over 99% on all examined datasets' at the
    quality operating point (rho=1.5)."""
    x, _ = clustered_data
    _, ti = truth
    cfg = DescentConfig(k=20, rho=1.5, max_iters=25, delta=1e-4,
                        merge_size=120)
    _, idx, stats = build_knn_graph(x, k=20, cfg=cfg)
    r = recall_at_k(idx, ti)
    assert r > 0.99, r
    assert stats.reordered


def test_recall_fast_operating_point(clustered_data, truth):
    """Speed point (rho=1.0) still above 95%."""
    x, _ = clustered_data
    _, ti = truth
    cfg = DescentConfig(k=20, rho=1.0, max_iters=15)
    _, idx, _ = build_knn_graph(x, k=20, cfg=cfg)
    assert recall_at_k(idx, ti) > 0.95


def test_recall_regression_small_seeded():
    """Seeded end-to-end floor on a small Gaussian-blob set: recall@10
    >= 0.9 at the default operating point. Guards future kernel/selection
    changes against silently degrading graph quality (fast tier)."""
    x = datasets.clustered(jax.random.key(11), 512, 16, 8)
    _, ti = brute_force_knn(x, x, 10)
    cfg = DescentConfig(k=10, rho=1.0, max_iters=15)
    _, idx, stats = build_knn_graph(x, k=10, cfg=cfg, key=jax.random.key(5))
    r = recall_at_k(idx, ti)
    assert r >= 0.9, r
    assert stats.iters <= cfg.max_iters


def test_convergence_updates_decrease(clustered_data):
    x, _ = clustered_data
    cfg = DescentConfig(k=10, rho=1.0, max_iters=10, reorder=False)
    _, _, stats = build_knn_graph(x, k=10, cfg=cfg)
    u = stats.updates
    assert u[-1] < u[0] / 10, u           # strong decay = convergence


def test_selection_variants_equivalent_quality(clustered_data, truth):
    """naive / heap / turbo give the same quality family (paper §3.1:
    turbosampling is equal in expectation)."""
    x, _ = clustered_data
    _, ti = truth
    recalls = {}
    for sel in ("naive", "heap", "turbo"):
        cfg = DescentConfig(k=20, rho=1.0, max_iters=10, selection=sel,
                            reorder=False)
        _, idx, _ = build_knn_graph(x, k=20, cfg=cfg)
        recalls[sel] = recall_at_k(idx, ti)
    assert min(recalls.values()) > 0.90, recalls
    assert max(recalls.values()) - min(recalls.values()) < 0.06, recalls


def test_deterministic_given_key(clustered_data):
    x, _ = clustered_data
    cfg = DescentConfig(k=10, max_iters=4)
    _, i1, _ = build_knn_graph(x, k=10, cfg=cfg, key=jax.random.key(42))
    _, i2, _ = build_knn_graph(x, k=10, cfg=cfg, key=jax.random.key(42))
    np.testing.assert_array_equal(i1, i2)


def test_result_ids_are_original(clustered_data):
    """Reordering must not leak permuted ids to the caller."""
    x, _ = clustered_data
    dist, idx, stats = build_knn_graph(
        x, k=10, cfg=DescentConfig(k=10, max_iters=6, reorder=True))
    assert stats.reordered
    # neighbor 0 of node i must be at the distance the result claims,
    # measured in the ORIGINAL coordinates
    i0 = np.asarray(idx[:, 0])
    d0 = np.asarray(dist[:, 0])
    x_np = np.asarray(x)
    real = ((x_np - x_np[i0]) ** 2).sum(-1)
    np.testing.assert_allclose(real, d0, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# greedy reorder (paper §3.2, Algorithm 1)
# ---------------------------------------------------------------------------

def test_reorder_is_permutation(clustered_data):
    x, _ = clustered_data
    cfg = DescentConfig(k=10, max_iters=2, reorder=False)
    _, idx, _ = build_knn_graph(x, k=10, cfg=cfg)
    nl = NeighborLists(jnp.zeros_like(idx, dtype=jnp.float32), idx,
                       jnp.zeros_like(idx, dtype=bool))
    sigma, sigma_inv = greedy_reorder(nl)
    n = x.shape[0]
    assert sorted(np.asarray(sigma).tolist()) == list(range(n))
    np.testing.assert_array_equal(np.asarray(sigma)[np.asarray(sigma_inv)],
                                  np.arange(n))


def test_reorder_improves_locality(clustered_data):
    """The cachegrind stand-in: in-block edge fraction rises after σ
    (paper Table 1: LL read misses nearly halve)."""
    x, labels = clustered_data
    cfg = DescentConfig(k=10, rho=1.0, max_iters=4, reorder=False)
    dist, idx, _ = build_knn_graph(x, k=10, cfg=cfg)
    nl = NeighborLists(dist, idx, jnp.zeros_like(idx, dtype=bool))
    before = locality_stats(nl, block=128)
    sigma, sigma_inv = greedy_reorder(nl)
    _, nl2 = apply_permutation(x, nl, sigma, sigma_inv)
    after = locality_stats(nl2, block=128)
    assert after["in_block_fraction"] > 2 * before["in_block_fraction"], (
        before, after)
    assert after["mean_gather_spread"] < before["mean_gather_spread"]


def test_reorder_recovers_clusters(clustered_data):
    """Paper Fig. 4: windowed cluster purity high at the start of the
    reordered array."""
    x, labels = clustered_data
    cfg = DescentConfig(k=10, rho=1.0, max_iters=4, reorder=False)
    dist, idx, _ = build_knn_graph(x, k=10, cfg=cfg)
    nl = NeighborLists(dist, idx, jnp.zeros_like(idx, dtype=bool))
    sigma, _ = greedy_reorder(nl)
    starts, purity = window_cluster_purity(labels, sigma, window=256,
                                           stride=128)
    # 8 clusters -> random purity ~0.125; early windows should be >0.5
    assert max(purity[:4]) > 0.5, purity[:6]


# ---------------------------------------------------------------------------
# graph search (serving-side consumer)
# ---------------------------------------------------------------------------

def test_graph_search_recall():
    """Connected (single-gaussian) corpus: greedy graph search must find
    the true neighbors. (On CLUSTERED corpora the K-NN graph is
    disconnected by construction — no inter-cluster edges — so coverage
    comes from entry spread; see graph_search's entry default.)"""
    x = datasets.gaussian(jax.random.key(3), 2048, 16)
    cfg = DescentConfig(k=20, rho=1.5, max_iters=15, merge_size=120)
    _, gidx, _ = build_knn_graph(x, k=20, cfg=cfg)
    q = x[:64] + 0.01
    td, ti = brute_force_knn(x, q, 10, exclude_self=False)
    dist, idx = graph_search(x, gidx, q, k_out=10, beam=48, rounds=48)
    assert recall_at_k(idx, ti) > 0.9


def test_graph_search_disconnected_coverage(clustered_data):
    """Clustered corpus: beam-wide entry spread still reaches most
    clusters."""
    x, _ = clustered_data
    cfg = DescentConfig(k=20, rho=1.0, max_iters=8)
    _, gidx, _ = build_knn_graph(x, k=20, cfg=cfg)
    q = x[:64] + 0.01
    _, ti = brute_force_knn(x, q, 10, exclude_self=False)
    _, idx = graph_search(x, gidx, q, k_out=10, beam=64, rounds=48)
    assert recall_at_k(idx, ti) > 0.75


# ---------------------------------------------------------------------------
# bounded neighbor lists (heap.py)
# ---------------------------------------------------------------------------

def test_merge_keeps_sorted_and_counts():
    nl = heap.NeighborLists(
        jnp.array([[0.1, 0.5, 0.9]]), jnp.array([[3, 5, 9]], jnp.int32),
        jnp.zeros((1, 3), bool))
    cd = jnp.array([[0.05, 0.7]])
    ci = jnp.array([[7, 8]], jnp.int32)
    out, upd = heap.merge(nl, cd, ci)
    # 0.05 (id 7) enters; 0.7 (id 8) is beaten by 0.5 for the last slot
    assert int(upd[0]) == 1
    d = np.asarray(out.dist[0])
    assert (np.diff(d) >= 0).all()
    assert np.asarray(out.idx[0]).tolist() == [7, 3, 5]
