"""Distributed layer tests — these need >1 device, so they run in forked
interpreters with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the main test process must keep seeing ONE device per the dry-run
isolation requirement)."""
import pytest

from conftest import run_with_devices


@pytest.mark.slow
def test_exact_knn_sharded_matches_brute_force():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import datasets
        from repro.core.distributed import exact_knn_sharded
        from repro.core.recall import brute_force_knn, recall_at_k
        mesh = jax.make_mesh((8,), ('data',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = datasets.clustered(jax.random.key(0), 1024, 16, 8)
        d, i = exact_knn_sharded(mesh, x, 10)
        td, ti = brute_force_knn(x, x, 10)
        r = recall_at_k(i, ti)
        assert r > 0.99, r
        print('recall', r)
    """)
    assert "recall" in out


@pytest.mark.slow
def test_sharded_nn_descent_recall():
    out = run_with_devices("""
        import jax
        from repro.core import datasets
        from repro.core.distributed import build_knn_graph_sharded
        from repro.core.recall import brute_force_knn, recall_at_k
        from repro import DescentConfig
        mesh = jax.make_mesh((8,), ('data',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = datasets.clustered(jax.random.key(0), 1024, 16, 8)
        cfg = DescentConfig(k=10, rho=1.5, max_iters=12, merge_size=60,
                            reorder=False)
        d, i, st = build_knn_graph_sharded(mesh, x, 10, cfg=cfg)
        td, ti = brute_force_knn(x, x, 10)
        r = recall_at_k(i, ti)
        assert r > 0.93, (r, st)
        print('recall', r, st)
    """)
    assert "recall" in out


@pytest.mark.slow
def test_graph_search_sharded_recall():
    """Serving: replicated queries against row-sharded corpus + per-shard
    local subgraphs; the all_gather top-k merge must recover the global
    neighbors (each shard's local search is near-exhaustive here)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import datasets, DescentConfig, SearchConfig
        from repro.core.distributed import graph_search_sharded
        from repro.core.nn_descent import build_knn_graph
        from repro.core.recall import brute_force_knn, recall_at_k
        mesh = jax.make_mesh((8,), ('data',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        P, n, d = 8, 1024, 16
        n_local = n // P
        x = datasets.clustered(jax.random.key(0), n, d, 8)
        cfg = DescentConfig(k=10, rho=1.0, max_iters=10, reorder=False)
        # per-shard subgraphs in LOCAL ids (each shard's slice built
        # independently — the sharded-serving deployment shape)
        parts = []
        for s in range(P):
            _, gi, _ = build_knn_graph(x[s*n_local:(s+1)*n_local], k=10,
                                       cfg=cfg, key=jax.random.key(s))
            parts.append(gi)
        gidx = jnp.concatenate(parts)
        q = x[:64] + 0.01
        d_out, i_out = graph_search_sharded(
            mesh, x, gidx, q, k_out=10,
            cfg=SearchConfig(beam=32, rounds=24, expand=4),
            key=jax.random.key(2))
        _, ti = brute_force_knn(x, q, 10, exclude_self=False)
        r = recall_at_k(i_out, ti)
        assert r > 0.9, r
        # merged ids are unique and distances ascend
        i_np = np.asarray(i_out); d_np = np.asarray(d_out)
        for row in range(i_np.shape[0]):
            v = i_np[row][i_np[row] >= 0]
            assert len(set(v.tolist())) == len(v)
        fin = np.isfinite(d_np)
        assert (np.diff(np.where(fin, d_np, 3e38), axis=1) >= 0).all()
        print('recall', r)
    """)
    assert "recall" in out


@pytest.mark.slow
def test_compressed_psum_matches_plain():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.train.compression import compressed_psum
        mesh = jax.make_mesh((8,), ('pod',),
                             axis_types=(jax.sharding.AxisType.Auto,))
        @functools.partial(jax.shard_map, mesh=mesh, in_specs=(P('pod'), P('pod')),
                           out_specs=(P('pod'), P('pod')), check_vma=False)
        def f(g, res):
            red, new_res = compressed_psum(g[0], 'pod', res[0])
            return red[None], new_res[None]
        g = jax.random.normal(jax.random.key(0), (8, 4096)) * 0.01
        res = jnp.zeros((8, 4096))
        red, res1 = f(g, res)
        want = jnp.sum(g, axis=0)
        got = red[0]
        err = float(jnp.max(jnp.abs(got - want)))
        # int8 quantization noise, bounded by ~8 * step/2
        assert err < 8 * float(jnp.max(jnp.abs(g))) / 127, err
        # error feedback: the residual carries the quantization error
        assert float(jnp.max(jnp.abs(res1))) > 0
        print('ok', err)
    """)
    assert "ok" in out


@pytest.mark.slow
def test_train_step_lowers_on_test_mesh():
    """A small (2,2) mesh lower+compile of the real train_step with the
    real sharding rules — the unit-scale version of the dry-run."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config, input_specs
        from repro.models import abstract_tree, model_schema, sharding_tree
        from repro.models.sharding import activation_mesh
        from repro.train import TrainConfig, make_train_step
        from repro.train import optimizer as opt_mod
        from repro.launch.mesh import make_test_mesh
        import dataclasses
        cfg = dataclasses.replace(get_smoke_config('yi-6b'), remat='full')
        mesh = make_test_mesh((2, 2), ('data', 'model'))
        schema = model_schema(cfg)
        params_abs = abstract_tree(schema)
        params_sh = sharding_tree(schema, mesh)
        opt_abs = opt_mod.abstract_init(params_abs)
        opt_sh = opt_mod.AdamState(
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            params_sh, params_sh)
        B, L = 8, 128
        batch_abs = {'tokens': jax.ShapeDtypeStruct((B, L), jnp.int32),
                     'labels': jax.ShapeDtypeStruct((B, L), jnp.int32)}
        bs = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec('data'))
        step = make_train_step(cfg, TrainConfig(microbatches=2))
        with activation_mesh(mesh):
            lowered = jax.jit(step,
                in_shardings=(params_sh, opt_sh, {'tokens': bs, 'labels': bs}),
                out_shardings=(params_sh, opt_sh, None),
            ).lower(params_abs, opt_abs, batch_abs)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes > 0
        print('compiled ok', ma.temp_size_in_bytes)
    """)
    assert "compiled ok" in out


@pytest.mark.slow
def test_train_step_runs_sharded_and_matches_single_device():
    """EXECUTE one sharded train step on 8 devices and compare the loss
    to the single-device result (numerical equivalence of the
    distribution strategy)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import init_tree, model_schema, sharding_tree
        from repro.models.sharding import activation_mesh
        from repro.train import TrainConfig, make_train_step
        from repro.train import optimizer as opt_mod
        mesh = jax.make_mesh((4, 2), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = get_smoke_config('yi-6b')
        params = init_tree(jax.random.key(0), model_schema(cfg))
        state = opt_mod.init(params)
        B, L = 8, 64
        batch = {'tokens': jax.random.randint(jax.random.key(1), (B, L), 0, cfg.vocab),
                 'labels': jax.random.randint(jax.random.key(2), (B, L), 0, cfg.vocab)}
        step = make_train_step(cfg, TrainConfig())
        # single device
        p1, s1, m1 = jax.jit(step)(params, state, batch)
        # sharded
        sh = sharding_tree(model_schema(cfg), mesh)
        params_s = jax.tree.map(lambda x, s: jax.device_put(x, s), params, sh)
        bs = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec('data'))
        batch_s = jax.tree.map(lambda x: jax.device_put(x, bs), batch)
        state_s = opt_mod.init(params_s)
        with activation_mesh(mesh):
            p2, s2, m2 = jax.jit(step)(params_s, state_s, batch_s)
        l1, l2 = float(m1['loss']), float(m2['loss'])
        assert abs(l1 - l2) / max(abs(l1), 1e-9) < 2e-3, (l1, l2)
        g1, g2 = float(m1['grad_norm']), float(m2['grad_norm'])
        assert abs(g1 - g2) / max(abs(g1), 1e-9) < 2e-2, (g1, g2)
        print('match', l1, l2)
    """)
    assert "match" in out


@pytest.mark.slow
def test_graph_search_sharded_routed_parity_and_fanout():
    """Routed dispatch: with a router over the global corpus and
    route_p < P, each query's distances are evaluated on at most route_p
    shards (fan-out p < P asserted via the stats), yet the merged top-k
    stays >= 0.95 aligned with the replicated all-shard merge. Shards are
    cluster-ALIGNED (each shard holds whole clusters) so top-p shard
    routing can actually cover the true neighbors."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import DescentConfig, RouterConfig, SearchConfig
        from repro.core.distributed import graph_search_sharded
        from repro.core.nn_descent import build_knn_graph
        from repro.core.recall import brute_force_knn, recall_at_k
        from repro.core.router import build_router
        mesh = jax.make_mesh((8,), ('data',))
        P, n, d = 8, 1024, 16
        n_local = n // P
        # cluster-aligned rows: shard s holds one tight cluster
        cent = jax.random.normal(jax.random.key(0), (P, d)) * 8.0
        noise = jax.random.normal(jax.random.key(1), (P, n_local, d)) * 0.5
        x = (cent[:, None, :] + noise).reshape(n, d).astype(jnp.float32)
        cfg = DescentConfig(k=10, rho=1.0, max_iters=10, reorder=False)
        parts = []
        for s in range(P):
            _, gi, _ = build_knn_graph(x[s*n_local:(s+1)*n_local], k=10,
                                       cfg=cfg, key=jax.random.key(s))
            parts.append(gi)
        gidx = jnp.concatenate(parts)
        router = build_router(
            x, cfg=RouterConfig(n_centroids=32, sample=1024),
            key=jax.random.key(7))
        q = x[::8] + 0.01
        scfg = SearchConfig(beam=32, rounds=24, expand=4)
        kk = jax.random.key(2)
        rd, ri = graph_search_sharded(mesh, x, gidx, q, k_out=10,
                                      cfg=scfg, key=kk)
        d_out, i_out, st = graph_search_sharded(
            mesh, x, gidx, q, k_out=10, cfg=scfg, key=kk,
            router=router, route_p=2, route_cap=64, with_stats=True)
        # fan-out: p < P, and no query lost a shard to buffer overflow
        assert st['fanout'] == 2 and st['shards'] == 8, st
        assert st['dropped_queries'] == 0, st
        assert st['searched_queries'] == st['routed_queries'], st
        # routed top-k vs replicated top-k intersection
        ra, rb = np.asarray(ri), np.asarray(i_out)
        inter = np.mean([
            len(set(ra[r][ra[r] >= 0]) & set(rb[r][rb[r] >= 0])) / 10.0
            for r in range(ra.shape[0])])
        assert inter >= 0.95, inter
        _, ti = brute_force_knn(x, q, 10, exclude_self=False)
        r_rep = recall_at_k(ri, ti)
        r_rt = recall_at_k(i_out, ti)
        assert r_rt > 0.9, (r_rt, r_rep)
        print('routed', float(r_rt), float(r_rep), float(inter), st)
    """)
    assert "routed" in out
