"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracles in kernels/ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.knn_merge import knn_merge_blocked
from repro.kernels.l2_blocked import pairwise_sq_l2_blocked, vmem_bytes


# ---------------------------------------------------------------------------
# l2_blocked (paper §3.3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,d", [
    (128, 128, 128), (200, 130, 96), (64, 256, 300),
    (1, 128, 8), (128, 1, 513), (37, 41, 7),
])
def test_l2_blocked_shapes(m, n, d):
    k1, k2 = jax.random.split(jax.random.key(m * 1000 + n))
    a = jax.random.normal(k1, (m, d), jnp.float32)
    b = jax.random.normal(k2, (n, d), jnp.float32)
    out = pairwise_sq_l2_blocked(a, b, tm=128, tn=128, tk=128,
                                 interpret=True)
    np.testing.assert_allclose(out, ref.pairwise_sq_l2(a, b),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_blocked_dtypes(dtype):
    k1, k2 = jax.random.split(jax.random.key(0))
    a = jax.random.normal(k1, (96, 64)).astype(dtype)
    b = jax.random.normal(k2, (80, 64)).astype(dtype)
    out = pairwise_sq_l2_blocked(a, b, tm=128, tn=128, tk=128,
                                 interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, ref.pairwise_sq_l2(a, b),
                               rtol=tol, atol=tol)


def test_l2_blocked_vs_diff_form():
    """The norm-expansion kernel vs the paper's diff-FMA oracle — the
    numerics assumption change (DESIGN.md #2): clamp guards cancellation."""
    k1, k2 = jax.random.split(jax.random.key(3))
    a = jax.random.normal(k1, (64, 32), jnp.float32)
    b = a + 1e-4 * jax.random.normal(k2, (64, 32), jnp.float32)
    out = pairwise_sq_l2_blocked(a, b, interpret=True, tk=128)
    want = ref.pairwise_sq_l2_diff(a, b)
    assert float(jnp.min(out)) >= 0.0
    np.testing.assert_allclose(out, want, atol=1e-4)


def test_l2_blocked_tile_sweep():
    k1, k2 = jax.random.split(jax.random.key(1))
    a = jax.random.normal(k1, (130, 100), jnp.float32)
    b = jax.random.normal(k2, (70, 100), jnp.float32)
    want = ref.pairwise_sq_l2(a, b)
    for tm, tn, tk in [(8, 128, 128), (128, 8, 128), (16, 16, 256)]:
        out = pairwise_sq_l2_blocked(a, b, tm=tm, tn=tn, tk=tk,
                                     interpret=True)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)


def test_vmem_budget():
    # default tiles must fit v5e VMEM (~128 MiB, budget half for pipeline)
    assert vmem_bytes(128, 128, 512) < 64 * 1024 * 1024


# ---------------------------------------------------------------------------
# knn_merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,c", [(64, 8, 12), (100, 20, 7), (256, 4, 40)])
def test_knn_merge_shapes(n, k, c):
    key = jax.random.key(n + k)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    cur_d = jnp.sort(jax.random.uniform(k1, (n, k)), axis=1)
    cur_i = jax.random.randint(k2, (n, k), 0, 10 * n)
    cand_d = jax.random.uniform(k3, (n, c))
    cand_i = jax.random.randint(k4, (n, c), -1, 10 * n)
    got = knn_merge_blocked(cur_d, cur_i, cand_d, cand_i, tm=32,
                            interpret=True)
    want = ref.knn_merge(cur_d, cur_i, cand_d, cand_i)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-6)
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_array_equal(got[2], want[2])


def test_knn_merge_dedup():
    """Candidates already present in the list must not be double-counted."""
    cur_d = jnp.array([[0.1, 0.2, jnp.inf]])
    cur_i = jnp.array([[5, 7, -1]], jnp.int32)
    cand_d = jnp.array([[0.05, 0.1, 0.3]])
    cand_i = jnp.array([[7, 5, 9]], jnp.int32)    # 7 and 5 are dups
    d, i, upd = knn_merge_blocked(cur_d, cur_i, cand_d, cand_i, tm=8,
                                  interpret=True)
    assert int(upd[0]) == 1                       # only 9 accepted
    assert 9 in np.asarray(i[0])
    assert sorted(np.asarray(i[0]).tolist()) == [5, 7, 9]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    dict(causal=True, window=None, softcap=None),
    dict(causal=True, window=64, softcap=None),
    dict(causal=True, window=None, softcap=20.0),
    dict(causal=False, window=None, softcap=None),
])
def test_flash_attention_modes(cfg):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    B, L, H, Hkv, Dh = 2, 256, 4, 2, 32
    q = jax.random.normal(k1, (B, L, H, Dh), jnp.float32)
    k = jax.random.normal(k2, (B, L, Hkv, Dh), jnp.float32)
    v = jax.random.normal(k3, (B, L, Hkv, Dh), jnp.float32)
    got = flash_attention(q, k, v, tq=128, tk=128, interpret=True, **cfg)
    want = ref.attention(q, k, v, **cfg)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_gqa_fold():
    """kv-head folding in the index map must match repeat-based ref."""
    k1, k2, k3 = jax.random.split(jax.random.key(5), 3)
    B, L, H, Hkv, Dh = 1, 128, 8, 2, 16
    q = jax.random.normal(k1, (B, L, H, Dh))
    k = jax.random.normal(k2, (B, L, Hkv, Dh))
    v = jax.random.normal(k3, (B, L, Hkv, Dh))
    got = flash_attention(q, k, v, tq=128, tk=128, interpret=True)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
