"""core/persist.py: snapshot/restore crash safety, bit-identical round
trips (store + quantized mirror + router + tombstones), mutate-after-
restore parity, the async writer + retention, the quantized-first cold
start, and the scheduler's zero-rebuild cold-start path."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import persist
from repro.core.nn_descent import DescentConfig
from repro.core.router import RouterConfig
from repro.serve.knn_lm import KNNDatastore, MutableKNNDatastore
from repro.serve.scheduler import ContinuousBatcher, Request


def _build(n=256, d=8, k=6, precision="int8", router=True):
    x = jax.random.normal(jax.random.key(0), (n, d), jnp.float32)
    vals = jnp.arange(n, dtype=jnp.int32)
    rcfg = (RouterConfig(n_centroids=8, sample=256, members=16, iters=2)
            if router else None)
    return MutableKNNDatastore.build(
        x, vals, k=k, cfg=DescentConfig(k=k, rho=1.0, max_iters=6),
        precision=precision, router=rcfg, key=jax.random.key(1))


def _mutate(ds, d=8):
    """Tombstones + streamed rows, so snapshots carry real online state."""
    ds, _ = ds.delete(jnp.arange(5, dtype=jnp.int32))
    extra = jax.random.normal(jax.random.key(2), (7, d), jnp.float32)
    ds, _ = ds.append(extra, jnp.arange(7, dtype=jnp.int32) + 1000,
                      key=jax.random.key(3))
    return ds


def _search_bits(ds, d=8, k_out=6):
    q = jax.random.normal(jax.random.key(4), (16, d), jnp.float32)
    dist, idx = ds.store.search(q, k_out=k_out, key=jax.random.key(5))
    return (np.asarray(dist, np.float32).view(np.int32),
            np.asarray(idx, np.int32))


def _store_arrays(store):
    out = {"x": store.x, "x2": store.x2, "alive": store.alive,
           "nl_dist": store.nl.dist, "nl_idx": store.nl.idx,
           "nl_new": store.nl.new}
    if store.qs is not None:
        out.update(qs_data=store.qs.data, qs_scale=store.qs.scale,
                   qs_x2=store.qs.x2)
    if store.router is not None:
        out.update(r_centroids=store.router.centroids,
                   r_c2=store.router.c2, r_graph=store.router.graph,
                   r_assign=store.router.assign,
                   r_counts=store.router.counts,
                   r_stale=store.router.stale,
                   r_mdist=store.router.members.dist,
                   r_midx=store.router.members.idx,
                   r_mnew=store.router.members.new)
    return out


def _assert_stores_equal(s1, s2):
    a1, a2 = _store_arrays(s1), _store_arrays(s2)
    assert a1.keys() == a2.keys()
    for name in a1:
        x, y = np.asarray(a1[name]), np.asarray(a2[name])
        assert x.shape == y.shape and x.dtype == y.dtype, name
        assert (x == y).all(), name
    assert s1.n == s2.n and s1.d == s2.d and s1.cfg == s2.cfg


def test_round_trip_bit_identical(tmp_path):
    ds = _mutate(_build())
    step_dir = ds.snapshot(str(tmp_path))
    assert os.path.exists(os.path.join(step_dir, "COMMIT"))
    ds2 = MutableKNNDatastore.restore(str(tmp_path))
    _assert_stores_equal(ds.store, ds2.store)
    assert (np.asarray(ds.values) == np.asarray(ds2.values)).all()
    assert ds2.build_stats["tombstones"] == 5
    b1, i1 = _search_bits(ds)
    b2, i2 = _search_bits(ds2)
    assert (i1 == i2).all() and (b1 == b2).all()


def test_partial_dir_without_commit_marker_is_invisible(tmp_path):
    ds = _build(router=False)
    ds.snapshot(str(tmp_path), step=10)
    # a higher-step directory whose writer died before the marker: holds
    # arrays and even a manifest, but no COMMIT
    partial = tmp_path / "step_00000020"
    partial.mkdir()
    np.save(partial / "x.npy", np.zeros((4, 4), np.float32))
    (partial / "manifest.json").write_text("{}")
    assert persist.list_snapshots(str(tmp_path)) == [10]
    assert persist.latest_snapshot(str(tmp_path)) == 10
    # default restore silently lands on the committed step...
    ds2 = MutableKNNDatastore.restore(str(tmp_path))
    assert ds2.build_stats["restored_step"] == 10
    # ...and asking for the partial step by name refuses loudly
    with pytest.raises(persist.SnapshotError, match="COMMIT"):
        persist.read_snapshot(str(tmp_path), 20)


def test_no_committed_snapshot_raises(tmp_path):
    with pytest.raises(persist.SnapshotError, match="no committed"):
        persist.read_snapshot(str(tmp_path))


def test_truncated_array_file_names_the_file(tmp_path):
    ds = _build(router=False)
    step_dir = ds.snapshot(str(tmp_path))
    fp = os.path.join(step_dir, "x.npy")
    with open(fp, "r+b") as f:
        f.truncate(40)      # mid-header: np.load fails outright
    with pytest.raises(persist.SnapshotError, match="x.npy"):
        persist.read_snapshot(str(tmp_path))


def test_short_array_file_names_the_file(tmp_path):
    ds = _build(router=False)
    step_dir = ds.snapshot(str(tmp_path))
    fp = os.path.join(step_dir, "nl_idx.npy")
    # a loadable-but-wrong file (e.g. torn write recovered by the fs):
    # shape disagrees with the manifest -> refused, file named
    np.save(fp, np.zeros((2, 2), np.int32))
    with pytest.raises(persist.SnapshotError, match="nl_idx.npy"):
        persist.read_snapshot(str(tmp_path))


def test_format_version_mismatch_refuses(tmp_path):
    ds = _build(router=False)
    step_dir = ds.snapshot(str(tmp_path))
    mf = os.path.join(step_dir, "manifest.json")
    with open(mf) as f:
        manifest = json.load(f)
    manifest["format_version"] = persist.FORMAT_VERSION + 1
    with open(mf, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(persist.SnapshotError, match="format version"):
        persist.read_snapshot(str(tmp_path))


def test_mutate_after_restore_parity(tmp_path):
    """Restored stores are not read-only artifacts: inserts and deletes
    (router + mirror maintenance included) must track the never-
    snapshotted store bit for bit."""
    ds = _mutate(_build())
    ds.snapshot(str(tmp_path))
    ds2 = MutableKNNDatastore.restore(str(tmp_path))
    extra = jax.random.normal(jax.random.key(6), (9, 8), jnp.float32)
    ev = jnp.arange(9, dtype=jnp.int32) + 2000
    a1, _ = ds.append(extra, ev, key=jax.random.key(7))
    a2, _ = ds2.append(extra, ev, key=jax.random.key(7))
    d1, _ = a1.delete(jnp.arange(20, 30, dtype=jnp.int32))
    d2, _ = a2.delete(jnp.arange(20, 30, dtype=jnp.int32))
    _assert_stores_equal(d1.store, d2.store)
    assert (np.asarray(d1.values) == np.asarray(d2.values)).all()
    b1, i1 = _search_bits(d1)
    b2, i2 = _search_bits(d2)
    assert (i1 == i2).all() and (b1 == b2).all()


def test_bf16_mirror_round_trips(tmp_path):
    """npy can't describe bfloat16 — the format stores raw bits + the
    logical dtype in the manifest and must view them back exactly."""
    ds = _build(precision="bf16", router=False)
    ds.snapshot(str(tmp_path))
    ds2 = MutableKNNDatastore.restore(str(tmp_path))
    assert ds2.store.qs.data.dtype == jnp.bfloat16
    assert (np.asarray(ds.store.qs.data.view(jnp.uint16))
            == np.asarray(ds2.store.qs.data.view(jnp.uint16))).all()


def test_snapshot_writer_async_and_retention(tmp_path):
    ds = _build(router=False)
    w = persist.SnapshotWriter(str(tmp_path), keep=2)
    for step in (1, 2, 3):
        w.save(ds.store, step, values=ds.values, wait=False)
    w.wait()
    # keep=2: only the newest two committed snapshots survive
    assert persist.list_snapshots(str(tmp_path)) == [2, 3]
    ds2 = MutableKNNDatastore.restore(str(tmp_path))
    _assert_stores_equal(ds.store, ds2.store)


def test_failed_rewrite_keeps_committed_same_step(tmp_path):
    """Retention edge (regression): the scheduler re-uses step=store.n
    when no inserts landed between snapshots, so a re-snapshot of an
    already-COMMITTED step whose write then fails must leave the
    committed copy exactly as it was — the old rmtree-then-rewrite
    policy destroyed it first and failed after, losing the only
    committed snapshot."""
    from repro.core.faults import FaultPlan, FaultSpec, InjectedFault
    ds = _build(router=False)
    ds.snapshot(str(tmp_path), step=5)
    plan = FaultPlan(specs=(FaultSpec(site="persist.write"),))
    with plan.active(), pytest.raises(InjectedFault):
        ds.snapshot(str(tmp_path), step=5)
    # the committed step survived the failed rewrite, bit for bit
    assert persist.list_snapshots(str(tmp_path)) == [5]
    ds2 = MutableKNNDatastore.restore(str(tmp_path))
    _assert_stores_equal(ds.store, ds2.store)
    b1, i1 = _search_bits(ds)
    b2, i2 = _search_bits(ds2)
    assert (i1 == i2).all() and (b1 == b2).all()


def test_rewrite_same_step_replaces_atomically(tmp_path):
    """The successful-rewrite half of the same edge: a re-snapshot of a
    committed step swaps the new bytes in and leaves no staging or
    backup directories behind."""
    ds = _build(router=False)
    ds.snapshot(str(tmp_path), step=5)
    ds2 = _mutate(ds)
    ds2.snapshot(str(tmp_path), step=5)
    assert persist.list_snapshots(str(tmp_path)) == [5]
    r = MutableKNNDatastore.restore(str(tmp_path))
    _assert_stores_equal(ds2.store, r.store)
    leftovers = [d for d in os.listdir(str(tmp_path))
                 if d.endswith((".tmp", ".old"))]
    assert leftovers == []


def test_snapshot_writer_surfaces_background_errors(tmp_path):
    ds = _build(router=False)
    blocker = tmp_path / "snaps"
    blocker.write_text("not a directory")    # makedirs will raise
    w = persist.SnapshotWriter(str(blocker))
    w.save(ds.store, 1, wait=False)
    with pytest.raises(Exception):
        w.wait()


def test_quantized_first_restore(tmp_path):
    ds = _mutate(_build())
    ds.snapshot(str(tmp_path))
    exact = MutableKNNDatastore.restore(str(tmp_path))
    qf = MutableKNNDatastore.restore(str(tmp_path), quantized_first=True)
    assert qf.fp32_loader is not None
    # immediately servable: two-stage quantized-only search runs
    _search_bits(qf)
    # after the background fp32 load lands, results are exact again
    qf = qf.finish_fp32()
    assert qf.fp32_loader is None
    _assert_stores_equal(exact.store, qf.store)
    b1, i1 = _search_bits(exact)
    b2, i2 = _search_bits(qf)
    assert (i1 == i2).all() and (b1 == b2).all()


def test_quantized_first_requires_mirror(tmp_path):
    ds = _build(precision="f32", router=False)
    ds.snapshot(str(tmp_path))
    with pytest.raises(persist.SnapshotError, match="quantized mirror"):
        MutableKNNDatastore.restore(str(tmp_path), quantized_first=True)


def test_static_datastore_round_trip(tmp_path):
    keys = jax.random.normal(jax.random.key(0), (128, 8), jnp.float32)
    vals = jax.random.randint(jax.random.key(1), (128,), 0, 16)
    ds = KNNDatastore.build(
        keys, vals, k=6, cfg=DescentConfig(k=6, rho=1.0, max_iters=6),
        precision="int8",
        router=RouterConfig(n_centroids=8, sample=128, members=16,
                            iters=2),
        key=jax.random.key(2))
    ds.snapshot(str(tmp_path))
    ds2 = KNNDatastore.restore(str(tmp_path))
    for name in ("keys", "values", "graph_idx"):
        assert (np.asarray(getattr(ds, name))
                == np.asarray(getattr(ds2, name))).all(), name
    assert (np.asarray(ds.qstore.data) == np.asarray(ds2.qstore.data)).all()
    assert (np.asarray(ds.router.centroids)
            == np.asarray(ds2.router.centroids)).all()
    assert ds2.build_stats["restored_step"] == 0
    # a mutable-store snapshot is not a static-datastore snapshot
    with pytest.raises(persist.SnapshotError, match="kind"):
        arrays, meta = persist.capture_store(_build(router=False).store)
        persist.rebuild_datastore(arrays, {"kind": "mutable_store",
                                           **meta})


def test_scheduler_cold_start_and_drain_snapshot(tmp_path):
    """ContinuousBatcher(knn_snapshot_dir=...): with no store passed, the
    batcher restores from the newest committed snapshot instead of
    rebuilding; run() leaves a drain snapshot carrying the streamed
    inserts for the NEXT cold start."""
    vocab, dk = 16, 8
    keys0 = jax.random.normal(jax.random.key(0), (64, dk))
    vals0 = jax.random.randint(jax.random.key(1), (64,), 0, vocab)
    ds = MutableKNNDatastore.build(keys0, vals0, k=8,
                                   key=jax.random.key(2))
    ds.snapshot(str(tmp_path))
    proj = jax.random.normal(jax.random.key(5), (vocab, dk))

    def prefill_fn(toks):
        return jnp.ones((1, vocab)), None, toks.shape[1]

    def step_fn(cache, toks, lengths):
        lg = jax.nn.one_hot((toks[:, 0] * 3 + lengths) % vocab, vocab) * 4.0
        return lg, cache

    b = ContinuousBatcher(
        2, step_fn, prefill_fn, lambda c, i, o, length: c,
        knn_capture=lambda lg: lg @ proj, knn_chunk=8,
        knn_snapshot_dir=str(tmp_path), knn_snapshot_every=8)
    # cold start: the store came from the snapshot, not a rebuild
    assert b.knn_store is not None
    assert b.knn_store.build_stats["restored_step"] == ds.store.n
    _assert_stores_equal(ds.store, b.knn_store.store)
    for r in range(3):
        b.submit(Request(rid=r, prompt=np.array([1, 2, 3], np.int32),
                         max_new=8))
    b.run(None)
    assert b.knn_store.store.n == ds.store.n + 21
    # the drain snapshot is committed at the new high-water mark, so a
    # second cold start resumes from the full stream
    assert persist.latest_snapshot(str(tmp_path)) == ds.store.n + 21
    b2 = ContinuousBatcher(
        2, step_fn, prefill_fn, lambda c, i, o, length: c,
        knn_capture=lambda lg: lg @ proj, knn_chunk=8,
        knn_snapshot_dir=str(tmp_path))
    _assert_stores_equal(b.knn_store.store, b2.knn_store.store)


def test_drain_snapshot_survives_failed_periodic_write(tmp_path):
    """Regression: a failed PERIODIC background snapshot used to
    re-raise at the drain's save() and abort it — the full stream's
    final snapshot was silently lost. Now the drain commits and the
    stale error degrades to a warning."""
    from repro.core.faults import FaultPlan, FaultSpec
    vocab, dk = 16, 8
    keys0 = jax.random.normal(jax.random.key(0), (64, dk))
    vals0 = jax.random.randint(jax.random.key(1), (64,), 0, vocab)
    MutableKNNDatastore.build(keys0, vals0, k=8,
                              key=jax.random.key(2)).snapshot(str(tmp_path))
    proj = jax.random.normal(jax.random.key(5), (vocab, dk))

    def prefill_fn(toks):
        return jnp.ones((1, vocab)), None, toks.shape[1]

    def step_fn(cache, toks, lengths):
        lg = jax.nn.one_hot((toks[:, 0] * 3 + lengths) % vocab, vocab) * 4.0
        return lg, cache

    b = ContinuousBatcher(
        2, step_fn, prefill_fn, lambda c, i, o, length: c,
        knn_capture=lambda lg: lg @ proj, knn_chunk=8,
        knn_snapshot_dir=str(tmp_path), knn_snapshot_every=16)
    for r in range(3):
        b.submit(Request(rid=r, prompt=np.array([1, 2, 3], np.int32),
                         max_new=8))
    # 21 streamed rows → exactly ONE periodic snapshot (at >=16 rows);
    # its write fails persistently (3 events outlast the default 2
    # retries), then the fault budget is spent — the drain write is clean
    plan = FaultPlan(specs=(FaultSpec(site="persist.write", times=3),))
    with plan.active(), pytest.warns(RuntimeWarning, match="supersedes"):
        b.run(None)
    assert plan.fired("persist.write") == 3
    # the drain snapshot landed at the final high-water mark anyway
    assert persist.latest_snapshot(str(tmp_path)) == b.knn_store.store.n
    b2 = ContinuousBatcher(
        2, step_fn, prefill_fn, lambda c, i, o, length: c,
        knn_capture=lambda lg: lg @ proj, knn_chunk=8,
        knn_snapshot_dir=str(tmp_path))
    _assert_stores_equal(b.knn_store.store, b2.knn_store.store)
