"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward + one train step on CPU with correct
output shapes and no NaNs. Plus targeted layer-level equivalence tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import forward, init_tree, loss_fn, model_schema, param_count
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_rope
from repro.kernels import ref
from repro.train import OptimizerConfig, TrainConfig, make_train_step
from repro.train import optimizer as opt_mod

ARCHS = list_archs()


def _batch(cfg, B=2, L=64, key=0):
    ks = jax.random.split(jax.random.key(key), 4)
    b = {"tokens": jax.random.randint(ks[0], (B, L), 0, cfg.vocab),
         "labels": jax.random.randint(ks[1], (B, L), 0, cfg.vocab)}
    if cfg.frontend == "audio":
        b = {"frames": jax.random.normal(ks[2], (B, L, cfg.frontend_dim)),
             "labels": b["labels"]}
    elif cfg.frontend == "vision":
        b["patches"] = jax.random.normal(
            ks[3], (B, cfg.n_patches, cfg.frontend_dim))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    params = init_tree(jax.random.key(0), model_schema(cfg))
    B, L = 2, 64
    batch = _batch(cfg, B, L)
    logits = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    L_out = L + (cfg.n_patches if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, L_out, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_tree(jax.random.key(0), model_schema(cfg))
    state = opt_mod.init(params)
    tc = TrainConfig(opt=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                         total_steps=10))
    step = jax.jit(make_train_step(cfg, tc))
    batch = _batch(cfg)
    p1, s1, m1 = step(params, state, batch)
    assert bool(jnp.isfinite(m1["loss"]))
    assert int(m1.get("skipped", 0)) == 0
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree.map(lambda a, b: (a, b), p1, params), 0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", ["yi-6b", "gemma2-27b", "mamba2-130m"])
def test_smoke_microbatched_grads_match(arch):
    """Gradient accumulation must equal the single-batch gradient.
    f32 activations: this is a numerics test, not a dtype test."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config(arch),
                              act_dtype=jnp.float32)
    params = init_tree(jax.random.key(0), model_schema(cfg))
    batch = _batch(cfg, B=4, L=32)

    g1, _ = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg)[0]))(params), None
    tc1 = TrainConfig(microbatches=1, opt=OptimizerConfig(lr=0.0,
                                                          weight_decay=0.0))
    tc4 = TrainConfig(microbatches=4, opt=OptimizerConfig(lr=0.0,
                                                          weight_decay=0.0))
    s0 = opt_mod.init(params)
    _, s1, m1 = jax.jit(make_train_step(cfg, tc1))(params, s0, batch)
    _, s4, m4 = jax.jit(make_train_step(cfg, tc4))(params, s0, batch)
    # first Adam moment after one step = (1-b1) * grad -> compare moments
    flat1 = jax.tree.leaves(s1.m)
    flat4 = jax.tree.leaves(s4.m)
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_rope_rotation_invariance():
    """RoPE: relative scores depend only on distance."""
    k1, k2 = jax.random.split(jax.random.key(0))
    q = jax.random.normal(k1, (1, 1, 1, 32))
    k = jax.random.normal(k2, (1, 1, 1, 32))
    def score(qpos, kpos):
        qr = apply_rope(q, jnp.array([[qpos]]))
        kr = apply_rope(k, jnp.array([[kpos]]))
        return float(jnp.sum(qr * kr))
    assert abs(score(5, 3) - score(105, 103)) < 1e-3
    assert abs(score(5, 3) - score(6, 3)) > 1e-5


def test_chunked_attention_matches_ref():
    """models.attention chunked scan == kernels.ref full softmax."""
    ks = jax.random.split(jax.random.key(0), 3)
    B, L, H, Hkv, Dh = 2, 130, 4, 2, 16
    q = jax.random.normal(ks[0], (B, L, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, Hkv, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, Hkv, Dh), jnp.float32)
    for kwargs in [dict(causal=True), dict(causal=True, window=32),
                   dict(causal=True, softcap=10.0)]:
        got = attn_mod.chunked_attention(q, k, v, cq=64, ckv=64, **kwargs)
        want = ref.attention(q, k, v, **kwargs)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_triangle_schedule_matches_rectangular():
    """§Perf optimization must be numerically identical."""
    ks = jax.random.split(jax.random.key(1), 3)
    B, L, H, Dh = 1, 256, 2, 16
    q = jax.random.normal(ks[0], (B, L, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, H, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, H, Dh), jnp.float32)
    rect = attn_mod.chunked_attention(q, k, v, causal=True, cq=64, ckv=64)
    tri = attn_mod.chunked_attention(q, k, v, causal=True, cq=64, ckv=64,
                                     triangle=True)
    np.testing.assert_allclose(tri, rect, rtol=1e-5, atol=1e-5)


def test_ssd_equals_naive_recurrence():
    """Chunked SSD == direct per-token recurrence."""
    key = jax.random.key(0)
    ks = jax.random.split(key, 5)
    B, L, H, P, N, G = 1, 40, 2, 4, 8, 1
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, G, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, L, G, N), jnp.float32)
    got = ssm_mod.ssd_scan(x, dt, A, Bm, Cm, chunk=16)

    h = np.zeros((B, H, P, N), np.float32)
    want = np.zeros((B, L, H, P), np.float32)
    xn, dtn, An = map(np.asarray, (x, dt, A))
    Bn, Cn = np.asarray(Bm), np.asarray(Cm)
    for t in range(L):
        for hh in range(H):
            a = np.exp(dtn[:, t, hh] * An[hh])
            h[:, hh] = a[:, None, None] * h[:, hh] + (
                dtn[:, t, hh][:, None, None]
                * xn[:, t, hh][:, :, None] * Bn[:, t, 0][:, None, :])
            want[:, t, hh] = np.einsum("bpn,bn->bp", h[:, hh], Cn[:, t, 0])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_param_counts_sane():
    """Full configs land near their nameplate sizes."""
    from repro.configs import get_config
    expect = {
        "yi-6b": (5.5e9, 7.0e9),
        "gemma2-27b": (26e9, 29e9),
        "codeqwen1.5-7b": (6.3e9, 8.5e9),   # MHA kv=32 per assignment
        "starcoder2-3b": (2.8e9, 3.5e9),
        "hubert-xlarge": (0.9e9, 1.3e9),
        "zamba2-1.2b": (1.0e9, 1.5e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "granite-moe-3b-a800m": (2.8e9, 3.8e9),
        "internvl2-1b": (0.4e9, 1.1e9),   # 0.5B nameplate counts ViT too
        "mamba2-130m": (0.1e9, 0.17e9),
    }
    from repro.models import param_count
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, (arch, f"{n:,}")
