"""Serving: prefill/decode equivalence per arch, ring caches, continuous
batching, kNN-LM retrieval."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import forward, init_tree, model_schema
from repro.serve import (
    ContinuousBatcher,
    KNNDatastore,
    Request,
    init_cache,
    interpolate,
    knn_logits,
    prefill,
    serve_step,
)

DECODE_ARCHS = [a for a in list_archs()
                if not get_smoke_config(a).encoder_only]


def _f32(cfg):
    return dataclasses.replace(cfg, cache_dtype=jnp.float32,
                               act_dtype=jnp.float32)


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """prefill(L-1) + decode(1) logits == full forward's last position."""
    cfg = _f32(get_smoke_config(arch))
    params = init_tree(jax.random.key(0), model_schema(cfg))
    B, L, S = 2, 33, 64
    toks = jax.random.randint(jax.random.key(1), (B, L), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            jax.random.key(3), (B, cfg.n_patches, cfg.frontend_dim))
    full = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    pre = dict(batch)
    pre["tokens"] = toks[:, :-1]
    _, cache, lengths = jax.jit(
        lambda p, b: prefill(p, b, cfg, S))(params, pre)
    got, _ = jax.jit(
        lambda p, c, t, l: serve_step(p, c, t, l, cfg))(
        params, cache, toks[:, -1:], lengths)
    ref = full[:, -1]
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(got - ref))) / scale < 2e-2


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_multi_token_decode_consistency(arch):
    """Decoding 4 tokens step-by-step == forward on the extended seq."""
    cfg = _f32(get_smoke_config(arch))
    params = init_tree(jax.random.key(0), model_schema(cfg))
    B, L0, T, S = 1, 17, 4, 64
    toks = jax.random.randint(jax.random.key(2), (B, L0 + T), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            jax.random.key(3), (B, cfg.n_patches, cfg.frontend_dim))
    full = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)

    pre = dict(batch)
    pre["tokens"] = toks[:, :L0]
    _, cache, lengths = jax.jit(
        lambda p, b: prefill(p, b, cfg, S))(params, pre)
    step = jax.jit(lambda p, c, t, l: serve_step(p, c, t, l, cfg))
    outs = []
    for t in range(T):
        lg, cache = step(params, cache, toks[:, L0 + t:L0 + t + 1], lengths)
        lengths = lengths + 1
        outs.append(lg)
    got = jnp.stack(outs, axis=1)           # (B, T, V)
    off = cfg.n_patches if cfg.frontend == "vision" else 0
    ref = full[:, off + L0:off + L0 + T]
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(got - ref))) / scale < 3e-2


def test_ring_cache_window_equivalence():
    """A windowed arch decoding past the window must match the full
    forward — exercises the ring-buffer cache (starcoder2 family)."""
    cfg = _f32(get_smoke_config("starcoder2-3b"))
    assert cfg.window is not None
    params = init_tree(jax.random.key(0), model_schema(cfg))
    B = 1
    L_total = cfg.window + 24          # decode well past the window
    S = cfg.window                     # ring cache = window slots exactly
    toks = jax.random.randint(jax.random.key(4), (B, L_total), 0, cfg.vocab)
    full = jax.jit(lambda p, b: forward(p, b, cfg))(
        params, {"tokens": toks})
    L0 = 16
    _, cache, lengths = jax.jit(
        lambda p, b: prefill(p, b, cfg, S))(params, {"tokens": toks[:, :L0]})
    step = jax.jit(lambda p, c, t, l: serve_step(p, c, t, l, cfg))
    last = None
    for t in range(L0, L_total):
        last, cache = step(params, cache, toks[:, t:t + 1], lengths)
        lengths = lengths + 1
    ref = full[:, -1]
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(last - ref))) / scale < 3e-2


def test_mla_cache_is_latent_sized():
    """deepseek-v2's decode cache must store the compressed latent, not
    per-head K/V — the arch's KV-memory contribution."""
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    from repro.serve.decode import cache_schema
    from repro.models.params import ParamDef
    sch = cache_schema(cfg, batch=4, max_len=32)
    leaves = jax.tree.leaves(sch, is_leaf=lambda x: isinstance(x, ParamDef))
    per_tok = sum(
        np.prod(d.shape) / (4 * 32) * jnp.dtype(d.dtype).itemsize
        for d in leaves)
    full_kv = (cfg.n_layers * cfg.n_kv_heads * (cfg.qk_nope_dim
               + cfg.qk_rope_dim + cfg.v_head_dim) * 2)
    latent = cfg.n_layers * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    assert per_tok < full_kv * 2 / 3
    assert per_tok < latent * 3


def test_continuous_batcher():
    cfg = _f32(get_smoke_config("yi-6b"))
    params = init_tree(jax.random.key(0), model_schema(cfg))
    B, S = 3, 64
    step_jit = jax.jit(lambda p, c, t, l: serve_step(p, c, t, l, cfg))
    prefill_jit = jax.jit(
        lambda p, b: prefill(p, b, cfg, S, last_only=True))

    def step_fn(cache, tokens, lengths):
        lg, cache = step_jit(params, cache, tokens, lengths)
        return lg, cache

    def prefill_fn(prompt):
        lg, c1, _ = prefill_jit(params, {"tokens": jnp.asarray(prompt)})
        return lg, c1, prompt.shape[1]

    def write_slot(cache, i, one, length):
        return jax.tree.map(lambda big, o: big.at[:, i].set(o[:, 0]),
                            cache, one)

    bat = ContinuousBatcher(B, step_fn, prefill_fn, write_slot)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=r, prompt=rng.randint(
        0, cfg.vocab, size=8).astype(np.int32), max_new=5)
        for r in range(5)]
    for r in reqs:
        bat.submit(r)
    cache = init_cache(cfg, B, S)
    bat.run(cache)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)


def test_knn_lm_retrieval_shifts_distribution():
    """kNN interpolation must move mass toward retrieved tokens."""
    key = jax.random.key(0)
    n, d, vocab = 512, 16, 64
    keys = jax.random.normal(key, (n, d))
    vals = jnp.full((n,), 7, jnp.int32)       # every neighbor votes token 7
    ds = KNNDatastore.build(keys, vals, k=8)
    q = keys[:4] + 0.01
    knl = knn_logits(ds, q, vocab, k=4)
    lm = jnp.zeros((4, vocab))
    mixed = interpolate(lm, knl, lam=0.5)
    assert (jnp.argmax(mixed, -1) == 7).all()
    # and with lam=0 the LM wins
    mixed0 = interpolate(lm.at[:, 3].set(5.0), knl, lam=1e-6)
    assert (jnp.argmax(mixed0, -1) == 3).all()
