"""Overload-robustness tests: bounded two-lane admission, deadline
expiry at both queue boundaries, typed rejections (no silent drops),
deterministic shedding under a seeded FaultPlan burst, deadline
propagation into the fused search's round budget, the bucketed q_block
ladder, and the per-shard latency circuit breaker."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices
from repro.core import faults
from repro.core.distributed import BreakerConfig, ShardBreaker
from repro.core.faults import FaultPlan, FaultSpec
from repro.core.graph_search import SearchConfig, q_block_bucket
from repro.serve.scheduler import (
    ContinuousBatcher,
    LaneQueue,
    QueryRequest,
    Request,
    RetrievalScheduler,
    SchedulerConfig,
)


def _req(qid, lane="interactive", deadline_ms=None):
    return QueryRequest(qid=qid, query=np.zeros(4, np.float32), lane=lane,
                        deadline_ms=deadline_ms)


# ---------------------------------------------------------------- LaneQueue

def test_lane_priority_and_fifo():
    q = LaneQueue()
    b0, i0, b1, i1 = (_req(0, "batch"), _req(1), _req(2, "batch"), _req(3))
    for r in (b0, i0, b1, i1):
        assert q.push(r, 0.0) is None
    # interactive lane drains first, FIFO within each lane
    assert [q.pop(0.0).qid for _ in range(4)] == [1, 3, 0, 2]
    assert q.pop(0.0) is None


def test_bounded_queue_at_exactly_capacity():
    q = LaneQueue(max_queue=3)
    rs = [_req(i) for i in range(3)]
    for r in rs:
        assert q.push(r, 0.0) is None       # fills to exactly capacity
    assert len(q) == 3 and q.admitted == 3 and q.shed == 0
    over = _req(99)
    rej = q.push(over, 0.0)
    assert rej is not None and rej.code == "queue-full"
    assert over.rejection is rej            # typed, attached, not silent
    assert len(q) == 3 and q.shed == 1
    q.pop(0.0)                              # one slot frees up...
    assert q.push(_req(100), 0.0) is None   # ...and admission resumes


def test_drop_oldest_batch_policy():
    q = LaneQueue(max_queue=2, shed_policy="drop-oldest-batch")
    old, newer = _req(0, "batch"), _req(1, "batch")
    q.push(old, 0.0), q.push(newer, 0.0)
    inter = _req(2)
    assert q.push(inter, 0.0) is None       # admitted by evicting `old`
    assert old.rejection is not None and old.rejection.code == "shed-oldest"
    assert len(q) == 2 and q.shed == 1
    # with no batch request left to evict the policy degrades to
    # reject-new — the interactive lane is never shed from the tail
    q.pop(0.0), q.pop(0.0)
    a, b = _req(3), _req(4)
    q.push(a, 0.0), q.push(b, 0.0)
    c = _req(5)
    rej = q.push(c, 0.0)
    assert rej is not None and rej.code == "queue-full"
    assert len(q) == 2


def test_deadline_expired_at_admission():
    q = LaneQueue()
    r = _req(0, deadline_ms=0.0)
    rej = q.push(r, 10.0)
    assert rej is not None and rej.code == "expired-at-admission"
    assert len(q) == 0 and q.expired == 1


def test_deadline_expired_in_queue():
    q = LaneQueue()
    r = _req(0, deadline_ms=50.0)
    assert q.push(r, 0.0) is None
    # clock advances past the deadline while the request waits
    assert q.pop(0.061) is None
    assert r.rejection is not None and r.rejection.code == "expired-in-queue"
    assert q.expired == 1
    # no deadline -> never expires
    r2 = _req(1)
    q.push(r2, 0.0)
    assert q.pop(1e9) is r2


# ------------------------------------------------------- RetrievalScheduler

def _capture_search(captured):
    def search_fn(qs, cfg):
        captured.append((int(qs.shape[0]), cfg))
        m = qs.shape[0]
        return jnp.zeros((m, 4)), jnp.tile(jnp.arange(4, dtype=jnp.int32),
                                           (m, 1))
    return search_fn


def test_scheduler_serves_and_submit_after_drain():
    captured = []
    clk = [0.0]
    s = RetrievalScheduler(_capture_search(captured),
                           cfg=SchedulerConfig(max_queue=16),
                           clock=lambda: clk[0])
    for _ in range(5):
        s.submit(np.zeros(4, np.float32))
    served = s.run_until_drained()
    assert len(served) == 5 and all(r.done for r in served)
    assert all(r.idx is not None and r.rejection is None for r in served)
    # drained scheduler accepts fresh work — no sticky closed state
    r = s.submit(np.ones(4, np.float32), lane="batch")
    assert r.rejection is None
    served2 = s.run_until_drained()
    assert served2 == [r] and r.done
    st = s.stats()
    assert st["admitted"] == 6 and st["served"] == 6 and st["shed"] == 0
    assert len(st["latency_ms"]["interactive"]) == 5


def test_lane_pure_batches_and_bucketed_block():
    """One pump never mixes lanes, and a small interactive burst is
    dispatched at its q_block_bucket ladder step, not the full block."""
    captured = []
    s = RetrievalScheduler(_capture_search(captured),
                           base_cfg=SearchConfig(q_block=256),
                           cfg=SchedulerConfig(max_queue=64, max_batch=32))
    for _ in range(7):
        s.submit(np.zeros(4, np.float32), lane="interactive")
    for _ in range(3):
        s.submit(np.zeros(4, np.float32), lane="batch")
    s.run_until_drained()
    assert [nq for nq, _ in captured] == [7, 3]     # lane-pure dispatches
    assert q_block_bucket(7, captured[0][1]) == 8   # 8-block, not 256
    assert q_block_bucket(3, captured[1][1]) == 4


def test_deadline_propagates_into_round_budget():
    captured = []
    clk = [0.0]
    s = RetrievalScheduler(_capture_search(captured),
                           base_cfg=SearchConfig(q_block=4),
                           cfg=SchedulerConfig(max_queue=64, max_batch=8),
                           clock=lambda: clk[0])
    assert s.base_cfg.max_rounds_deadline == 0.0    # off by default
    for _ in range(8):                              # 2 blocks of 4
        s.submit(np.zeros(4, np.float32), deadline_ms=100.0)
    s.pump()
    (nq, cfg), = captured
    assert nq == 8
    # tightest remaining deadline (0.1s) split across the 2 blocks
    assert cfg.max_rounds_deadline == pytest.approx(0.05)
    # without deadlines the budget cut stays disabled
    captured.clear()
    s.submit(np.zeros(4, np.float32), deadline_ms=None)
    s.pump()
    assert captured[0][1].max_rounds_deadline == 0.0


def test_sched_stall_expires_queued_deadlines():
    """A scripted stall advances the scheduler clock past queued
    deadlines: the requests expire with typed rejections, deterministic
    across runs."""
    def one_run():
        captured = []
        s = RetrievalScheduler(_capture_search(captured),
                               cfg=SchedulerConfig(max_queue=16),
                               clock=lambda: 0.0)   # frozen real clock
        plan = FaultPlan(seed=3, specs=(
            FaultSpec(site="sched.stall", arg=0.2, times=1),))
        with plan.active():
            rs = [s.submit(np.zeros(4, np.float32), deadline_ms=50.0)
                  for _ in range(4)]
            served = s.run_until_drained()
        return rs, served, s.stats()

    rs, served, st = one_run()
    assert served == [] and st["expired"] == 4
    assert all(r.rejection is not None
               and r.rejection.code == "expired-in-queue" for r in rs)
    rs2, served2, st2 = one_run()
    assert [r.rejection.code for r in rs2] == \
        [r.rejection.code for r in rs]
    assert st2["expired"] == st["expired"]


def test_seeded_burst_shed_determinism():
    """sched.burst amplifies one arrival past the bounded queue; the
    shed set (codes + counters) is byte-identical across two runs with
    the same plan — no silent drops anywhere."""
    def one_run():
        captured = []
        s = RetrievalScheduler(_capture_search(captured),
                               cfg=SchedulerConfig(max_queue=4),
                               clock=lambda: 0.0)
        plan = FaultPlan(seed=7, specs=(
            FaultSpec(site="sched.burst", arg=9, times=1),))
        every = []
        with plan.active():
            r = s.submit(np.zeros(4, np.float32))
            every.append(r)
        served = s.run_until_drained()
        # all ten requests (1 real + 9 injected) are accounted for:
        # queue contents were served, everything else carries a typed
        # rejection recorded at push time
        st = s.stats()
        assert st["admitted"] + st["shed"] + st["expired"] == 10
        assert st["admitted"] == len(served) == 4
        return st

    st1, st2 = one_run(), one_run()
    assert st1["shed"] == st2["shed"] == 6
    assert st1 == st2           # frozen clock -> byte-identical stats


def test_truncated_drain_is_typed():
    captured = []
    s = RetrievalScheduler(_capture_search(captured),
                           cfg=SchedulerConfig(max_queue=16, max_batch=1))
    rs = [s.submit(np.zeros(4, np.float32)) for _ in range(3)]
    with pytest.warns(RuntimeWarning, match="truncated"):
        served = s.run_until_drained(max_pumps=1)
    assert len(served) == 1
    left = [r for r in rs if r not in served]
    assert all(r.rejection is not None
               and r.rejection.code == "truncated" for r in left)
    assert len(s.queue) == 0                        # usable afterwards


# -------------------------------------------------------- ContinuousBatcher

def _fake_batcher(n_slots=2, **kw):
    V = 8

    def step_fn(cache, tokens, lengths):
        return jnp.zeros((tokens.shape[0], V)), cache

    def prefill_fn(prompt):
        return jnp.zeros((1, V)), None, prompt.shape[1]

    def write_slot(cache, i, one, length):
        return cache

    return ContinuousBatcher(n_slots, step_fn, prefill_fn, write_slot, **kw)


def _lm_req(rid, **kw):
    return Request(rid=rid, prompt=np.zeros(4, np.int32), max_new=3, **kw)


def test_batcher_bounded_queue_and_deadlines():
    clk = [0.0]
    bat = _fake_batcher(n_slots=1, max_queue=2, clock=lambda: clk[0])
    a, b, c = _lm_req(0), _lm_req(1), _lm_req(2)
    assert bat.submit(a) is None and bat.submit(b) is None
    rej = bat.submit(c)
    assert rej is not None and rej.code == "queue-full"
    assert c.rejection is rej
    # queued request whose deadline lapses is skipped with a typed
    # rejection, and the batcher still finishes the rest
    bat.run({})                             # drain so d is admissible
    d = _lm_req(3, deadline_ms=10.0)
    clk[0] = 1.0
    assert bat.submit(d) is None
    clk[0] = 2.0
    bat.run({})
    assert a.done and b.done and not d.done
    assert d.rejection is not None and d.rejection.code == "expired-in-queue"


def test_batcher_max_steps_marks_truncated():
    bat = _fake_batcher(n_slots=1)
    rs = [_lm_req(i) for i in range(4)]
    for r in rs:
        bat.submit(r)
    with pytest.warns(RuntimeWarning, match="truncated"):
        bat.run({}, max_steps=2)
    assert any(r.truncated for r in rs)
    # nothing silently lost: every request either finished or is marked
    assert all(r.done or r.truncated for r in rs)
    # and a fresh run with budget finishes the remainder
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        bat.run({})
    assert all(r.done for r in rs)


def test_batcher_submit_after_drain():
    bat = _fake_batcher(n_slots=2)
    first = _lm_req(0)
    bat.submit(first)
    bat.run({})
    assert first.done
    second = _lm_req(1)
    assert bat.submit(second) is None
    bat.run({})
    assert second.done


# ------------------------------------------------------------ ShardBreaker

def test_breaker_trips_and_recovers():
    b = ShardBreaker(4, BreakerConfig(min_samples=2, probe_every=3))
    for _ in range(2):
        assert b.excluded() == []           # not tripped before min_samples
        b.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 12.0})
    assert b.open[3] and b.stats()["trips"] == 1
    assert b.excluded() == [3]              # open shard sits out
    b.observe({0: 1.0, 1: 1.0, 2: 1.0})
    # half-open probe re-includes the shard; a healthy sample closes it
    recovered = False
    for _ in range(8):
        ex = b.excluded()
        b.observe({s: 1.0 for s in range(4) if s not in ex})
        if not b.open[3]:
            recovered = True
            break
    assert recovered
    st = b.stats()
    assert st["probes"] >= 1 and st["recoveries"] == 1
    assert st["open_shards"] == []


def test_breaker_unhealthy_probe_stays_open():
    b = ShardBreaker(3, BreakerConfig(min_samples=2, probe_every=2))
    for _ in range(3):
        b.excluded()
        b.observe({0: 1.0, 1: 1.0, 2: 20.0})
    assert b.open[2]
    for _ in range(6):                      # probes keep seeing 20x
        ex = b.excluded()
        lat = {s: 1.0 for s in range(3) if s not in ex}
        if 2 in lat:
            lat[2] = 20.0
        b.observe(lat)
    assert b.open[2] and b.stats()["recoveries"] == 0


def test_breaker_never_excludes_all():
    # the ratio trip cannot open the last closed shard by itself (its
    # median-of-others is empty), so force the pathological all-open
    # state directly: excluded() must still leave someone serving
    b = ShardBreaker(2, BreakerConfig(probe_every=1000))
    b.ewma = [2.0, 1.0]
    b.open = [True, True]
    assert b.excluded() == [0]              # lowest-EWMA shard stays live


@pytest.mark.slow
def test_breaker_wired_into_sharded_search():
    """shard.degrade inflates one shard's latency samples until the
    breaker trips it into the degraded-merge path; stats report it."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.distributed import (BreakerConfig, ShardBreaker,
                                            graph_search_sharded)
        from repro.core.faults import FaultPlan, FaultSpec
        from repro.core.graph_search import SearchConfig
        from repro.core.nn_descent import build_knn_graph

        P = 4
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:P]), ('data',))
        n, d = 256, 16
        x = jax.random.normal(jax.random.key(0), (n, d))
        n_local = n // P
        parts = []
        for p in range(P):
            _, gi, _ = build_knn_graph(x[p*n_local:(p+1)*n_local], k=8,
                                       key=jax.random.key(p))
            parts.append(gi)
        gidx = jnp.concatenate(parts)
        q = jax.random.normal(jax.random.key(1), (8, d))
        cfg = SearchConfig(beam=16, rounds=8, q_block=8)
        br = ShardBreaker(P, BreakerConfig(min_samples=3, probe_every=50))
        plan = FaultPlan(seed=0, specs=(
            FaultSpec(site="shard.degrade", arg=(2, 40.0)),))
        with plan.active():
            for _ in range(4):
                d_, i_, st = graph_search_sharded(
                    mesh, x, gidx, q, k_out=5, cfg=cfg,
                    with_stats=True, breaker=br)
        assert br.open[2], br.stats()
        assert st["breaker"]["trips"] == 1, st
        # next dispatch runs degraded without the slow shard — answers
        # still flow, ids valid, shard 2 reported degraded
        d_, i_, st = graph_search_sharded(
            mesh, x, gidx, q, k_out=5, cfg=cfg, with_stats=True,
            breaker=br)
        assert 2 in st["degraded_shards"], st
        assert st["cover_frac"] == 0.75
        i_np = np.asarray(i_)
        assert bool((i_np >= 0).all())
        assert not (i_np // n_local == 2).any()
        print("BREAKER_OK")
    """, n=4)
    assert "BREAKER_OK" in out


# ------------------------------------------------------------ q_block ladder

def test_q_block_bucket_ladder():
    cfg = SearchConfig(q_block=256)
    assert q_block_bucket(1, cfg) == 1
    assert q_block_bucket(7, cfg) == 8
    assert q_block_bucket(8, cfg) == 8
    assert q_block_bucket(9, cfg) == 16
    assert q_block_bucket(300, cfg) == 256   # capped at q_block
    fixed = SearchConfig(q_block=256, fixed_block=True)
    assert q_block_bucket(7, fixed) == 256   # baseline knob pads fully
