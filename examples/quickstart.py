"""Quickstart: build a K-NN graph with the paper's NN-Descent, validate
recall, and see every optimization knob.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro import (
    DescentConfig,
    brute_force_knn,
    build_knn_graph,
    graph_search,
    recall_at_k,
)
from repro.core import datasets


def main():
    key = jax.random.key(0)
    print("generating Synthetic Clustered Dataset (paper §4): "
          "8192 points, 64-d, 16 clusters")
    x = datasets.clustered(key, 8192, 64, 16)

    # ---- the one-liner
    t0 = time.time()
    dist, idx, stats = build_knn_graph(x, k=20)
    print(f"built K-NN graph in {time.time()-t0:.1f}s: "
          f"{stats.iters} iterations, {stats.dist_evals:,} distance "
          f"evaluations ({stats.flops(64):,} flops by the paper's model), "
          f"reordered={stats.reordered}")

    # ---- recall vs brute force (paper claims >99% at quality settings)
    td, ti = brute_force_knn(x, x, 20)
    print(f"recall@20 = {recall_at_k(idx, ti):.4f}")

    # ---- the quality operating point
    cfg = DescentConfig(k=20, rho=1.5, max_iters=25, delta=1e-4,
                        merge_size=120)
    _, idx_hq, st = build_knn_graph(x, k=20, cfg=cfg)
    print(f"quality point (rho=1.5): recall@20 = "
          f"{recall_at_k(idx_hq, ti):.4f} "
          f"({st.dist_evals:,} evals — the runtime/quality trade-off "
          f"the paper describes)")

    # ---- query-time graph search (the serving-side consumer)
    q = x[:16] + 0.05
    t0 = time.time()
    qd, qi = graph_search(x, idx_hq, q, k_out=10)
    _, tqi = brute_force_knn(x, q, 10, exclude_self=False)
    print(f"graph search: 16 queries in {time.time()-t0:.2f}s, "
          f"recall@10 = {recall_at_k(qi, tqi):.3f}")

    # ---- other metrics: same kernels, input-side reductions
    # (docs/METRICS.md). Cosine normalizes rows inside the store; MIPS
    # appends the augmented coordinate. Distances come back in the
    # transformed space — monotone in the native metric — and
    # similarity_from_dist converts them back exactly.
    from repro.core import metric as metric_mod
    from repro.core.online import MutableKNNStore, OnlineConfig

    store, _ = MutableKNNStore.build(
        x, k=20, cfg=OnlineConfig(metric="cosine"), key=jax.random.key(1))
    # wider beam than the l2 demo: normalization tightens the clusters
    # on the sphere, so random entries need more budget to navigate in
    # (attach a router — docs/ARCHITECTURE.md — to fix the entries
    # themselves)
    cd, ci = store.search(q, k_out=10, beam=64, rounds=32,
                          key=jax.random.key(2))
    cos = metric_mod.similarity_from_dist(cd, "cosine")
    xn = x / jax.numpy.linalg.norm(x, axis=1, keepdims=True)
    qn = q / jax.numpy.linalg.norm(q, axis=1, keepdims=True)
    _, cti = jax.lax.top_k(qn @ xn.T, 10)     # native cosine oracle
    print(f"cosine search: recall@10 = {recall_at_k(ci, cti):.3f} vs "
          f"the top-similarity oracle; best cos = {float(cos[0, 0]):.4f}")

    # ---- knobs
    print("\nknobs (DescentConfig):")
    for f, v in DescentConfig().__dict__.items():
        print(f"  {f:15s} = {v}")


if __name__ == "__main__":
    main()
