"""kNN-LM serving example: the paper's K-NN graph as the retrieval index
behind a language model (DESIGN.md §3).

1. train a tiny LM for a handful of steps,
2. run it over a corpus to collect (hidden state -> next token) pairs,
3. build the datastore K-NN GRAPH with NN-Descent (the paper's engine:
   turbosampling + blocked distances + greedy reorder for datastore-page
   locality),
4. decode with graph-search retrieval interpolated into the LM logits and
   show perplexity improves on corpus-like text,
5. snapshot the datastore and cold-start a second server from disk —
   zero rebuild, bit-identical retrieval (core/persist.py).

    PYTHONPATH=src python examples/knn_serve.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.models import init_tree, model_schema
from repro.models.model import embed_inputs, output_logits
from repro.models.transformer import run_stack
from repro.serve import KNNDatastore, interpolate, knn_logits
from repro.train import OptimizerConfig, TrainConfig, make_train_step
from repro.train import optimizer as opt_mod


def hidden_states(params, batch, cfg):
    x = embed_inputs(params, batch, cfg)
    return run_stack(params["stack"], x, cfg)


def main():
    cfg = dataclasses.replace(get_smoke_config("yi-6b"), vocab=2048)
    params = init_tree(jax.random.key(0), model_schema(cfg))
    state = opt_mod.init(params)
    dc = DataConfig(seq_len=128, global_batch=8, vocab=cfg.vocab,
                    prefetch=0)
    pipe = TokenPipeline(dc)

    print("1) quick-train the LM (60 steps)")
    tc = TrainConfig(opt=OptimizerConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=60))
    step = jax.jit(make_train_step(cfg, tc))
    it = iter(pipe)
    for i in range(60):
        params, state, m = step(params, state, next(it))
    print(f"   train loss {float(m['loss']):.3f}")

    print("2) collect datastore: hidden states -> next tokens")
    hs = jax.jit(lambda p, b: hidden_states(p, b, cfg))
    keys, vals = [], []
    for i in range(8):
        b = next(it)
        h = hs(params, b)                       # (B, L, d)
        keys.append(np.asarray(h[:, :-1].reshape(-1, cfg.d_model)))
        vals.append(np.asarray(b["tokens"][:, 1:]).reshape(-1))
    keys = jnp.asarray(np.concatenate(keys))
    vals = jnp.asarray(np.concatenate(vals))
    print(f"   {keys.shape[0]:,} entries, d={keys.shape[1]}")

    print("3) build the K-NN graph over the datastore (NN-Descent)")
    ds = KNNDatastore.build(keys, vals, k=16)
    print(f"   {ds.build_stats}")

    print("4) decode with kNN interpolation")
    b = next(it)
    h = hs(params, b)
    lm_logits = output_logits(params, h, cfg)   # (B, L, V)
    q = h[:, :-1].reshape(-1, cfg.d_model)
    tgt = b["tokens"][:, 1:].reshape(-1)
    lm_lp = jax.nn.log_softmax(
        lm_logits[:, :-1].reshape(-1, cfg.vocab), axis=-1)

    # thread an explicit entry key (a decode loop would fold in its step)
    knl = knn_logits(ds, q, cfg.vocab, k=8, key=jax.random.key(11))
    for lam in (0.0, 0.25, 0.5):
        mixed = interpolate(lm_lp, knl, lam=lam) if lam else lm_lp
        nll = -jnp.take_along_axis(
            jax.nn.log_softmax(mixed, -1), tgt[:, None], axis=1).mean()
        print(f"   lambda={lam:.2f}: ppl = {float(jnp.exp(nll)):.2f}")

    print("5) snapshot -> zero-rebuild cold start (core/persist.py)")
    with tempfile.TemporaryDirectory() as snap_dir:
        ds.snapshot(snap_dir)
        # a restarted server: no NN-Descent, no re-quantization — just
        # array load; retrieval is bit-identical to the store that died
        ds2 = KNNDatastore.restore(snap_dir)
        knl2 = knn_logits(ds2, q, cfg.vocab, k=8, key=jax.random.key(11))
        same = bool(jnp.all(knl2 == knl))
        print(f"   restored retrieval bit-identical: {same} "
              f"(stats: {ds2.build_stats})")


if __name__ == "__main__":
    main()
