"""The paper's centerpiece, visualized: the greedy reordering heuristic
(Algorithm 1) turning data-space locality into memory-space locality.

Prints an ASCII rendition of the paper's Fig. 4 (windowed cluster purity
along the reordered axis) and the Table-1 analog (locality metrics before
/ after), plus the per-iteration timing of Fig. 5.

    PYTHONPATH=src python examples/reorder_locality.py
"""
import time

import jax
import jax.numpy as jnp

from repro import (
    DescentConfig,
    NeighborLists,
    apply_permutation,
    build_knn_graph,
    greedy_reorder,
    locality_stats,
    window_cluster_purity,
)
from repro.core import datasets


def bar(frac, width=40):
    n = int(frac * width)
    return "#" * n + "." * (width - n)


def main():
    n, d, c = 8192, 8, 8
    key = jax.random.key(0)
    x, labels = datasets.clustered(key, n, d, c, labels=True)
    print(f"Synthetic Clustered Dataset: n={n}, d={d}, {c} clusters "
          f"(input order shuffled — reveals nothing)\n")

    cfg = DescentConfig(k=20, rho=1.0, max_iters=4, reorder=False)
    dist, idx, _ = build_knn_graph(x, k=20, cfg=cfg)
    nl = NeighborLists(dist, idx, jnp.zeros_like(idx, dtype=bool))

    before = locality_stats(nl)
    t0 = time.time()
    sigma, sigma_inv = greedy_reorder(nl)
    t_reorder = time.time() - t0
    _, nl2 = apply_permutation(x, nl, sigma, sigma_inv)
    after = locality_stats(nl2)

    print("Table-1 analog (cachegrind stand-in):")
    print(f"  in-block edge fraction : {before['in_block_fraction']:.3f} "
          f"-> {after['in_block_fraction']:.3f}")
    print(f"  mean gather spread     : {before['mean_gather_spread']:.0f} "
          f"-> {after['mean_gather_spread']:.0f} rows")
    print(f"  (reorder pass itself: {t_reorder*1e3:.0f} ms, one pass, "
          f"O(nk))\n")

    print("Fig. 4: dominant-cluster fraction per 1000-row window after "
          "reordering")
    starts, purity = window_cluster_purity(labels, sigma, window=1000,
                                           stride=1000)
    for s, p in zip(starts, purity):
        print(f"  rows {s:5d}+ |{bar(p)}| {p:.2f}")
    print(f"  (random order would sit at {1/c:.3f} everywhere; the tail "
          f"decays exactly as the paper's Fig. 4 describes — the "
          f"single-pass heuristic runs out of unassigned nodes)\n")

    print("Fig. 5: per-iteration wall time, with vs without the heuristic")
    for variant, reorder in (("no-heuristic", False),
                             ("greedyheuristic", True)):
        times = []

        def cb(it, upd, nl, _t=[time.perf_counter()]):
            now = time.perf_counter()
            times.append(now - _t[0])
            _t[0] = now

        cfgv = DescentConfig(k=20, rho=1.0, max_iters=6, reorder=reorder)
        build_knn_graph(x, k=20, cfg=cfgv, callback=cb)
        row = " ".join(f"{t:5.2f}" for t in times)
        print(f"  {variant:16s} [{row}] s  total={sum(times):.2f}")


if __name__ == "__main__":
    main()
