"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production substrate — semantic data ordering (the paper's
technique in the data pipeline), checkpointing, fault policy, straggler
watchdog — on CPU with a reduced-width config.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import json
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline, mean_pool_embeddings, semantic_order
from repro.data.pipeline import SyntheticLMSource
from repro.models import init_tree, model_schema, param_count
from repro.train import OptimizerConfig, TrainConfig, TrainLoop, make_train_step
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import Checkpointer, config_hash
from repro.train.fault import FaultPolicy, StragglerWatchdog


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--semantic-order", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="the ~100M-param config (real-hardware scale; "
                         "tens of seconds PER STEP on this 1-core CPU)")
    args = ap.parse_args(argv)

    base = get_smoke_config(args.arch)
    if args.full:      # ~100M params
        cfg = dataclasses.replace(
            base, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
            d_head=64, d_ff=1408, vocab=65536,
            attn_chunk_q=128, attn_chunk_kv=128)
    else:              # CPU-friendly end-to-end demo (~8M params)
        cfg = dataclasses.replace(
            base, n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
            d_head=64, d_ff=704, vocab=4096,
            attn_chunk_q=128, attn_chunk_kv=128)
    print(f"training {cfg.arch}-mini: {param_count(cfg):,} params")

    order = None
    if args.semantic_order:
        # the paper's greedy reorder at corpus level: embed 2048 docs,
        # build the K-NN graph, reorder the traversal (C3)
        src = SyntheticLMSource(cfg.vocab, seed=0)
        docs = np.stack([
            np.resize(src.doc(i), 128) for i in range(2048)])
        emb = mean_pool_embeddings(docs, vocab=cfg.vocab)
        order, stats = semantic_order(emb, k=8)
        print(f"semantic order built: locality "
              f"{stats['in_block_before']:.3f} -> "
              f"{stats['in_block_after']:.3f}")

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    vocab=cfg.vocab, prefetch=2)
    pipe = TokenPipeline(dc, order=order)

    params = init_tree(jax.random.key(0), model_schema(cfg))
    state = opt_mod.init(params)
    tc = TrainConfig(
        microbatches=2,
        opt=OptimizerConfig(lr=3e-3, warmup_steps=20,
                            total_steps=args.steps))
    step = jax.jit(make_train_step(cfg, tc))

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    ck = Checkpointer(ckpt_dir, every=50, cfg_hash=config_hash(cfg))
    fault = FaultPolicy(ck)
    dog = StragglerWatchdog()

    def batches():
        for i, b in enumerate(pipe):
            if i >= args.steps:
                return
            dog.step_start()
            yield b

    def log(m):
        dog.step_end(m["step"])
        print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                          for k, v in m.items()}))

    loop = TrainLoop(cfg, tc, step, checkpointer=ck, fault=fault,
                     log_every=10)
    params, state, hist = loop.run(params, state, batches(), callback=log)
    print(f"\nfirst loss {hist[0]['loss']:.4f} -> last "
          f"{hist[-1]['loss']:.4f}; stragglers={dog.stragglers}; "
          f"checkpoints at {ckpt_dir} (latest step "
          f"{ck.latest_step()})")
    if not args.ckpt_dir:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
