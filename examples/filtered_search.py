"""Filtered search: two tenants, ONE store, zero cross-tenant leakage.

Per-query predicates (tenant visibility here; date ranges or soft
deletes work the same way) ride the tombstone id-mask path of the
fused search: a row a query may not see reaches the distance kernels
as id -1 and exits +inf, so a leak is structurally impossible rather
than filtered out of the results afterwards. See docs/METRICS.md.

    PYTHONPATH=src python examples/filtered_search.py
"""
import jax
import jax.numpy as jnp

from repro import DescentConfig, recall_at_k
from repro.core import datasets
from repro.core.online import MutableKNNStore, OnlineConfig


def main():
    key = jax.random.key(0)
    n, d, nq, k = 4096, 32, 64, 10
    x = datasets.clustered(key, n, d, 8)

    # one shared store; each row belongs to tenant 0 or tenant 1
    tenant_of_row = jnp.arange(n) % 2
    store, _ = MutableKNNStore.build(
        x, k=16, cfg=OnlineConfig(), descent=DescentConfig(k=16),
        key=jax.random.key(1))

    # queries alternate tenants too; the visibility mask is per-query:
    # True = this query may see this row
    q = x[:nq] + 0.02 * jax.random.normal(jax.random.key(2), (nq, d))
    tenant_of_query = jnp.arange(nq) % 2
    visible = tenant_of_row[None, :] == tenant_of_query[:, None]  # (nq, n)

    dist, ids = store.search(q, k_out=k, filter_ids=visible,
                             key=jax.random.key(3))

    # --- zero leakage: every returned id belongs to the query's tenant
    valid = ids >= 0
    leaked = int(jnp.sum(jnp.where(
        valid, tenant_of_row[jnp.clip(ids, 0)] != tenant_of_query[:, None],
        False)))
    print(f"{nq} queries, {int(valid.sum())} results, "
          f"cross-tenant leaks = {leaked}")
    assert leaked == 0, "a predicate-excluded id surfaced"

    # --- quality: score against the predicate-restricted oracle (the
    # true top-k AMONG the visible rows, not the global top-k)
    d2 = (jnp.sum(q**2, 1)[:, None] + jnp.sum(x**2, 1)[None, :]
          - 2.0 * q @ x.T)
    _, true_ids = jax.lax.top_k(-jnp.where(visible, d2, jnp.inf), k)
    print(f"filtered recall@{k} = {recall_at_k(ids, true_ids):.3f} "
          "(vs the visible-rows oracle)")

    # --- a shared (n,) mask works too, e.g. hiding one tenant globally
    only_t0 = tenant_of_row == 0
    _, ids0 = store.search(q, k_out=k, filter_ids=only_t0,
                           key=jax.random.key(4))
    assert int(jnp.sum(jnp.where(
        ids0 >= 0, tenant_of_row[jnp.clip(ids0, 0)] != 0, False))) == 0
    print("shared-mask search: every result from tenant 0, as required")


if __name__ == "__main__":
    main()
