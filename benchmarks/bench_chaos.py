"""Chaos benchmark: a scripted fault schedule against the live serving
paths (the CI receipt for core/faults.py and every graceful-degradation
site it scripts).

Modes (``python benchmarks/bench_chaos.py --mode ...``):

  * ``smoke`` (default) — the gated CI lane. Four phases, one scripted
    ``FaultPlan`` each, all against real serving objects (a live
    ContinuousBatcher, a restored datastore, a sharded dispatch):

      1. **flaky/slow writer** — a ContinuousBatcher streams decode
         captures into its datastore while the periodic background
         snapshot write fails transiently (``persist.write``, absorbed
         by the SnapshotWriter's backoff retries — the retry sleeps ARE
         the slowed writer). The decode stream must finish every
         request and the drain snapshot must land at the final
         high-water mark.
      2. **poisoned batch** — a NaN-poisoned query batch goes through
         ``knn_logits`` un-strict (sanitized: every row answered, all
         logits finite) and an Inf-poisoned batch goes through strict
         admission (rejected with ValueError, never a crash).
      3. **corrupted newest snapshot** — the newest committed step is
         torn post-commit (truncated array file); a cold start must
         quarantine it and fall back to the next-older committed step
         bit-identically (same ids and fp32 distance bits as restoring
         that step directly). ``recovery_s`` is the fallback restore
         wall time.
      4. **dead shard** — routed sharded dispatch on a forced 4-device
         CPU topology (forked subprocess, like bench_search's routed
         sidecar) with shard 1 marked dead via ``shard.dead``.
         ``degraded_recall`` is recall against the best *attainable*
         ground truth (brute force over surviving shards' rows): the
         survivors must still answer well, with 0 dropped queries.

    Emits one ``smoke_chaos`` row into results/bench/chaos.json, gated
    by check_gate.py --chaos: ``crashes == 0`` (any unhandled exception
    OR violated degradation contract counts), ``dropped_queries == 0``,
    ``degraded_recall >= --chaos-floor``, ``fallback_bitident``.

  * ``shard-child`` — internal: phase 4's forked half (jax device
    topology is fixed at first backend init, so the multi-device run
    needs a fresh process).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import subprocess
import sys
import time
import warnings

import numpy as np

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# phase-4 child: cluster-aligned 4-shard corpus, routed dispatch with
# shard 1 dead. route_p=2 gives every query a second entry shard, so a
# query whose home shard died still lands somewhere near; route_cap has
# slack for the re-routed load (256*2/3 ~ 171 per surviving shard).
_SHARD_CHILD_SRC = """
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.core import DescentConfig, RouterConfig, SearchConfig
from repro.core.distributed import graph_search_sharded
from repro.core.faults import FaultPlan, FaultSpec
from repro.core.nn_descent import build_knn_graph
from repro.core.recall import brute_force_knn, recall_at_k
from repro.core.router import build_router

P, n, d, k_out, DEAD = 4, 1024, 16, 10, 1
n_local = n // P
cent = jax.random.normal(jax.random.key(0), (P, d)) * 8.0
noise = jax.random.normal(jax.random.key(1), (P, n_local, d)) * 0.5
x = (cent[:, None, :] + noise).reshape(n, d).astype(jnp.float32)
cfg = DescentConfig(k=10, rho=1.0, max_iters=10, reorder=False)
parts = []
for s in range(P):
    _, gi, _ = build_knn_graph(x[s*n_local:(s+1)*n_local], k=10, cfg=cfg,
                               key=jax.random.key(s))
    parts.append(gi)
gidx = jnp.concatenate(parts)
router = build_router(x, cfg=RouterConfig(n_centroids=16, sample=1024),
                      key=jax.random.key(7))
mesh = jax.make_mesh((P,), ("data",))
q = x[::8] + 0.01
scfg = SearchConfig(beam=16, rounds=24, expand=4)

def dispatch():
    return graph_search_sharded(mesh, x, gidx, q, k_out=k_out, cfg=scfg,
                                key=jax.random.key(2), router=router,
                                route_p=2, route_cap=256, with_stats=True)

_, ti_full = brute_force_knn(x, q, k_out, exclude_self=False)
_, gi_live, st_live = dispatch()
plan = FaultPlan(specs=(FaultSpec(site="shard.dead", arg=DEAD),))
with plan.active():
    _, gi_dead, st_dead = dispatch()

# attainable ground truth: brute force over the SURVIVING shards' rows
live_ids = np.concatenate([np.arange(s*n_local, (s+1)*n_local)
                           for s in range(P) if s != DEAD])
_, tl = brute_force_knn(x[live_ids], q, k_out, exclude_self=False)
ti_live = jnp.asarray(live_ids)[tl]
print("CHAOS_SHARD " + json.dumps({
    "baseline_recall": float(recall_at_k(gi_live, ti_full)),
    "degraded_recall": float(recall_at_k(gi_dead, ti_live)),
    "degraded_recall_full": float(recall_at_k(gi_dead, ti_full)),
    "baseline_dropped": int(st_live.get("dropped_queries", 0)),
    "dropped_queries": int(st_dead.get("dropped_queries", 0)),
    "degraded_shards": list(st_dead.get("degraded_shards", [])),
    "cover_frac": float(st_dead.get("cover_frac", 0.0)),
}))
"""


def _shard_phase(n_devices: int = 4, timeout: int = 600) -> dict:
    """Run the dead-shard phase in a forked process with a forced
    multi-device CPU topology; returns the CHAOS_SHARD dict."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_REPO, "src"), env.get("PYTHONPATH")) if p)
    proc = subprocess.run([sys.executable, "-c", _SHARD_CHILD_SRC],
                          capture_output=True, text=True, env=env,
                          cwd=_REPO, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(
            f"dead-shard chaos child failed "
            f"(rc={proc.returncode}):\n{proc.stderr}")
    lines = [ln for ln in proc.stdout.splitlines()
             if ln.startswith("CHAOS_SHARD ")]
    if not lines:
        raise RuntimeError(
            f"dead-shard chaos child printed no CHAOS_SHARD:"
            f"\n{proc.stdout}")
    return json.loads(lines[-1][len("CHAOS_SHARD "):])


def _search_bits(ds, q, k_out: int, key):
    dist, idx = ds.store.search(q, k_out=k_out, key=key)
    return (np.asarray(dist, np.float32).view(np.int32),
            np.asarray(idx, np.int32))


def _tear_newest(snap_root: str, step: int) -> str:
    """Truncate one array file of an already-committed step directory —
    the torn-page corruption COMMIT ordering alone cannot catch."""
    step_dir = os.path.join(snap_root, f"step_{step:08d}")
    target = sorted(glob.glob(os.path.join(step_dir, "*.npy")))[0]
    size = os.path.getsize(target)
    with open(target, "r+b") as f:
        f.truncate(size // 2)
    return target


def run_smoke(n0: int = 256, dk: int = 16, vocab: int = 32,
              n_requests: int = 4, max_new: int = 25,
              k_out: int = 8) -> list:
    import jax
    import jax.numpy as jnp

    from benchmarks.common import RESULTS_DIR, Sink
    from repro.core import SearchConfig, faults, persist
    from repro.core.faults import FaultPlan, FaultSpec
    from repro.serve.knn_lm import MutableKNNDatastore, knn_logits
    from repro.serve.scheduler import ContinuousBatcher, Request

    sink = Sink("chaos")
    snap_root = os.path.join(RESULTS_DIR, "chaos_smoke")
    shutil.rmtree(snap_root, ignore_errors=True)

    crashes = 0
    dropped = 0
    notes = []

    # ---- phase 1: live batcher with a flaky (and thereby slow) writer
    keys0 = jax.random.normal(jax.random.key(0), (n0, dk))
    vals0 = jax.random.randint(jax.random.key(1), (n0,), 0, vocab)
    ds0 = MutableKNNDatastore.build(keys0, vals0, k=8,
                                    key=jax.random.key(2))
    proj = jax.random.normal(jax.random.key(5), (vocab, dk))

    def prefill_fn(toks):
        return jnp.ones((1, vocab)), None, toks.shape[1]

    def step_fn(cache, toks, lengths):
        lg = jax.nn.one_hot((toks[:, 0] * 3 + lengths) % vocab,
                            vocab) * 4.0
        return lg, cache

    b = ContinuousBatcher(
        2, step_fn, prefill_fn, lambda c, i, o, length: c,
        knn_store=ds0, knn_capture=lambda lg: lg @ proj, knn_chunk=16,
        knn_snapshot_dir=snap_root, knn_snapshot_every=48)
    reqs = [Request(rid=r, prompt=np.array([1, 2, 3], np.int32),
                    max_new=max_new) for r in range(n_requests)]
    for r in reqs:
        b.submit(r)
    # 2 transient write failures: absorbed by the writer's 2 retries
    # with backoff — the first periodic snapshot is slowed, never lost
    plan = FaultPlan(specs=(FaultSpec(site="persist.write", times=2),))
    t0 = time.perf_counter()
    try:
        with plan.active(), warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            b.run(None)
    except Exception as e:          # noqa: BLE001 — the gate counts these
        crashes += 1
        notes.append(f"batcher: {e!r}")
    run_s = time.perf_counter() - t0
    streamed = n_requests * (max_new - 1)
    # a request that did not finish its full token budget was dropped
    dropped += sum(1 for r in reqs
                   if not r.done or len(r.out) < r.max_new)
    writer_faults = plan.fired("persist.write")
    drain_committed = (persist.latest_snapshot(snap_root)
                       == b.knn_store.store.n)
    if not drain_committed:
        crashes += 1
        notes.append("batcher: drain snapshot missing at high-water mark")
    ds = b.knn_store

    # ---- phase 2: poisoned query batches at the retrieval boundary
    qc = jax.random.normal(jax.random.key(11), (32, dk), jnp.float32)
    skey = jax.random.key(12)
    poisoned_finite = False
    strict_rejected = False
    try:
        qp = jnp.asarray(faults.poison_batch(np.asarray(qc), "nan"))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            lg = knn_logits(ds, qp, vocab, k=k_out, key=skey)
        poisoned_finite = bool(jnp.isfinite(lg).all())
        try:
            qi = jnp.asarray(faults.poison_batch(np.asarray(qc), "inf"))
            knn_logits(ds, qi, vocab, k=k_out, key=skey,
                       cfg=SearchConfig(beam=32, rounds=24, strict=True))
        except ValueError:
            strict_rejected = True
    except Exception as e:          # noqa: BLE001
        crashes += 1
        notes.append(f"poison: {e!r}")
    if not poisoned_finite:
        crashes += 1
        notes.append("poison: sanitized batch produced non-finite logits")
    if not strict_rejected:
        crashes += 1
        notes.append("poison: strict admission did not reject Inf batch")

    # ---- phase 3: corrupted newest snapshot -> bit-identical fallback
    fallback_bitident = False
    fallback_step = None
    torn_step = None
    recovery_s = float("nan")
    try:
        committed = persist.list_snapshots(snap_root)
        older, torn_step = committed[-2], committed[-1]
        ref = MutableKNNDatastore.restore(snap_root, step=older)
        ref_bits, ref_ids = _search_bits(ref, qc, k_out, skey)
        _tear_newest(snap_root, torn_step)
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            ds2 = MutableKNNDatastore.restore(snap_root)
        jax.block_until_ready(ds2.store.x)
        recovery_s = time.perf_counter() - t0
        fallback_step = ds2.build_stats.get("restored_step")
        bits2, ids2 = _search_bits(ds2, qc, k_out, skey)
        fallback_bitident = bool(fallback_step == older
                                 and (ids2 == ref_ids).all()
                                 and (bits2 == ref_bits).all())
    except Exception as e:          # noqa: BLE001
        crashes += 1
        notes.append(f"fallback: {e!r}")

    # ---- phase 4: dead shard 1-of-4 under routed dispatch (forked)
    shard = {}
    try:
        shard = _shard_phase()
        dropped += int(shard.get("dropped_queries", 0))
        dropped += int(shard.get("baseline_dropped", 0))
        if shard.get("degraded_shards") != [1]:
            crashes += 1
            notes.append(
                f"shard: degraded_shards={shard.get('degraded_shards')} "
                "(expected [1])")
    except Exception as e:          # noqa: BLE001
        crashes += 1
        notes.append(f"shard: {e!r}")

    sink.row(op="smoke_chaos", n0=n0, dk=dk, vocab=vocab,
             streamed=streamed, k_out=k_out,
             crashes=crashes, dropped_queries=dropped,
             degraded_recall=round(shard.get("degraded_recall", 0.0), 4),
             baseline_recall=round(shard.get("baseline_recall", 0.0), 4),
             degraded_recall_full=round(
                 shard.get("degraded_recall_full", 0.0), 4),
             cover_frac=round(shard.get("cover_frac", 0.0), 4),
             degraded_shards=shard.get("degraded_shards", []),
             fallback_bitident=fallback_bitident,
             fallback_step=fallback_step, torn_step=torn_step,
             recovery_s=round(recovery_s, 3),
             writer_faults=writer_faults,
             drain_committed=drain_committed,
             poisoned_finite=poisoned_finite,
             strict_rejected=strict_rejected,
             run_s=round(run_s, 3),
             notes="; ".join(notes))
    return sink.save()


def main(argv: list | None = None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mode", choices=("smoke", "shard-child"),
                   default="smoke")
    args = p.parse_args(argv)
    if args.mode == "shard-child":
        # exec the child inline (debug convenience; CI forks it itself)
        exec(compile(_SHARD_CHILD_SRC, "<shard-child>", "exec"), {})
        return None
    return run_smoke()


if __name__ == "__main__":
    main()
