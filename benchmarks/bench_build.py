"""Offline build benchmark: the fused local join vs. the global-lexsort
pair routing (the tentpole receipt for kernels/knn_join.py).

Modes (``python benchmarks/bench_build.py --mode ...``):

  * ``compare`` (default) — builds the same clustered corpus twice with
    identical DescentConfig except ``backend``: the fused local join
    (backend="auto": knn_join kernels, incidence inversion, chunked block
    merge) against the retained lexsort oracle path (backend="ref":
    ``compact_pairs``). Reports wall-clock, per-iteration time after the
    compile-bearing first build, dist_evals (must NOT increase under the
    fused path) and recall vs. brute force. Default n=20000 — the size
    regime where the O(n*C^2) pair sort dominates the ref path. A second
    ``build_quant_compare`` row builds the same corpus with the
    two-stage int8 path (``DescentConfig.precision``: quantized sampled
    joins + fp32 rerank/polish) for the mixed-precision receipt.

  * ``smoke`` — tiny fixed config for the CI benchmark lane (< ~1 min on
    a CPU runner): one fused and one ref build on a 1024-point corpus,
    emitting ``build_speedup``, ``fused_evals``/``lexsort_evals`` and
    ``build_recall``, gated by benchmarks/check_gate.py (evals must not
    increase under the fused path; recall floor), so the perf trajectory
    tracks the offline build too (see benchmarks/README.md).

All rows go through benchmarks.common.Sink into results/bench/build.json;
the CI `bench-online` artifact uploads the whole results/bench directory,
so the build rows ride in the existing artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Sink
from repro.core import (
    DescentConfig,
    brute_force_knn,
    build_knn_graph,
    datasets,
    recall_at_k,
)


def _build(x, k, cfg, key):
    t0 = time.perf_counter()
    dist, idx, st = build_knn_graph(x, k=k, cfg=cfg, key=key)
    jax.block_until_ready(dist)
    return idx, st, time.perf_counter() - t0


def run_compare(n: int = 20000, d: int = 32, k: int = 20,
                iters: int = 4, sink: Sink | None = None) -> list:
    sink = sink or Sink("build")
    x = datasets.clustered(jax.random.key(0), n, d, 32)
    cfg = DescentConfig(k=k, rho=1.0, max_iters=iters, reorder=False,
                        polish=1)
    key = jax.random.key(1)
    row = {"op": "build_compare", "n": n, "d": d, "k": k, "iters": iters}
    fused_idx = None
    for tag, backend in (("fused", "auto"), ("lexsort", "ref")):
        c = dataclasses.replace(cfg, backend=backend)
        idx, st, dt = _build(x, k, c, key)
        if tag == "fused":
            fused_idx = idx        # deterministic given key: reuse below
        row[f"{tag}_s"] = round(dt, 2)
        row[f"{tag}_evals"] = st.dist_evals
    # recall sanity on a subsample of the truth (full brute force at 2e4
    # is itself minutes-long on CPU; 2048 query rows suffice). The query
    # rows are corpus rows, so fetch k+1 and drop the self column by id
    # (exclude_self needs row-aligned queries).
    q = x[:2048]
    _, ti = brute_force_knn(x, q, k + 1, exclude_self=False)
    keep = ti != jnp.arange(q.shape[0], dtype=ti.dtype)[:, None]
    order = jnp.argsort(~keep, axis=1, stable=True)   # non-self first
    ti = jnp.take_along_axis(ti, order, axis=1)[:, :k]
    row["fused_recall_2048q"] = round(
        float(recall_at_k(fused_idx[:2048], ti)), 4)
    row["speedup"] = round(row["lexsort_s"] / max(row["fused_s"], 1e-9), 2)
    sink.row(**row)

    # --- the two-stage quantized build (DescentConfig.precision): the
    # sampled joins score int8, rerank_lists + polish restore exact fp32.
    # Receipt columns ride in a second row (same corpus, same key).
    qrow = {"op": "build_quant_compare", "n": n, "d": d, "k": k,
            "f32_s": row["fused_s"], "f32_recall": row["fused_recall_2048q"]}
    for prec in ("int8",):
        qcfg = dataclasses.replace(cfg, precision=prec)
        qidx, qst, qdt = _build(x, k, qcfg, key)
        qrow[f"{prec}_s"] = round(qdt, 2)
        qrow[f"{prec}_evals"] = qst.dist_evals
        qrow[f"{prec}_recall_2048q"] = round(
            float(recall_at_k(qidx[:2048], ti)), 4)
    qrow["int8_recall_gap"] = round(
        row["fused_recall_2048q"] - qrow["int8_recall_2048q"], 4)
    sink.row(**qrow)
    return sink.save()


def run_smoke(n: int = 1024, d: int = 16, k: int = 10) -> list:
    """CI lane: small seeded fused-vs-lexsort build (build.json)."""
    sink = Sink("build")
    x = datasets.clustered(jax.random.key(4), n, d, 8)
    cfg = DescentConfig(k=k, rho=1.0, max_iters=12)
    key = jax.random.key(2)
    _, ti = brute_force_knn(x, x, k)
    out = {}
    for tag, backend in (("fused", "auto"), ("lexsort", "ref")):
        c = dataclasses.replace(cfg, backend=backend)
        idx, st, dt = _build(x, k, c, key)
        out[tag] = (dt, st.dist_evals, float(recall_at_k(idx, ti)))
    sink.row(op="smoke_build", n=n, k=k,
             fused_s=round(out["fused"][0], 3),
             lexsort_s=round(out["lexsort"][0], 3),
             build_speedup=round(out["lexsort"][0] /
                                 max(out["fused"][0], 1e-9), 2),
             fused_evals=out["fused"][1],
             lexsort_evals=out["lexsort"][1],
             build_recall=round(out["fused"][2], 4))
    return sink.save()


def main(argv: list | None = None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mode", choices=("compare", "smoke"), default="compare")
    p.add_argument("--n", type=int, default=None,
                   help="override corpus size (compare mode)")
    args = p.parse_args(argv)
    if args.mode == "smoke":
        return run_smoke()
    kw = {} if args.n is None else {"n": args.n}
    return run_compare(**kw)


if __name__ == "__main__":
    main()
