"""Paper Fig. 3: roofline of the K-NN build at d=8 (memory-bound) vs
d=256 (compute-bound).

The paper measures operational intensity with cachegrind on a Coffee Lake
core; the TPU-target analog derives the three roofline terms from the
compiled sharded NN-Descent iteration (launch/dryrun.py knn-build cells)
— run separately because it needs the 512-device dry-run process. THIS
bench computes the single-chip operational-intensity model for the
blocked kernel (flops/byte as a function of d and tile choice) and
reports which side of the v5e ridge each setting lands on, reproducing
the Fig. 3 memory->compute crossover structurally.
"""
from __future__ import annotations

from benchmarks.common import Sink
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

RIDGE = PEAK_FLOPS_BF16 / HBM_BW      # flops/byte where compute == memory


def run(n: int = 131_072, k: int = 20, rho_k: int = 20) -> list:
    sink = Sink("roofline_fig3")
    # per NN-Descent iteration: pairs ~ n * 1.5 * rho_k^2; each pair in
    # the MXU expansion form: 2d flops; bytes: candidate gathers dominate
    # (rows fetched once per neighborhood tile thanks to blocking):
    # ~ (2 * rho_k rows * d * bytes) per node + neighbor-list traffic.
    pairs_per_node = 1.5 * rho_k ** 2
    for d in (8, 64, 256, 1024):
        for dtype_bytes, dtname in ((4, "f32"), (2, "bf16")):
            flops = n * pairs_per_node * 2 * d
            # blocked: each candidate row loaded once per tile pass
            bytes_moved = n * (2 * rho_k * d * dtype_bytes      # features
                               + k * 8                          # lists
                               + pairs_per_node * 4)            # distances
            oi = flops / bytes_moved
            t_c = flops / PEAK_FLOPS_BF16
            t_m = bytes_moved / HBM_BW
            sink.row(d=d, dtype=dtname, n=n,
                     flops=f"{flops:.2e}", bytes=f"{bytes_moved:.2e}",
                     op_intensity=round(oi, 2),
                     ridge=round(RIDGE, 1),
                     bound="compute" if oi > RIDGE else "memory",
                     t_compute_ms=round(t_c * 1e3, 3),
                     t_memory_ms=round(t_m * 1e3, 3))
    sink.row(note="paper Fig.3: d=8 memory-bound, d=256 compute-bound on "
                  "CPU; on v5e the ridge sits at "
                  f"{RIDGE:.0f} flops/byte, so the crossover moves to "
                  "d~O(1k) f32 / d~O(512) bf16 — same structure, "
                  "TPU-shifted. Compiled-artifact terms: results/dryrun/"
                  "knn-build__*.json")
    return sink.save()


if __name__ == "__main__":
    run()
