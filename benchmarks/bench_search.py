"""Serving search benchmark: the fused batched multi-expansion beam
search vs. the retained greedy ref loop (the tentpole receipt for
kernels/knn_search.py + core/graph_search.py).

Modes (``python benchmarks/bench_search.py --mode ...``):

  * ``compare`` (default) — the acceptance receipt: builds one clustered
    corpus graph (default n=1e5, d=64), then answers the same q=4096
    query batch with the ref loop (``SearchConfig(backend="ref")`` — one
    node expanded per round, per-round argsorts) and the fused batched
    path (blocked distance tile + partial top-C select + sort-free pool
    merge, ``expand`` nodes per round) at the SAME expansion budget.
    Reports QPS for both, recall of both against brute force on a query
    subsample (the gate: fused recall within 0.005 of ref), and the
    paper §3.2 reordering claim measured on the SERVING gather path:
    ``locality_stats`` (in-block fraction / mean gather spread) before
    vs. after ``greedy_reorder``, plus fused QPS on the reordered graph.

  * ``smoke`` — tiny fixed config for the CI benchmark lane (< ~2 min on
    a CPU runner): one build, ref + fused search, emitting
    ``search_recall`` / ``ref_recall`` / ``fused_qps`` / ``ref_qps``,
    gated by benchmarks/check_gate.py (pinned search-recall floor and
    fused QPS >= ref QPS).

All rows go through benchmarks.common.Sink into results/bench/search.json;
the CI artifact uploads the whole results/bench directory.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import Sink, timeit
from repro.core import (
    DescentConfig,
    NeighborLists,
    SearchConfig,
    apply_permutation,
    brute_force_knn,
    datasets,
    greedy_reorder,
    locality_stats,
    recall_at_k,
)
from repro.core.graph_search import graph_search
from repro.core.nn_descent import build_knn_graph


def _qps(x, gidx, q, k_out, cfg, key, **kw):
    t = timeit(
        lambda: graph_search(x, gidx, q, k_out=k_out, key=key, cfg=cfg),
        **kw,
    )
    return q.shape[0] / t, t


def run_compare(n: int = 100_000, d: int = 64, q_n: int = 4096,
                k: int = 16, k_out: int = 10, beam: int = 32,
                rounds: int = 48, expand: int = 6, q_block: int = 512,
                n_eval: int = 1024, sink: Sink | None = None) -> list:
    sink = sink or Sink("search")
    x = datasets.clustered(jax.random.key(0), n, d, 16)
    # graph quality only needs to be good enough for both paths to search;
    # reorder=False so the locality story is measured separately below
    dcfg = DescentConfig(k=k, rho=0.5, max_iters=4, polish=1, reorder=False)
    dist, idx, _ = build_knn_graph(x, k=k, cfg=dcfg, key=jax.random.key(1))
    q = x[:q_n] + 0.01 * jax.random.normal(jax.random.key(2), (q_n, d))

    # ground truth on a subsample (full brute force at 1e5 x 4096 is the
    # point of NOT serving brute force; n_eval rows suffice for recall)
    _, ti = brute_force_knn(x, q[:n_eval], k_out, exclude_self=False)

    key = jax.random.key(3)
    row = {"op": "search_compare", "n": n, "d": d, "q": q_n, "k": k,
           "k_out": k_out, "beam": beam, "rounds": rounds, "expand": expand,
           "q_block": q_block}
    fcfg = SearchConfig(beam=beam, rounds=rounds, expand=expand,
                        q_block=q_block)
    for tag, cfg in (
        ("ref", SearchConfig(beam=beam, rounds=rounds, backend="ref")),
        ("fused", fcfg),
    ):
        qps, t = _qps(x, idx, q, k_out, cfg, key)
        _, gi = graph_search(x, idx, q[:n_eval], k_out=k_out, key=key,
                             cfg=cfg)
        row[f"{tag}_s"] = round(t, 3)
        row[f"{tag}_qps"] = round(qps, 1)
        row[f"{tag}_recall"] = round(float(recall_at_k(gi, ti)), 4)
    row["speedup"] = round(row["fused_qps"] / max(row["ref_qps"], 1e-9), 2)
    row["recall_gap"] = round(row["ref_recall"] - row["fused_recall"], 4)
    sink.row(**row)

    # --- paper §3.2 on the serving gather path: reorder locality + QPS
    nl = NeighborLists(dist, idx, jnp.zeros_like(idx, dtype=bool))
    pre = locality_stats(nl)
    sigma, sigma_inv = greedy_reorder(nl)
    x_r, nl_r = apply_permutation(x.astype(jnp.float32), nl, sigma,
                                  sigma_inv)
    post = locality_stats(nl_r)
    qps_r, _ = _qps(x_r, nl_r.idx, q, k_out, fcfg, key)
    _, gi_r = graph_search(x_r, nl_r.idx, q[:n_eval], k_out=k_out, key=key,
                           cfg=fcfg)
    # returned ids are positions in the reordered array; map back for recall
    gi_orig = jnp.where(gi_r >= 0, sigma_inv[jnp.clip(gi_r, 0, n - 1)], -1)
    sink.row(op="search_reorder_locality",
             in_block_pre=round(pre["in_block_fraction"], 4),
             in_block_post=round(post["in_block_fraction"], 4),
             spread_pre=round(pre["mean_gather_spread"], 1),
             spread_post=round(post["mean_gather_spread"], 1),
             block=pre["block"],
             fused_qps_reordered=round(qps_r, 1),
             fused_recall_reordered=round(
                 float(recall_at_k(gi_orig, ti)), 4))
    return sink.save()


def run_smoke(n: int = 2048, d: int = 16, q_n: int = 512, k: int = 10,
              k_out: int = 10, beam: int = 48, rounds: int = 24,
              expand: int = 4) -> list:
    """CI lane: small seeded ref-vs-fused search (search.json). beam=48
    over an 8-cluster corpus keeps entry coverage off the critical path
    (the K-NN graph has no inter-cluster edges), so the gated recall
    measures the search itself."""
    sink = Sink("search")
    x = datasets.clustered(jax.random.key(5), n, d, 8)
    dcfg = DescentConfig(k=k, rho=1.0, max_iters=10)
    _, idx, _ = build_knn_graph(x, k=k, cfg=dcfg, key=jax.random.key(6))
    q = x[:q_n] + 0.01 * jax.random.normal(jax.random.key(7), (q_n, d))
    _, ti = brute_force_knn(x, q, k_out, exclude_self=False)

    key = jax.random.key(8)
    out = {}
    for tag, cfg in (
        ("ref", SearchConfig(beam=beam, rounds=rounds, backend="ref")),
        ("fused", SearchConfig(beam=beam, rounds=rounds, expand=expand)),
    ):
        qps, t = _qps(x, idx, q, k_out, cfg, key, warmup=1, iters=3)
        _, gi = graph_search(x, idx, q, k_out=k_out, key=key, cfg=cfg)
        out[tag] = (qps, t, float(recall_at_k(gi, ti)))
    sink.row(op="smoke_search", n=n, q=q_n, k=k, beam=beam, rounds=rounds,
             expand=expand,
             ref_s=round(out["ref"][1], 3),
             fused_s=round(out["fused"][1], 3),
             ref_qps=round(out["ref"][0], 1),
             fused_qps=round(out["fused"][0], 1),
             ref_recall=round(out["ref"][2], 4),
             search_recall=round(out["fused"][2], 4),
             speedup=round(out["fused"][0] / max(out["ref"][0], 1e-9), 2))
    return sink.save()


def main(argv: list | None = None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mode", choices=("compare", "smoke"), default="compare")
    p.add_argument("--n", type=int, default=None,
                   help="override corpus size (compare mode)")
    p.add_argument("--q", type=int, default=None,
                   help="override query count (compare mode)")
    p.add_argument("--expand", type=int, default=None,
                   help="override fused expansion width (compare mode)")
    args = p.parse_args(argv)
    if args.mode == "smoke":
        return run_smoke()
    kw = {}
    if args.n is not None:
        kw["n"] = args.n
    if args.q is not None:
        kw["q_n"] = args.q
    if args.expand is not None:
        kw["expand"] = args.expand
    return run_compare(**kw)


if __name__ == "__main__":
    main()
