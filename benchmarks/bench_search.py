"""Serving search benchmark: the fused batched multi-expansion beam
search vs. the retained greedy ref loop (the tentpole receipt for
kernels/knn_search.py + core/graph_search.py).

Modes (``python benchmarks/bench_search.py --mode ...``):

  * ``compare`` (default) — the acceptance receipt: builds one clustered
    corpus graph (default n=1e5, d=64), then answers the same q=4096
    query batch with the ref loop (``SearchConfig(backend="ref")`` — one
    node expanded per round, per-round argsorts) and the fused batched
    path (blocked distance tile + partial top-C select + sort-free pool
    merge, ``expand`` nodes per round) at the SAME expansion budget.
    Reports QPS for both, recall of both against brute force on a query
    subsample (the gate: fused recall within 0.005 of ref), and the
    paper §3.2 reordering claim measured on the SERVING gather path:
    ``locality_stats`` (in-block fraction / mean gather spread) before
    vs. after ``greedy_reorder``, plus fused QPS on the reordered graph.

  * ``smoke`` — tiny fixed config for the CI benchmark lane (< ~2 min on
    a CPU runner): one build, ref + fused search, emitting
    ``search_recall`` / ``ref_recall`` / ``fused_qps`` / ``ref_qps``,
    gated by benchmarks/check_gate.py (pinned search-recall floor and
    fused QPS >= ref QPS).

  * ``smoke --precision int8|bf16`` — the quant-parity CI step: the same
    smoke corpus answered by the fused fp32 path and the two-stage
    quantized path (quantized candidate scoring + fp32 re-rank, scoring
    on a precomputed QuantizedStore — the serving-cache semantics).
    Emits ``f32_qps`` / ``f32_recall`` / ``quant_qps`` / ``quant_recall``
    into results/bench/search_quant.json (its own sink so it never
    clobbers the gated smoke rows), gated by check_gate.py (pinned
    quantized-recall floor and quant QPS >= f32 QPS).

``compare`` additionally measures the two-stage quantized path (int8 and
bf16) against fused fp32 at the same budget — the receipt for the
mixed-precision datastore. Rows go through benchmarks.common.Sink into
results/bench/search.json (search_quant.json for the quant smoke); the
CI artifact uploads the whole results/bench directory.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, Sink, timeit
from repro.core import (
    DescentConfig,
    NeighborLists,
    RouterConfig,
    SearchConfig,
    apply_permutation,
    brute_force_knn,
    build_router,
    datasets,
    greedy_reorder,
    heap,
    locality_stats,
    quantize_corpus,
    recall_at_k,
)
from repro.core.graph_search import graph_search
from repro.core.layout import pad_features
from repro.core.nn_descent import build_knn_graph
from repro.core.quantize import mirror_width


def _qps(x, gidx, q, k_out, cfg, key, qstore=None, x2=None, router=None,
         **kw):
    t = timeit(
        lambda: graph_search(x, gidx, q, k_out=k_out, key=key, cfg=cfg,
                             qstore=qstore, x2=x2, router=router),
        **kw,
    )
    return q.shape[0] / t, t


def _interleaved_qps(runs: dict, qn: int, reps: int = 7) -> dict:
    """Median wall time per tagged thunk with the reps INTERLEAVED
    (a-b-a-b...), so slow patches of a shared/noisy runner hit every
    path equally instead of whichever happened to run second. Returns
    {tag: (qps, median_s)}."""
    for fn in runs.values():             # warm every compiled path first
        jax.block_until_ready(fn())
    ts = {tag: [] for tag in runs}
    for _ in range(reps):
        for tag, fn in runs.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[tag].append(time.perf_counter() - t0)
    return {tag: (qn / float(np.median(v)), float(np.median(v)))
            for tag, v in ts.items()}


def run_compare(n: int = 100_000, d: int = 64, q_n: int = 4096,
                k: int = 16, k_out: int = 10, beam: int = 32,
                rounds: int = 48, expand: int = 6, q_block: int = 512,
                n_eval: int = 1024, sink: Sink | None = None) -> list:
    sink = sink or Sink("search")
    x = datasets.clustered(jax.random.key(0), n, d, 16)
    # graph quality only needs to be good enough for both paths to search;
    # reorder=False so the locality story is measured separately below
    dcfg = DescentConfig(k=k, rho=0.5, max_iters=4, polish=1, reorder=False)
    dist, idx, _ = build_knn_graph(x, k=k, cfg=dcfg, key=jax.random.key(1))
    q = x[:q_n] + 0.01 * jax.random.normal(jax.random.key(2), (q_n, d))

    # ground truth on a subsample (full brute force at 1e5 x 4096 is the
    # point of NOT serving brute force; n_eval rows suffice for recall)
    _, ti = brute_force_knn(x, q[:n_eval], k_out, exclude_self=False)

    key = jax.random.key(3)
    row = {"op": "search_compare", "n": n, "d": d, "q": q_n, "k": k,
           "k_out": k_out, "beam": beam, "rounds": rounds, "expand": expand,
           "q_block": q_block}
    fcfg = SearchConfig(beam=beam, rounds=rounds, expand=expand,
                        q_block=q_block)
    for tag, cfg in (
        ("ref", SearchConfig(beam=beam, rounds=rounds, backend="ref")),
        ("fused", fcfg),
    ):
        qps, t = _qps(x, idx, q, k_out, cfg, key)
        _, gi = graph_search(x, idx, q[:n_eval], k_out=k_out, key=key,
                             cfg=cfg)
        row[f"{tag}_s"] = round(t, 3)
        row[f"{tag}_qps"] = round(qps, 1)
        row[f"{tag}_recall"] = round(float(recall_at_k(gi, ti)), 4)
    row["speedup"] = round(row["fused_qps"] / max(row["ref_qps"], 1e-9), 2)
    row["recall_gap"] = round(row["ref_recall"] - row["fused_recall"], 4)

    # --- routed entry seeding at the SAME budget (the large-n receipt):
    # uniform-random beam entries strand the search far from the query at
    # this scale, so fused recall collapses; the router's hierarchical
    # entries (nearest members of the query's top centroids) start the
    # beam inside the answer's neighborhood and recover it
    # wide member lists (IVF-style: the top-t cells are enumerated nearly
    # in full as seed candidates) because the cheap compare-bench graph is
    # itself the recall ceiling for pure traversal at this n
    router = build_router(
        x, cfg=RouterConfig(n_centroids=512, iters=6, members=256),
        key=jax.random.key(4))
    rcfg = dataclasses.replace(fcfg, router_t=16)
    qps_rt, t_rt = _qps(x, idx, q, k_out, rcfg, key, router=router)
    _, gi_rt = graph_search(x, idx, q[:n_eval], k_out=k_out, key=key,
                            cfg=rcfg, router=router)
    row["routed_s"] = round(t_rt, 3)
    row["routed_qps"] = round(qps_rt, 1)
    row["routed_recall"] = round(float(recall_at_k(gi_rt, ti)), 4)
    row["routed_gain"] = round(row["routed_recall"] - row["fused_recall"], 4)
    sink.row(**row)

    # --- the two-stage quantized path at the SERVING layout and the same
    # budget: production searches go through the store (MutableKNNStore /
    # serve), whose fp32 rows are padded to the 128-lane quantum
    # (layout.pad_features); the quantized mirror keeps only the logical
    # dims (quantize.mirror_width) with per-row scales — a precomputed
    # cache, like the store keeps (never re-quantized per batch). Both
    # paths answer the same padded store + graph; only the candidate-
    # scoring stage differs, and the quantized pool re-ranks fp32. The
    # acceptance claim: int8 QPS above fp32 with recall within 0.02.
    xp = pad_features(x.astype(jnp.float32))
    x2p = jnp.sum(xp * xp, axis=1)
    qp = pad_features(q.astype(jnp.float32))
    qrow = {"op": "search_quant_compare", "n": n, "d": d, "q": q_n,
            "dp_serving": xp.shape[1], "beam": beam, "rounds": rounds,
            "expand": expand}
    stores = {"f32": None}
    cfgs = {"f32": fcfg}
    for prec in ("int8", "bf16"):
        cfgs[prec] = dataclasses.replace(fcfg, precision=prec)
        stores[prec] = quantize_corpus(xp, prec,
                                       width=mirror_width(d, xp.shape[1]))
        jax.block_until_ready(stores[prec].data)
    res = _interleaved_qps(
        {tag: (lambda tag=tag: graph_search(
            xp, idx, qp, k_out=k_out, key=key, cfg=cfgs[tag],
            qstore=stores[tag], x2=x2p))
         for tag in ("f32", "int8", "bf16")},
        q_n,
    )
    for tag in ("f32", "int8", "bf16"):
        _, gi = graph_search(xp, idx, qp[:n_eval], k_out=k_out, key=key,
                             cfg=cfgs[tag], qstore=stores[tag], x2=x2p)
        qrow[f"{tag}_s"] = round(res[tag][1], 3)
        qrow[f"{tag}_qps"] = round(res[tag][0], 1)
        qrow[f"{tag}_recall"] = round(float(recall_at_k(gi, ti)), 4)
    qrow["int8_speedup_vs_f32"] = round(
        qrow["int8_qps"] / max(qrow["f32_qps"], 1e-9), 2)
    qrow["int8_recall_gap"] = round(
        qrow["f32_recall"] - qrow["int8_recall"], 4)
    sink.row(**qrow)

    # --- paper §3.2 on the serving gather path: reorder locality + QPS
    nl = NeighborLists(dist, idx, jnp.zeros_like(idx, dtype=bool))
    pre = locality_stats(nl)
    sigma, sigma_inv = greedy_reorder(nl)
    x_r, nl_r = apply_permutation(x.astype(jnp.float32), nl, sigma,
                                  sigma_inv)
    post = locality_stats(nl_r)
    qps_r, _ = _qps(x_r, nl_r.idx, q, k_out, fcfg, key)
    _, gi_r = graph_search(x_r, nl_r.idx, q[:n_eval], k_out=k_out, key=key,
                           cfg=fcfg)
    # returned ids are positions in the reordered array; map back for recall
    gi_orig = jnp.where(gi_r >= 0, sigma_inv[jnp.clip(gi_r, 0, n - 1)], -1)
    sink.row(op="search_reorder_locality",
             in_block_pre=round(pre["in_block_fraction"], 4),
             in_block_post=round(post["in_block_fraction"], 4),
             spread_pre=round(pre["mean_gather_spread"], 1),
             spread_post=round(post["mean_gather_spread"], 1),
             block=pre["block"],
             fused_qps_reordered=round(qps_r, 1),
             fused_recall_reordered=round(
                 float(recall_at_k(gi_orig, ti)), 4))
    return sink.save()


def run_smoke(n: int = 2048, d: int = 16, q_n: int = 512, k: int = 10,
              k_out: int = 10, beam: int = 48, rounds: int = 24,
              expand: int = 4) -> list:
    """CI lane: small seeded ref-vs-fused search (search.json). beam=48
    over an 8-cluster corpus keeps entry coverage off the critical path
    (the K-NN graph has no inter-cluster edges), so the gated recall
    measures the search itself."""
    sink = Sink("search")
    x = datasets.clustered(jax.random.key(5), n, d, 8)
    dcfg = DescentConfig(k=k, rho=1.0, max_iters=10)
    _, idx, _ = build_knn_graph(x, k=k, cfg=dcfg, key=jax.random.key(6))
    q = x[:q_n] + 0.01 * jax.random.normal(jax.random.key(7), (q_n, d))
    _, ti = brute_force_knn(x, q, k_out, exclude_self=False)

    key = jax.random.key(8)
    out = {}
    for tag, cfg in (
        ("ref", SearchConfig(beam=beam, rounds=rounds, backend="ref")),
        ("fused", SearchConfig(beam=beam, rounds=rounds, expand=expand)),
    ):
        qps, t = _qps(x, idx, q, k_out, cfg, key, warmup=1, iters=3)
        _, gi = graph_search(x, idx, q, k_out=k_out, key=key, cfg=cfg)
        out[tag] = (qps, t, float(recall_at_k(gi, ti)))
    sink.row(op="smoke_search", n=n, q=q_n, k=k, beam=beam, rounds=rounds,
             expand=expand,
             ref_s=round(out["ref"][1], 3),
             fused_s=round(out["fused"][1], 3),
             ref_qps=round(out["ref"][0], 1),
             fused_qps=round(out["fused"][0], 1),
             ref_recall=round(out["ref"][2], 4),
             search_recall=round(out["fused"][2], 4),
             speedup=round(out["fused"][0] / max(out["ref"][0], 1e-9), 2))
    return sink.save()


def run_smoke_quant(precision: str, n: int = 2048, d: int = 16,
                    q_n: int = 512, k: int = 10, k_out: int = 10,
                    beam: int = 48, rounds: int = 24, expand: int = 4,
                    qps_n: int = 65536, qps_d: int = 64, qps_q: int = 1024,
                    qps_k: int = 16) -> list:
    """CI quant-parity lane, two sub-measurements in one row:

    * ``quant_recall`` / ``f32_recall`` — end-to-end two-stage search on
      the SAME quality smoke corpus as run_smoke (n=2048, real NN-Descent
      graph), so the quantized recall floor is directly comparable to
      the gated fp32 ``search_recall`` floor.
    * ``quant_qps`` / ``f32_qps`` — serving throughput at the layout and
      scale where the mixed-precision store matters: an n=65536 store at
      the padded serving layout (layout.pad_features, 128 lanes) with a
      random regular graph (graph construction is not under test and a
      random graph maximizes gather entropy — the bandwidth-bound regime
      the int8 mirror exists for), identical graph/budget for both
      paths, reps interleaved so runner noise hits both paths equally.

    Its own sink (search_quant.json) so the gated smoke rows in
    search.json survive; gated by check_gate.py --quant."""
    sink = Sink("search_quant")

    # --- recall parity on the quality corpus
    x = datasets.clustered(jax.random.key(5), n, d, 8)
    dcfg = DescentConfig(k=k, rho=1.0, max_iters=10)
    _, idx, _ = build_knn_graph(x, k=k, cfg=dcfg, key=jax.random.key(6))
    q = x[:q_n] + 0.01 * jax.random.normal(jax.random.key(7), (q_n, d))
    _, ti = brute_force_knn(x, q, k_out, exclude_self=False)
    key = jax.random.key(8)
    fcfg = SearchConfig(beam=beam, rounds=rounds, expand=expand)
    qcfg = dataclasses.replace(fcfg, precision=precision)
    qstore = quantize_corpus(x.astype(jnp.float32), precision)
    recalls = {}
    for tag, cfg, qst in (("f32", fcfg, None), ("quant", qcfg, qstore)):
        _, gi = graph_search(x, idx, q, k_out=k_out, key=key, cfg=cfg,
                             qstore=qst)
        recalls[tag] = float(recall_at_k(gi, ti))

    # --- serving-layout throughput (see docstring)
    xb = datasets.clustered(jax.random.key(15), qps_n, qps_d, 16)
    xbp = pad_features(xb.astype(jnp.float32))
    x2bp = jnp.sum(xbp * xbp, axis=1)
    gidx = heap.init_random(jax.random.key(16), qps_n, qps_k).idx
    qb = pad_features(
        (xb[:qps_q] + 0.01 * jax.random.normal(jax.random.key(17),
                                               (qps_q, qps_d))
         ).astype(jnp.float32))
    scfg = SearchConfig(beam=32, rounds=48, expand=6, q_block=512)
    sqcfg = dataclasses.replace(scfg, precision=precision)
    bstore = quantize_corpus(xbp, precision,
                             width=mirror_width(qps_d, xbp.shape[1]))
    jax.block_until_ready(bstore.data)
    res = _interleaved_qps(
        {"f32": lambda: graph_search(xbp, gidx, qb, k_out=k_out, key=key,
                                     cfg=scfg, x2=x2bp),
         "quant": lambda: graph_search(xbp, gidx, qb, k_out=k_out, key=key,
                                       cfg=sqcfg, qstore=bstore, x2=x2bp)},
        qps_q,
    )
    sink.row(op="smoke_search_quant", precision=precision, n=n, q=q_n,
             k=k, beam=beam, rounds=rounds, expand=expand,
             qps_n=qps_n, qps_d=qps_d, qps_q=qps_q,
             f32_s=round(res["f32"][1], 3),
             quant_s=round(res["quant"][1], 3),
             f32_qps=round(res["f32"][0], 1),
             quant_qps=round(res["quant"][0], 1),
             f32_recall=round(recalls["f32"], 4),
             quant_recall=round(recalls["quant"], 4),
             quant_speedup=round(res["quant"][0] /
                                 max(res["f32"][0], 1e-9), 2))
    return sink.save()


def _metric_sink(op: str, metric: str | None = None) -> Sink:
    """search_metric.json is shared by the --metric and --filter lanes,
    which CI runs as SEPARATE invocations: preload any rows an earlier
    invocation saved (append semantics), dropping only a stale row of
    this same lane so re-runs replace rather than duplicate."""
    sink = Sink("search_metric")
    path = os.path.join(RESULTS_DIR, "search_metric.json")
    if os.path.exists(path):
        with open(path) as f:
            sink.rows = [
                r for r in json.load(f)
                if not (r.get("op") == op
                        and (metric is None or r.get("metric") == metric))
            ]
    return sink


def run_smoke_metric(metric: str, n: int = 2048, d: int = 16,
                     q_n: int = 512, k_out: int = 10, beam: int = 48,
                     rounds: int = 24, expand: int = 4) -> list:
    """CI metric lane: the smoke corpus served under cosine / mips
    through the full store path (MutableKNNStore — transformed rows,
    transformed-row graph, query transform at the search boundary).
    Recall is measured against the NATIVE-metric brute-force oracle
    (descending cosine / inner product), and ``sim_err_rel`` receipts
    the exactness claim: ``similarity_from_dist`` applied to the
    returned transformed-space distances must reproduce the true native
    similarities of the returned rows (relative to the oracle's score
    scale). MIPS builds a denser graph (k=20 vs the smoke k=10):
    max-IP neighbors concentrate on large-norm hub rows, and the
    sparser graph under-connects them (docs/METRICS.md).

    Own sink (search_metric.json, shared with the filter lane) so the
    gated fp32 smoke rows survive; gated by check_gate.py --metric."""
    from repro.core import metric as metric_mod
    from repro.core.online import MutableKNNStore, OnlineConfig

    sink = _metric_sink("smoke_search_metric", metric)
    k = 20 if metric == "mips" else 10
    x = datasets.clustered(jax.random.key(5), n, d, 8)
    q = x[:q_n] + 0.01 * jax.random.normal(jax.random.key(7), (q_n, d))
    store, _ = MutableKNNStore.build(
        x, k=k, cfg=OnlineConfig(metric=metric),
        descent=DescentConfig(k=k, rho=1.0, max_iters=10),
        key=jax.random.key(6))

    # native-metric oracle
    if metric == "cosine":
        xs = x / jnp.linalg.norm(x, axis=1, keepdims=True)
        qs = q / jnp.linalg.norm(q, axis=1, keepdims=True)
        scores = qs @ xs.T
    else:
        scores = q @ x.T
    ti = jax.lax.top_k(scores, k_out)[1]

    key = jax.random.key(8)
    t = timeit(lambda: store.search(q, k_out=k_out, beam=beam,
                                    rounds=rounds, key=key),
               warmup=1, iters=3)
    dd, ii = store.search(q, k_out=k_out, beam=beam, rounds=rounds,
                          key=key)
    rec = float(recall_at_k(ii, ti))

    # exact-similarity receipt on the returned ids
    sim = metric_mod.similarity_from_dist(
        dd, metric, q2=jnp.sum(q.astype(jnp.float32) ** 2, axis=1)[:, None],
        mips_m=store.mips_m)
    true_sim = jnp.take_along_axis(scores, jnp.clip(ii, 0, n - 1), axis=1)
    valid = ii >= 0
    scale = max(1.0, float(jnp.max(jnp.abs(scores))))
    sim_err_rel = float(jnp.max(jnp.where(
        valid, jnp.abs(sim - true_sim), 0.0))) / scale

    sink.row(op="smoke_search_metric", metric=metric, n=n, q=q_n, k=k,
             beam=beam, rounds=rounds, expand=expand,
             search_s=round(t, 3),
             qps=round(q_n / max(t, 1e-9), 1),
             metric_recall=round(rec, 4),
             sim_err_rel=round(sim_err_rel, 8),
             mips_m=round(float(store.mips_m), 4))
    return sink.save()


def run_smoke_filter(n: int = 2048, d: int = 16, q_n: int = 256,
                     k: int = 10, k_out: int = 10, beam: int = 48,
                     rounds: int = 24, expand: int = 4) -> list:
    """CI filtered-search lane: per-query predicate masks on the smoke
    corpus — the two-tenant split (even / odd rows), which admits half
    the corpus per query (``filter_frac`` = 0.5). ``leaked`` counts
    returned ids that violate their query's predicate, summed over four
    variants (fused per-query, fused shared-mask, int8 per-query, ref
    per-query) — the gate pins it to exactly 0. ``filtered_recall`` is
    measured against the predicate-restricted brute-force oracle, so
    the lane also catches a filter path that silently trades recall.

    Shares search_metric.json with the metric lane; gated by
    check_gate.py --metric."""
    from repro.core import metric as metric_mod

    sink = _metric_sink("smoke_search_filter")
    x = datasets.clustered(jax.random.key(5), n, d, 8)
    dcfg = DescentConfig(k=k, rho=1.0, max_iters=10)
    _, idx, _ = build_knn_graph(x, k=k, cfg=dcfg, key=jax.random.key(6))
    q = x[:q_n] + 0.01 * jax.random.normal(jax.random.key(7), (q_n, d))
    key = jax.random.key(8)

    # two tenants: query i sees only rows with id % 2 == i % 2
    parity = jnp.arange(n) % 2
    filt_pq = parity[None, :] == (jnp.arange(q_n)[:, None] % 2)
    filt_shared = parity == 0                     # one tenant, all queries

    # predicate-restricted oracle (per-query tenancy)
    d2 = jnp.sum((q[:, None, :] - x[None]) ** 2, axis=-1)
    ti = jax.lax.top_k(-jnp.where(filt_pq, d2, jnp.inf), k_out)[1]

    fcfg = SearchConfig(beam=beam, rounds=rounds, expand=expand)
    variants = {
        "fused_pq": (fcfg, filt_pq, None),
        "fused_shared": (fcfg, filt_shared, None),
        "int8_pq": (dataclasses.replace(fcfg, precision="int8"), filt_pq,
                    quantize_corpus(x.astype(jnp.float32), "int8")),
        "ref_pq": (SearchConfig(beam=beam, rounds=rounds, backend="ref"),
                   filt_pq, None),
    }
    leaked = 0
    rec = {}
    par = np.asarray(parity)
    for tag, (cfg, filt, qst) in variants.items():
        _, gi = graph_search(x, idx, q, k_out=k_out, key=key, cfg=cfg,
                             filter_ids=filt, qstore=qst)
        gi = np.asarray(gi)
        for r in range(q_n):
            ids = gi[r][gi[r] >= 0]
            want = (r % 2) if filt is filt_pq else 0
            leaked += int((par[ids] != want).sum())
        if filt is filt_pq:
            rec[tag] = float(recall_at_k(jnp.asarray(gi), ti))

    qps_t = timeit(lambda: graph_search(x, idx, q, k_out=k_out, key=key,
                                        cfg=fcfg, filter_ids=filt_pq),
                   warmup=1, iters=3)
    sink.row(op="smoke_search_filter", n=n, q=q_n, k=k, beam=beam,
             rounds=rounds, expand=expand,
             filter_frac=round(metric_mod.filter_frac(filt_pq), 4),
             leaked=leaked,
             filtered_recall=round(rec["fused_pq"], 4),
             filtered_recall_int8=round(rec["int8_pq"], 4),
             filtered_recall_ref=round(rec["ref_pq"], 4),
             filtered_s=round(qps_t, 3),
             filtered_qps=round(q_n / max(qps_t, 1e-9), 1))
    return sink.save()


# the routed-dispatch half of the router lane: run in a forked
# subprocess with a forced multi-device CPU topology (the bench process
# already initialized jax single-device). Cluster-aligned shards +
# route_p=1 means own-cluster top-1 routing: queries spread uniformly
# (q = x[::8] -> n/(8*P) per shard), so route_cap=48 > 32 expected per
# shard and dropped_queries must be exactly 0 — the gate's watch item.
_ROUTED_STATS_SRC = """
import json
import jax, jax.numpy as jnp
from repro.core import DescentConfig, RouterConfig, SearchConfig
from repro.core.distributed import graph_search_sharded
from repro.core.nn_descent import build_knn_graph
from repro.core.router import build_router

P, n, d = 4, 1024, 16
n_local = n // P
cent = jax.random.normal(jax.random.key(0), (P, d)) * 8.0
noise = jax.random.normal(jax.random.key(1), (P, n_local, d)) * 0.5
x = (cent[:, None, :] + noise).reshape(n, d).astype(jnp.float32)
cfg = DescentConfig(k=10, rho=1.0, max_iters=10, reorder=False)
parts = []
for s in range(P):
    _, gi, _ = build_knn_graph(x[s*n_local:(s+1)*n_local], k=10, cfg=cfg,
                               key=jax.random.key(s))
    parts.append(gi)
gidx = jnp.concatenate(parts)
router = build_router(x, cfg=RouterConfig(n_centroids=16, sample=1024),
                      key=jax.random.key(7))
mesh = jax.make_mesh((P,), ("data",))
q = x[::8] + 0.01
scfg = SearchConfig(beam=16, rounds=24, expand=4)
_, _, st = graph_search_sharded(mesh, x, gidx, q, k_out=10, cfg=scfg,
                                key=jax.random.key(2), router=router,
                                route_p=1, route_cap=48, with_stats=True)
print("ROUTED_STATS " + json.dumps(
    {k: (v if isinstance(v, (list, tuple, float)) else int(v))
     for k, v in st.items()}))
"""


def _routed_dispatch_stats(n_devices: int = 4, timeout: int = 600) -> dict:
    """Routed sharded dispatch on a forced n_devices CPU topology, in a
    fork (jax device topology is fixed at first backend init). Returns
    the with_stats dict: fanout / shards / routed / searched / dropped."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo, "src"), env.get("PYTHONPATH")) if p)
    proc = subprocess.run([sys.executable, "-c", _ROUTED_STATS_SRC],
                          capture_output=True, text=True, env=env,
                          cwd=repo, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(
            f"routed-dispatch stats child failed "
            f"(rc={proc.returncode}):\n{proc.stderr}")
    lines = [ln for ln in proc.stdout.splitlines()
             if ln.startswith("ROUTED_STATS ")]
    if not lines:
        raise RuntimeError(
            f"routed-dispatch stats child printed no ROUTED_STATS:"
            f"\n{proc.stdout}")
    return json.loads(lines[-1][len("ROUTED_STATS "):])


def run_smoke_router(n: int = 4096, d: int = 16, n_clusters: int = 32,
                     q_n: int = 512, k: int = 10, k_out: int = 10,
                     beam: int = 16, rounds: int = 24,
                     expand: int = 4) -> list:
    """CI router lane: the unit-scale large-n collapse. 32 clusters with
    a beam of 16 means uniform-random entries cover only ~40% of the
    clusters (the K-NN graph has no inter-cluster edges — uncovered
    clusters are unreachable), while the routed entries seed every query
    inside its own cluster at the SAME budget. Emits ``routed_recall`` /
    ``random_recall`` / ``routed_qps`` / ``random_qps`` into
    results/bench/search_router.json (its own sink so the gated fp32
    smoke rows survive), gated by check_gate.py --router.

    The row also carries the routed-DISPATCH stats from a forked
    multi-device run (``_routed_dispatch_stats``): ``dropped_queries``
    must be 0 — a ``route_cap`` regression on the sharded serving path
    silently degrades recall, so the gate makes it loud."""
    sink = Sink("search_router")
    x = datasets.clustered(jax.random.key(5), n, d, n_clusters)
    dcfg = DescentConfig(k=k, rho=1.0, max_iters=10)
    _, idx, _ = build_knn_graph(x, k=k, cfg=dcfg, key=jax.random.key(6))
    q = x[:q_n] + 0.01 * jax.random.normal(jax.random.key(7), (q_n, d))
    _, ti = brute_force_knn(x, q, k_out, exclude_self=False)
    router = build_router(
        x, cfg=RouterConfig(n_centroids=2 * n_clusters),
        key=jax.random.key(9))

    key = jax.random.key(8)
    cfg = SearchConfig(beam=beam, rounds=rounds, expand=expand)
    out = {}
    for tag, rt in (("random", None), ("routed", router)):
        qps, t = _qps(x, idx, q, k_out, cfg, key, router=rt,
                      warmup=1, iters=3)
        _, gi = graph_search(x, idx, q, k_out=k_out, key=key, cfg=cfg,
                             router=rt)
        out[tag] = (qps, t, float(recall_at_k(gi, ti)))
    st = _routed_dispatch_stats()
    sink.row(op="smoke_search_router", n=n, q=q_n, k=k, beam=beam,
             rounds=rounds, expand=expand,
             n_clusters=n_clusters,
             n_centroids=router.centroids.shape[0],
             route_fanout=st["fanout"],
             route_shards=st["shards"],
             routed_queries=st["routed_queries"],
             searched_queries=st["searched_queries"],
             dropped_queries=st["dropped_queries"],
             random_s=round(out["random"][1], 3),
             routed_s=round(out["routed"][1], 3),
             random_qps=round(out["random"][0], 1),
             routed_qps=round(out["routed"][0], 1),
             random_recall=round(out["random"][2], 4),
             routed_recall=round(out["routed"][2], 4),
             routed_gain=round(out["routed"][2] - out["random"][2], 4))
    return sink.save()


def main(argv: list | None = None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mode", choices=("compare", "smoke"), default="compare")
    p.add_argument("--n", type=int, default=None,
                   help="override corpus size (compare mode)")
    p.add_argument("--q", type=int, default=None,
                   help="override query count (compare mode)")
    p.add_argument("--expand", type=int, default=None,
                   help="override fused expansion width (compare mode)")
    p.add_argument("--precision", choices=("int8", "bf16"), default=None,
                   help="smoke mode: run the two-stage quantized parity "
                        "lane (search_quant.json) instead of the fp32 "
                        "smoke; compare mode measures both regardless")
    p.add_argument("--router", action="store_true",
                   help="smoke mode: run the routed-vs-random entry lane "
                        "(search_router.json) instead of the fp32 smoke; "
                        "compare mode measures the routed path regardless")
    p.add_argument("--metric", choices=("cosine", "mips"), default=None,
                   help="smoke mode: run the metric lane (store build + "
                        "search under cosine/mips vs the native-metric "
                        "oracle, search_metric.json) instead of the fp32 "
                        "smoke")
    p.add_argument("--filter", action="store_true", dest="filter_lane",
                   help="smoke mode: run the filtered-search lane "
                        "(per-query predicate masks, leakage pinned to "
                        "0, search_metric.json) instead of the fp32 "
                        "smoke")
    args = p.parse_args(argv)
    if args.mode == "smoke":
        if args.router:
            return run_smoke_router()
        if args.metric is not None:
            return run_smoke_metric(args.metric)
        if args.filter_lane:
            return run_smoke_filter()
        if args.precision is not None:
            return run_smoke_quant(args.precision)
        return run_smoke()
    kw = {}
    if args.n is not None:
        kw["n"] = args.n
    if args.q is not None:
        kw["q_n"] = args.q
    if args.expand is not None:
        kw["expand"] = args.expand
    return run_compare(**kw)


if __name__ == "__main__":
    main()
