"""Paper §3.2/§4.3: the greedy reordering heuristic.

  --locality  : Table 1 analog — in-block edge fraction + gather spread
                before/after σ (the cachegrind LL-miss stand-in).
  --clusters  : Fig. 4 — windowed cluster purity along the reordered axis.
  --iterations: Fig. 5 — per-iteration wall time with/without reorder on
                the Synthetic Clustered Dataset (16'384 pts, 16 clusters,
                d=8 — the paper's exact setting).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Sink
from repro import DescentConfig, NeighborLists, apply_permutation, build_knn_graph, greedy_reorder, locality_stats, window_cluster_purity
from repro.core import datasets


def run(n: int = 16_384, d: int = 8, c: int = 16) -> list:
    sink = Sink("reorder")
    key = jax.random.key(0)
    x, labels = datasets.clustered(key, n, d, c, labels=True)

    # --- locality (Table 1 analog)
    cfg = DescentConfig(k=20, rho=1.0, max_iters=4, reorder=False)
    dist, idx, _ = build_knn_graph(x, k=20, cfg=cfg)
    nl = NeighborLists(dist, idx, jnp.zeros_like(idx, dtype=bool))
    before = locality_stats(nl)
    sigma, sigma_inv = greedy_reorder(nl)
    _, nl2 = apply_permutation(x, nl, sigma, sigma_inv)
    after = locality_stats(nl2)
    sink.row(metric="in_block_fraction", before=round(before["in_block_fraction"], 4),
             after=round(after["in_block_fraction"], 4),
             improvement=round(after["in_block_fraction"]
                               / max(before["in_block_fraction"], 1e-9), 2))
    sink.row(metric="mean_gather_spread",
             before=round(before["mean_gather_spread"], 1),
             after=round(after["mean_gather_spread"], 1),
             improvement=round(before["mean_gather_spread"]
                               / max(after["mean_gather_spread"], 1e-9), 2))

    # --- cluster purity (Fig. 4)
    starts, purity = window_cluster_purity(labels, sigma, window=2000,
                                           stride=2000)
    for s, p in zip(starts, purity):
        sink.row(metric="window_purity", window_start=s,
                 purity=round(p, 3), random_baseline=round(1 / c, 3))

    # --- per-iteration time (Fig. 5)
    for variant, reorder in (("no-heuristic", False),
                             ("greedyheuristic", True)):
        times = []

        def cb(it, upd, nl, _t=[time.perf_counter()]):
            now = time.perf_counter()
            times.append(now - _t[0])
            _t[0] = now

        cfg = DescentConfig(k=20, rho=1.0, max_iters=6, reorder=reorder)
        t0 = time.perf_counter()
        build_knn_graph(x, k=20, cfg=cfg, callback=cb)
        total = time.perf_counter() - t0
        for i, t in enumerate(times):
            sink.row(metric="iteration_time", variant=variant, iteration=i,
                     seconds=round(t, 3))
        sink.row(metric="total_time", variant=variant,
                 seconds=round(total, 3))
    return sink.save()


if __name__ == "__main__":
    run()
