"""Benchmark harness entry point — one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run             # all
    PYTHONPATH=src python -m benchmarks.run --only selection,reorder
    PYTHONPATH=src python -m benchmarks.run --quick     # reduced sizes

Prints one CSV-ish line per measurement; JSON sinks go to results/bench/.
Paper artifact map:
    selection   -> §4.1 (16x fused, 1.12x turbosampling)
    reorder     -> Table 1 (locality), Fig. 4 (purity), Fig. 5 (per-iter)
    scaling     -> Fig. 6 (vs n), Fig. 7 (vs d), O(n^1.14)
    realworld   -> Table 2 (MNIST/Audio stand-ins)
    roofline    -> Fig. 3 (memory/compute crossover, v5e ridge)
    kernels     -> (ours) blocked-kernel tile model
    online      -> (ours) streaming insert/delete vs. full rebuild
    build       -> (ours) fused local join vs. global-lexsort routing
    search      -> (ours) fused batched beam search vs. greedy ref loop
    persist     -> (ours) snapshot/restore parity + zero-rebuild cold start
    metric      -> (ours) cosine/MIPS reductions + filtered-search leakage
    slo         -> (ours) overload: admission/backpressure under a burst
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_build,
        bench_kernels,
        bench_online,
        bench_persist,
        bench_realworld,
        bench_reorder,
        bench_roofline,
        bench_scaling,
        bench_search,
        bench_selection,
        bench_slo,
    )

    quick = args.quick
    jobs = {
        "selection": lambda: bench_selection.run(
            n=4096 if quick else 16_384),
        "roofline": lambda: bench_roofline.run(),
        "kernels": lambda: bench_kernels.run(
            m=1024 if quick else 2048, n=1024 if quick else 2048),
        "reorder": lambda: bench_reorder.run(
            n=4096 if quick else 8192),
        "scaling": lambda: bench_scaling.run(
            axis="d" if quick else "both"),
        "realworld": lambda: bench_realworld.run(
            n_mnist=2048 if quick else 4096,
            n_audio=2048 if quick else 4096),
        "online": lambda: bench_online.run(
            n=2048 if quick else 8192, batch=128 if quick else 256,
            n_batches=2 if quick else 4),
        "build": lambda: bench_build.run_compare(
            n=4096 if quick else 20000),
        "search": lambda: bench_search.run_compare(
            n=8192 if quick else 100_000, q_n=512 if quick else 4096,
            n_eval=256 if quick else 1024),
        "persist": lambda: bench_persist.run_smoke(
            n=2048 if quick else 4096),
        "metric": lambda: (
            bench_search.run_smoke_metric("cosine",
                                          n=2048 if quick else 8192),
            bench_search.run_smoke_metric("mips",
                                          n=2048 if quick else 8192),
            bench_search.run_smoke_filter(n=2048 if quick else 8192),
        ),
        "slo": lambda: (bench_slo.run_smoke() if quick
                        else bench_slo.main(["--mode", "full"])),
    }
    only = set(args.only.split(",")) if args.only else set(jobs)
    t0 = time.time()
    for name, fn in jobs.items():
        if name not in only:
            continue
        print(f"\n=== bench:{name} ===", flush=True)
        t = time.time()
        fn()
        print(f"=== bench:{name} done in {time.time()-t:.1f}s", flush=True)
    print(f"\nall benches done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
