"""SLO benchmark: a bursty open-loop arrival process against the
RetrievalScheduler (the CI receipt for admission control, backpressure,
deadline propagation, and the bucketed q_block ladder).

Modes (``python benchmarks/bench_slo.py --mode ...``):

  * ``smoke`` (default) — the gated CI lane. A seeded arrival schedule
    mixing three traffic shapes over a few seconds of wall time:

      - interactive singles (Poisson-ish arrivals, tight deadline)
      - periodic batch groups (one multi-query submit burst per period,
        loose deadline)
      - one scripted interactive BURST that exceeds the bounded queue,
        so shedding is exercised deterministically every run

    The driver is OPEN-LOOP: arrivals land on their scheduled wall-clock
    times whether or not the scheduler is keeping up (that is what makes
    overload possible — a closed loop would just slow down). Between
    arrivals the driver pumps the scheduler; each pump dispatches one
    lane-pure batch at its bucketed ``q_block`` ladder step with the
    batch's tightest remaining deadline propagated into
    ``SearchConfig.max_rounds_deadline``.

    The SAME schedule then replays against ``fixed_block=True`` — the
    pre-ladder baseline that pads every dispatch to the full ``q_block``
    — so the ladder's interactive-latency win is measured in the same
    run on the same machine. Both sub-runs pre-warm their compile caches
    (every reachable bucket, plus the deadline-cut variant) before the
    clock starts, exactly like a production server would.

    Emits one ``smoke_slo`` row into results/bench/slo.json, gated by
    check_gate.py --slo: ``crashes == 0``, ``silent_drops == 0`` (every
    non-served request carries a typed rejection), interactive p99 at or
    below ``--slo-p99-floor``, ``shed_frac`` at or below
    ``--slo-shed-max``, and bucketed interactive p99 at or below 0.9x
    the fixed-block baseline's.

  * ``full`` — the same workload at a longer duration / higher rates
    (not gated; for local latency investigation).
"""
from __future__ import annotations

import argparse
import time
import warnings

import numpy as np


def make_schedule(seed: int, duration_s: float, inter_rate: float,
                  batch_every_s: float, batch_group: int,
                  burst_at_s: float, burst_n: int,
                  inter_deadline_ms: float, batch_deadline_ms: float):
    """The seeded arrival schedule: a sorted list of
    (t_s, lane, deadline_ms) tuples. Same seed -> byte-identical
    schedule, so the bucketed and fixed-block sub-runs see the same
    offered load."""
    rng = np.random.RandomState(seed)
    arrivals = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / inter_rate))
        if t >= duration_s:
            break
        arrivals.append((t, "interactive", inter_deadline_ms))
    bt = batch_every_s
    while bt < duration_s:
        arrivals.extend((bt, "batch", batch_deadline_ms)
                        for _ in range(batch_group))
        bt += batch_every_s
    # the scripted overload: burst_n interactive arrivals at one instant
    arrivals.extend((burst_at_s, "interactive", inter_deadline_ms)
                    for _ in range(burst_n))
    arrivals.sort(key=lambda a: a[0])
    return arrivals


def _warm(search_fn, base_cfg, d: int, fixed: bool, max_batch: int):
    """Compile every block shape a sub-run can dispatch before its clock
    starts: each reachable bucket (the ladder up to the dispatch cap
    when bucketed, just the full block when fixed) x {normal,
    deadline-cut} round budgets."""
    import dataclasses

    import jax.numpy as jnp

    top = min(base_cfg.q_block, max_batch)
    sizes = [base_cfg.q_block] if fixed else sorted(
        {1 << b for b in range(top.bit_length() + 1) if (1 << b) <= top}
        | {top})
    cut = dataclasses.replace(base_cfg, rounds=base_cfg.expand)
    for m in sizes:
        q = jnp.zeros((m, d), jnp.float32)
        for cfg in (base_cfg, cut):
            dd, _ = search_fn(q, cfg)
            dd.block_until_ready()


def drive(schedule, sched, qpool) -> dict:
    """Open-loop replay of ``schedule`` against ``sched``. Returns the
    run accounting (requests, crashes)."""
    reqs = []
    crashes = 0
    notes = []
    i = 0
    t0 = time.perf_counter()
    while i < len(schedule) or len(sched.queue):
        now = time.perf_counter() - t0
        while i < len(schedule) and schedule[i][0] <= now:
            _, lane, dl = schedule[i]
            try:
                reqs.append(sched.submit(qpool[i % len(qpool)],
                                         lane=lane, deadline_ms=dl))
            except Exception as e:  # noqa: BLE001 — the gate counts these
                crashes += 1
                notes.append(f"submit: {e!r}")
            i += 1
        if len(sched.queue):
            try:
                sched.pump()
            except Exception as e:  # noqa: BLE001
                crashes += 1
                notes.append(f"pump: {e!r}")
                break               # a broken pump would spin forever
        elif i < len(schedule):
            time.sleep(min(2e-3, max(0.0, schedule[i][0] - now)))
    return {"reqs": reqs, "crashes": crashes, "notes": notes}


def _pcts(vals: list) -> tuple:
    if not vals:
        return float("nan"), float("nan")
    v = np.asarray(vals)
    return float(np.percentile(v, 50)), float(np.percentile(v, 99))


def run_smoke(*, n: int = 2048, d: int = 16, k: int = 10,
              duration_s: float = 2.0, inter_rate: float = 40.0,
              batch_every_s: float = 0.5, batch_group: int = 24,
              burst_n: int = 48, max_queue: int = 32, max_batch: int = 8,
              seed: int = 0, op: str = "smoke_slo") -> list:
    import jax
    import jax.numpy as jnp

    from benchmarks.common import Sink
    from repro.core import datasets
    from repro.core.graph_search import SearchConfig, graph_search
    from repro.core.nn_descent import DescentConfig, build_knn_graph
    from repro.serve.scheduler import RetrievalScheduler, SchedulerConfig

    sink = Sink("slo")
    x = datasets.clustered(jax.random.key(0), n, d, 16)
    _, gidx, _ = build_knn_graph(
        x, k=k, cfg=DescentConfig(k=k, rho=1.0, max_iters=10,
                                  reorder=False),
        key=jax.random.key(1))
    base_cfg = SearchConfig(beam=16, rounds=12, expand=4, q_block=32)

    def search_fn(q, cfg):
        return graph_search(x, gidx, q, k_out=k, key=jax.random.key(2),
                            cfg=cfg)

    qpool = np.asarray(x[::4] + 0.01, np.float32)
    schedule = make_schedule(
        seed, duration_s, inter_rate, batch_every_s, batch_group,
        burst_at_s=duration_s / 2, burst_n=burst_n,
        inter_deadline_ms=250.0, batch_deadline_ms=2000.0)

    def one_run(fixed: bool) -> dict:
        cfg = SearchConfig(beam=16, rounds=12, expand=4, q_block=32,
                           fixed_block=fixed)
        sched = RetrievalScheduler(
            search_fn, base_cfg=cfg,
            cfg=SchedulerConfig(max_queue=max_queue,
                                shed_policy="drop-oldest-batch",
                                max_batch=max_batch))
        _warm(search_fn, cfg, d, fixed, max_batch)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            acct = drive(schedule, sched, qpool)
        st = sched.stats()
        # the no-silent-drops ledger: every offered request must end
        # served (idx set) or carry a typed rejection — nothing vanishes
        silent = sum(1 for r in acct["reqs"]
                     if r.idx is None and r.rejection is None)
        if len(acct["reqs"]) != len(schedule):
            silent += abs(len(schedule) - len(acct["reqs"]))
            acct["notes"].append(
                f"{len(acct['reqs'])} tracked requests vs "
                f"{len(schedule)} offered")
        p50_i, p99_i = _pcts(st["latency_ms"]["interactive"])
        p50_b, p99_b = _pcts(st["latency_ms"]["batch"])
        return {
            "stats": st, "crashes": acct["crashes"],
            "silent_drops": silent, "notes": acct["notes"],
            "p50_i": p50_i, "p99_i": p99_i,
            "p50_b": p50_b, "p99_b": p99_b,
        }

    bucketed = one_run(fixed=False)
    fixed = one_run(fixed=True)

    st = bucketed["stats"]
    shed_frac = st["shed"] / max(len(schedule), 1)
    sink.row(
        op=op, n=n, d=d, k=k, duration_s=duration_s,
        offered=len(schedule), admitted=st["admitted"],
        served=st["served"], shed=st["shed"], expired=st["expired"],
        shed_frac=round(shed_frac, 4), dispatches=st["dispatches"],
        crashes=bucketed["crashes"] + fixed["crashes"],
        silent_drops=bucketed["silent_drops"] + fixed["silent_drops"],
        interactive_p50_ms=round(bucketed["p50_i"], 3),
        interactive_p99_ms=round(bucketed["p99_i"], 3),
        batch_p50_ms=round(bucketed["p50_b"], 3),
        batch_p99_ms=round(bucketed["p99_b"], 3),
        fixed_interactive_p99_ms=round(fixed["p99_i"], 3),
        fixed_shed=fixed["stats"]["shed"],
        notes="; ".join(bucketed["notes"] + fixed["notes"]))
    return sink.save()


def main(argv: list | None = None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mode", choices=("smoke", "full"), default="smoke")
    args = p.parse_args(argv)
    if args.mode == "full":
        return run_smoke(n=8192, duration_s=6.0, inter_rate=80.0,
                         batch_group=48, burst_n=96, op="full_slo")
    return run_smoke()


if __name__ == "__main__":
    main()
