"""Reconstruct a clean benchmark log from the results/bench JSON sinks
(used when a previous writer interleaved bench_output.txt)."""
import glob
import json
import sys


def main(outdir="results/bench"):
    order = ["selection", "roofline_fig3", "kernels", "reorder",
             "scaling", "realworld"]
    files = {f.split("/")[-1][:-5]: f
             for f in glob.glob(f"{outdir}/*.json")}
    for name in order + sorted(set(files) - set(order)):
        if name not in files:
            continue
        rows = json.load(open(files[name]))
        print(f"\n=== bench:{name} ===")
        for r in rows:
            print(",".join(f"{k}={v}" for k, v in r.items()))
        print(f"=== bench:{name} done ===")


if __name__ == "__main__":
    main(*sys.argv[1:])
