"""CI perf/quality gate for the online-update + offline-build bench lanes.

Reads the JSON written by ``bench_online.py --mode smoke`` (and, when
``--build`` is given, ``bench_build.py --mode smoke``) and fails (exit 1)
when any gated metric violates its pinned floor:

  * ``insert_recall`` — combined-corpus recall@k after a streamed insert
    batch must stay at or above ``--floor`` (quality gate)
  * ``dangling_edges`` — a delete must leave zero edges pointing at
    tombstoned rows (correctness gate)
  * ``fused_evals``/``lexsort_evals`` — the fused local join must not
    spend more distance evaluations than the lexsort oracle path
    (cost-model gate; tiny slack for sampling divergence)
  * ``build_recall`` — the fused build must stay at or above
    ``--build-floor`` on the smoke corpus (quality gate)
  * ``search_recall`` — the fused batched graph search must stay at or
    above ``--search-floor`` on the smoke corpus, and ``fused_qps`` must
    not drop below ``ref_qps`` (the serving hot path must never be slower
    than the greedy oracle loop it replaced) — when ``--search`` is given
  * ``quant_recall`` — the two-stage quantized search (int8 scoring +
    fp32 re-rank) must stay at or above ``--quant-floor`` (pinned <= 0.02
    below the fp32 search floor: quantization may cost bounded candidate
    recall, never more), and ``quant_qps`` must not drop below
    ``f32_qps`` (quantized scoring exists to be FASTER; parity or worse
    means the two-stage plumbing regressed) — when ``--quant`` is given
  * ``routed_recall`` — the router-seeded search must stay at or above
    ``--router-floor`` on the adversarial router smoke shape (32
    clusters, beam 16: uniform-random entries reach only ~0.4 recall
    there, so the floor pins the routing win itself) and must never drop
    below ``random_recall`` at the same budget — when ``--router`` is
    given. The routed-dispatch stats sidecar must report
    ``dropped_queries == 0`` (a ``route_cap`` regression silently
    degrades recall on real shards; the gate makes it loud).
  * ``ids_bitident``/``dists_bitident`` — a snapshot restored in a fresh
    process must answer the smoke query batch bit-identically (ids and
    fp32 distance bits) to the live store it was captured from, and
    ``cold_start_speedup`` (rebuild wall-clock / restore wall-clock)
    must stay at or above ``--persist-floor`` — when ``--persist`` is
    given (correctness + the zero-rebuild cold-start claim)
  * chaos — the scripted fault schedule (bench_chaos.py: flaky writer,
    poisoned batch, torn newest snapshot, dead shard) must degrade
    gracefully: ``crashes == 0`` (unhandled exceptions AND violated
    degradation contracts both count), ``dropped_queries == 0``,
    ``degraded_recall`` (vs the surviving shards' attainable ground
    truth) at or above ``--chaos-floor``, and the corrupted-snapshot
    cold start must fall back to the older committed step
    bit-identically (``fallback_bitident``) — when ``--chaos`` is given
  * metric — the cosine AND MIPS smoke lanes (``bench_search.py --mode
    smoke --metric ...``) must each reach ``metric_recall`` at or above
    ``--metric-floor`` against the NATIVE-metric brute-force oracle
    (top cosine similarity / top inner product, not l2), and
    ``sim_err_rel`` — the relative error of the distance→similarity
    conversion (core/metric.py similarity_from_dist) on the returned
    neighbors — must stay tiny (<= 1e-3; observed ~1e-7: the reduction
    is exact up to fp32 rounding). The filtered lane
    (``--filter``) must report ``leaked == 0`` — no query may ever
    surface an id its predicate excluded, across the fused per-query,
    fused shared-mask, int8 store and ref-oracle variants — with a
    non-vacuous ``filter_frac`` — when ``--metric`` is given
  * SLO — the bursty open-loop overload schedule (bench_slo.py) must be
    survived gracefully: ``crashes == 0``, ``silent_drops == 0`` (every
    non-served request carries a typed rejection), interactive p99 at or
    below ``--slo-p99-floor`` ms, ``shed_frac`` of the offered load at
    or below ``--slo-shed-max`` but strictly positive (the scripted
    burst must actually exercise admission control), and the bucketed
    ``q_block`` ladder's interactive p99 must sit measurably (0.9x)
    below the fixed-block baseline replayed on the same schedule in the
    same run — when ``--slo`` is given

When running under GitHub Actions (``GITHUB_STEP_SUMMARY`` set) a
markdown metrics table (recall / QPS / evals per gate, fp32 vs
quantized) is appended to the step summary, so bench trends are readable
from the run page without downloading the JSON artifact.

See benchmarks/README.md for how the floors are pinned and when to move
them.

Usage: python benchmarks/check_gate.py results/bench/online.json \
           --floor 0.85 --build results/bench/build.json --build-floor 0.95 \
           --search results/bench/search.json --search-floor 0.92 \
           --quant results/bench/search_quant.json --quant-floor 0.90 \
           --router results/bench/search_router.json --router-floor 0.90 \
           --persist results/bench/persist.json --persist-floor 5.0 \
           --chaos results/bench/chaos.json --chaos-floor 0.80 \
           --metric results/bench/search_metric.json --metric-floor 0.90 \
           --slo results/bench/slo.json --slo-p99-floor 150 \
           --slo-shed-max 0.35
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def check(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_insert"]
    if not smoke:
        failures.append("no smoke_insert row in benchmark output")
    for r in smoke:
        recall = float(r.get("insert_recall", 0.0))
        if recall < floor:
            failures.append(
                f"insert_recall {recall:.4f} below pinned floor {floor}"
            )
    for r in rows:
        if r.get("op") == "smoke_delete" and int(r.get("dangling_edges", 0)):
            failures.append(
                f"delete left {r['dangling_edges']} dangling edges"
            )
    return failures


def check_build(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_build"]
    if not smoke:
        failures.append("no smoke_build row in benchmark output")
    for r in smoke:
        missing = [key for key in ("fused_evals", "lexsort_evals",
                                   "build_recall") if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(f"smoke_build row missing gated keys {missing}")
            continue
        fused = int(r["fused_evals"])
        ref = int(r["lexsort_evals"])
        # 2% slack: the fused and lexsort paths sample identically only
        # on the first iteration; later iterations diverge benignly
        if fused > ref * 1.02:
            failures.append(
                f"fused build spent {fused} dist evals vs lexsort {ref}"
            )
        recall = float(r["build_recall"])
        if recall < floor:
            failures.append(
                f"build_recall {recall:.4f} below pinned floor {floor}"
            )
    return failures


def check_search(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_search"]
    if not smoke:
        failures.append("no smoke_search row in benchmark output")
    for r in smoke:
        missing = [key for key in ("search_recall", "ref_recall",
                                   "fused_qps", "ref_qps") if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(f"smoke_search row missing gated keys {missing}")
            continue
        recall = float(r["search_recall"])
        if recall < floor:
            failures.append(
                f"search_recall {recall:.4f} below pinned floor {floor}"
            )
        fused = float(r["fused_qps"])
        ref = float(r["ref_qps"])
        if fused < ref:
            failures.append(
                f"fused search QPS {fused} below ref loop QPS {ref}"
            )
    return failures


def check_quant(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_search_quant"]
    if not smoke:
        failures.append("no smoke_search_quant row in benchmark output")
    for r in smoke:
        missing = [key for key in ("quant_recall", "f32_recall",
                                   "quant_qps", "f32_qps") if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(
                f"smoke_search_quant row missing gated keys {missing}")
            continue
        recall = float(r["quant_recall"])
        if recall < floor:
            failures.append(
                f"quant_recall {recall:.4f} below pinned floor {floor}"
            )
        quant = float(r["quant_qps"])
        f32 = float(r["f32_qps"])
        if quant < f32:
            failures.append(
                f"quantized search QPS {quant} below fp32 QPS {f32}"
            )
    return failures


def check_router(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_search_router"]
    if not smoke:
        failures.append("no smoke_search_router row in benchmark output")
    for r in smoke:
        missing = [key for key in ("routed_recall", "random_recall",
                                   "routed_qps", "random_qps")
                   if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(
                f"smoke_search_router row missing gated keys {missing}")
            continue
        routed = float(r["routed_recall"])
        random = float(r["random_recall"])
        if routed < floor:
            failures.append(
                f"routed_recall {routed:.4f} below pinned floor {floor}"
            )
        # the routed floor must sit ABOVE what random entries reach on
        # this adversarial shape — and routed may never be worse than
        # random at the same budget (the router would be pure overhead)
        if routed < random:
            failures.append(
                f"routed_recall {routed:.4f} below random-entry recall "
                f"{random:.4f} at the same budget"
            )
        # routed-dispatch watch item: the sharded dispatch must have a
        # route_cap wide enough that NO query is silently dropped — a
        # missing stat means the sidecar measurement regressed, which
        # must fail loudly too
        if "dropped_queries" not in r:
            failures.append(
                "smoke_search_router row missing dropped_queries "
                "(routed-dispatch stats sidecar did not run)")
        elif int(r["dropped_queries"]):
            failures.append(
                f"routed dispatch dropped {r['dropped_queries']} queries "
                f"(route_cap too tight for the smoke shard shape)")
    return failures


def check_persist(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_persist"]
    if not smoke:
        failures.append("no smoke_persist row in benchmark output")
    for r in smoke:
        missing = [key for key in ("ids_bitident", "dists_bitident",
                                   "rebuild_s", "restore_s",
                                   "cold_start_speedup") if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(
                f"smoke_persist row missing gated keys {missing}")
            continue
        if not r["ids_bitident"]:
            failures.append(
                "restored search returned different neighbor ids than "
                "the live store (snapshot round trip is lossy)")
        if not r["dists_bitident"]:
            failures.append(
                "restored search distances differ from the live store "
                "at the bit level (snapshot round trip is lossy)")
        speedup = float(r["cold_start_speedup"])
        if speedup < floor:
            failures.append(
                f"cold_start_speedup {speedup:.2f}x below pinned floor "
                f"{floor}x (restore_s={r['restore_s']}, "
                f"rebuild_s={r['rebuild_s']})")
    return failures


def check_chaos(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_chaos"]
    if not smoke:
        failures.append("no smoke_chaos row in benchmark output")
    for r in smoke:
        missing = [key for key in ("crashes", "dropped_queries",
                                   "degraded_recall", "fallback_bitident",
                                   "recovery_s") if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(f"smoke_chaos row missing gated keys {missing}")
            continue
        if int(r["crashes"]):
            failures.append(
                f"chaos schedule produced {r['crashes']} crash(es)/"
                f"contract violation(s): {r.get('notes', '')}")
        if int(r["dropped_queries"]):
            failures.append(
                f"chaos schedule dropped {r['dropped_queries']} queries "
                "(degraded serving must answer every query)")
        recall = float(r["degraded_recall"])
        if recall < floor:
            failures.append(
                f"degraded_recall {recall:.4f} below pinned floor {floor} "
                "(survivors must still answer well with a dead shard)")
        if not r["fallback_bitident"]:
            failures.append(
                "corrupted-snapshot cold start was not bit-identical to "
                "the older committed step (fallback restore is lossy)")
    return failures


def check_metric(rows: list, floor: float, sim_tol: float = 1e-3) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_search_metric"]
    seen = {r.get("metric") for r in smoke}
    # BOTH reductions are gated: a lane silently dropping out of the CI
    # matrix must fail here, not pass vacuously
    for want in ("cosine", "mips"):
        if want not in seen:
            failures.append(
                f"no smoke_search_metric row for metric '{want}'")
    for r in smoke:
        met = r.get("metric", "?")
        missing = [key for key in ("metric_recall", "sim_err_rel")
                   if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(
                f"smoke_search_metric[{met}] row missing gated keys "
                f"{missing}")
            continue
        recall = float(r["metric_recall"])
        if recall < floor:
            failures.append(
                f"{met} metric_recall {recall:.4f} below pinned floor "
                f"{floor} (vs the native-metric brute-force oracle)")
        err = float(r["sim_err_rel"])
        if not err == err or err > sim_tol:
            failures.append(
                f"{met} sim_err_rel {err} above bound {sim_tol} "
                "(distance->similarity conversion is no longer exact "
                "for the native metric)")
    filt = [r for r in rows if r.get("op") == "smoke_search_filter"]
    if not filt:
        failures.append("no smoke_search_filter row in benchmark output")
    for r in filt:
        missing = [key for key in ("leaked", "filter_frac",
                                   "filtered_recall") if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(
                f"smoke_search_filter row missing gated keys {missing}")
            continue
        if int(r["leaked"]):
            failures.append(
                f"filtered search leaked {r['leaked']} predicate-"
                "excluded id(s) across variants (zero-leakage contract "
                "broken)")
        if float(r["filter_frac"]) <= 0.0:
            failures.append(
                "filter_frac is 0 — the smoke filter excluded nothing, "
                "the leakage gate is vacuous")
    return failures


def check_slo(rows: list, p99_floor: float, shed_max: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_slo"]
    if not smoke:
        failures.append("no smoke_slo row in benchmark output")
    for r in smoke:
        missing = [key for key in ("crashes", "silent_drops",
                                   "interactive_p99_ms",
                                   "fixed_interactive_p99_ms",
                                   "shed_frac", "shed") if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(f"smoke_slo row missing gated keys {missing}")
            continue
        if int(r["crashes"]):
            failures.append(
                f"SLO schedule produced {r['crashes']} crash(es): "
                f"{r.get('notes', '')}")
        if int(r["silent_drops"]):
            failures.append(
                f"{r['silent_drops']} request(s) ended with neither a "
                "result nor a typed rejection (silent drop)")
        p99 = float(r["interactive_p99_ms"])
        if not p99 == p99:          # NaN: no interactive request served
            failures.append("interactive_p99_ms is NaN (no interactive "
                            "latency samples)")
        elif p99 > p99_floor:
            failures.append(
                f"interactive p99 {p99:.1f}ms above pinned ceiling "
                f"{p99_floor}ms under the scripted burst")
        shed_frac = float(r["shed_frac"])
        if shed_frac > shed_max:
            failures.append(
                f"shed_frac {shed_frac:.3f} above bound {shed_max} "
                "(overload control shedding too much of the offered "
                "load)")
        if not int(r["shed"]):
            failures.append(
                "scripted burst shed nothing — the bounded queue / "
                "admission path was not exercised")
        fixed = float(r["fixed_interactive_p99_ms"])
        # the bucketed q_block ladder must beat the fixed-block baseline
        # on the SAME schedule in the SAME run; relative gate (0.9x) so
        # machine speed cancels out
        if fixed == fixed and p99 == p99 and p99 > 0.9 * fixed:
            failures.append(
                f"bucketed interactive p99 {p99:.1f}ms not measurably "
                f"below the fixed-block baseline {fixed:.1f}ms")
    return failures


# rows rendered into the step-summary table: (gate, metric, source op,
# row key, floor text). "vs" floors compare against another key.
_SUMMARY_SPEC = (
    ("online", "insert_recall", "smoke_insert", "insert_recall",
     "floor"),
    ("online", "dangling_edges", "smoke_delete", "dangling_edges",
     "== 0"),
    ("build", "build_recall", "smoke_build", "build_recall",
     "build_floor"),
    ("build", "fused_evals", "smoke_build", "fused_evals",
     "<= 1.02x lexsort_evals"),
    ("build", "lexsort_evals", "smoke_build", "lexsort_evals", ""),
    ("search", "search_recall (fused)", "smoke_search", "search_recall",
     "search_floor"),
    ("search", "ref_recall (fp32 oracle)", "smoke_search", "ref_recall",
     ""),
    ("search", "fused_qps", "smoke_search", "fused_qps", ">= ref_qps"),
    ("search", "ref_qps", "smoke_search", "ref_qps", ""),
    ("quant", "quant_recall (int8 two-stage)", "smoke_search_quant",
     "quant_recall", "quant_floor"),
    ("quant", "f32_recall (same budget)", "smoke_search_quant",
     "f32_recall", ""),
    ("quant", "quant_qps", "smoke_search_quant", "quant_qps",
     ">= f32_qps"),
    ("quant", "f32_qps", "smoke_search_quant", "f32_qps", ""),
    ("router", "routed_recall (hierarchical entries)",
     "smoke_search_router", "routed_recall", "router_floor"),
    ("router", "random_recall (uniform entries)", "smoke_search_router",
     "random_recall", "<= routed_recall"),
    ("router", "routed_qps", "smoke_search_router", "routed_qps", ""),
    ("router", "random_qps", "smoke_search_router", "random_qps", ""),
    ("router", "dropped_queries (routed dispatch)", "smoke_search_router",
     "dropped_queries", "== 0"),
    ("persist", "ids_bitident (restored search)", "smoke_persist",
     "ids_bitident", "== True"),
    ("persist", "dists_bitident (fp32 bits)", "smoke_persist",
     "dists_bitident", "== True"),
    ("persist", "cold_start_speedup", "smoke_persist",
     "cold_start_speedup", "persist_floor"),
    ("persist", "restore_s", "smoke_persist", "restore_s", ""),
    ("persist", "rebuild_s", "smoke_persist", "rebuild_s", ""),
    ("persist", "snapshot_mb", "smoke_persist", "snapshot_mb", ""),
    ("chaos", "crashes / contract violations", "smoke_chaos", "crashes",
     "== 0"),
    ("chaos", "dropped_queries (degraded dispatch)", "smoke_chaos",
     "dropped_queries", "== 0"),
    ("chaos", "degraded_recall (1 dead shard of 4)", "smoke_chaos",
     "degraded_recall", "chaos_floor"),
    ("chaos", "baseline_recall (all shards live)", "smoke_chaos",
     "baseline_recall", ""),
    ("chaos", "fallback_bitident (torn newest snapshot)", "smoke_chaos",
     "fallback_bitident", "== True"),
    ("chaos", "recovery_s (fallback cold start)", "smoke_chaos",
     "recovery_s", ""),
    ("metric", "cosine metric_recall (fused)", "smoke_search_metric:cosine",
     "metric_recall", "metric_floor"),
    ("metric", "cosine sim_err_rel", "smoke_search_metric:cosine",
     "sim_err_rel", "<= 0.001"),
    ("metric", "mips metric_recall (fused)", "smoke_search_metric:mips",
     "metric_recall", "metric_floor"),
    ("metric", "mips sim_err_rel", "smoke_search_metric:mips",
     "sim_err_rel", "<= 0.001"),
    ("metric", "mips_m (augmentation bound)", "smoke_search_metric:mips",
     "mips_m", ""),
    ("metric", "leaked (filtered, all variants)", "smoke_search_filter",
     "leaked", "== 0"),
    ("metric", "filter_frac (excluded fraction)", "smoke_search_filter",
     "filter_frac", "> 0"),
    ("metric", "filtered_recall (fused per-query)", "smoke_search_filter",
     "filtered_recall", ""),
    ("metric", "filtered_recall_int8 (store path)", "smoke_search_filter",
     "filtered_recall_int8", ""),
    ("metric", "filtered_recall_ref (oracle)", "smoke_search_filter",
     "filtered_recall_ref", ""),
    ("slo", "crashes (open-loop burst schedule)", "smoke_slo", "crashes",
     "== 0"),
    ("slo", "silent_drops (typed rejections only)", "smoke_slo",
     "silent_drops", "== 0"),
    ("slo", "interactive_p50_ms (bucketed)", "smoke_slo",
     "interactive_p50_ms", ""),
    ("slo", "interactive_p99_ms (bucketed)", "smoke_slo",
     "interactive_p99_ms", "slo_p99"),
    ("slo", "fixed_interactive_p99_ms (baseline)", "smoke_slo",
     "fixed_interactive_p99_ms", ">= interactive_p99 / 0.9"),
    ("slo", "batch_p99_ms", "smoke_slo", "batch_p99_ms", ""),
    ("slo", "shed_frac (of offered load)", "smoke_slo", "shed_frac",
     "slo_shed"),
    ("slo", "expired (deadline misses)", "smoke_slo", "expired", ""),
)


def write_step_summary(row_sets: dict, floors: dict, failures: list):
    """Append a markdown metrics table to $GITHUB_STEP_SUMMARY (no-op
    outside GitHub Actions): one row per gated/contextual metric, so the
    fp32-vs-quantized trend is readable from the run page."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    by_op = {}
    for rows in row_sets.values():
        for r in rows or []:
            by_op.setdefault(r.get("op"), r)     # first row per op
            if "metric" in r:                    # per-metric lanes share an op
                by_op.setdefault(f"{r.get('op')}:{r['metric']}", r)
    lines = [
        "## bench-smoke gates",
        "",
        "| gate | metric | value | requirement |",
        "|---|---|---|---|",
    ]
    ceilings = {"slo_p99", "slo_shed"}   # upper bounds, not floors
    for gate, metric, op, rkey, req in _SUMMARY_SPEC:
        r = by_op.get(op)
        if r is None or rkey not in r:
            continue
        if req in floors:
            req_txt = f"{'<=' if req in ceilings else '>='} {floors[req]}"
        else:
            req_txt = req or "—"
        lines.append(f"| {gate} | {metric} | {r[rkey]} | {req_txt} |")
    lines.append("")
    lines.append("**GATE FAIL:** " + "; ".join(failures) if failures
                 else "All gates passed.")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main(argv: list | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("results", help="path to online.json")
    p.add_argument("--floor", type=float, default=0.85,
                   help="pinned insert_recall floor")
    p.add_argument("--build", default=None,
                   help="path to build.json (enables the build gate)")
    p.add_argument("--build-floor", type=float, default=0.95,
                   help="pinned build_recall floor")
    p.add_argument("--search", default=None,
                   help="path to search.json (enables the search gate)")
    p.add_argument("--search-floor", type=float, default=0.92,
                   help="pinned search_recall floor")
    p.add_argument("--quant", default=None,
                   help="path to search_quant.json (enables the "
                        "quantized-search gate)")
    p.add_argument("--quant-floor", type=float, default=0.90,
                   help="pinned quant_recall floor (<= 0.02 below the "
                        "fp32 search floor)")
    p.add_argument("--router", default=None,
                   help="path to search_router.json (enables the routed-"
                        "entry gate)")
    p.add_argument("--router-floor", type=float, default=0.90,
                   help="pinned routed_recall floor — sits ABOVE what "
                        "uniform-random entries reach on the adversarial "
                        "router smoke shape (~0.4)")
    p.add_argument("--persist", default=None,
                   help="path to persist.json (enables the snapshot/"
                        "restore gate)")
    p.add_argument("--persist-floor", type=float, default=5.0,
                   help="pinned cold_start_speedup floor (restore must "
                        "beat rebuild by at least this factor; observed "
                        "~250x on the smoke corpus)")
    p.add_argument("--chaos", default=None,
                   help="path to chaos.json (enables the fault-schedule "
                        "gate)")
    p.add_argument("--chaos-floor", type=float, default=0.80,
                   help="pinned degraded_recall floor — recall against "
                        "the surviving shards' attainable ground truth "
                        "with 1 of 4 shards dead")
    p.add_argument("--metric", default=None,
                   help="path to search_metric.json (enables the cosine/"
                        "MIPS + filtered-search gate)")
    p.add_argument("--metric-floor", type=float, default=0.90,
                   help="pinned metric_recall floor vs the native-metric "
                        "brute-force oracle, for BOTH the cosine and "
                        "MIPS smoke lanes (observed ~0.97 / ~0.95)")
    p.add_argument("--slo", default=None,
                   help="path to slo.json (enables the overload/SLO "
                        "gate)")
    p.add_argument("--slo-p99-floor", type=float, default=150.0,
                   help="pinned interactive p99 CEILING in ms under the "
                        "scripted burst (observed ~20ms locally; slack "
                        "for CI machine variance)")
    p.add_argument("--slo-shed-max", type=float, default=0.35,
                   help="max fraction of the offered load the scheduler "
                        "may shed (observed ~0.2 on the smoke schedule; "
                        "shedding MORE means admission is broken, 0 "
                        "means the burst stopped exercising it)")
    args = p.parse_args(argv)
    with open(args.results) as f:
        rows = json.load(f)
    row_sets = {"online": rows}
    failures = check(rows, args.floor)
    if args.build is not None:
        with open(args.build) as f:
            build_rows = json.load(f)
        row_sets["build"] = build_rows
        failures += check_build(build_rows, args.build_floor)
    if args.search is not None:
        with open(args.search) as f:
            search_rows = json.load(f)
        row_sets["search"] = search_rows
        failures += check_search(search_rows, args.search_floor)
    if args.quant is not None:
        with open(args.quant) as f:
            quant_rows = json.load(f)
        row_sets["quant"] = quant_rows
        failures += check_quant(quant_rows, args.quant_floor)
    if args.router is not None:
        with open(args.router) as f:
            router_rows = json.load(f)
        row_sets["router"] = router_rows
        failures += check_router(router_rows, args.router_floor)
    if args.persist is not None:
        with open(args.persist) as f:
            persist_rows = json.load(f)
        row_sets["persist"] = persist_rows
        failures += check_persist(persist_rows, args.persist_floor)
    if args.chaos is not None:
        with open(args.chaos) as f:
            chaos_rows = json.load(f)
        row_sets["chaos"] = chaos_rows
        failures += check_chaos(chaos_rows, args.chaos_floor)
    if args.metric is not None:
        with open(args.metric) as f:
            metric_rows = json.load(f)
        row_sets["metric"] = metric_rows
        failures += check_metric(metric_rows, args.metric_floor)
    if args.slo is not None:
        with open(args.slo) as f:
            slo_rows = json.load(f)
        row_sets["slo"] = slo_rows
        failures += check_slo(slo_rows, args.slo_p99_floor,
                              args.slo_shed_max)
    write_step_summary(
        row_sets,
        {"floor": args.floor, "build_floor": args.build_floor,
         "search_floor": args.search_floor,
         "quant_floor": args.quant_floor,
         "router_floor": args.router_floor,
         "persist_floor": args.persist_floor,
         "chaos_floor": args.chaos_floor,
         "metric_floor": args.metric_floor,
         "slo_p99": args.slo_p99_floor,
         "slo_shed": args.slo_shed_max},
        failures,
    )
    for msg in failures:
        print(f"GATE FAIL: {msg}", file=sys.stderr)
    if not failures:
        print(f"gate ok: insert_recall >= {args.floor}, no dangling edges"
              + ("" if args.build is None else
                 f"; build_recall >= {args.build_floor}, fused evals <= ref")
              + ("" if args.search is None else
                 f"; search_recall >= {args.search_floor}, "
                 "fused QPS >= ref QPS")
              + ("" if args.quant is None else
                 f"; quant_recall >= {args.quant_floor}, "
                 "quant QPS >= f32 QPS")
              + ("" if args.router is None else
                 f"; routed_recall >= {args.router_floor} "
                 "and >= random-entry recall, 0 dropped queries")
              + ("" if args.persist is None else
                 f"; restored search bit-identical, cold start >= "
                 f"{args.persist_floor}x faster than rebuild")
              + ("" if args.chaos is None else
                 f"; chaos schedule: 0 crashes, 0 dropped queries, "
                 f"degraded_recall >= {args.chaos_floor}, "
                 "bit-identical snapshot fallback")
              + ("" if args.metric is None else
                 f"; cosine+MIPS metric_recall >= {args.metric_floor} "
                 "with exact similarity conversion, filtered search "
                 "leaked 0 ids")
              + ("" if args.slo is None else
                 f"; SLO burst: 0 crashes, 0 silent drops, interactive "
                 f"p99 <= {args.slo_p99_floor}ms, shed_frac <= "
                 f"{args.slo_shed_max}, bucketed p99 < 0.9x fixed-block"))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
