"""CI perf/quality gate for the online-update + offline-build bench lanes.

Reads the JSON written by ``bench_online.py --mode smoke`` (and, when
``--build`` is given, ``bench_build.py --mode smoke``) and fails (exit 1)
when any gated metric violates its pinned floor:

  * ``insert_recall`` — combined-corpus recall@k after a streamed insert
    batch must stay at or above ``--floor`` (quality gate)
  * ``dangling_edges`` — a delete must leave zero edges pointing at
    tombstoned rows (correctness gate)
  * ``fused_evals``/``lexsort_evals`` — the fused local join must not
    spend more distance evaluations than the lexsort oracle path
    (cost-model gate; tiny slack for sampling divergence)
  * ``build_recall`` — the fused build must stay at or above
    ``--build-floor`` on the smoke corpus (quality gate)
  * ``search_recall`` — the fused batched graph search must stay at or
    above ``--search-floor`` on the smoke corpus, and ``fused_qps`` must
    not drop below ``ref_qps`` (the serving hot path must never be slower
    than the greedy oracle loop it replaced) — when ``--search`` is given

See benchmarks/README.md for how the floors are pinned and when to move
them.

Usage: python benchmarks/check_gate.py results/bench/online.json \
           --floor 0.85 --build results/bench/build.json --build-floor 0.95 \
           --search results/bench/search.json --search-floor 0.92
"""
from __future__ import annotations

import argparse
import json
import sys


def check(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_insert"]
    if not smoke:
        failures.append("no smoke_insert row in benchmark output")
    for r in smoke:
        recall = float(r.get("insert_recall", 0.0))
        if recall < floor:
            failures.append(
                f"insert_recall {recall:.4f} below pinned floor {floor}"
            )
    for r in rows:
        if r.get("op") == "smoke_delete" and int(r.get("dangling_edges", 0)):
            failures.append(
                f"delete left {r['dangling_edges']} dangling edges"
            )
    return failures


def check_build(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_build"]
    if not smoke:
        failures.append("no smoke_build row in benchmark output")
    for r in smoke:
        missing = [key for key in ("fused_evals", "lexsort_evals",
                                   "build_recall") if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(f"smoke_build row missing gated keys {missing}")
            continue
        fused = int(r["fused_evals"])
        ref = int(r["lexsort_evals"])
        # 2% slack: the fused and lexsort paths sample identically only
        # on the first iteration; later iterations diverge benignly
        if fused > ref * 1.02:
            failures.append(
                f"fused build spent {fused} dist evals vs lexsort {ref}"
            )
        recall = float(r["build_recall"])
        if recall < floor:
            failures.append(
                f"build_recall {recall:.4f} below pinned floor {floor}"
            )
    return failures


def check_search(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_search"]
    if not smoke:
        failures.append("no smoke_search row in benchmark output")
    for r in smoke:
        missing = [key for key in ("search_recall", "ref_recall",
                                   "fused_qps", "ref_qps") if key not in r]
        if missing:
            # a gated key drifting out of the bench output must FAIL the
            # gate, not pass it vacuously
            failures.append(f"smoke_search row missing gated keys {missing}")
            continue
        recall = float(r["search_recall"])
        if recall < floor:
            failures.append(
                f"search_recall {recall:.4f} below pinned floor {floor}"
            )
        fused = float(r["fused_qps"])
        ref = float(r["ref_qps"])
        if fused < ref:
            failures.append(
                f"fused search QPS {fused} below ref loop QPS {ref}"
            )
    return failures


def main(argv: list | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("results", help="path to online.json")
    p.add_argument("--floor", type=float, default=0.85,
                   help="pinned insert_recall floor")
    p.add_argument("--build", default=None,
                   help="path to build.json (enables the build gate)")
    p.add_argument("--build-floor", type=float, default=0.95,
                   help="pinned build_recall floor")
    p.add_argument("--search", default=None,
                   help="path to search.json (enables the search gate)")
    p.add_argument("--search-floor", type=float, default=0.92,
                   help="pinned search_recall floor")
    args = p.parse_args(argv)
    with open(args.results) as f:
        rows = json.load(f)
    failures = check(rows, args.floor)
    if args.build is not None:
        with open(args.build) as f:
            build_rows = json.load(f)
        failures += check_build(build_rows, args.build_floor)
    if args.search is not None:
        with open(args.search) as f:
            search_rows = json.load(f)
        failures += check_search(search_rows, args.search_floor)
    for msg in failures:
        print(f"GATE FAIL: {msg}", file=sys.stderr)
    if not failures:
        print(f"gate ok: insert_recall >= {args.floor}, no dangling edges"
              + ("" if args.build is None else
                 f"; build_recall >= {args.build_floor}, fused evals <= ref")
              + ("" if args.search is None else
                 f"; search_recall >= {args.search_floor}, "
                 "fused QPS >= ref QPS"))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
