"""CI perf/quality gate for the online-update benchmark lane.

Reads the JSON written by ``bench_online.py --mode smoke`` and fails
(exit 1) when any gated metric violates its pinned floor:

  * ``insert_recall`` — combined-corpus recall@k after a streamed insert
    batch must stay at or above ``--floor`` (quality gate)
  * ``dangling_edges`` — a delete must leave zero edges pointing at
    tombstoned rows (correctness gate)

See benchmarks/README.md for how the floor is pinned and when to move it.

Usage: python benchmarks/check_gate.py results/bench/online.json --floor 0.85
"""
from __future__ import annotations

import argparse
import json
import sys


def check(rows: list, floor: float) -> list:
    failures = []
    smoke = [r for r in rows if r.get("op") == "smoke_insert"]
    if not smoke:
        failures.append("no smoke_insert row in benchmark output")
    for r in smoke:
        recall = float(r.get("insert_recall", 0.0))
        if recall < floor:
            failures.append(
                f"insert_recall {recall:.4f} below pinned floor {floor}"
            )
    for r in rows:
        if r.get("op") == "smoke_delete" and int(r.get("dangling_edges", 0)):
            failures.append(
                f"delete left {r['dangling_edges']} dangling edges"
            )
    return failures


def main(argv: list | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("results", help="path to online.json")
    p.add_argument("--floor", type=float, default=0.85,
                   help="pinned insert_recall floor")
    args = p.parse_args(argv)
    with open(args.results) as f:
        rows = json.load(f)
    failures = check(rows, args.floor)
    for msg in failures:
        print(f"GATE FAIL: {msg}", file=sys.stderr)
    if not failures:
        print(f"gate ok: insert_recall >= {args.floor}, no dangling edges")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
