"""Paper Table 2: real-world datasets (MNIST 70'000x784, Audio
54'387x192).

The real files are not downloadable in this offline container, so the
stand-ins match (n, d, dtype, clusteredness) — mnist_like = 10-cluster
GMM in 784-d, audio_like = 40 mild clusters in 192-d — at REDUCED n for
the single CPU core (noted in EXPERIMENTS.md; the shape of the Table-2
comparison — greedyclustering < no-heuristic, both far under the naive
tier — is what is reproduced, not the absolute seconds).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import Sink
from repro import DescentConfig, brute_force_knn, build_knn_graph, recall_at_k
from repro.core import datasets


def run(n_mnist: int = 8192, n_audio: int = 8192, k: int = 20) -> list:
    sink = Sink("realworld")
    key = jax.random.key(0)
    sets = {
        "mnist_like": datasets.mnist_like(key, n=n_mnist, d=784),
        "audio_like": datasets.audio_like(jax.random.fold_in(key, 1),
                                          n=n_audio, d=192),
    }
    for name, x in sets.items():
        _, ti = brute_force_knn(x, x, k)
        for variant, reorder in (("no-heuristic", False),
                                 ("greedyclustering", True)):
            cfg = DescentConfig(k=k, rho=1.0, max_iters=8, reorder=reorder)
            t0 = time.perf_counter()
            _, idx, st = build_knn_graph(x, k=k, cfg=cfg)
            dt = time.perf_counter() - t0
            sink.row(dataset=name, n=x.shape[0], d=x.shape[1],
                     variant=variant, seconds=round(dt, 2),
                     recall=round(recall_at_k(idx, ti), 4),
                     dist_evals=st.dist_evals)
    return sink.save()


if __name__ == "__main__":
    run()
