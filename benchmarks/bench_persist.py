"""Persistence benchmark: snapshot -> fresh-process restore parity and
zero-rebuild cold start (the CI receipt for core/persist.py).

Modes (``python benchmarks/bench_persist.py --mode ...``):

  * ``smoke`` (default) — the gated CI lane: builds a small datastore
    with the full serving state attached (int8 mirror + router), runs a
    streamed insert + delete so the snapshot carries tombstones and
    post-build rows, snapshots it, then restores IN A FRESH PROCESS
    (subprocess — nothing cached, the honest cold start) and answers the
    same query batch on both sides. Emits ``results/bench/persist.json``
    with ``ids_bitident`` / ``dists_bitident`` (restored search results
    compared to the live store's, float bits and all), ``rebuild_s``
    (what a restart pays without persistence: the full NN-Descent build
    including compile) vs ``restore_s`` (what it pays with: array load +
    device put), and ``cold_start_speedup``. Gated by check_gate.py
    --persist (bit-identical AND speedup >= the pinned floor). An
    informative ``smoke_persist_qfirst`` row measures the quantized-first
    cold start (serve from the int8 mirror while fp32 loads) — not gated.

  * ``restore-child`` — internal: the fresh-process half of the smoke
    lane. Restores from ``--dir``, regenerates the (deterministic,
    seeded) query batch, searches, and prints one ``RESTORE_RESULT``
    JSON line for the parent to compare bit-for-bit.

The snapshot directory lands under results/bench/persist_smoke/ so the
CI artifact picks up its manifest.json next to the bench JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _queries(n: int, d: int, q_n: int):
    """Deterministic query batch derived from the (seeded) smoke corpus —
    regenerated identically on both sides of the process boundary."""
    from repro.core import datasets
    x = datasets.clustered(jax.random.key(20), n, d, 16)
    q = x[:q_n] + 0.01 * jax.random.normal(jax.random.key(23), (q_n, d))
    return x, q


def _search(ds, q, k_out: int):
    dist, idx = ds.store.search(q, k_out=k_out, key=jax.random.key(24))
    return (np.asarray(dist, np.float32).view(np.int32),
            np.asarray(idx, np.int32))


def _build_live(n: int, d: int, k: int):
    """Full build (the cost persistence avoids) + post-build mutations
    (so the snapshot carries tombstones, streamed rows, and the
    incrementally-maintained mirror/router — the real online state)."""
    from repro.core.nn_descent import DescentConfig
    from repro.core.router import RouterConfig
    from repro.serve.knn_lm import MutableKNNDatastore
    x, _ = _queries(n, d, 0)
    vals = jnp.arange(n, dtype=jnp.int32)
    t0 = time.perf_counter()
    ds = MutableKNNDatastore.build(
        x, vals, k=k, cfg=DescentConfig(k=k, rho=1.0, max_iters=8),
        precision="int8",
        router=RouterConfig(n_centroids=32, members=32),
        key=jax.random.key(21))
    jax.block_until_ready(ds.store.nl.idx)
    rebuild_s = time.perf_counter() - t0
    ds, _ = ds.delete(jnp.arange(16, dtype=jnp.int32))
    extra = x[:32] + 0.05 * jax.random.normal(jax.random.key(22), (32, d))
    ds, _ = ds.append(extra, jnp.arange(32, dtype=jnp.int32) + n,
                      key=jax.random.key(25))
    jax.block_until_ready(ds.store.nl.idx)
    return ds, rebuild_s


def _dir_mb(path: str) -> float:
    total = 0
    for root, _, files in os.walk(path):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total / 1e6


def run_restore_child(snap_dir: str, n: int, d: int, q_n: int,
                      k_out: int, qfirst: bool) -> None:
    """Fresh-process restore: nothing from the builder process survives
    except the snapshot directory. Prints RESTORE_RESULT for the parent."""
    from repro.serve.knn_lm import MutableKNNDatastore
    t0 = time.perf_counter()
    ds = MutableKNNDatastore.restore(snap_dir)
    jax.block_until_ready(ds.store.x)
    restore_s = time.perf_counter() - t0
    _, q = _queries(n, d, q_n)
    bits, ids = _search(ds, q, k_out)
    out = {
        "restore_s": restore_s,
        "ids": ids.ravel().tolist(),
        "dist_bits": bits.ravel().tolist(),
        "live": ds.build_stats.get("live"),
        "tombstones": ds.build_stats.get("tombstones"),
        "restored_step": ds.build_stats.get("restored_step"),
    }
    if qfirst:
        t0 = time.perf_counter()
        dq = MutableKNNDatastore.restore(snap_dir, quantized_first=True)
        jax.block_until_ready(dq.store.x)
        qfirst_s = time.perf_counter() - t0
        qbits, qids = _search(dq, q, k_out)
        dq = dq.finish_fp32()
        fbits, fids = _search(dq, q, k_out)
        out["qfirst"] = {
            "restore_s": qfirst_s,
            # quantized-accurate serving while fp32 streams in: overlap
            # with the exact answer is informative, not gated
            "ids_overlap": float((qids == ids).mean()),
            # after finish_fp32 the swap must be exact again
            "fp32_ids_bitident": bool((fids == ids).all()),
            "fp32_dists_bitident": bool((fbits == bits).all()),
        }
    print("RESTORE_RESULT " + json.dumps(out), flush=True)


def run_smoke(n: int = 4096, d: int = 16, q_n: int = 256, k: int = 10,
              k_out: int = 10, qfirst: bool = True) -> list:
    from benchmarks.common import RESULTS_DIR, Sink
    sink = Sink("persist")
    snap_root = os.path.join(RESULTS_DIR, "persist_smoke")
    # a stale snapshot from an earlier (differently-sized) run would both
    # win the keep=1 retention race and be what the child restores — the
    # lane must only ever see the snapshot written by THIS run
    shutil.rmtree(snap_root, ignore_errors=True)

    ds, rebuild_s = _build_live(n, d, k)
    step_dir = ds.snapshot(snap_root, keep=1)
    snapshot_mb = _dir_mb(step_dir)
    _, q = _queries(n, d, q_n)
    bits_live, ids_live = _search(ds, q, k_out)

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_REPO, "src"), _REPO,
                    env.get("PYTHONPATH")) if p)
    cmd = [sys.executable, os.path.abspath(__file__),
           "--mode", "restore-child", "--dir", snap_root,
           "--n", str(n), "--d", str(d), "--q", str(q_n),
           "--k-out", str(k_out)]
    if qfirst:
        cmd.append("--qfirst")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=_REPO, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(
            f"restore child failed (rc={proc.returncode}):\n{proc.stderr}")
    lines = [ln for ln in proc.stdout.splitlines()
             if ln.startswith("RESTORE_RESULT ")]
    if not lines:
        raise RuntimeError(
            f"restore child printed no RESTORE_RESULT:\n{proc.stdout}")
    res = json.loads(lines[-1][len("RESTORE_RESULT "):])

    ids_child = np.asarray(res["ids"], np.int32).reshape(ids_live.shape)
    bits_child = np.asarray(res["dist_bits"],
                            np.int32).reshape(bits_live.shape)
    restore_s = float(res["restore_s"])
    sink.row(op="smoke_persist", n=n, d=d, q=q_n, k=k, k_out=k_out,
             precision="int8", router_centroids=32,
             live=res["live"], tombstones=res["tombstones"],
             restored_step=res["restored_step"],
             ids_bitident=bool((ids_child == ids_live).all()),
             dists_bitident=bool((bits_child == bits_live).all()),
             rebuild_s=round(rebuild_s, 3),
             restore_s=round(restore_s, 3),
             cold_start_speedup=round(rebuild_s / max(restore_s, 1e-9), 2),
             snapshot_mb=round(snapshot_mb, 3))
    if "qfirst" in res:
        qf = res["qfirst"]
        sink.row(op="smoke_persist_qfirst",
                 restore_s=round(float(qf["restore_s"]), 3),
                 ids_overlap=round(qf["ids_overlap"], 4),
                 fp32_ids_bitident=qf["fp32_ids_bitident"],
                 fp32_dists_bitident=qf["fp32_dists_bitident"])
    return sink.save()


def main(argv: list | None = None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mode", choices=("smoke", "restore-child"),
                   default="smoke")
    p.add_argument("--dir", default=None,
                   help="snapshot directory (restore-child mode)")
    p.add_argument("--n", type=int, default=4096)
    p.add_argument("--d", type=int, default=16)
    p.add_argument("--q", type=int, default=256)
    p.add_argument("--k-out", type=int, default=10)
    p.add_argument("--qfirst", action="store_true", default=None,
                   help="also measure the quantized-first cold start "
                        "(informative row; on by default in smoke mode)")
    args = p.parse_args(argv)
    if args.mode == "restore-child":
        if args.dir is None:
            p.error("--mode restore-child requires --dir")
        return run_restore_child(args.dir, args.n, args.d, args.q,
                                 args.k_out, bool(args.qfirst))
    return run_smoke(n=args.n, d=args.d, q_n=args.q, k_out=args.k_out,
                     qfirst=True if args.qfirst is None else args.qfirst)


if __name__ == "__main__":
    main()
