"""Shared benchmark utilities: timing, CSV rows, result sink."""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.environ.get("BENCH_RESULTS", "results/bench")


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


class Sink:
    def __init__(self, name: str):
        self.name = name
        self.rows: list[dict] = []

    def row(self, **kw):
        kw["bench"] = self.name
        self.rows.append(kw)
        print(",".join(f"{k}={v}" for k, v in kw.items()), flush=True)

    def save(self):
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(RESULTS_DIR, f"{self.name}.json"), "w") as f:
            json.dump(self.rows, f, indent=2, default=str)
        return self.rows


def flops_per_eval(d: int) -> int:
    """Paper §2 cost model: d subs + d mults + (d-1) adds per evaluation."""
    return 3 * d - 1
