"""Paper §4.1 / Table: selection-step variants.

The paper reports the fused-heap selection 16x faster than naive 3-pass,
and turbosampling another 1.12x on top. Same measurement here, on the
Synthetic Gaussian Dataset (n=16'384, d=8, the paper's setting), in
runtime (the flop counts differ across variants, as the paper notes).
"""
from __future__ import annotations

import functools

import jax

from benchmarks.common import Sink, timeit
from repro.core import datasets, heap, selection


def run(n: int = 16_384, k: int = 20, rho_k: int = 10) -> list:
    sink = Sink("selection")
    key = jax.random.key(0)
    k1, k2 = jax.random.split(key)
    x = datasets.gaussian(k1, n, 8)
    nl = heap.init_random_with_dists(k2, x, k)

    fns = {
        "naive": selection.selection_naive,
        "heap_fused": selection.selection_heap,
        "turbo": selection.selection_turbo,
    }
    base = None
    for name, fn in fns.items():
        jfn = jax.jit(functools.partial(fn, rho_k=rho_k))
        t = timeit(lambda: jfn(k2, nl))
        if name == "naive":
            base = t
        sink.row(variant=name, n=n, k=k, rho_k=rho_k,
                 ms=round(t * 1e3, 3),
                 speedup_vs_naive=round(base / t, 2))
    return sink.save()


if __name__ == "__main__":
    run()
