"""Paper Fig. 6 (perf vs n at d=256) and Fig. 7 (perf vs d at n=16'384),
plus the O(n^1.14) empirical-cost check from Dong et al. §2.

'Performance' follows the paper's convention: distance-evaluation flops
(3d-1 per eval) per second — counted, not estimated — for the optimization
tiers that exist in the JAX build:

    naive_selection  3-pass reverse/union/sample selection (paper's
                     pre-PyNNDescent baseline); blocked distances
    turbosampling    heap-free fused selection (paper C2)
    greedyheuristic  + memory reordering (paper C3)

(The l2intrinsics/mem-align/blocked distance tiers are kernel-level: the
Pallas MXU kernel IS the blocked tier — bench_kernels covers its tile
model; every tier here already uses the blocked norm-expansion distances,
since a non-blocked scalar path would be meaningless under XLA.)

CPU-budget note: n stops at 32k (vs the paper's 131k on native C).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Sink, flops_per_eval
from repro import DescentConfig, build_knn_graph
from repro.core import datasets

TIERS = {
    "naive_selection": dict(selection="naive", reorder=False),
    "turbosampling": dict(selection="turbo", reorder=False),
    "greedyheuristic": dict(selection="turbo", reorder=True),
}


def _run_once(x, k, tier, max_iters=6):
    cfg = DescentConfig(k=k, rho=1.0, max_iters=max_iters, **TIERS[tier])
    t0 = time.perf_counter()
    _, _, stats = build_knn_graph(x, k=k, cfg=cfg)
    dt = time.perf_counter() - t0
    return dt, stats


def run(axis: str = "both", k: int = 20) -> list:
    sink = Sink("scaling")
    key = jax.random.key(0)

    if axis in ("n", "both"):
        d = 256
        evals_by_n = {}
        for n in (2048, 4096, 8192):   # CPU-core budget
            x = datasets.gaussian(jax.random.fold_in(key, n), n, d)
            for tier in TIERS:
                dt, st = _run_once(x, k, tier)
                gf = st.dist_evals * flops_per_eval(d) / dt / 1e9
                sink.row(axis="n", n=n, d=d, tier=tier,
                         seconds=round(dt, 2),
                         dist_evals=st.dist_evals,
                         gflops=round(gf, 3))
                if tier == "blocked":
                    evals_by_n[n] = st.dist_evals
        # O(n^1.14) empirical-cost exponent (Dong et al.)
        ns = sorted(evals_by_n)
        loge = np.polyfit(np.log(ns), np.log([evals_by_n[n] for n in ns]), 1)
        sink.row(axis="n", metric="empirical_cost_exponent",
                 exponent=round(float(loge[0]), 3), paper_value=1.14)

    if axis in ("d", "both"):
        n = 4096                               # CPU-core budget
        for d in (8, 64, 256, 1024):
            x = datasets.gaussian(jax.random.fold_in(key, 1000 + d), n, d,
                                  single=True)
            for tier in TIERS:
                dt, st = _run_once(x, k, tier)
                gf = st.dist_evals * flops_per_eval(d) / dt / 1e9
                sink.row(axis="d", n=n, d=d, tier=tier,
                         seconds=round(dt, 2),
                         dist_evals=st.dist_evals,
                         gflops=round(gf, 3))
    return sink.save()


if __name__ == "__main__":
    run()
