"""Online updates (core/online.py): insert throughput vs. full rebuild.

Streams batches of new points into a built store with ``knn_insert`` and
compares against rebuilding the graph from scratch on the grown corpus —
in wall time, points/s, and the paper's cost model (distance evaluations,
via DescentStats.dist_evals). Also reports delete+patch latency.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Sink
from repro.core import DescentConfig, build_knn_graph, datasets
from repro.core.online import MutableKNNStore, knn_delete, knn_insert


def run(n: int = 8192, d: int = 32, k: int = 20, batch: int = 256,
        n_batches: int = 4) -> list:
    sink = Sink("online")
    key = jax.random.key(0)
    x = datasets.clustered(key, n + batch * n_batches, d, 16)
    x0, stream = x[:n], x[n:]
    dcfg = DescentConfig(k=k, rho=1.0, max_iters=15)

    t0 = time.perf_counter()
    store, build_stats = MutableKNNStore.build(
        x0, k=k, descent=dcfg, key=jax.random.key(1))
    jax.block_until_ready(store.nl.dist)
    t_build = time.perf_counter() - t0
    sink.row(op="initial_build", n=n, k=k, s=round(t_build, 3),
             dist_evals=build_stats.dist_evals)

    # --- streaming inserts (first batch pays compile; report both)
    total_ins = 0
    ins_evals = 0
    t_stream = 0.0
    for b in range(n_batches):
        xb = stream[b * batch:(b + 1) * batch]
        t0 = time.perf_counter()
        store, st = knn_insert(store, xb, key=jax.random.fold_in(key, b))
        jax.block_until_ready(store.nl.dist)
        dt = time.perf_counter() - t0
        t_stream += dt
        total_ins += batch
        ins_evals += st.dist_evals
        sink.row(op="insert", batch=batch, n_after=store.n,
                 s=round(dt, 3), pts_per_s=round(batch / dt, 1),
                 dist_evals=st.dist_evals, compile_included=b == 0)

    # --- full rebuild on the grown corpus (the alternative to streaming)
    grown = x[:n + total_ins]
    t0 = time.perf_counter()
    _, _, rb = build_knn_graph(grown, k=k, cfg=dcfg, key=jax.random.key(1))
    t_rebuild = time.perf_counter() - t0
    sink.row(op="rebuild", n=grown.shape[0], s=round(t_rebuild, 3),
             dist_evals=rb.dist_evals,
             insert_speedup=round(t_rebuild / max(t_stream, 1e-9), 2),
             eval_ratio=round(ins_evals / rb.dist_evals, 4))

    # --- delete + patch
    dead = jnp.arange(0, n // 10, dtype=jnp.int32)
    t0 = time.perf_counter()
    store, dst = knn_delete(store, dead)
    jax.block_until_ready(store.nl.dist)
    dt = time.perf_counter() - t0
    sink.row(op="delete", n_dead=int(dead.shape[0]), s=round(dt, 3),
             dist_evals=dst.dist_evals)
    return sink.save()


if __name__ == "__main__":
    run()
