"""Online updates (core/online.py): insert throughput vs. full rebuild,
and the frontier-compaction scaling story.

Modes (``python benchmarks/bench_online.py --mode ...``):

  * ``stream`` (default) — streams batches of new points into a built
    store with ``knn_insert`` and compares against rebuilding the graph
    from scratch on the grown corpus — in wall time, points/s, and the
    paper's cost model (distance evaluations, via DescentStats.dist_evals).
    Also reports delete+patch latency.

  * ``smoke`` — tiny fixed config for the CI benchmark lane: one insert
    batch + one delete on a small clustered corpus, reporting
    ``insert_recall`` (combined-corpus recall vs. brute force) and the
    frontier accounting. CI fails the lane when ``insert_recall`` drops
    below the pinned floor (see benchmarks/check_gate.py and
    benchmarks/README.md).

  * ``sweep`` — the frontier-compaction scaling sweep: for each store
    size up to 10^5 rows, time delete+refill with the frontier path
    (cost ~ affected rows) against the dense baseline
    (``OnlineConfig(frontier=False)``: every allocated row processed).
    The dense wall-clock grows linearly with n; the frontier wall-clock
    tracks the (fixed) frontier size — the acceptance gate for the
    frontier refactor is frontier >= 5x faster at n = 10^5.

All modes write JSON rows via benchmarks.common.Sink (online.json).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Sink
from repro.core import (
    DescentConfig,
    brute_force_knn,
    build_knn_graph,
    datasets,
    recall_at_k,
)
from repro.core.online import (
    MutableKNNStore,
    OnlineConfig,
    knn_delete,
    knn_insert,
)


def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    return out, time.perf_counter() - t0


def run(n: int = 8192, d: int = 32, k: int = 20, batch: int = 256,
        n_batches: int = 4, sink: Sink | None = None) -> list:
    """Streaming insert vs. rebuild (the original online benchmark)."""
    sink = sink or Sink("online")
    key = jax.random.key(0)
    x = datasets.clustered(key, n + batch * n_batches, d, 16)
    x0, stream = x[:n], x[n:]
    dcfg = DescentConfig(k=k, rho=1.0, max_iters=15)

    t0 = time.perf_counter()
    store, build_stats = MutableKNNStore.build(
        x0, k=k, descent=dcfg, key=jax.random.key(1))
    jax.block_until_ready(store.nl.dist)
    t_build = time.perf_counter() - t0
    sink.row(op="initial_build", n=n, k=k, s=round(t_build, 3),
             dist_evals=build_stats.dist_evals)

    # --- streaming inserts (first batch pays compile; report both)
    total_ins = 0
    ins_evals = 0
    t_stream = 0.0
    for b in range(n_batches):
        xb = stream[b * batch:(b + 1) * batch]
        t0 = time.perf_counter()
        store, st = knn_insert(store, xb, key=jax.random.fold_in(key, b))
        jax.block_until_ready(store.nl.dist)
        dt = time.perf_counter() - t0
        t_stream += dt
        total_ins += batch
        ins_evals += st.dist_evals
        sink.row(op="insert", batch=batch, n_after=store.n,
                 s=round(dt, 3), pts_per_s=round(batch / dt, 1),
                 dist_evals=st.dist_evals, compile_included=b == 0,
                 frontier_rows=st.frontier_rows,
                 padded_rows=st.padded_rows)

    # --- full rebuild on the grown corpus (the alternative to streaming)
    grown = x[:n + total_ins]
    t0 = time.perf_counter()
    _, _, rb = build_knn_graph(grown, k=k, cfg=dcfg, key=jax.random.key(1))
    t_rebuild = time.perf_counter() - t0
    sink.row(op="rebuild", n=grown.shape[0], s=round(t_rebuild, 3),
             dist_evals=rb.dist_evals,
             insert_speedup=round(t_rebuild / max(t_stream, 1e-9), 2),
             eval_ratio=round(ins_evals / rb.dist_evals, 4))

    # --- delete + patch
    dead = jnp.arange(0, n // 10, dtype=jnp.int32)
    t0 = time.perf_counter()
    store, dst = knn_delete(store, dead)
    jax.block_until_ready(store.nl.dist)
    dt = time.perf_counter() - t0
    sink.row(op="delete", n_dead=int(dead.shape[0]), s=round(dt, 3),
             dist_evals=dst.dist_evals, frontier_rows=dst.frontier_rows,
             padded_rows=dst.padded_rows)
    return sink.save()


def run_smoke(n: int = 768, d: int = 16, k: int = 10,
              batch: int = 96) -> list:
    """CI benchmark lane: small, seeded, < ~2 min on a CPU runner.

    Emits ``insert_recall`` — recall@k of the store's neighbor lists on
    the combined corpus after one streamed insert batch, against brute
    force — which check_gate.py compares to the pinned floor."""
    sink = Sink("online")
    x = datasets.clustered(jax.random.key(3), n + batch, d, 8)
    x0, xn = x[:n], x[n:]
    dcfg = DescentConfig(k=k, rho=1.0, max_iters=15)

    store, _ = MutableKNNStore.build(
        x0, k=k, descent=dcfg, key=jax.random.key(1))
    (store, ins), t_ins = _timed(
        lambda: knn_insert(store, xn, key=jax.random.key(2)))
    combined = jnp.concatenate([x0, xn], axis=0)
    _, true_idx = brute_force_knn(combined, combined, k)
    r = recall_at_k(store.nl.idx[:combined.shape[0]], true_idx)
    sink.row(op="smoke_insert", n=n, batch=batch, k=k,
             s=round(t_ins, 3), insert_recall=round(float(r), 4),
             dist_evals=ins.dist_evals,
             frontier_rows=ins.frontier_rows,
             padded_rows=ins.padded_rows)

    dead = jnp.arange(0, n // 10, dtype=jnp.int32)
    (store, dst), t_del = _timed(lambda: knn_delete(store, dead))
    live = store.nl.idx[:combined.shape[0]]
    dangling = int(
        ((live[:, :, None] == dead[None, None, :]).any(-1)
         & (live >= 0)).sum()
    )
    sink.row(op="smoke_delete", n_dead=int(dead.shape[0]),
             s=round(t_del, 3), dangling_edges=dangling,
             frontier_rows=dst.frontier_rows,
             padded_rows=dst.padded_rows)
    return sink.save()


def run_sweep(sizes: tuple = (12_500, 25_000, 50_000, 100_000),
              d: int = 32, k: int = 20, n_dead: int = 128,
              iters: int = 2) -> list:
    """Frontier vs. dense delete+refill scaling (the tentpole's receipt).

    The store is built once per size with a cheap descent config (graph
    quality is irrelevant for update timing), then the same delete is
    timed under the frontier path and the dense baseline. Both paths run
    the identical chunked kernels; the dense baseline simply puts every
    allocated row on the frontier."""
    sink = Sink("online")
    for n in sizes:
        x = datasets.clustered(jax.random.key(0), n, d, 32)
        dcfg = DescentConfig(k=k, rho=0.5, max_iters=4, polish=1)
        t0 = time.perf_counter()
        dist, idx, _ = build_knn_graph(x, k=k, cfg=dcfg,
                                       key=jax.random.key(1))
        t_build = time.perf_counter() - t0
        dead = jnp.arange(0, n_dead, dtype=jnp.int32)

        row = {"op": "sweep_delete", "n": n, "k": k, "n_dead": n_dead,
               "build_s": round(t_build, 2)}
        for mode, frontier in (("frontier", True), ("dense", False)):
            cfg = OnlineConfig(frontier=frontier)
            store = MutableKNNStore.from_graph(x, dist, idx, cfg=cfg)
            # warm-up pays compile, then time fresh deletes of the same
            # rows (delete is not idempotent state-wise, so rebuild the
            # store wrapper each rep — from_graph is O(n) copies, cheap)
            knn_delete(store, dead)
            ts = []
            for _ in range(iters):
                store_i = MutableKNNStore.from_graph(x, dist, idx, cfg=cfg)
                (_, st), dt = _timed(lambda s=store_i: knn_delete(s, dead))
                ts.append(dt)
            row[f"{mode}_s"] = round(min(ts), 4)
            row[f"{mode}_rows"] = st.padded_rows
            row[f"{mode}_evals"] = st.dist_evals
        row["speedup"] = round(row["dense_s"] / max(row["frontier_s"], 1e-9),
                               2)
        sink.row(**row)
    return sink.save()


def main(argv: list | None = None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mode", choices=("stream", "smoke", "sweep"),
                   default="stream")
    p.add_argument("--n", type=int, default=None,
                   help="override corpus size (stream mode)")
    args = p.parse_args(argv)
    if args.mode == "smoke":
        return run_smoke()
    if args.mode == "sweep":
        return run_sweep()
    kw = {} if args.n is None else {"n": args.n}
    return run(**kw)


if __name__ == "__main__":
    main()
