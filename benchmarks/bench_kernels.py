"""Kernel-level benchmark: the blocked-l2 kernel's tile-choice sweep
(VMEM working set + arithmetic intensity per tile) and CPU wall time of
the jnp reference path it dispatches to off-TPU.

The MXU reuse argument (DESIGN.md): a (TM, TK)x(TN, TK) tile produces
TM*TN partial distances from TM+TN rows -> reuse TM*TN/(TM+TN), the
128-scale version of the paper's 25 distances / 10 loads.
"""
from __future__ import annotations

import jax

from benchmarks.common import Sink, timeit
from repro.core import datasets
from repro.kernels import ops
from repro.kernels.l2_blocked import vmem_bytes


def run(m: int = 2048, n: int = 2048, d: int = 512) -> list:
    sink = Sink("kernels")
    key = jax.random.key(0)
    a = datasets.gaussian(key, m, d)
    b = datasets.gaussian(jax.random.fold_in(key, 1), n, d)

    t_ref = timeit(jax.jit(
        lambda x, y: ops.pairwise_sq_l2(x, y, backend="ref")), a, b)
    flops = 2.0 * m * n * d
    sink.row(path="ref_jnp", m=m, n=n, d=d, ms=round(t_ref * 1e3, 2),
             gflops=round(flops / t_ref / 1e9, 2))

    for tm, tn, tk in [(128, 128, 128), (128, 128, 512), (256, 256, 512),
                       (512, 512, 512), (128, 512, 1024)]:
        reuse = tm * tn / (tm + tn)
        vb = vmem_bytes(tm, tn, tk)
        sink.row(path="pallas_tile_model", tm=tm, tn=tn, tk=tk,
                 vmem_kib=round(vb / 1024, 1),
                 fits_vmem=vb < 64 * 1024 * 1024,
                 reuse_rows_per_output=round(reuse, 1),
                 paper_analogue="25 dists / 10 loads = 2.5; this tile: "
                 f"{reuse:.0f}")
    return sink.save()


if __name__ == "__main__":
    run()
